"""Tests for the beyond-paper optimization features added in §Perf:
chunked-vocab fused loss, int8 KV cache, carry-cache decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import LM
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig
from repro.nn.param import init_tree
from repro.nn.sharding import ShardCtx
from repro.nn.xent import chunked_xent

CTX = ShardCtx(None)


def _dense_xent(x, w, lab, cap=0.0):
    lg = (x @ w.T).astype(jnp.float32)
    if cap:
        lg = jnp.tanh(lg / cap) * cap
    m = jax.lax.stop_gradient(lg.max(axis=1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=1)) + m[:, 0]
    picked = jnp.take_along_axis(lg, lab[:, None], 1)[:, 0]
    return jnp.mean(lse - picked)


@pytest.mark.parametrize("v,chunk,cap", [
    (1000, 96, 0.0), (1000, 96, 30.0), (512, 512, 0.0), (769, 100, 0.0),
])
def test_chunked_xent_matches_dense(v, chunk, cap, rng):
    t, d = 48, 24
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((v, d)) * 0.1, jnp.float32)
    lab = jnp.asarray(rng.integers(0, v, t))
    l1 = _dense_xent(x, w, lab, cap)
    l2 = chunked_xent(x, w, lab, chunk, cap)
    assert abs(float(l1 - l2)) < 1e-5
    g1 = jax.grad(lambda a, b: _dense_xent(a, b, lab, cap), (0, 1))(x, w)
    g2 = jax.grad(
        lambda a, b: chunked_xent(a, b, lab, chunk, cap), (0, 1)
    )(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def _tiny_lm():
    attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16)
    cfg = ModelConfig(
        "t", "dense", 64, 97,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=128),), n_repeat=2,
        param_dtype="float32", compute_dtype="float32",
    )
    lm = LM(cfg)
    params = init_tree(jax.random.PRNGKey(0), lm.param_specs())
    return lm, params


def test_int8_kv_decode_close_to_fp():
    lm, params = _tiny_lm()
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0, 97)
    c_fp = jax.tree.map(
        jnp.zeros_like,
        init_tree(jax.random.PRNGKey(2), lm.cache_specs(1, S + 1)),
    )
    c_q8 = jax.tree.map(
        jnp.zeros_like,
        init_tree(
            jax.random.PRNGKey(2), lm.cache_specs(1, S + 1, kv_quant=True)
        ),
    )
    for t in range(S + 1):
        lg_fp, c_fp = lm.decode(CTX, params, toks[:, t:t + 1], c_fp,
                                jnp.int32(t))
        lg_q8, c_q8 = lm.decode(CTX, params, toks[:, t:t + 1], c_q8,
                                jnp.int32(t))
    rel = float(jnp.max(jnp.abs(lg_fp - lg_q8))) / float(
        jnp.max(jnp.abs(lg_fp))
    )
    assert rel < 0.05, f"int8 KV drift {rel:.3f}"
    # quantized cache really is int8
    leaves = jax.tree.leaves(c_q8)
    assert any(l.dtype == jnp.int8 for l in leaves)


def test_carry_cache_decode_structure():
    """Decode preserves the cache tree and only mutates position `pos`."""
    lm, params = _tiny_lm()
    S = 16
    caches = jax.tree.map(
        jnp.zeros_like,
        init_tree(jax.random.PRNGKey(2), lm.cache_specs(1, S)),
    )
    tok = jnp.array([[5]], jnp.int32)
    _, nc = lm.decode(CTX, params, tok, caches, jnp.int32(3))
    k = nc["blocks"]["l0"]["mixer"]["k"]  # (n_repeat, B, S, kv, dh)
    written = np.asarray(jnp.any(k != 0, axis=(0, 1, 3, 4)))
    assert written[3] and not written[:3].any() and not written[4:].any()
