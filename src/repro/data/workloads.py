"""Workload generators matching the paper's three evaluation families
(§6.1): skewed (Zipf-0.99) search, trend-driven bursty search, and
SWE-bench-style code-file access.

Each generator yields a list of :class:`Request` with arrival times (for
open-loop runs) — the engine can also replay them closed-loop at a fixed
concurrency (Fig 10).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.world import SemanticWorld


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    query: str                 # round-0 tool query
    session: int = 0
    n_rounds: int = 1          # agent think→tool→observe rounds
    round_queries: tuple = ()  # per-round queries (len == n_rounds);
                               # defaults to (query,) — real agents refine
                               # the query each round, so generators fill
                               # this with distinct paraphrases/intents

    def query_for_round(self, r: int) -> str:
        if self.round_queries:
            return self.round_queries[min(r, len(self.round_queries) - 1)]
        return self.query


def _zipf_probs(n: int, s: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-s)
    return w / w.sum()


def zipf_workload(
    world: SemanticWorld,
    n_requests: int,
    *,
    zipf_s: float = 0.99,
    n_paraphrases: int = 100,
    rate: float = 4.0,
    n_rounds: int = 2,
    seed: int = 0,
) -> list[Request]:
    """Skewed search workload: intents drawn Zipf(s), each query a random
    paraphrase — exact-match caches miss on wording changes, semantic
    caches group them (paper Fig 7)."""
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(world.n_intents, zipf_s)
    # shuffle intent ranks so confusable pairs land across the popularity
    # spectrum rather than only in the head
    perm = rng.permutation(world.n_intents)
    out = []
    t = 0.0
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        intent = int(perm[rng.choice(world.n_intents, p=probs)])
        rounds = []
        for r in range(n_rounds):
            # each reasoning round issues a fresh phrasing; ~30% of later
            # rounds drill into a correlated follow-up intent
            it = intent
            if r > 0 and rng.random() < 0.3:
                it = (intent + 1) % world.n_intents
            rounds.append(world.query(it, int(rng.integers(0, n_paraphrases))))
        out.append(
            Request(i, t, rounds[0], session=i, n_rounds=n_rounds,
                    round_queries=tuple(rounds))
        )
    return out


def trend_workload(
    world: SemanticWorld,
    n_requests: int,
    *,
    duration: float = 600.0,   # 12h of Trends compressed to 10 min (§6.1)
    n_waves: int = 4,
    wave_width_frac: float = 0.12,
    base_rate_frac: float = 0.15,
    n_paraphrases: int = 100,
    topic_intents: int = 40,
    n_rounds: int = 2,
    seed: int = 1,
) -> list[Request]:
    """Bursty, correlated workload: n_waves topic spikes (Gaussian bumps in
    arrival intensity), each wave concentrated on one topic's intents —
    the LCFU staticity/TTL path is what absorbs these (paper Fig 8)."""
    rng = np.random.default_rng(seed)
    wave_centers = np.linspace(0.15, 0.85, n_waves) * duration
    width = wave_width_frac * duration
    topics = rng.permutation(
        max(world.n_intents // topic_intents, n_waves)
    )[:n_waves]

    # thinning-based inhomogeneous Poisson
    def intensity(t):
        lam = base_rate_frac
        for c in wave_centers:
            lam += np.exp(-0.5 * ((t - c) / width) ** 2)
        return lam

    grid = np.linspace(0, duration, 2048)
    total_mass = np.trapezoid([intensity(t) for t in grid], grid)
    out = []
    t = 0.0
    i = 0
    lam_max = intensity(wave_centers[0]) * 1.2
    scale = n_requests / total_mass / lam_max * lam_max
    while i < n_requests:
        t += rng.exponential(total_mass / n_requests / max(intensity(t), 1e-3))
        if t > duration:
            t = duration  # tail burst clipped
        # pick the wave whose bump dominates at t (or background)
        weights = np.array(
            [np.exp(-0.5 * ((t - c) / width) ** 2) for c in wave_centers]
            + [base_rate_frac]
        )
        weights /= weights.sum()
        k = int(rng.choice(n_waves + 1, p=weights))
        if k < n_waves:
            base = int(topics[k]) * topic_intents
            intent = (base + int(rng.zipf(1.5))) % world.n_intents
        else:
            intent = int(rng.integers(0, world.n_intents))
        rounds = []
        for r in range(n_rounds):
            it = intent if (r == 0 or rng.random() >= 0.3) \
                else (intent + 1) % world.n_intents
            rounds.append(world.query(it, int(rng.integers(0, n_paraphrases))))
        out.append(
            Request(i, float(t), rounds[0], session=i, n_rounds=n_rounds,
                    round_queries=tuple(rounds))
        )
        i += 1
    out.sort(key=lambda r: r.arrival)
    return out


# SWE-bench file-access frequencies for sqlfluff (paper Table 2)
SWE_FILE_FREQ = [1.0, 0.28, 0.22, 0.14, 0.1, 0.08, 0.04, 0.04, 0.04]


def swe_workload(
    world: SemanticWorld,
    n_tasks: int,
    *,
    files_per_task: tuple[int, int] = (3, 8),
    n_paraphrases: int = 6,
    rate: float = 2.0,
    tail_files: int = 60,
    seed: int = 2,
) -> list[Request]:
    """Code-agent workload: each task (GitHub issue) touches a set of repo
    files; hot core files recur across tasks per Table 2, the long tail is
    task-specific. One request per file access; requests of one task share
    a session (prefetcher learns file→file transitions)."""
    rng = np.random.default_rng(seed)
    n_core = len(SWE_FILE_FREQ)
    freqs = np.array(SWE_FILE_FREQ + [0.02] * tail_files)
    probs = freqs / freqs.sum()
    n_files = len(freqs)
    out = []
    t = 0.0
    rid = 0
    for task in range(n_tasks):
        t += rng.exponential(1.0 / rate)
        n_f = int(rng.integers(files_per_task[0], files_per_task[1] + 1))
        # core file 0 is required by nearly all tasks (freq 1.0)
        files = [0] if rng.random() < 0.95 else []
        files += list(
            rng.choice(n_files, size=n_f, replace=False, p=probs)
        )
        dt = 0.0
        for f in dict.fromkeys(files):  # dedupe, keep order
            intent = int(f) % world.n_intents
            para = int(rng.integers(0, n_paraphrases))
            out.append(
                Request(
                    rid, float(t + dt), world.query(intent, para),
                    session=task, n_rounds=1,
                )
            )
            rid += 1
            dt += float(rng.exponential(0.5))
    out.sort(key=lambda r: r.arrival)
    for i, r in enumerate(out):
        r.rid = i
    return out


def longtail_workload(
    world: SemanticWorld,
    n_requests: int,
    *,
    head_intents: int = 48,
    head_frac: float = 0.35,
    tail_len: int | None = None,
    zipf_s: float = 0.9,
    n_paraphrases: int = 30,
    rate: float = 4.0,
    seed: int = 0,
) -> list[Request]:
    """Capacity-pressure workload for the tiered-storage experiments
    (DESIGN.md §10): a small Zipf head every request might touch, plus a
    cyclic scan over a long tail of ``tail_len`` intents.

    The scan is the classic capacity-killer: each tail intent returns
    after a reuse distance of exactly ``tail_len`` draws, so any tier
    whose byte budget holds fewer than ``tail_len`` values evicts the
    entry before its next use — every tail revisit pays the WAN fetch.
    A warm tier at the same TOTAL bytes holds ~1/value_ratio× more
    entries, converting those refetches into demote→promote round trips.
    Paraphrases rotate per visit so exact-match caches never hit.
    """
    rng = np.random.default_rng(seed)
    if tail_len is None:
        tail_len = world.n_intents - head_intents
    if head_intents + tail_len > world.n_intents:
        raise ValueError("head + tail exceeds world intents")
    perm = rng.permutation(world.n_intents)
    head = perm[:head_intents]
    tail = perm[head_intents:head_intents + tail_len]
    p_head = _zipf_probs(head_intents, zipf_s)
    out = []
    t = 0.0
    pos = 0
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        if rng.random() < head_frac:
            intent = int(head[rng.choice(head_intents, p=p_head)])
        else:
            intent = int(tail[pos % tail_len])
            pos += 1
        q = world.query(intent, int(rng.integers(0, n_paraphrases)))
        out.append(Request(i, t, q, session=i, n_rounds=1))
    return out


def churn_workload(
    world: SemanticWorld,
    n_requests: int,
    *,
    zipf_s: float = 0.9,
    n_paraphrases: int = 40,
    rate: float = 4.0,
    seed: int = 0,
) -> list[Request]:
    """Freshness workload (DESIGN.md §11): steady Zipf revisits meant to
    run against a :class:`~repro.data.world.MutableWorld`.

    Single-round requests on a fixed moderate-skew popularity law, so
    the same intents are revisited throughout the run and the run
    duration (``n_requests / rate``) spans several update periods of the
    low-staticity intents — every revisit-after-update is a chance to
    serve stale knowledge, which is exactly what the freshness policies
    (TTL-only vs invalidation vs invalidation+refresh-ahead) differ on.
    Paraphrases rotate per visit so exact-match caches can't shortcut.
    The generator itself is world-agnostic: on a static world it is just
    a single-round Zipf stream (and ``stale_hits`` must stay 0).
    """
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(world.n_intents, zipf_s)
    perm = rng.permutation(world.n_intents)
    out = []
    t = 0.0
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        intent = int(perm[rng.choice(world.n_intents, p=probs)])
        q = world.query(intent, int(rng.integers(0, n_paraphrases)))
        out.append(Request(i, t, q, session=i, n_rounds=1))
    return out


def region_workloads(
    world: SemanticWorld,
    n_per_region: int,
    n_regions: int,
    *,
    overlap: float = 0.5,
    shared_frac: float = 0.3,
    zipf_s: float = 0.99,
    rate: float = 2.0,
    n_paraphrases: int = 100,
    n_rounds: int = 2,
    seed: int = 0,
) -> list[list[Request]]:
    """Region-skewed request streams for the federation experiments
    (DESIGN.md §9).

    The intent space splits into one *shared* pool (``shared_frac`` of all
    intents — globally hot knowledge every region asks about) and
    ``n_regions`` disjoint *private* pools (region-local interest). Each
    request draws from the shared pool with probability ``overlap``, else
    from its region's private pool; both draws are Zipf(``zipf_s``) within
    the pool. ``overlap`` is therefore the knob peering exploits: at 0 the
    regions are disjoint and peeking siblings is pure overhead; at 1 every
    region serves the same hot set and a sibling has almost everything.

    Arrivals are independent per-region Poisson(``rate``); request ids are
    globally unique across regions (records can be merged), and each
    request keeps its own session id so per-region prefetchers learn
    uncontaminated transition chains.
    """
    if not 0.0 <= overlap <= 1.0:
        raise ValueError("overlap must be in [0, 1]")
    rng = np.random.default_rng(seed)
    n = world.n_intents
    perm = rng.permutation(n)
    n_shared = max(int(n * shared_frac), 1)
    shared = perm[:n_shared]
    private_all = perm[n_shared:]
    if len(private_all) < n_regions:
        raise ValueError("need at least one private intent per region")
    privates = np.array_split(private_all, n_regions)
    p_shared = _zipf_probs(len(shared), zipf_s)
    out: list[list[Request]] = []
    rid = 0
    for r in range(n_regions):
        priv = privates[r]
        p_priv = _zipf_probs(len(priv), zipf_s)
        reqs = []
        t = 0.0
        for _ in range(n_per_region):
            t += rng.exponential(1.0 / rate)
            if rng.random() < overlap:
                intent = int(shared[rng.choice(len(shared), p=p_shared)])
            else:
                intent = int(priv[rng.choice(len(priv), p=p_priv)])
            rounds = []
            for rr in range(n_rounds):
                it = intent
                if rr > 0 and rng.random() < 0.3:
                    it = (intent + 1) % n
                rounds.append(
                    world.query(it, int(rng.integers(0, n_paraphrases)))
                )
            reqs.append(
                Request(rid, t, rounds[0], session=rid, n_rounds=n_rounds,
                        round_queries=tuple(rounds))
            )
            rid += 1
        out.append(reqs)
    return out


def closed_loop(requests: list[Request], concurrency: int) -> list[Request]:
    """Strip arrival times for closed-loop replay at fixed concurrency —
    the engine dispatches the next request when a slot frees (Fig 10)."""
    out = [dataclasses.replace(r, arrival=0.0) for r in requests]
    return out
