"""Hypothesis property tests on the Cortex cache invariants."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cache import make_cache
from repro.core.judge import OracleJudge
from repro.core.semantic_element import ttl_from_staticity
from repro.data.world import SemanticWorld

WORLD = SemanticWorld(n_intents=120, dim=48, seed=7)


def fresh_cache(capacity=20_000, eviction="lcfu", tau_lsm=0.9, acc=1.0,
                max_ttl=600.0):
    judge = OracleJudge(WORLD, accuracy=acc, seed=1)
    return make_cache(
        capacity_bytes=capacity, dim=WORLD.dim, judge=judge,
        eviction=eviction, max_ttl=max_ttl, index_capacity=256,
    )


ops = st.lists(
    st.tuples(
        st.integers(0, 119),       # intent
        st.integers(0, 30),        # paraphrase
        st.floats(0.0, 500.0),     # time offset
    ),
    min_size=1, max_size=60,
)


@given(ops)
@settings(max_examples=40, deadline=None)
def test_capacity_never_exceeded(seq):
    cache = fresh_cache()
    now = 0.0
    for intent, para, dt in seq:
        now += dt
        q = WORLD.query(intent, para)
        emb = WORLD.embed(q)
        res = cache.lookup(q, emb, now)
        if not res.hit:
            cache.insert(q, emb, WORLD.fetch(q), now=now, cost=0.005,
                         latency=0.4, size=WORLD.value_size(q))
        # invariants
        assert cache.usage <= cache.capacity_bytes
        assert cache.usage == sum(se.size for se in cache.store.values())
        assert len(cache.store) == len(cache.rows)
        assert len(cache.seri.index) == len(cache.store)


@given(ops)
@settings(max_examples=25, deadline=None)
def test_no_expired_item_ever_hits(seq):
    cache = fresh_cache(max_ttl=120.0)
    now = 0.0
    for intent, para, dt in seq:
        now += dt
        q = WORLD.query(intent, para)
        emb = WORLD.embed(q)
        res = cache.lookup(q, emb, now)
        if res.hit:
            assert not res.se.expired(now)
        else:
            cache.insert(q, emb, WORLD.fetch(q), now=now, cost=0.005,
                         latency=0.4, size=WORLD.value_size(q))


@given(ops)
@settings(max_examples=25, deadline=None)
def test_semantic_hits_are_correct_with_perfect_judge(seq):
    """With a perfect judge every hit serves the right intent's answer."""
    cache = fresh_cache(acc=1.0)
    now = 0.0
    for intent, para, dt in seq:
        now += dt
        q = WORLD.query(intent, para)
        emb = WORLD.embed(q)
        res = cache.lookup(q, emb, now)
        if res.hit:
            assert res.se.value == WORLD.answer(q)
        else:
            cache.insert(q, emb, WORLD.fetch(q), now=now, cost=0.005,
                         latency=0.4, size=WORLD.value_size(q))


def test_lcfu_evicts_lowest_score():
    cache = fresh_cache(capacity=5_000)
    now = 0.0
    inserted = []
    for i in range(30):
        q = WORLD.query(i, 0)
        emb = WORLD.embed(q)
        se = cache.insert(q, emb, WORLD.fetch(q), now=now, cost=0.005,
                          latency=0.4, size=WORLD.value_size(q))
        inserted.append(se)
        now += 1.0
        # every survivor must score >= every evicted item at eviction time
    surviving = set(cache.store)
    scores = {se.se_id: se.lcfu_score(now) for se in inserted}
    if surviving and len(surviving) < len(inserted):
        max_evicted = max(
            s for i, s in scores.items() if i not in surviving
        )
        # allow ties; freq growth can reorder later, so compare loosely:
        # at least one survivor must outscore the best evicted item
        assert any(
            scores[i] >= max_evicted for i in surviving
        )


def test_insert_honors_explicit_staticity_zero():
    """Regression: `staticity or judge.staticity(...)` re-estimated when a
    caller passed a legitimate 0 — the guard must be `is None`."""
    cache = fresh_cache()
    q = WORLD.query(3, 0)
    se = cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=0.0,
                      cost=0.005, latency=0.4, size=100, staticity=0)
    assert se.staticity == 0
    # staticity 0 clamps to the shortest TTL class
    assert se.expires_at == pytest.approx(
        ttl_from_staticity(0, cache.max_ttl, cache.min_ttl)
    )
    # same guard on the batched path
    [se2] = cache.insert_batch(
        [dict(query=WORLD.query(4, 0), q_emb=WORLD.embed(WORLD.query(4, 0)),
              value="v", cost=0.005, latency=0.4, size=100, staticity=0)],
        now=0.0,
    )
    assert se2.staticity == 0
    # None still delegates to the judge (world ground truth >= 1)
    se3 = cache.insert(WORLD.query(5, 0), WORLD.embed(WORLD.query(5, 0)),
                       "v", now=0.0, cost=0.005, latency=0.4, size=100)
    assert se3.staticity == WORLD.staticity(WORLD.query(5, 0)) >= 1


def test_shared_hit_accounting_counts_prefetch_hits():
    """account_hit is the single bookkeeping path for every validated-hit
    flavor (full lookup, staged finalize, the engine's nojudge ablation):
    a prefetched entry's first confirmed hit must bump prefetch_hits."""
    cache = fresh_cache()
    q = WORLD.query(6, 0)
    se = cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=0.0,
                      cost=0.005, latency=0.4, size=100, prefetched=True)
    assert se.freq == 0
    cache.account_hit(se, now=1.0)
    assert cache.stats.prefetch_hits == 1
    assert cache.stats.hits == 1
    assert se.freq == 1 and se.last_access == 1.0
    cache.account_hit(se, now=2.0)
    assert cache.stats.prefetch_hits == 1     # only the first hit counts


def test_ttl_from_staticity_monotone():
    ttls = [ttl_from_staticity(s, 3600.0) for s in range(1, 11)]
    assert all(a <= b for a, b in zip(ttls, ttls[1:]))
    # STRICTLY monotone on the interior: every class buys real lifetime
    assert all(a < b for a, b in zip(ttls, ttls[1:]))
    assert ttls[0] == 30.0
    assert abs(ttls[-1] - 3600.0) < 1e-6


def test_ttl_from_staticity_clamps_at_class_bounds():
    """Out-of-range staticity clamps to class 1 / class 10 — callers can
    pass 0 (explicit ephemeral override) or a judge-mangled 11+ without
    escaping the [min_ttl, max_ttl] envelope."""
    for s in (-5, 0, 1):
        assert ttl_from_staticity(s, 3600.0) == ttl_from_staticity(1, 3600.0)
    for s in (10, 11, 99):
        assert ttl_from_staticity(s, 3600.0) == ttl_from_staticity(10, 3600.0)
    # custom min/max honored at the clamped ends
    assert ttl_from_staticity(0, 900.0, 15.0) == 15.0
    assert ttl_from_staticity(42, 900.0, 15.0) == pytest.approx(900.0)


def test_eviction_policies_differ():
    """LCFU keeps high-cost items that LRU would drop."""
    from repro.core.seri import Seri, VectorIndex
    from repro.core.cache import CortexCache

    for ev in ("lcfu", "lru", "lfu"):
        cache = fresh_cache(capacity=1_500, eviction=ev)
        now = 0.0
        for i in range(5):  # expensive, once-validated items
            q = WORLD.query(i, 0)
            cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=now,
                         cost=0.5, latency=2.0, size=100)
            # one confirmed semantic hit -> freq=1 (Algorithm 2: fresh
            # items score 0 regardless of cost — prefetch self-correction)
            q2 = WORLD.query(i, 1)
            assert cache.lookup(q2, WORLD.embed(q2), now).hit
            now += 1.0
        for i in range(5, 25):  # cheap one-shot items, each also hit once
            q = WORLD.query(i, 0)
            cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=now,
                         cost=1e-4, latency=0.05, size=100)
            q2 = WORLD.query(i, 1)
            cache.lookup(q2, WORLD.embed(q2), now)
            now += 1.0
        kept = {WORLD.intent_of(se.key) for se in cache.store.values()}
        if ev == "lcfu":
            # expensive early items survive under LCFU
            assert any(i < 5 for i in kept)
        if ev == "lru":
            # pure recency: the early expensive items are gone
            assert not any(i < 5 for i in kept)
