"""Synthetic semantic world — the ground-truth universe behind the
behavioural experiments.

The paper evaluates on HotpotQA/Musique/2Wiki/Zilliz questions with a real
embedding model. Offline, we construct an equivalent *controlled* world:

* N intents; each has a unit-norm cluster center, an answer, a staticity
  class, a topic group (for correlated trends), and paraphrases.
* embed(query) = normalize(center + σ_para · noise) — paraphrases of one
  intent are tightly clustered (cos ≈ 0.97+).
* A fraction of intents come in *confusable pairs*: centers engineered to
  cosine ≈ confusable_cos (default 0.93 > τ_sim) with different answers —
  the "apple nutrition facts" vs "Apple stock price" failure mode that
  defeats pure-ANN caches and makes the semantic judge necessary (§6.6).

Query strings are structured ("q:<intent>:<paraphrase>") so ground truth
(same_intent, answer, staticity) is exact and experiments are reproducible.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Intent:
    iid: int
    answer: str
    staticity: int
    topic: int
    confusable_with: int | None = None


class SemanticWorld:
    def __init__(
        self,
        n_intents: int = 1000,
        dim: int = 128,
        *,
        n_topics: int = 10,
        confusable_frac: float = 0.2,
        confusable_cos: float = 0.93,
        sigma_para: float = 0.12,
        value_bytes: tuple[int, int] = (512, 4096),
        seed: int = 0,
    ):
        self.dim = dim
        self.sigma_para = sigma_para
        self.rng = np.random.default_rng(seed)
        self.n_intents = n_intents

        centers = self.rng.standard_normal((n_intents, dim)).astype(np.float32)
        centers /= np.linalg.norm(centers, axis=1, keepdims=True)
        # carve confusable pairs: c_b = cos·c_a + sin·orth
        n_pairs = int(n_intents * confusable_frac / 2)
        self.intents: list[Intent] = []
        pair_partner = {}
        for p in range(n_pairs):
            a, b = 2 * p, 2 * p + 1
            ca = centers[a]
            orth = self.rng.standard_normal(dim).astype(np.float32)
            orth -= (orth @ ca) * ca
            orth /= np.linalg.norm(orth)
            cos = confusable_cos
            centers[b] = cos * ca + np.sqrt(1 - cos * cos) * orth
            pair_partner[a] = b
            pair_partner[b] = a
        self.centers = centers

        stat_choices = np.array([1, 2, 3, 5, 7, 9, 10])
        stat_probs = np.array([0.1, 0.1, 0.15, 0.2, 0.15, 0.15, 0.15])
        for i in range(n_intents):
            self.intents.append(
                Intent(
                    iid=i,
                    answer=f"answer-{i}",
                    staticity=int(self.rng.choice(stat_choices, p=stat_probs)),
                    topic=int(self.rng.integers(0, n_topics)),
                    confusable_with=pair_partner.get(i),
                )
            )
        self.value_bytes = value_bytes
        self._sizes = self.rng.integers(
            value_bytes[0], value_bytes[1], size=n_intents
        )
        # heterogeneous tool economics: ~25% of intents come from an
        # expensive/slow tool (premium API), the rest from the cheap one —
        # the heterogeneity LCFU's cost-aware retention exploits (Table 6)
        premium = self.rng.random(n_intents) < 0.25
        self._cost_mult = np.where(premium, 8.0, 1.0)
        self._lat_mult = np.where(premium, 4.0, 1.0)

    # ------------------------------------------------------------ queries

    def query(self, intent: int, paraphrase: int) -> str:
        return f"q:{intent}:{paraphrase}"

    def intent_of(self, query: str) -> int:
        return int(query.split(":")[1])

    def same_intent(self, q1: str, q2: str) -> bool:
        return self.intent_of(q1) == self.intent_of(q2)

    def staticity(self, query: str) -> int:
        return self.intents[self.intent_of(query)].staticity

    def answer(self, query: str) -> str:
        return self.intents[self.intent_of(query)].answer

    def value_size(self, query: str) -> int:
        return int(self._sizes[self.intent_of(query)])

    def topic(self, query: str) -> int:
        return self.intents[self.intent_of(query)].topic

    def embed(self, query: str) -> np.ndarray:
        iid = self.intent_of(query)
        para = int(query.split(":")[2])
        # deterministic per (intent, paraphrase) noise, unit direction so
        # cos(paraphrase, center) ≈ 1/√(1+σ²) regardless of dim
        rng = np.random.default_rng((iid * 1_000_003 + para) & 0x7FFFFFFF)
        n = rng.standard_normal(self.dim).astype(np.float32)
        n /= np.linalg.norm(n)
        v = self.centers[iid] + self.sigma_para * n
        return (v / np.linalg.norm(v)).astype(np.float32)

    def cost_mult(self, query: str) -> float:
        return float(self._cost_mult[self.intent_of(query)])

    def latency_mult(self, query: str) -> float:
        return float(self._lat_mult[self.intent_of(query)])

    # ------------------------------------------------- freshness surface
    # The static world exposes the same time-aware API as MutableWorld so
    # the engine/cache/federation never branch on the world flavor: here
    # every intent is eternally at version 0 and never updates.

    def intent_version(self, iid: int, t: float) -> int:
        """Knowledge version of intent ``iid`` as of virtual time ``t``."""
        return 0

    def version_at(self, query: str, t: float) -> int:
        return self.intent_version(self.intent_of(query), t)

    def answer_at(self, query: str, t: float) -> str:
        """Ground-truth answer as of virtual time ``t``."""
        return self.answer(query)

    def next_update(self, iid: int, t: float) -> float:
        """Virtual time of the first update strictly after ``t`` (inf =
        this intent never changes). The origin change-feed schedules its
        notification events from this."""
        return float("inf")

    # the "live tool": ground truth fetch (used by recalibration too).
    # ``t`` is the virtual instant the origin serves the request; the
    # static world ignores it.
    def fetch(self, query: str, t: float | None = None) -> str:
        return self.answer_at(query, 0.0 if t is None else t)

    def equivalent(self, cached_value, ground_value) -> bool:
        return cached_value == ground_value


class MutableWorld(SemanticWorld):
    """Semantic world whose knowledge CHANGES over virtual time.

    Each intent's answer updates on a deterministic schedule driven
    *inversely* by its staticity class: class-1 (ephemeral) intents update
    every ``churn_min_period`` seconds, class-10 (stable) every
    ``churn_max_period`` — the same exponential shape as
    ``ttl_from_staticity``, so the staticity metadata the judge estimates
    is *empirically meaningful*: a TTL derived from it either does or does
    not outrun the intent's real update cadence.

    Updates are versioned, never random at query time: intent ``i``
    updates at ``phase_i + k · period_i`` (``phase_i`` a seeded per-intent
    offset in ``[0, period_i)`` so updates de-synchronize), and
    ``answer_at(q, t)`` returns ``answer-<i>`` before the first update,
    ``answer-<i>-v<k>`` after the k-th. Cached values therefore go stale
    exactly when the schedule says so, and ``info_accuracy`` measures
    staleness, not judge noise alone. Embeddings and value sizes stay
    fixed — the *knowledge value* churns, not the query semantics.

    ``churn_frac`` < 1 leaves a seeded fraction of intents permanently
    static (period = inf), modelling the mixed world the staticity score
    exists for. ``churn_frac=0`` is behaviourally identical to the static
    :class:`SemanticWorld`.
    """

    def __init__(
        self,
        n_intents: int = 1000,
        dim: int = 128,
        *,
        churn_min_period: float = 60.0,
        churn_max_period: float = 3600.0,
        churn_frac: float = 1.0,
        **kw,
    ):
        super().__init__(n_intents, dim, **kw)
        self.churn_min_period = churn_min_period
        self.churn_max_period = churn_max_period
        stat = np.array([it.staticity for it in self.intents], np.float64)
        frac = (np.clip(stat, 1, 10) - 1) / 9.0
        period = churn_min_period * (
            churn_max_period / churn_min_period
        ) ** frac
        # phase BEFORE the churn mask: one rng draw per intent either way,
        # so the schedule of churning intents is invariant to churn_frac
        phase = self.rng.random(n_intents) * period
        churns = self.rng.random(n_intents) < churn_frac
        # inf * random() would be nan for random()==0 — set both explicitly
        self._period = np.where(churns, period, np.inf)
        self._phase = np.where(churns, phase, np.inf)

    def intent_version(self, iid: int, t: float) -> int:
        ph = float(self._phase[iid])
        if t < ph:
            return 0
        return int((t - ph) // float(self._period[iid])) + 1

    def answer_at(self, query: str, t: float) -> str:
        iid = self.intent_of(query)
        v = self.intent_version(iid, t)
        return f"answer-{iid}" if v == 0 else f"answer-{iid}-v{v}"

    def next_update(self, iid: int, t: float) -> float:
        ph = float(self._phase[iid])
        if not np.isfinite(ph):
            return float("inf")
        per = float(self._period[iid])
        u = ph + self.intent_version(iid, t) * per
        # strict progress despite float rounding: at t == ph + k·per the
        # floor in intent_version can land one step short, which would
        # return u == t and spin the change feed at a frozen instant
        while u <= t:
            u += per
        return u
