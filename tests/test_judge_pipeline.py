"""JudgePipeline seam tests (DESIGN.md §14).

Pins the admission-band edge semantics, the micro-batch invariance of
the real tiny-LM judge (§8: batched and scalar execution bit-identical),
the FLOPs-derived judge token cost, and the LRU bound on the oracle's
per-pair noise counters.
"""
import numpy as np
import pytest

from repro.core.judge import ModelJudge, OracleJudge
from repro.core.judge_pipeline import (
    AdmissionBand,
    JudgePipeline,
    as_pipeline,
    default_judge_cfg,
    judge_token_cost,
)
from repro.data.world import SemanticWorld

WORLD = SemanticWorld(n_intents=60, dim=32, seed=7)


def _oracle(**kw):
    return OracleJudge(WORLD, accuracy=0.98, seed=1, **kw)


# ---------------------------------------------------------------- band edges


def test_band_edges_pinned():
    band = AdmissionBand(width=0.1)
    tau = 0.9
    assert band.lo(tau) == pytest.approx(0.85)
    assert band.hi(tau) == pytest.approx(0.95)
    # upper edge INCLUSIVE: exactly at hi => trusted
    assert band.classify(band.hi(tau), tau) == "trust"
    assert band.classify(band.hi(tau) - 1e-9, tau) == "uncertain"
    # lower edge INCLUSIVE: exactly at lo => judged, never dropped
    assert band.classify(band.lo(tau), tau) == "uncertain"
    assert band.classify(band.lo(tau) - 1e-9, tau) == "reject"


def test_admit_high_sim_bypasses_judge():
    pipe = JudgePipeline(_oracle(), band=AdmissionBand(width=0.1))
    assert pipe.admit(np.array([0.97, 0.91]), 0.9) == "bypass"
    assert pipe.stats.bypass_hits == 1
    assert pipe.stats.band_judged == 0


def test_admit_uncertain_band_pays_judge():
    pipe = JudgePipeline(_oracle(), band=AdmissionBand(width=0.1))
    assert pipe.admit(np.array([0.91]), 0.9) == "judge"
    assert pipe.stats.band_judged == 1
    assert pipe.stats.bypass_hits == 0


def test_admit_low_sim_shortcut_to_miss():
    # the band lowers the stage-1 gate to lo; anything the gate admits
    # but the caller filtered to empty is a straight miss — and a
    # sub-lo best candidate would never be in sims (stage1_gate == lo)
    pipe = JudgePipeline(_oracle(), band=AdmissionBand(width=0.1))
    assert pipe.stage1_gate(0.9) == pytest.approx(0.85)
    assert pipe.admit(np.array([]), 0.9) == "miss"


def test_width_zero_is_legacy_per_seam():
    # engine seam: width 0 => judge everything (the pre-band engine)
    pipe = JudgePipeline(_oracle(), band=AdmissionBand(width=0.0))
    assert pipe.admit(np.array([0.999]), 0.9) == "judge"
    assert pipe.stage1_gate(0.9) == 0.9
    # federation seam: width 0 => ANN-only leases (always valid)
    assert pipe.validate_lease("q", "k", 0.5, 0.9, 0.9) is True
    assert pipe.stats.lease_validations == 0
    # no band object behaves the same
    bare = JudgePipeline(_oracle())
    assert bare.admit(np.array([0.999]), 0.9) == "judge"
    assert bare.validate_lease("q", "k", 0.5, 0.9, 0.9) is True


def test_validate_lease_in_band_judges():
    pipe = JudgePipeline(_oracle(), band=AdmissionBand(width=0.1))
    # trust region: no judge call
    assert pipe.validate_lease("q", "k", 0.97, 0.9, 0.9) is True
    assert pipe.stats.lease_validations == 0
    # uncertain region: exactly one judged pair per call
    q = WORLD.query(0, 0)
    k = WORLD.query(0, 1)
    pipe.validate_lease(q, k, 0.9, 0.9, 0.9)
    assert pipe.stats.lease_validations == 1
    assert pipe.stats.judged_pairs == 1


# --------------------------------------------------------- model-derived cost


def test_judge_token_cost_tracks_d_model():
    c128 = judge_token_cost(default_judge_cfg(d_model=128))
    c256 = judge_token_cost(default_judge_cfg(d_model=256))
    assert c128 == pytest.approx(16.0)
    assert c256 == pytest.approx(32.0)


def test_pipeline_base_tokens_from_cfg_no_constant():
    small = JudgePipeline(_oracle(), judge_cfg=default_judge_cfg(d_model=64))
    big = JudgePipeline(_oracle(), judge_cfg=default_judge_cfg(d_model=256))
    assert big.base_tokens > small.base_tokens
    # micro-batch cost follows the co-location formula over that base
    assert small.batch_tokens(1) == pytest.approx(small.base_tokens)
    assert small.batch_tokens(4, 0.5) == pytest.approx(
        small.base_tokens * 2.5)


# ------------------------------------------------------- micro-batch identity


def test_model_judge_batch_bit_identical_to_solo():
    """DESIGN.md §8: scores must not depend on micro-batch shape."""
    judge = ModelJudge(cfg=default_judge_cfg(d_model=64), max_len=32, seed=3)
    qs = [WORLD.query(i % 4, i) for i in range(6)]
    ks = [WORLD.query(i % 4, i + 1) for i in range(6)]
    batched = judge.score_pairs(qs, ks)
    solo = np.concatenate([
        judge.score_pairs([q], [k]) for q, k in zip(qs, ks)
    ])
    assert np.array_equal(batched, solo)
    # and any interior split point
    mid = judge.score_pairs(qs[:2], ks[:2]), judge.score_pairs(qs[2:], ks[2:])
    assert np.array_equal(batched, np.concatenate(mid))


def test_pipeline_scores_come_from_decisions_not_compute():
    oracle = _oracle()
    ref = OracleJudge(WORLD, accuracy=0.98, seed=1)
    model = ModelJudge(cfg=default_judge_cfg(d_model=64), max_len=32, seed=3)
    pipe = JudgePipeline(oracle, compute=model)
    q = [WORLD.query(0, 0)]
    k = [WORLD.query(0, 1)]
    assert np.array_equal(pipe.score_pairs(q, k), ref.score_pairs(q, k))
    assert pipe.stats.judge_batches == 1


# ------------------------------------------------------------- misc invariants


def test_staticity_stable_and_deterministic():
    judge = ModelJudge(cfg=default_judge_cfg(d_model=64), max_len=32)
    vals = {judge.staticity("some query") for _ in range(5)}
    assert len(vals) == 1
    assert 1 <= vals.pop() <= 10


def test_oracle_pair_counts_lru_bounded():
    judge = _oracle(max_pairs=8)
    pairs = [(WORLD.query(i % 50, i), WORLD.query(i % 50, 0))
             for i in range(50)]
    for q, k in pairs:
        judge.score_pairs([q], [k])
    assert len(judge._pair_counts) <= 8
    # most-recent pairs survive, oldest evicted
    assert pairs[-1] in judge._pair_counts
    assert pairs[0] not in judge._pair_counts


def test_as_pipeline_idempotent():
    pipe = JudgePipeline(_oracle())
    assert as_pipeline(pipe) is pipe
    wrapped = as_pipeline(_oracle())
    assert isinstance(wrapped, JudgePipeline)
    assert wrapped.band is None
