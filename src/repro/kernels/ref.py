"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ann_topk_ref(emb, active, q, k):
    """emb (N, D), active (N,) bool/int, q (B, D) -> (vals (B,k), rows (B,k)).

    Exact cosine top-k (inputs assumed unit-norm) over active rows.
    """
    scores = jnp.einsum("nd,bd->bn", emb.astype(jnp.float32),
                        q.astype(jnp.float32))
    scores = jnp.where(active.astype(bool)[None, :], scores, -jnp.inf)
    vals, rows = jax.lax.top_k(scores, k)
    return vals, rows


def flash_attention_ref(q, k, v, scale, causal=True, window=None):
    """q (B,Sq,KV,G,Dh), k/v (B,Sk,KV,Dh) -> (B,Sq,KV,G,Dh). f32 softmax."""
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bkgqs,bskd->bqkgd", p, v.astype(jnp.float32)
    ).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, scale):
    """q (B,KV,G,Dh); caches (B,S,KV,Dh); pos scalar — attend to <= pos."""
    b, kvh, g, dh = q.shape
    s_cache = k_cache.shape[1]
    s = jnp.einsum(
        "bkgd,bskd->bkgs", q.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * scale
    valid = jnp.arange(s_cache) <= pos
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32)
    ).astype(q.dtype)
