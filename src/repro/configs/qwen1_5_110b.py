"""qwen1.5-110b [dense] — hf:Qwen/Qwen1.5 family scaled per assignment.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig

NAME = "qwen1.5-110b"


@register(NAME)
def config() -> ModelConfig:
    attn = AttnConfig(
        n_heads=64, n_kv_heads=8, head_dim=128, qkv_bias=True,
        rope_theta=1_000_000.0,
    )
    return ModelConfig(
        name=NAME,
        family="dense",
        d_model=8192,
        vocab_size=152064,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=49152),),
        n_repeat=80,
        tie_embeddings=False,
    )
