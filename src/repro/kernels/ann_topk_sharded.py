"""Shard-parallel routed scans over the IVF Pallas kernels (DESIGN.md §13).

Sharding lives ABOVE the kernel: ``ann_topk_ivf`` / ``ann_topk_ivf_quant``
run unmodified, once per mesh shard. ``sel`` carries GLOBAL cluster ids
from the shared router; each shard masks the probes down to the
contiguous cluster range it owns (``lo ≤ sel < hi``), translates them to
its local bucket space, scans its ``(Cmax, cap[, D])`` slice, and
translates winning bucket slots back to GLOBAL index rows. Probes a
shard does not own run disabled (the kernel's existing ``enabled=0``
path), so every shard launches the same grid — no data-dependent shapes.

Two execution modes produce identical ``(S, B, nprobe, k)`` stacks:

  * ``shard_map`` over a 1-D ``("shards",)`` device mesh
    (``launch/mesh.make_shard_mesh``) — one program per device, the
    bucket slices land device-local;
  * an unrolled host loop for hosts with fewer devices than shards
    (``jax.device_count() < S``) — same math, same outputs.

``kernels/ops.py`` merges the stacks with one cross-shard
``jax.lax.top_k`` (the ``_merge_shards`` step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.ann_topk_ivf import NEG, ann_topk_ivf, ann_topk_ivf_quant

__all__ = ["ann_topk_ivf_sharded", "ann_topk_ivf_quant_sharded",
           "mesh_available", "NEG"]


def mesh_available(n_shards: int) -> bool:
    """True when the host can lay one cache shard per device (the CI
    gate simulates 8 CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    return jax.device_count() >= n_shards


def _own_probes(sel, en, lo, hi, cmax):
    """Mask ``sel`` down to one shard's owned cluster range and
    translate to its local bucket ids. Non-owned probes come back
    disabled with a clipped (in-range, never scanned) local id."""
    own = (sel >= lo) & (sel < hi)
    loc = jnp.clip(sel - lo, 0, cmax - 1).astype(jnp.int32)
    return loc, (en * own).astype(jnp.int32)


@functools.lru_cache(maxsize=None)
def _mesh_fn(n_shards: int, k: int, interpret: bool, quant: bool):
    """Build (once per config) the jitted shard_map program: sharded
    operands carry a leading length-1 shard axis inside the body."""
    from repro.launch.mesh import make_shard_mesh
    from repro.nn.sharding import shard_map_compat

    mesh = make_shard_mesh(n_shards)
    if quant:
        def body(bkt, bsc, vld, rws, lo, hi, qq, qs, sel, en):
            loc, en_s = _own_probes(sel, en, lo[0, 0], hi[0, 0],
                                    bkt.shape[1])
            vals, slots = ann_topk_ivf_quant(
                loc, en_s, qq, qs, bkt[0], bsc[0], vld[0], k,
                interpret=interpret,
            )
            rows = jnp.where(vals > NEG / 2,
                             rws[0][loc[:, :, None], slots], -1)
            return vals[None], rows[None]

        in_specs = (P("shards"),) * 6 + (P(), P(), P(), P())
    else:
        def body(bkt, vld, rws, lo, hi, q, sel, en):
            loc, en_s = _own_probes(sel, en, lo[0, 0], hi[0, 0],
                                    bkt.shape[1])
            vals, slots = ann_topk_ivf(loc, en_s, q, bkt[0], vld[0], k,
                                       interpret=interpret)
            rows = jnp.where(vals > NEG / 2,
                             rws[0][loc[:, :, None], slots], -1)
            return vals[None], rows[None]

        in_specs = (P("shards"),) * 5 + (P(), P(), P())
    fn = shard_map_compat(body, mesh=mesh, in_specs=in_specs,
                          out_specs=(P("shards"), P("shards")),
                          axis_names={"shards"})
    return jax.jit(fn)


def ann_topk_ivf_sharded(sel, enabled, q, shard_buckets, shard_valid,
                         shard_rows, bounds, k: int = 4, *,
                         interpret: bool = True):
    """fp32 shard-parallel routed scan. Returns ``(vals, rows)`` each
    ``(S, B, nprobe, k)``; rows are GLOBAL index rows, -1 where masked.
    ``bounds`` is the router's (S+1,) cluster-ownership prefix."""
    s = shard_buckets.shape[0]
    if s > 1 and mesh_available(s):
        fn = _mesh_fn(s, k, interpret, False)
        lo = jnp.asarray(bounds[:-1], jnp.int32).reshape(s, 1)
        hi = jnp.asarray(bounds[1:], jnp.int32).reshape(s, 1)
        return fn(jnp.asarray(shard_buckets), jnp.asarray(shard_valid),
                  jnp.asarray(shard_rows), lo, hi, jnp.asarray(q),
                  jnp.asarray(sel), jnp.asarray(enabled))
    sel, en, q = jnp.asarray(sel), jnp.asarray(enabled), jnp.asarray(q)
    cmax = shard_buckets.shape[1]
    vs, rs = [], []
    for si in range(s):
        loc, en_s = _own_probes(sel, en, int(bounds[si]),
                                int(bounds[si + 1]), cmax)
        vals, slots = ann_topk_ivf(
            loc, en_s, q, jnp.asarray(shard_buckets[si]),
            jnp.asarray(shard_valid[si]), k, interpret=interpret,
        )
        rs.append(jnp.where(
            vals > NEG / 2,
            jnp.asarray(shard_rows[si])[loc[:, :, None], slots], -1))
        vs.append(vals)
    return jnp.stack(vs), jnp.stack(rs)


def ann_topk_ivf_quant_sharded(sel, enabled, qq, q_scales, shard_bq,
                               shard_scale, shard_valid, shard_rows,
                               bounds, k: int = 16, *,
                               interpret: bool = True):
    """int8 shard-parallel routed coarse scan — the quantized sibling of
    :func:`ann_topk_ivf_sharded` (same ownership masking, same global
    row translation)."""
    s = shard_bq.shape[0]
    if s > 1 and mesh_available(s):
        fn = _mesh_fn(s, k, interpret, True)
        lo = jnp.asarray(bounds[:-1], jnp.int32).reshape(s, 1)
        hi = jnp.asarray(bounds[1:], jnp.int32).reshape(s, 1)
        return fn(jnp.asarray(shard_bq), jnp.asarray(shard_scale),
                  jnp.asarray(shard_valid), jnp.asarray(shard_rows),
                  lo, hi, jnp.asarray(qq), jnp.asarray(q_scales),
                  jnp.asarray(sel), jnp.asarray(enabled))
    sel, en = jnp.asarray(sel), jnp.asarray(enabled)
    qq, q_scales = jnp.asarray(qq), jnp.asarray(q_scales)
    cmax = shard_bq.shape[1]
    vs, rs = [], []
    for si in range(s):
        loc, en_s = _own_probes(sel, en, int(bounds[si]),
                                int(bounds[si + 1]), cmax)
        vals, slots = ann_topk_ivf_quant(
            loc, en_s, qq, q_scales, jnp.asarray(shard_bq[si]),
            jnp.asarray(shard_scale[si]), jnp.asarray(shard_valid[si]),
            k, interpret=interpret,
        )
        rs.append(jnp.where(
            vals > NEG / 2,
            jnp.asarray(shard_rows[si])[loc[:, :, None], slots], -1))
        vs.append(vals)
    return jnp.stack(vs), jnp.stack(rs)
