"""Process-wide lowering knobs (set by launch.dryrun, default-safe)."""

# Unroll factor applied to every structural lax.scan (superblocks, attention
# chunk loops, mamba/mlstm time-chunk loops). 1 = rolled while-loops (small
# HLO, fast compile). The dry-run metric compiles set this large because XLA
# cost_analysis counts a while body ONCE, not ×trip_count — metrics are only
# exact when the hot-path loops are fully unrolled.
UNROLL = 1


def unroll_for(n: int) -> int:
    return max(1, min(UNROLL, n))
