"""Clustered (IVF-style) stage-1 routing — DESIGN.md §12.

The paper's Seri front end is a Faiss IVF index; until this module our
stage 1 brute-force scanned every row of the embedding matrix on every
lookup, so stage-1 cost grew linearly with the cache and became the
bottleneck at large N (the MeanCache observation). This module makes
stage 1 sublinear with a clustered two-level index:

  * **route** — score the query block against ``n_clusters`` centroids
    (spherical mini-batch k-means over the cached embeddings) and select
    the ``nprobe`` nearest clusters per query;
  * **scan** — gather only the member rows of the selected clusters and
    run the usual masked top-k over that union.

Per query the scan touches ``n_clusters + nprobe·N/n_clusters`` rows in
expectation instead of N — minimized at ``n_clusters ≈ sqrt(nprobe·N)``.

The router is *free-list aware*: it composes with
:class:`~repro.core.seri.RowIndex` row recycling. ``note_add`` buckets a
new row under its nearest centroid immediately (no rebuild), and
``note_remove`` unbuckets freed rows, so routing stays correct through
insert/evict/demote/promote churn. Centroids drift as the cached
distribution shifts, so they are **refreshed on a mutation budget**
(``refresh_every`` adds+removes): a few seeded mini-batch k-means steps
followed by one full re-bucketing pass — amortized
O(N·C·D / refresh_every) per mutation.

``nprobe=None`` probes every non-empty cluster: the scanned set is then
exactly the active row set (ascending row order, like the brute-force
scan), which is what makes the brute-vs-IVF parity gates bit-exact.

Everything is seeded and counter-driven — same seed + same mutation
sequence ⇒ same centroids, buckets, and retrieval results — so the
benchmark suite's same-seed bit-identity gates extend to clustered runs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

NEG = -3.0e38  # masked-score sentinel shared with the ANN kernels

_ASSIGN_CHUNK = 8192   # rows per chunk in the full re-bucketing pass
_MIGRATE_CHUNK = 4096  # rows per cross-shard migration chunk (rebalance)


@dataclasses.dataclass
class ClusterConfig:
    """Knobs for one :class:`ClusterRouter` (one per index tier)."""

    n_clusters: int = 64
    # clusters probed per query; None = all non-empty clusters (the
    # brute-force-parity mode: same candidate set, same tie order)
    nprobe: Optional[int] = 8
    refresh_every: int = 1024   # mutations (adds+removes) per refresh
    min_train: int = 256        # active rows before the first training
    batch_size: int = 1024      # mini-batch rows per k-means step
    iters: int = 4              # mini-batch steps per refresh
    seed: int = 0
    # mesh shards the index is partitioned over (DESIGN.md §13): each
    # shard owns a CONTIGUOUS cluster range and scans only its members.
    # 1 = unsharded (every pre-§13 path unchanged). Sharding never
    # touches training or routing — centroids, assignments, and the
    # routed candidate set are shard-count invariant by construction.
    n_shards: int = 1


class ClusterRouter:
    """Incremental spherical mini-batch k-means over an index's rows.

    Owns the centroid matrix, the row→cluster assignment (row-aligned
    with the index, -1 = unassigned/inactive), and the per-cluster
    member lists. The owning index calls ``note_add``/``note_remove``
    from its row lifecycle and ``route`` from its search path; before
    the first training (``min_train`` active rows) the router reports
    ``ready == False`` and the index brute-force scans as before.
    """

    def __init__(self, capacity: int, dim: int,
                 cfg: Optional[ClusterConfig] = None):
        self.cfg = cfg or ClusterConfig()
        self.capacity = capacity
        self.dim = dim
        c = self.cfg.n_clusters
        self.centroids = np.zeros((c, dim), np.float32)
        self.counts = np.zeros(c, np.int64)
        self.assign = np.full(capacity, -1, np.int32)
        self.trained = False
        self.rng = np.random.default_rng(self.cfg.seed)
        self.refreshes = 0
        self._muts = 0
        # a training run needs at least a few rows per centroid
        self._min_train = max(self.cfg.min_train, 2 * c)
        # mini-batch per-center sample counts (the k-means learning-rate
        # denominators); persist across refreshes so centroids stabilize
        self._mb_counts = np.zeros(c, np.int64)
        # per-cluster member rows, maintained INCREMENTALLY (append on
        # add, remove on free) — a full rebuild per mutation would cost
        # O(N log N) on every serving-traffic stage-1 pass and eat the
        # host-side sublinearity this module exists for
        self._member_lists: list[list[int]] = [[] for _ in range(c)]
        self._bucket_cache = None             # kernel-layout arrays
        # ---- mesh-shard ownership (DESIGN.md §13) -------------------
        # shard s owns the contiguous cluster range
        # [shard_bounds[s], shard_bounds[s+1]); shard_of[ci] is the
        # owner of cluster ci. Seeded with an even cluster split;
        # refresh() rebalances the cut points to the member-count
        # distribution (and counts the member rows that change owner).
        s = max(1, int(self.cfg.n_shards))
        self.n_shards = s
        self.shard_bounds = (np.arange(s + 1, dtype=np.int64) * c) // s
        self.shard_of = self._owners_from_bounds(self.shard_bounds)
        self.rebalances = 0        # refreshes that moved ≥1 cluster
        self.migrated_rows = 0     # member rows that changed shards
        self.migration_chunks = 0  # ≤ _MIGRATE_CHUNK-row transfers
        self._shard_cache = None   # kernel shard-layout arrays

    @property
    def ready(self) -> bool:
        return self.trained

    # ------------------------------------------------ shard ownership

    def _owners_from_bounds(self, bounds: np.ndarray) -> np.ndarray:
        """Per-cluster owning shard from the cut-point prefix (repeated
        cut points = empty shards, which are legal)."""
        cs = np.arange(self.cfg.n_clusters)
        owners = np.searchsorted(bounds, cs, side="right") - 1
        return np.clip(owners, 0, self.n_shards - 1).astype(np.int32)

    def _rebalance_shards(self, count_migration: bool) -> None:
        """Re-cut cluster ownership to balance member counts across
        shards (contiguous ranges only, so routing stays a range test).

        Runs at the tail of every :meth:`refresh`, i.e. on the existing
        mutation budget — no extra scheduling. Each cut point lands on
        the member-count cumsum nearest to its ideal ``total·s/S``
        target. Clusters whose owner changes migrate their member rows
        in ≤ ``_MIGRATE_CHUNK``-row transfers; with the global SoA
        store the migration is pure accounting (ownership metadata +
        the counters the benchmarks report), mirroring what a
        multi-host deployment would ship over the interconnect.
        ``count_migration`` is False on the very first training pass —
        initial placement is not a migration.
        """
        s = self.n_shards
        if s <= 1:
            return
        c = self.cfg.n_clusters
        csum = np.concatenate(([0], np.cumsum(self.counts)))
        total = int(csum[-1])
        targets = np.arange(1, s, dtype=np.float64) * (total / s)
        cuts = np.searchsorted(csum[1:], targets, side="left") + 1
        bounds = np.maximum.accumulate(np.concatenate(
            ([0], np.minimum(cuts, c), [c])
        )).astype(np.int64)
        owners = self._owners_from_bounds(bounds)
        if count_migration:
            moved = self.counts[owners != self.shard_of]
            moved = moved[moved > 0]
            if len(moved):
                self.rebalances += 1
                self.migrated_rows += int(moved.sum())
                self.migration_chunks += int(
                    np.ceil(moved / _MIGRATE_CHUNK).sum())
        self.shard_bounds = bounds
        self.shard_of = owners
        self._shard_cache = None

    # ------------------------------------------------- lifecycle hooks

    def note_add(self, row: int, emb: np.ndarray, index) -> None:
        """Bucket a freshly-allocated row under its nearest centroid
        (or train the router once the index is big enough)."""
        if self.trained:
            sims = self.centroids @ np.asarray(emb, np.float32)
            c = int(np.argmax(sims))
            self.assign[row] = c
            self.counts[c] += 1
            self._member_lists[c].append(int(row))
            self._bucket_cache = None
        self._muts += 1
        if not self.trained:
            if len(index) >= self._min_train:
                self.refresh(index)
        elif self._muts >= self.cfg.refresh_every:
            self.refresh(index)

    def note_add_batch(self, rows: np.ndarray, embs: np.ndarray,
                       index) -> None:
        """Vectorized :meth:`note_add` for a block of freshly-allocated
        rows (bulk prefill). Only valid once trained — callers stay on
        the scalar hook until training flips so the first refresh fires
        at the same index size either way.

        Mutation-for-mutation equivalent to the scalar hook: chunks
        split at exactly ``refresh_every - _muts`` so refreshes fire at
        the same mutation counts as a sequential add loop, and the
        chunked (m, C) GEMM assignment matches the scalar GEMV argmax
        on tie-free (non-degenerate) scores — the float-summation-order
        caveat is the same one the chunked re-bucketing pass already
        carries.
        """
        assert self.trained, "note_add_batch requires a trained router"
        rows = np.asarray(rows, dtype=np.int64)
        embs = np.asarray(embs, dtype=np.float32)
        c = self.cfg.n_clusters
        n, i = len(rows), 0
        while i < n:
            room = self.cfg.refresh_every - self._muts
            take = min(n - i, max(1, room), _ASSIGN_CHUNK)
            r, e = rows[i:i + take], embs[i:i + take]
            a = np.argmax(e @ self.centroids.T, axis=1).astype(np.int32)
            self.assign[r] = a
            self.counts += np.bincount(a, minlength=c)
            order = np.argsort(a, kind="stable")  # keeps rows in order
            rs, asort = r[order], a[order]
            bnd = np.searchsorted(asort, np.arange(c + 1))
            for ci in np.unique(asort):
                self._member_lists[ci].extend(
                    int(x) for x in rs[bnd[ci]:bnd[ci + 1]])
            self._bucket_cache = None
            self._muts += take
            i += take
            if self._muts >= self.cfg.refresh_every:
                self.refresh(index)

    def note_remove(self, rows: np.ndarray) -> None:
        """Unbucket freed rows (TTL purge, eviction, demotion)."""
        ra = np.asarray(rows)
        cs = self.assign[ra]
        live = cs >= 0
        if live.any():
            np.subtract.at(self.counts, cs[live], 1)
            for r, c in zip(ra[live], cs[live]):
                self._member_lists[c].remove(int(r))
            self.assign[ra[live]] = -1
            self._bucket_cache = None
        self._muts += len(ra)
        # no refresh here: removals fire mid-eviction while the owning
        # cache is mutating; the budget check runs on the next add

    # --------------------------------------------------------- training

    def _mb_step(self, embs: np.ndarray) -> None:
        """One mini-batch k-means step (sklearn-style per-center rates):
        assign the sample, pull each centroid toward its sample mean with
        step size m_c / (mb_counts_c + m_c), then renormalize (spherical
        k-means — rows are unit vectors, assignment is by max dot)."""
        a = np.argmax(embs @ self.centroids.T, axis=1)
        for c in np.unique(a):
            pts = embs[a == c]
            m = len(pts)
            self._mb_counts[c] += m
            eta = m / float(self._mb_counts[c])
            self.centroids[c] = (1.0 - eta) * self.centroids[c] \
                + eta * pts.mean(axis=0)
        norms = np.linalg.norm(self.centroids, axis=1, keepdims=True)
        np.divide(self.centroids, norms, out=self.centroids,
                  where=norms > 0)

    def _rebucket(self, index) -> None:
        """Full re-bucketing: assign every active row to its nearest
        centroid, chunked so the (N, C) score block stays small."""
        rows = np.flatnonzero(index.active)
        self.assign[:] = -1
        for off in range(0, len(rows), _ASSIGN_CHUNK):
            chunk = rows[off:off + _ASSIGN_CHUNK]
            e = index.route_embs(chunk)
            self.assign[chunk] = np.argmax(
                e @ self.centroids.T, axis=1
            ).astype(np.int32)
        self.counts = np.bincount(
            self.assign[rows], minlength=self.cfg.n_clusters
        ).astype(np.int64)
        c = self.cfg.n_clusters
        a = self.assign[rows]
        order = np.argsort(a, kind="stable")  # keeps rows ascending
        rs, asort = rows[order], a[order]
        bounds = np.searchsorted(asort, np.arange(c + 1))
        self._member_lists = [
            rs[bounds[i]:bounds[i + 1]].tolist() for i in range(c)
        ]
        self._bucket_cache = None

    def refresh(self, index) -> None:
        """Centroid refresh on the mutation budget: (first call) seed
        centroids from a random row sample, then ``iters`` mini-batch
        steps and one full re-bucketing pass. Deterministic given the
        seed and the mutation history."""
        rows = np.flatnonzero(index.active)
        if len(rows) == 0:
            return
        first = not self.trained
        if not self.trained:
            pick = self.rng.choice(
                len(rows), size=min(self.cfg.n_clusters, len(rows)),
                replace=False,
            )
            init = index.route_embs(rows[pick])
            self.centroids[:len(init)] = init
            if len(init) < self.cfg.n_clusters:
                # tiny index: duplicate seeds so every centroid is valid
                reps = self.rng.choice(len(init),
                                       self.cfg.n_clusters - len(init))
                self.centroids[len(init):] = init[reps]
        for _ in range(self.cfg.iters):
            m = min(self.cfg.batch_size, len(rows))
            pick = self.rng.choice(len(rows), size=m, replace=False)
            self._mb_step(index.route_embs(rows[pick]))
        self._rebucket(index)
        self.trained = True
        self._muts = 0
        self.refreshes += 1
        self._rebalance_shards(count_migration=not first)

    # ---------------------------------------------------------- routing

    def members(self) -> list:
        """Per-cluster member-row arrays (insertion order — routing
        sorts the gathered union, so bucket-internal order is free).
        Materializes the incremental lists; the hot ``route`` path
        gathers only the selected clusters and never calls this."""
        return [np.asarray(m, dtype=np.int64) for m in self._member_lists]

    def route(self, q: np.ndarray):
        """Select clusters for a query block and gather their members.

        q (B, D) fp32 → ``(g_rows, allowed, rows_scanned)`` or None when
        nothing is bucketed (caller falls back to brute force):

          * g_rows  (G,)   — union of member rows across every selected
                             cluster in the block, ascending (at
                             nprobe=all this is exactly the active row
                             set in brute-force scan order);
          * allowed (B, G) — per-query mask: row j is scannable for
                             query i iff j's cluster is in i's selection;
          * rows_scanned   — centroids scored + rows gathered, the
                             work term of the scan-proportional latency
                             model (DESIGN.md §12).
        """
        from repro.core.seri import topk_desc

        nonempty = self.counts > 0
        n_live = int(nonempty.sum())
        if n_live == 0:
            return None
        nprobe = n_live if self.cfg.nprobe is None \
            else min(self.cfg.nprobe, n_live)
        cs = np.where(nonempty[None, :],
                      np.asarray(q, np.float32) @ self.centroids.T, NEG)
        sel, svals = topk_desc(cs, nprobe)               # (B, nprobe)
        ok = svals > NEG / 2       # nprobe ≤ n_live ⇒ all True; belt+braces
        uniq = np.unique(sel[ok])
        parts = [self._member_lists[c] for c in uniq
                 if self._member_lists[c]]
        if not parts:
            return None
        g_rows = np.sort(np.concatenate(
            [np.asarray(p, dtype=np.int64) for p in parts]
        ))
        onehot = np.zeros((q.shape[0], self.cfg.n_clusters), bool)
        np.put_along_axis(onehot, sel, ok, axis=1)
        allowed = onehot[:, self.assign[g_rows]]
        return g_rows, allowed, len(g_rows) + n_live

    # ----------------------------------------------------- kernel layout

    def kernel_buckets(self, index, quant: bool = False):
        """Cluster-major bucketed copy of the index's embedding rows for
        the Pallas routed-scan kernel (``kernels/ann_topk_ivf``): every
        cluster's members land in one fixed-capacity (padded) bucket so
        the kernel's scalar-prefetch grid can DMA exactly the selected
        buckets. Rebuilt lazily after mutations; on a real TPU this
        layout would be maintained incrementally in HBM.

        Returns ``(emb_or_(emb_q, scales), bucket_rows, bucket_valid)``
        with shapes (C, cap, D) / (C, cap) / (C, cap).
        """
        if self._bucket_cache is not None:
            return self._bucket_cache
        members = self.members()
        c = self.cfg.n_clusters
        top = int(max((len(m) for m in members), default=1))
        cap = 1 << max(3, int(np.ceil(np.log2(max(1, top)))))
        bucket_rows = np.full((c, cap), -1, np.int32)
        bucket_valid = np.zeros((c, cap), np.int32)
        if quant:
            emb = np.zeros((c, cap, self.dim), np.int8)
            scales = np.zeros((c, cap), np.float32)
        else:
            emb = np.zeros((c, cap, self.dim), np.float32)
        for ci, mem in enumerate(members):
            m = len(mem)
            if not m:
                continue
            # ascending row order within a bucket: the kernel's per-
            # bucket argmax then breaks exact-score ties by lowest row,
            # matching topk_desc's tie rule (ties BETWEEN buckets merge
            # in centroid-score order — a kernel-backend caveat the
            # numpy path does not share)
            mem = np.sort(mem)
            bucket_rows[ci, :m] = mem
            bucket_valid[ci, :m] = 1
            if quant:
                emb[ci, :m] = index.emb_q[mem]
                scales[ci, :m] = index.scale[mem]
            else:
                emb[ci, :m] = index.emb[mem]
        payload = (emb, scales) if quant else emb
        self._bucket_cache = (payload, bucket_rows, bucket_valid)
        return self._bucket_cache

    def kernel_shard_buckets(self, index, quant: bool = False):
        """Shard-major re-slice of :meth:`kernel_buckets` for the
        shard-parallel kernels (``kernels/ann_topk_sharded``): shard
        s's slice holds its owned cluster range, zero-padded to the
        widest ownership span so the (S, Cmax, cap[, D]) stacks can be
        laid out across the mesh's shard axis.

        Returns ``(payload, shard_rows, shard_valid, bounds)`` where
        payload is (S, Cmax, cap, D) fp32 — or ((S, Cmax, cap, D) int8,
        (S, Cmax, cap) fp32 scales) when ``quant`` — shard_rows /
        shard_valid are (S, Cmax, cap), and bounds is the (S+1,) global
        cluster-id prefix (shard s owns [bounds[s], bounds[s+1])).
        Cached against the underlying bucket layout: any mutation or
        rebalance invalidates it.
        """
        base = self.kernel_buckets(index, quant=quant)
        if self._shard_cache is not None and self._shard_cache[0] is base:
            return self._shard_cache[1]
        payload, bucket_rows, bucket_valid = base
        s, bounds = self.n_shards, self.shard_bounds
        cmax = int(max(1, np.diff(bounds).max()))
        cap = bucket_rows.shape[1]
        shard_rows = np.full((s, cmax, cap), -1, np.int32)
        shard_valid = np.zeros((s, cmax, cap), np.int32)
        if quant:
            emb_q, scales = payload
            se = np.zeros((s, cmax, cap, self.dim), np.int8)
            ss = np.zeros((s, cmax, cap), np.float32)
        else:
            se = np.zeros((s, cmax, cap, self.dim), np.float32)
        for si in range(s):
            lo, hi = int(bounds[si]), int(bounds[si + 1])
            w = hi - lo
            if w == 0:
                continue
            shard_rows[si, :w] = bucket_rows[lo:hi]
            shard_valid[si, :w] = bucket_valid[lo:hi]
            if quant:
                se[si, :w] = emb_q[lo:hi]
                ss[si, :w] = scales[lo:hi]
            else:
                se[si, :w] = payload[lo:hi]
        out = ((se, ss) if quant else se, shard_rows, shard_valid,
               bounds.astype(np.int64))
        self._shard_cache = (base, out)
        return out
