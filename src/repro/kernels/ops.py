"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU the launchers pass interpret=False for the Mosaic lowering. The
pure-jnp oracles live in kernels.ref; tests sweep shapes/dtypes and
assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ann_topk import ann_topk
from repro.kernels.ann_topk_quant import ann_topk_quant
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd

__all__ = ["ann_topk", "ann_topk_quant", "flash_attention_fwd",
           "decode_attention", "ann_topk_jit", "ann_topk_quant_jit"]


_B_ALIGN = 8  # fp32 sublane count: pad the query block to aligned shapes


def ann_topk_jit(emb, active, q, k: int = 4):
    """VectorIndex backend adapter: (D,) or (B, D) queries -> (sims, rows).

    The batched cache runtime sends variable-size query blocks (engine
    micro-batches, DESIGN.md §8); padding B up to a multiple of the fp32
    sublane count keeps the kernel's (B, D) block shape TPU-aligned and
    bounds jit retraces to one per padded size. Each query column is
    reduced independently inside the kernel, so the zero-padded rows are
    sliced off without affecting real results."""
    single = q.ndim == 1
    if single:
        q = q[None]
    b = q.shape[0]
    pad = (-b) % _B_ALIGN
    if pad:
        q = jnp.pad(jnp.asarray(q), ((0, pad), (0, 0)))
    vals, rows = ann_topk(
        jnp.asarray(emb), jnp.asarray(active), jnp.asarray(q), k
    )
    vals, rows = vals[:b], rows[:b]
    if single:
        return vals[0], rows[0]
    return vals, rows


def ann_topk_quant_jit(emb_q, scales, active, qq, q_scales, k: int = 16):
    """Warm-tier QuantIndex backend adapter (coarse phase only).

    Queries arrive already int8-quantized — the host quantizes them with
    the same routine the numpy path uses, so both backends score identical
    integers. B is padded to the sublane multiple like ``ann_topk_jit``;
    padded query lanes carry scale 0 (all-zero scores) and are sliced off.
    """
    b = qq.shape[0]
    pad = (-b) % _B_ALIGN
    if pad:
        qq = jnp.pad(jnp.asarray(qq), ((0, pad), (0, 0)))
        q_scales = jnp.pad(jnp.asarray(q_scales), (0, pad))
    vals, rows = ann_topk_quant(
        jnp.asarray(emb_q), jnp.asarray(scales), jnp.asarray(active),
        jnp.asarray(qq), jnp.asarray(q_scales), k,
    )
    return vals[:b], rows[:b]
