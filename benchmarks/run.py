"""Benchmark harness — one function per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig7,table5] [--smoke]
      [--json]

``--smoke`` shrinks benchmarks that support it (the federation and tiered
sweeps) to CI-sized problems; regressions still fail the run. ``--json``
additionally writes one machine-readable ``BENCH_<name>.json`` per
benchmark (rows of name/us_per_call/derived), so the perf trajectory is
tracked across PRs — the file is written even when a regression gate
fails the run.
"""
import argparse
import inspect
import json
import os
import subprocess
import sys
import time

from benchmarks import common, figures, kernels_bench


def git_sha() -> str:
    """Short commit id of the repo the benchmark ran from, for the
    BENCH_*.json trajectory (rows from different PRs must be tellable
    apart even after the artifacts are copied around)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


ALL = {
    "fig7": figures.fig7_skewed,
    "fig8": figures.fig8_trend,
    "fig9": figures.fig9_swebench,
    "fig10": figures.fig10_concurrency,
    "fig11": figures.fig11_breakdown,
    "fig12": figures.fig12_ratelimit,
    "table4": figures.table4_ratelimit_ablation,
    "table5": figures.table5_cost,
    "fig13": figures.fig13_accuracy,
    "table6": figures.table6_lcfu,
    "table7": figures.table7_colocation,
    "recal": figures.recalibration_overhead,
    "federation": figures.federation_sweep,
    "tiered": figures.tiered_sweep,
    "freshness": figures.freshness_sweep,
    "stage1_scaling": figures.stage1_scaling,
    "judge_colocation": figures.judge_colocation,
    "obs_trace": figures.obs_trace,
    "obs_timeseries": figures.obs_timeseries,
    "overload": figures.overload,
    "kernel_ann": kernels_bench.kernel_ann,
    "kernel_flash": kernels_bench.kernel_flash,
    "cache_path": kernels_bench.cache_path_calibration,
    "cache_batched": kernels_bench.cache_batched,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes (CI regression gate)")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<name>.json per benchmark")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="write §15 span traces (TRACE_*.jsonl + "
                         "Perfetto-loadable TRACE_*.chrome.json) for "
                         "traceable runs into DIR")
    args = ap.parse_args()
    if args.trace is not None:
        os.makedirs(args.trace, exist_ok=True)
        common.TRACE_DIR = args.trace
    names = list(ALL) if not args.only else args.only.split(",")
    sha = git_sha() if args.json else "unknown"
    devices = 0
    if args.json:
        # visible jax device count (XLA_FLAGS host-platform simulation
        # included) — sharded stage-1 rows (DESIGN.md §13) are only
        # comparable across runs with the same mesh width
        import jax
        devices = jax.device_count()
    print("name,us_per_call,derived")
    t0 = time.time()
    for n in names:
        if n not in ALL:
            print(f"unknown benchmark {n!r}", file=sys.stderr)
            sys.exit(2)
        t = time.time()
        fn = ALL[n]
        common.reset_rows()
        try:
            if args.smoke and "smoke" in inspect.signature(fn).parameters:
                fn(smoke=True)
            else:
                fn()
        finally:
            # write rows even when a regression gate SystemExits, so a
            # failing CI run still leaves the measurements behind. Every
            # row is stamped with the git sha and the jax device count
            # (and carries its seed / shard / nprobe config plus the
            # wall_s / trace_path stamps emit() adds) so BENCH_*.json
            # files from different PRs diff cleanly; the top-level
            # wall_s records the whole benchmark's real runtime.
            if args.json:
                rows = [dict(r, git_sha=sha, devices=devices)
                        for r in common.ROWS]
                with open(f"BENCH_{n}.json", "w") as f:
                    json.dump({"name": n, "git_sha": sha,
                               "devices": devices,
                               "wall_s": round(time.time() - t, 3),
                               "rows": rows}, f,
                              indent=1, default=str)
        print(f"# {n} done in {time.time()-t:.1f}s", file=sys.stderr)
    print(f"# all benchmarks done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
