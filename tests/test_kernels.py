"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True
executes the Pallas kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ann_topk import ann_topk
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import (
    ann_topk_ref, decode_attention_ref, flash_attention_ref,
)


@pytest.mark.parametrize(
    "n,d,b,k",
    [(1000, 128, 4, 4), (513, 64, 1, 8), (2048, 256, 16, 4), (64, 32, 2, 4)],
)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_ann_topk(n, d, b, k, dtype, rng):
    emb = rng.standard_normal((n, d)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    act = rng.random(n) > 0.2
    q = rng.standard_normal((b, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    embj = jnp.asarray(emb).astype(dtype)
    qj = jnp.asarray(q).astype(dtype)
    v1, i1 = ann_topk(embj, jnp.asarray(act), qj, k)
    v2, i2 = ann_topk_ref(embj, jnp.asarray(act), qj, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=2e-5)
    # indices may differ only where scores tie (bf16); check score parity
    s = (embj.astype(jnp.float32) @ qj.astype(jnp.float32).T)
    for bi in range(b):
        sv1 = np.asarray(s[np.asarray(i1)[bi], bi])
        sv2 = np.asarray(s[np.asarray(i2)[bi], bi])
        np.testing.assert_allclose(sv1, sv2, atol=2e-5)


@pytest.mark.parametrize(
    "b,sq,sk,kv,g,dh,causal,win,bq,bk",
    [
        (2, 256, 256, 2, 2, 32, True, None, 64, 64),
        (1, 128, 128, 4, 1, 64, True, 48, 64, 32),
        (2, 128, 256, 2, 4, 16, False, None, 128, 128),
        (1, 512, 512, 1, 8, 128, True, None, 256, 128),
    ],
)
def test_flash_attention(b, sq, sk, kv, g, dh, causal, win, bq, bk, rng):
    q = jnp.asarray(rng.standard_normal((b, sq, kv, g, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sk, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sk, kv, dh)), jnp.float32)
    scale = 1 / np.sqrt(dh)
    o1 = flash_attention_fwd(q, k, v, scale=scale, causal=causal,
                             window=win, bq=bq, bk=bk)
    o2 = flash_attention_ref(q, k, v, scale, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


def test_flash_attention_bf16(rng):
    b, s, kv, g, dh = 1, 128, 2, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, kv, g, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.bfloat16)
    o1 = flash_attention_fwd(q, k, v, scale=0.17, bq=64, bk=64)
    o2 = flash_attention_ref(q, k, v, 0.17)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), atol=3e-2
    )


@pytest.mark.parametrize(
    "b,kv,g,dh,s,pos,bs",
    [
        (2, 2, 4, 32, 256, 100, 64),
        (1, 4, 1, 64, 512, 511, 128),
        (4, 1, 8, 16, 128, 0, 128),
        (1, 8, 16, 128, 1024, 700, 256),
    ],
)
def test_decode_attention(b, kv, g, dh, s, pos, bs, rng):
    q = jnp.asarray(rng.standard_normal((b, kv, g, dh)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    scale = 1 / np.sqrt(dh)
    o1 = decode_attention(q, kc, vc, pos, scale=scale, bs=bs)
    o2 = decode_attention_ref(q, kc, vc, pos, scale)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


@pytest.mark.parametrize("c,cap,d,b,nprobe,k", [
    (8, 16, 32, 4, 3, 2), (16, 64, 64, 8, 5, 4), (4, 8, 16, 1, 4, 3),
])
def test_ann_topk_ivf(c, cap, d, b, nprobe, k, rng):
    """Scalar-prefetch routed scan vs a per-(query, probe) numpy oracle:
    identical values; indices may differ only on fully-masked (NEG)
    slots, which callers filter via vals > NEG/2."""
    from repro.kernels.ann_topk_ivf import NEG, ann_topk_ivf

    buckets = rng.standard_normal((c, cap, d)).astype(np.float32)
    valid = (rng.random((c, cap)) > 0.3).astype(np.int32)
    q = rng.standard_normal((b, d)).astype(np.float32)
    sel = np.stack([
        rng.choice(c, nprobe, replace=False) for _ in range(b)
    ]).astype(np.int32)
    en = (rng.random((b, nprobe)) > 0.2).astype(np.int32)
    vals, idx = ann_topk_ivf(jnp.asarray(sel), jnp.asarray(en),
                             jnp.asarray(q), jnp.asarray(buckets),
                             jnp.asarray(valid), k)
    vals, idx = np.asarray(vals), np.asarray(idx)
    for bi in range(b):
        for j in range(nprobe):
            s = buckets[sel[bi, j]] @ q[bi]
            s = np.where((valid[sel[bi, j]] > 0) & (en[bi, j] > 0), s, NEG)
            order = np.argsort(-s, kind="stable")[:k]
            np.testing.assert_allclose(vals[bi, j], s[order], atol=2e-5)
            # indices may differ where scores tie to fp ulp (the
            # ann_topk test idiom): check score parity at chosen slots
            live = s[order] > NEG / 2
            np.testing.assert_allclose(
                s[idx[bi, j][live]], s[order][live], atol=2e-5
            )


def test_ann_topk_ivf_quant(rng):
    """int8 routed coarse scan: exact int32 scores rescaled in the same
    order as the numpy path (row scale, then query scale)."""
    from repro.core.tiers import quantize_rows
    from repro.kernels.ann_topk_ivf import NEG, ann_topk_ivf_quant

    c, cap, d, b, nprobe, k = 8, 32, 48, 4, 4, 6
    emb = rng.standard_normal((c, cap, d)).astype(np.float32)
    bq, bscale = quantize_rows(emb.reshape(-1, d))
    bq = bq.reshape(c, cap, d)
    bscale = bscale.reshape(c, cap).astype(np.float32)
    valid = (rng.random((c, cap)) > 0.25).astype(np.int32)
    q = rng.standard_normal((b, d)).astype(np.float32)
    qq, qs = quantize_rows(q)
    sel = np.stack([
        rng.choice(c, nprobe, replace=False) for _ in range(b)
    ]).astype(np.int32)
    en = np.ones((b, nprobe), np.int32)
    vals, idx = ann_topk_ivf_quant(
        jnp.asarray(sel), jnp.asarray(en), jnp.asarray(qq),
        jnp.asarray(qs), jnp.asarray(bq), jnp.asarray(bscale),
        jnp.asarray(valid), k,
    )
    vals, idx = np.asarray(vals), np.asarray(idx)
    for bi in range(b):
        for j in range(nprobe):
            s = (bq[sel[bi, j]].astype(np.int32) @ qq[bi].astype(np.int32)
                 ).astype(np.float32)
            s = s * bscale[sel[bi, j]]
            s = s * qs[bi]
            s = np.where(valid[sel[bi, j]] > 0, s, NEG)
            order = np.argsort(-s, kind="stable")[:k]
            np.testing.assert_allclose(vals[bi, j], s[order], atol=0)
            live = s[order] > NEG / 2
            assert np.array_equal(idx[bi, j][live], order[live])
