"""Trace export: JSONL + Chrome trace-event JSON (DESIGN.md §15).

Both writers are byte-deterministic: spans are written in the tracer's
emission order (which is clock-event order, itself deterministic),
every ``json.dumps`` pins ``sort_keys=True`` and compact separators,
and floats serialize via Python's ``repr`` (shortest round-trip form) —
so same seed ⇒ byte-identical files, and a trace diff IS a regression
signal.

The Chrome file loads directly in Perfetto (https://ui.perfetto.dev →
"Open trace file") or ``chrome://tracing``: one process row per region,
one thread row per request id, complete events (``ph: "X"``) with
microsecond timestamps.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.obs.trace import BACKGROUND, Tracer


def write_jsonl(tracer: Tracer, path: str) -> str:
    """One span per line: ``{"rid", "name", "t0", "t1", "dur", "region",
    "tag"}`` (tag omitted when absent)."""
    with open(path, "w") as f:
        for rid, name, t0, t1, region, tag in tracer.spans:
            row = {
                "rid": rid, "name": name, "t0": t0, "t1": t1,
                "dur": t1 - t0, "region": region,
            }
            if tag is not None:
                row["tag"] = tag
            f.write(json.dumps(row, sort_keys=True,
                               separators=(",", ":")) + "\n")
    return path


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    """Chrome trace-event JSON array: ``pid`` = region, ``tid`` = rid
    (background spans land on a dedicated ``tid``), times in µs."""
    events = []
    for rid, name, t0, t1, region, tag in tracer.spans:
        ev = {
            "name": name,
            "ph": "X",
            "ts": t0 * 1e6,
            "dur": (t1 - t0) * 1e6,
            "pid": region,
            "tid": rid if rid != BACKGROUND else 999999,
            "args": {} if tag is None else {"tag": tag},
        }
        events.append(ev)
    meta = [
        {"name": "process_name", "ph": "M", "pid": pid,
         "args": {"name": f"region {pid}"}}
        for pid in sorted({s[4] for s in tracer.spans})
    ]
    with open(path, "w") as f:
        json.dump({"traceEvents": meta + events,
                   "displayTimeUnit": "ms"},
                  f, sort_keys=True, separators=(",", ":"))
    return path


def _canon_dumps(obj) -> str:
    """The repo's canonical JSON form: sorted keys, compact separators,
    floats via ``repr`` — same seed ⇒ byte-identical artifact."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def write_timeseries(samples: list[dict], path: str) -> str:
    """One telemetry sample per line (DESIGN.md §16), in emission order
    (= virtual-time order). Rows come straight from
    :class:`~repro.obs.sampler.TimeSeriesSampler.samples` — pure-Python
    scalars only, so serialization is byte-deterministic."""
    with open(path, "w") as f:
        for row in samples:
            f.write(_canon_dumps(row) + "\n")
    return path


def write_alerts(alerts: list[dict], path: str) -> str:
    """One SLO breach/recovery alert per line, in emission order (the
    :class:`~repro.obs.slo.SLOMonitor`'s deterministic sample-order ×
    declaration-order). An empty alert list writes an empty file — the
    steady-baseline gate byte-compares against exactly that."""
    with open(path, "w") as f:
        for a in alerts:
            f.write(_canon_dumps(a) + "\n")
    return path


def export_timeseries(sampler, monitor, prefix: str) -> dict[str, str]:
    """Write ``<prefix>.timeseries.jsonl`` (always) and
    ``<prefix>.alerts.jsonl`` (when a monitor ran, even if it raised
    nothing)."""
    out = {"timeseries": write_timeseries(sampler.samples,
                                          prefix + ".timeseries.jsonl")}
    if monitor is not None:
        out["alerts"] = write_alerts(monitor.alerts,
                                     prefix + ".alerts.jsonl")
    return out


def export_trace(tracer: Tracer, prefix: str) -> dict[str, str]:
    """Write both formats next to each other:
    ``<prefix>.jsonl`` + ``<prefix>.chrome.json``."""
    return {
        "jsonl": write_jsonl(tracer, prefix + ".jsonl"),
        "chrome": write_chrome_trace(tracer, prefix + ".chrome.json"),
    }
