"""gemma3-12b [dense] — hf:google/gemma-3 family (pattern per tech report).

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
5:1 local(sliding-window 1024):global interleave, 128k context:
superblock = 5 sliding + 1 global, repeated 8x. head_dim=256 (gemma3 uses
wide heads, d_model/n_heads != head_dim).
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig

NAME = "gemma3-12b"


@register(NAME)
def config() -> ModelConfig:
    local = AttnConfig(
        n_heads=16, n_kv_heads=8, head_dim=256,
        window=1024, rope_theta=10_000.0,
    )
    glob = AttnConfig(
        n_heads=16, n_kv_heads=8, head_dim=256, rope_theta=1_000_000.0
    )
    mk = lambda a: LayerSpec(kind="attn", attn=a, d_ff=15360)
    return ModelConfig(
        name=NAME,
        family="dense",
        d_model=3840,
        vocab_size=262144,
        blocks=(mk(local),) * 5 + (mk(glob),),
        n_repeat=8,  # 8 x 6 = 48 layers
        tie_embeddings=True,
        # 5/6 sliding-window layers -> long-context decode is dominated by
        # the ring buffers; global layers keep full KV. Treated as
        # sub-quadratic for the long_500k cell (see DESIGN.md §4).
        sub_quadratic=True,
    )
