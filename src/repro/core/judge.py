"""The lightweight semantic judge (LSM) — Seri stage 2 (paper §4.2).

Given (new query, cached result) the judge emits a confidence score
S_lsm ∈ [0,1] that the cached result answers the query, plus a staticity
estimate (1–10) at admission time.

* ``OracleJudge`` — decision-faithful judge for behavioural experiments:
  knows the synthetic world's ground-truth intent equivalence and flips
  decisions with configurable TPR/FPR noise. Its *scores* are drawn from
  two calibrated beta-like distributions so threshold recalibration
  (Algorithm 1) has a real precision curve to sweep.
* ``ModelJudge`` — a real tiny cross-encoder in JAX (prefill-only, single
  score token — the profile that makes co-location cheap, §4.4). With
  random weights its decisions are meaningless; it exists to measure the
  judge's true compute footprint and to drive the co-location scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class JudgeVerdict:
    score: float
    equivalent: bool      # score >= threshold decided by caller (Seri)
    staticity: int = 5


class OracleJudge:
    """Ground-truth-backed judge with calibrated score noise.

    Score noise is seeded per **(pair, nth-scoring-of-that-pair)** from a
    stable hash of the pair text — not drawn from one shared stream — so
    scores do not depend on how requests are micro-batched, reordered
    across lanes, or interleaved with other requests (DESIGN.md §8:
    batched and scalar execution stay bit-identical). Re-scoring the
    same pair later re-rolls (the judge's borderline mistakes stay
    transient, so threshold recalibration sees fresh noise, as with the
    original shared-stream model)."""

    def __init__(self, world, accuracy: float = 0.98, seed: int = 0,
                 max_pairs: int = 65536):
        self.world = world
        self.seed = seed
        # score distributions: equivalent pairs ~ high, others ~ low
        self.acc = accuracy
        # nth-scoring counter per pair, LRU-bounded at max_pairs (same
        # idiom as MarkovPrefetcher._prev): an evicted pair that comes
        # back re-rolls from n=0, which only perturbs borderline-noise
        # replay on workloads with > max_pairs distinct live pairs
        self.max_pairs = max_pairs
        self._pair_counts: dict = {}

    @staticmethod
    def _u01(x: int, salt: int) -> float:
        """splitmix64 finalizer -> uniform in [0, 1). Counter-based
        hashing is ~10× cheaper than constructing a Generator per pair,
        which matters because scoring sits on the hot lookup path."""
        m = (1 << 64) - 1
        x = (x + salt * 0x9E3779B97F4A7C15) & m
        x ^= x >> 30
        x = (x * 0xBF58476D1CE4E5B9) & m
        x ^= x >> 27
        x = (x * 0x94D049BB133111EB) & m
        x ^= x >> 31
        return x / 2.0**64

    def _pair_score(self, q: str, c: str) -> float:
        import zlib

        n = self._pair_counts.pop((q, c), 0)
        self._pair_counts[(q, c)] = n + 1  # reinsert = move to LRU tail
        if len(self._pair_counts) > self.max_pairs:
            self._pair_counts.pop(next(iter(self._pair_counts)))
        ent = zlib.crc32(f"{q}\x00{c}".encode())
        base = (ent << 32) ^ (n << 8) ^ (self.seed & 0xFF)
        same = self.world.same_intent(q, c)
        correct = self._u01(base, 1) < self.acc
        positive = same if correct else not same
        # Beta(1, b) via inverse CDF: x = 1 - (1-u)^(1/b)
        u = self._u01(base, 2)
        if positive:
            # P(score < 0.9) ≈ 0.04 — a few true matches fall below
            # τ_lsm=0.9; with capacity/TTL misses this lands at the
            # paper's ~85-88% steady-state hit rates
            return (1.0 - u) ** (1.0 / 30.0)
        return 1.0 - (1.0 - u) ** (1.0 / 19.0)

    def score_pairs(
        self, queries: Sequence[str], cached_keys: Sequence[str]
    ) -> np.ndarray:
        """S_lsm per (query, cached) pair."""
        out = np.empty(len(queries), np.float32)
        for i, (q, c) in enumerate(zip(queries, cached_keys)):
            out[i] = self._pair_score(q, c)
        return out

    def staticity(self, query: str) -> int:
        return self.world.staticity(query)


class ModelJudge:
    """Tiny cross-encoder: prefill-only classification (one score)."""

    def __init__(self, cfg=None, max_len: int = 128, seed: int = 1):
        import jax
        import jax.numpy as jnp

        from repro.configs import get_config, shrink
        from repro.core.embedder import byte_tokens
        from repro.models.lm import LM
        from repro.nn.param import init_tree
        from repro.nn.sharding import ShardCtx

        cfg = cfg or shrink(get_config("qwen3-0.6b"), d_model=128, vocab=512,
                            n_repeat=2)
        self.cfg = cfg
        self.max_len = max_len
        self._byte_tokens = byte_tokens
        self.lm = LM(cfg)
        self.ctx = ShardCtx(None)
        key = jax.random.PRNGKey(seed)
        self.params = init_tree(key, self.lm.param_specs())

        def score(params, tokens):
            x = self.lm._embed(self.ctx, params, tokens)
            pos = self.lm._positions(tokens)
            x, _, _ = self.lm._run_stack(self.ctx, params, x, pos)
            # single-token classification readout (prefill-only profile)
            logit = jnp.mean(x[:, -1, :].astype(jnp.float32), axis=-1)
            return jax.nn.sigmoid(logit)

        self._score = jax.jit(score)
        self._jnp = jnp

    def score_pairs(self, queries, cached_keys) -> np.ndarray:
        toks = np.stack([
            self._byte_tokens(f"{q} [SEP] {c}", self.max_len)
            for q, c in zip(queries, cached_keys)
        ]) % self.cfg.vocab_size
        return np.asarray(self._score(self.params, self._jnp.asarray(toks)),
                          np.float32)

    def staticity(self, query: str) -> int:
        # stable across processes (Python's hash() is salted per run,
        # which made admission TTLs irreproducible)
        import zlib

        return 1 + (zlib.crc32(query.encode()) % 10)


class HybridJudge:
    """Oracle decisions + model compute (used by e2e benchmarks so both the
    semantics AND the measured judge cost are faithful).

    Kept for back-compat; ``core/judge_pipeline.JudgePipeline(oracle,
    compute=model)`` is the same shim plus admission and cost derivation,
    and is what the serving stack threads through."""

    def __init__(self, oracle: OracleJudge, model: Optional[ModelJudge] = None):
        self.oracle = oracle
        self.model = model

    def score_pairs(self, queries, cached_keys) -> np.ndarray:
        if self.model is not None:
            self.model.score_pairs(queries, cached_keys)  # pay the compute
        return self.oracle.score_pairs(queries, cached_keys)

    def staticity(self, query: str) -> int:
        return self.oracle.staticity(query)
