"""Semantic Element (SE) — Cortex's core caching unit (paper §4.1, Fig 5).

An SE encapsulates one discrete agent↔tool interaction: the agent's query
(semantic key), the retrieved knowledge (value), the embedding fingerprint,
and the performance-aware metadata driving eviction/TTL decisions:

  * staticity  1–10  — expected validity duration class (judge-estimated):
                       10 = stable fact, 5 = moderate, 1 = ephemeral.
  * cost ($), latency (s) — what the remote fetch cost; retained items
                       with high fetch cost are worth more per byte.
  * freq       — confirmed semantic-hit count (only validated hits count).
  * size       — bytes of the cached value.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import numpy as np


@dataclasses.dataclass
class SemanticElement:
    se_id: int
    key: str                       # the tool-call query (from <search>/<tool>)
    value: Any                     # retrieved knowledge (from <info>)
    embedding: np.ndarray          # unit-norm semantic fingerprint
    staticity: int                 # 1..10
    cost: float                    # $ per original remote fetch
    latency: float                 # seconds of the original remote fetch
    size: int                      # bytes
    created_at: float
    expires_at: float
    freq: int = 0
    last_access: float = 0.0
    prefetched: bool = False       # entered via prefetch (freq starts at 0)
    intent: Optional[int] = None   # synthetic-world ground-truth intent id

    def expired(self, now: float) -> bool:
        return now >= self.expires_at

    def ttl_remaining(self, now: float) -> float:
        return self.expires_at - now

    def lcfu_score(self, now: float) -> float:
        """Algorithm 2 CalScore: log-composite value per byte."""
        if self.size == 0 or self.ttl_remaining(now) <= 0:
            return 0.0
        score = (
            math.log(self.freq + 1.0)
            * math.log(self.cost * 1e3 + 1.0)
            * math.log(self.latency + 1.0)
            * math.log(self.staticity + 1.0)
        )
        return score / self.size


def ttl_from_staticity(staticity: int, max_ttl: float,
                       min_ttl: float = 30.0) -> float:
    """Map the 1–10 staticity class to a TTL. Exponential in the class so
    ephemeral items (1–3) expire in minutes while stable facts (9–10) live
    at the user-defined ceiling (paper §4.1/§4.3 aging mechanism)."""
    frac = (max(1, min(10, staticity)) - 1) / 9.0
    return min_ttl * (max_ttl / min_ttl) ** frac
