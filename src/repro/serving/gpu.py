"""Accelerator model for the serving engine: processor-sharing lanes with
an MPS-style asymmetric static partition (paper §4.4, Fig 6).

TPU adaptation (DESIGN.md §3): the coarse 80/20 CUDA-MPS split becomes a
token-budget split of one chip's serving capacity; the fine-grained
guardrail (agent queue served exhaustively, judge only when the agent has
spare slots) is the same policy, expressed in the engine's dispatcher.

Each lane is a processor-sharing server: n active jobs each progress at
min(v1, capacity/n) token-equivalents per second — capturing both the
single-stream decode speed ceiling and the aggregate batched throughput of
continuous batching.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Callable, Optional


@dataclasses.dataclass
class Job:
    jid: int
    tokens: float          # remaining token-equivalents
    callback: Callable     # fn(now) fired at completion
    enqueued: float = 0.0
    started: float = 0.0


class PSLane:
    """Processor-sharing lane with single-stream cap and slot limit."""

    def __init__(self, capacity: float, v1: float, slots: int = 64):
        self.capacity = capacity
        self.v1 = v1
        self.slots = slots
        self.active: dict[int, Job] = {}
        # FIFO admission queue: deque so the promote-side popleft is O(1)
        self.queue: collections.deque[Job] = collections.deque()
        self.t_last = 0.0
        self.version = 0
        self._ids = itertools.count()
        self.busy_tokens = 0.0  # processed token-equivalents (utilisation)

    def _running(self) -> list:
        return [j for j in self.active.values() if j.tokens > 1e-9]

    def _rate(self) -> float:
        n = len(self._running())
        if n == 0:
            return 0.0
        return min(self.v1, self.capacity / n)

    def advance(self, now: float) -> None:
        """Piecewise-exact processor sharing: within [t_last, now] the rate
        redistributes at every internal job completion, so work accounting
        is exact even when completions aren't reaped promptly."""
        while now > self.t_last:
            running = self._running()
            if not running:
                break
            r = min(self.v1, self.capacity / len(running))
            rem_min = min(j.tokens for j in running)
            t_next = self.t_last + rem_min / r
            t_step = min(now, t_next)
            dt = t_step - self.t_last
            for j in running:
                j.tokens -= r * dt
            self.busy_tokens += r * dt * len(running)
            self.t_last = t_step
        self.t_last = max(self.t_last, now)

    def submit(self, now: float, tokens: float, callback) -> int:
        self.advance(now)
        jid = next(self._ids)
        job = Job(jid, tokens, callback, enqueued=now)
        if len(self.active) < self.slots:
            job.started = now
            self.active[jid] = job
        else:
            self.queue.append(job)
        self.version += 1
        return jid

    def _promote(self, now: float) -> None:
        while self.queue and len(self.active) < self.slots:
            job = self.queue.popleft()
            job.started = now
            self.active[job.jid] = job

    def next_completion(self) -> Optional[float]:
        if not self.active:
            return None
        if any(j.tokens <= 1e-9 for j in self.active.values()):
            return self.t_last  # finished-but-unreaped: fire immediately
        r = self._rate()
        rem = min(j.tokens for j in self.active.values())
        return self.t_last + max(rem, 0.0) / r

    def complete_due(self, now: float) -> list[Job]:
        """Advance to `now`, pop every finished job, promote queue."""
        self.advance(now)
        done = [j for j in self.active.values() if j.tokens <= 1e-9]
        for j in done:
            del self.active[j.jid]
        if done:
            self._promote(now)
            self.version += 1
        return done

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def n_waiting(self) -> int:
        return len(self.queue)


@dataclasses.dataclass
class GPUConfig:
    capacity: float = 3000.0   # aggregate token-eq/s (continuous batching)
    v1: float = 800.0          # single-stream token-eq/s
    agent_share: float = 0.8   # MPS-style static partition
    judge_share: float = 0.2
    agent_slots: int = 48
    judge_slots: int = 16
    colocated: bool = True     # False = judge on its own dedicated chip


class GPU:
    def __init__(self, cfg: GPUConfig):
        self.cfg = cfg
        if cfg.colocated:
            self.agent = PSLane(
                cfg.capacity * cfg.agent_share, cfg.v1, cfg.agent_slots
            )
            self.judge = PSLane(
                cfg.capacity * cfg.judge_share, cfg.v1, cfg.judge_slots
            )
            self.n_chips = 1
        else:
            self.agent = PSLane(cfg.capacity, cfg.v1, cfg.agent_slots)
            self.judge = PSLane(cfg.capacity, cfg.v1, cfg.judge_slots)
            self.n_chips = 2

    def rebalance(self, now: float) -> bool:
        """Work-conserving co-location (the TPU time-multiplexing model,
        DESIGN.md §3): the agent reclaims the judge's share whenever the
        judge lane is idle; the static 80/20 split is the floor the judge
        is guaranteed when busy. Returns True if capacities changed."""
        if not self.cfg.colocated:
            return False
        want = self.cfg.capacity * (
            self.cfg.agent_share if self.judge.n_active else 1.0
        )
        if abs(want - self.agent.capacity) < 1e-9:
            return False
        self.agent.advance(now)
        self.agent.capacity = want
        self.agent.version += 1
        return True

    def occupancy(self) -> dict:
        """Instantaneous lane-occupancy gauges (DESIGN.md §16): active
        jobs, admission-queue depth, and active-slot utilisation per
        lane. Pure reads — safe from the telemetry sampler."""
        return {
            "agent_active": self.agent.n_active,
            "agent_waiting": self.agent.n_waiting,
            "agent_util": self.agent.n_active / self.agent.slots,
            "judge_active": self.judge.n_active,
            "judge_waiting": self.judge.n_waiting,
            "judge_util": self.judge.n_active / self.judge.slots,
        }

    def judge_admission_ok(self) -> bool:
        """Fine-grained guardrail: defer judge work while the agent lane is
        saturated (queue backed up behind full slots)."""
        if not self.cfg.colocated:
            return True
        return self.agent.n_waiting == 0


def judge_batch_tokens(base: float, m: int, marginal: float) -> float:
    """Token cost of a judge micro-batch of m requests (paper §4.4).

    Judge jobs are prefill-only classifications over near-identical
    prompts; co-batching them into one accelerator launch shares the
    instruction/prompt prefill, so request 2..m each pay only a
    ``marginal`` fraction of the base cost. m=1 degenerates to the
    unbatched cost."""
    if m <= 0:
        return 0.0
    return base * (1.0 + marginal * (m - 1))
