"""Observability layer (DESIGN.md §15): tracer, registry, conservation.

The §15 contract has four legs, each tested here:

* **conservation** — every completed request's spans tile
  ``[arrival, t_done]`` with exact float ``==`` at every boundary, so
  the telescoped total equals ``rec.latency`` bit-for-bit — on plain,
  tiered/banded, and federated runs;
* **neutrality** — tracing is observational: a traced run's summary is
  byte-identical to the untraced run at the same seed;
* **determinism** — same seed ⇒ byte-identical JSONL and Chrome-trace
  artifacts;
* **registry** — ``summary()`` is rebuilt on ``MetricsRegistry``
  snapshots without changing a single legacy key.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.data.workloads import region_workloads
from repro.data.world import SemanticWorld
from repro.launch.serve import run_once
from repro.obs.analyze import (attribution, check_conservation,
                               format_attribution)
from repro.obs.export import export_trace
from repro.obs.metrics import (FixedHistogram, MetricsRegistry, ScanMetrics,
                               percentile)
from repro.obs.trace import BACKGROUND, NULL_TRACER, Tracer
from repro.serving.federation import FederationRunner


# ---------------------------------------------------------------- helpers

@dataclasses.dataclass
class _Rec:
    rid: int
    arrival: float
    t_done: float
    latency: float
    remote_calls: int = 0
    peer_transfers: int = 0


# ------------------------------------------------------------- unit tests

def test_percentile_matches_numpy_linear_default():
    vals = [0.3, 1.7, 0.02, 9.4, 2.2, 2.2, 0.5]
    for q in (0, 25, 50, 99, 100):
        assert percentile(vals, q) == float(np.percentile(vals, q))


def test_fixed_histogram_legacy_keys_and_mean():
    h = FixedHistogram((30.0, 60.0))
    for v in (0.0, 29.999, 30.0, 45.0, 60.0, 1e4):
        h.add(v)
    assert h.to_dict() == {"0-30": 2, "30-60": 2, "60+": 2}
    # mean must be np.mean over the RAW values (pairwise summation),
    # bit-identical to the pre-registry list-based summary code
    assert h.mean == float(np.mean(h.values))
    assert len(h) == 6
    assert FixedHistogram().mean == 0.0


def test_scan_metrics_pass_accounting():
    s = ScanMetrics()
    s.note_pass(100)                       # unsharded: max shard == rows
    assert (s.last_rows, s.last_max_shard_rows) == (100, 100)
    s.note_pass(80, max_shard_rows=50)     # new pass resets last_*
    s.add_warm_pass(40, max_shard_rows=40) # warm consult folds into it
    assert (s.last_rows, s.last_max_shard_rows) == (120, 90)
    assert (s.total_rows, s.total_max_shard_rows) == (220, 190)


def test_registry_snapshot_and_delta():
    reg = MetricsRegistry()
    state = {"hits": 3, "ratio": 0.5, "hist": {"0-30": 1}, "flag": True}
    reg.register("cache", lambda: state)
    reg.register("gpu", lambda: {"chips": 2})
    assert reg.namespaces() == ["cache", "gpu"]
    snap = reg.snapshot()
    assert snap == {"cache.hits": 3, "cache.ratio": 0.5,
                    "cache.hist": {"0-30": 1}, "cache.flag": True,
                    "gpu.chips": 2}
    state["hits"] = 10            # live counters: next snapshot sees it
    d = MetricsRegistry.delta(reg.snapshot(), snap)
    assert d["cache.hits"] == 7
    assert d["gpu.chips"] == 0
    # non-numerics (dicts, bools) pass through from the current snapshot
    assert d["cache.hist"] == {"0-30": 1}
    assert d["cache.flag"] is True
    # missing base keys count as zero
    assert MetricsRegistry.delta({"a.x": 4}, {})["a.x"] == 4


def test_tracer_groups_by_region_and_rid():
    tr = Tracer()
    assert tr.enabled
    tr.span(7, "stage1_scan", 0.0, 1.0)
    tr.span(7, "stage1_scan", 0.0, 1.0, region=2)
    tr.marker(7, "band_bypass", 1.0, region=2, tag="x")
    tr.span(BACKGROUND, "refresh", 0.0, 5.0)       # background: excluded
    by_req = tr.request_spans()
    assert set(by_req) == {(0, 7), (2, 7)}
    assert len(by_req[(2, 7)]) == 2
    assert len(tr.spans) == 4


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    NULL_TRACER.span(1, "x", 0.0, 1.0)
    NULL_TRACER.marker(1, "y", 0.0)
    assert not hasattr(NULL_TRACER, "spans")


def test_conservation_checker_names_gaps_overlaps_and_totals():
    tr = Tracer()
    tr.span(1, "a", 0.0, 1.0)
    tr.span(1, "b", 2.0, 3.0)                      # gap 1.0 -> 2.0
    recs = [_Rec(rid=1, arrival=0.0, t_done=3.0, latency=3.0)]
    v = check_conservation(tr, recs)
    assert len(v) == 1 and "gap" in v[0]

    tr = Tracer()
    tr.span(1, "a", 0.5, 1.0)                      # starts after arrival
    v = check_conservation(tr, recs)
    assert any("arrival" in x for x in v)
    assert any("t_done" not in x or "3.0" in x for x in v)

    v = check_conservation(Tracer(), recs)          # no spans at all
    assert v == ["region 0 rid 1: no spans recorded"]

    tr = Tracer()
    tr.span(1, "a", 0.0, 3.0)
    assert check_conservation(tr, recs) == []       # exact tiling passes


# --------------------------------------------- conservation on real runs

def test_conservation_plain_engine(tmp_path):
    out = run_once(n_requests=120, concurrency=4, seed=3,
                   trace=str(tmp_path / "t"))
    assert out["trace_conservation_violations"] == 0
    assert out["trace_spans"] > 0


def test_conservation_tiered_banded_engine(tmp_path):
    out = run_once(n_requests=120, concurrency=4, warm_frac=0.5,
                   workload="longtail", tail_len=40, judge_band=0.1,
                   seed=3, trace=str(tmp_path / "t"))
    assert out["trace_conservation_violations"] == 0


def test_conservation_federation():
    world = SemanticWorld(n_intents=300, dim=64, seed=5)
    reqs = region_workloads(world, n_regions=3, n_per_region=60, seed=6)
    tracer = Tracer()
    fr = FederationRunner(world=world, region_requests=reqs,
                          topology="peered", seed=7, tracer=tracer)
    fr.run()
    recs = fr.records_by_region()
    assert check_conservation(tracer, recs) == []
    # cross-region rid reuse must not alias: every region contributes
    assert {k[0] for k in tracer.request_spans()} == set(recs)


# ------------------------------------------------ neutrality, determinism

def test_traced_run_is_event_neutral(tmp_path):
    kw = dict(n_requests=120, concurrency=4, warm_frac=0.5,
              workload="longtail", tail_len=40, judge_band=0.1, seed=3)
    plain = run_once(**kw)
    traced = run_once(trace=str(tmp_path / "t"), **kw)
    for k in ("trace_jsonl", "trace_chrome", "trace_spans",
              "trace_conservation_violations"):
        traced.pop(k)
    assert json.dumps(traced, sort_keys=True, default=float) \
        == json.dumps(plain, sort_keys=True, default=float)


def test_same_seed_traces_are_byte_identical(tmp_path):
    kw = dict(n_requests=120, concurrency=4, judge_band=0.1, seed=3)
    a = run_once(trace=str(tmp_path / "a"), **kw)
    b = run_once(trace=str(tmp_path / "b"), **kw)
    assert (tmp_path / "a.jsonl").read_bytes() \
        == (tmp_path / "b.jsonl").read_bytes()
    assert (tmp_path / "a.chrome.json").read_bytes() \
        == (tmp_path / "b.chrome.json").read_bytes()
    assert a["trace_spans"] == b["trace_spans"] > 0


def test_export_artifacts_are_well_formed(tmp_path):
    tr = Tracer()
    tr.span(1, "stage1_scan", 0.5, 0.75, region=2)
    tr.marker(BACKGROUND, "invalidation_drop", 1.0, tag="stale")
    paths = export_trace(tr, str(tmp_path / "t"))
    rows = [json.loads(l) for l in
            open(paths["jsonl"]).read().splitlines()]
    assert rows[0] == {"dur": 0.25, "name": "stage1_scan", "region": 2,
                       "rid": 1, "t0": 0.5, "t1": 0.75}
    assert rows[1]["rid"] == BACKGROUND and rows[1]["tag"] == "stale"
    doc = json.load(open(paths["chrome"]))
    evs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert evs[0]["ts"] == 0.5e6 and evs[0]["dur"] == 0.25e6
    assert evs[0]["pid"] == 2 and evs[0]["tid"] == 1
    # Perfetto needs metadata process_name events per region
    assert any(e.get("ph") == "M" for e in doc["traceEvents"])


# ------------------------------------------------------------ attribution

def test_attribution_splits_by_request_class():
    tr = Tracer()
    tr.span(1, "stage1_scan", 0.0, 1.0)
    tr.span(1, "stage1_scan", 1.0, 2.0)   # same request, summed pre-quantile
    tr.span(2, "origin_fetch", 0.0, 4.0)
    recs = [_Rec(rid=1, arrival=0.0, t_done=2.0, latency=2.0),
            _Rec(rid=2, arrival=0.0, t_done=4.0, latency=4.0,
                 remote_calls=1, peer_transfers=1)]
    rep = attribution(tr, recs)
    assert set(rep) == {"hit", "federated"}
    seg = rep["hit"]["segments"]["stage1_scan"]
    assert seg["n"] == 1 and seg["total_s"] == 2.0 == seg["p50"]
    assert rep["federated"]["latency_p99"] == 4.0
    txt = format_attribution(rep)
    assert "[hit]" in txt and "origin_fetch" in txt


# ------------------------------------------------------- registry wiring

def test_summary_keeps_legacy_keys_and_registry_backs_them():
    out = run_once(n_requests=120, concurrency=4, seed=3)
    for k in ("latency_p50", "latency_p99", "api_calls", "retry_ratio",
              "hit_rate", "rows_scanned", "stale_hits", "stale_age_hist",
              "judge_calls", "gpu_cost"):
        assert k in out, k
    assert "trace_jsonl" not in out   # untraced runs carry no trace keys
