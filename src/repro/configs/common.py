"""Shared helpers for architecture configs: input specs per workload shape,
reduced smoke-config shrinking, and the arch registry."""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.nn.config import (
    AttnConfig, LayerSpec, MambaConfig, ModelConfig, MoEConfig, ShapeCell,
    XLSTMConfig,
)
from repro.nn.sharding import ShardCtx, resolve_pspec


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# --------------------------------------------------------------- inputs


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh | None = None):
    """ShapeDtypeStruct stand-ins for every model input of one workload cell.

    train/prefill: {tokens, labels?, (positions | frontend_* | enc_emb)}
    decode: {tokens (B,1), caches, pos} — built by launch.dryrun via
    cache_specs; here we return the token-side inputs only.
    """
    b = cell.global_batch
    s = cell.seq_len

    def sds(shape, dtype, *axes):
        if mesh is None:
            return jax.ShapeDtypeStruct(shape, dtype)
        ps = resolve_pspec(mesh, axes, shape)
        return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, ps))

    out = {}
    if cell.kind == "decode":
        out["tokens"] = sds((b, 1), jnp.int32, "dp", None)
    else:
        out["tokens"] = sds((b, s), jnp.int32, "dp", None)
        if cell.kind == "train":
            out["labels"] = sds((b, s), jnp.int32, "dp", None)
    if cfg.frontend == "vision" and cell.kind != "decode":
        out["frontend_emb"] = sds((b, s, cfg.d_model), cfg.pdt, "dp", None, None)
        out["frontend_mask"] = sds((b, s), jnp.bool_, "dp", None)
        out["positions"] = sds((3, b, s), jnp.int32, None, "dp", None)
    if cfg.enc_dec and cell.kind != "decode":
        out["enc_emb"] = sds((b, s, cfg.d_model), cfg.pdt, "dp", None, None)
    return out


# --------------------------------------------------------------- shrink


def shrink(cfg: ModelConfig, *, d_model=64, vocab=512, n_repeat=1,
           seq_chunk=8) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small widths, few
    experts, tiny vocab — but the *same* layer pattern and code paths."""

    def sh_attn(a: AttnConfig | None):
        if a is None:
            return None
        heads = max(2, min(4, a.n_heads))
        kv = max(1, min(heads, a.n_kv_heads if a.n_kv_heads <= heads else heads))
        upd = dict(
            n_heads=heads, n_kv_heads=kv, head_dim=16,
            window=min(a.window, 8) if a.window else None,
        )
        if a.kind == "mla":
            upd.update(
                q_lora_rank=16 if a.q_lora_rank else None, kv_lora_rank=16,
                qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
            )
        if a.rope_kind == "mrope":
            upd["mrope_sections"] = (2, 3, 3)
        return dataclasses.replace(a, **upd)

    def sh_layer(l: LayerSpec) -> LayerSpec:
        moe = None
        if l.moe is not None:
            moe = dataclasses.replace(
                l.moe, n_experts=4, top_k=min(2, l.moe.top_k),
                d_ff_expert=32, n_shared=min(1, l.moe.n_shared),
                d_ff_shared=32 if l.moe.n_shared else 0, capacity_factor=2.0,
            )
        return dataclasses.replace(
            l,
            attn=sh_attn(l.attn),
            mamba=dataclasses.replace(
                l.mamba, d_state=4, chunk=seq_chunk
            ) if l.mamba else None,
            xlstm=dataclasses.replace(
                l.xlstm, n_heads=2, chunk=seq_chunk
            ) if l.xlstm else None,
            d_ff=128 if l.d_ff else 0,
            moe=moe,
        )

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        vocab_size=vocab,
        blocks=tuple(sh_layer(l) for l in cfg.blocks),
        n_repeat=n_repeat,
        prefix=tuple(sh_layer(l) for l in cfg.prefix),
        enc_blocks=tuple(sh_layer(l) for l in cfg.enc_blocks),
        enc_repeat=min(1, cfg.enc_repeat),
    )


# --------------------------------------------------------------- registry

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
