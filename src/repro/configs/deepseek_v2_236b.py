"""deepseek-v2-236b [moe] — arXiv:2405.04434.

60L d_model=5120 128H, MLA (kv_lora=512, q_lora=1536, qk_nope=128,
qk_rope=64, v_head=128), d_ff_expert=1536, MoE 160 routed top-6 + 2 shared,
first layer dense (d_ff=12288), vocab=102400.

EP note: 160 experts do not divide the 16-way model axis evenly per shard
group of 10 — 160 % 16 == 0, so 10 experts/device. Softmax router with
top-k scaling, aux load-balance loss.
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig, MoEConfig

NAME = "deepseek-v2-236b"


def _mla() -> AttnConfig:
    return AttnConfig(
        n_heads=128, n_kv_heads=128, head_dim=128, kind="mla",
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    )


@register(NAME)
def config() -> ModelConfig:
    moe = MoEConfig(
        n_experts=160, top_k=6, d_ff_expert=1536,
        n_shared=2, d_ff_shared=3072,
    )
    dense = LayerSpec(kind="attn", attn=_mla(), d_ff=12288)
    moel = LayerSpec(kind="attn", attn=_mla(), moe=moe)
    return ModelConfig(
        name=NAME,
        family="moe",
        d_model=5120,
        vocab_size=102400,
        prefix=(dense,),
        blocks=(moel,),
        n_repeat=59,  # 1 dense + 59 MoE = 60 layers
        tie_embeddings=False,
    )
