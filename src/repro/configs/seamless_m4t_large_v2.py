"""seamless-m4t-large-v2 [audio] — arXiv:2308.11596. Encoder-decoder.

24L d_model=1024 16H (GQA kv=16 = MHA) d_ff=8192 vocab=256206 (padded to
256256 for 16-way vocab sharding). The speech frontend (w2v-BERT conformer
feature extractor) is a STUB: input_specs() provides precomputed frame
embeddings (B, S, D) consumed by the text-transformer encoder backbone;
the decoder is the autoregressive text decoder with cross-attention.
"24L" is interpreted as 24 encoder + 24 decoder backbone layers (the real
model's per-stack depth); decode shapes exercise the decoder.
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig

NAME = "seamless-m4t-large-v2"
PAPER_VOCAB = 256206


@register(NAME)
def config() -> ModelConfig:
    attn = AttnConfig(n_heads=16, n_kv_heads=16, head_dim=64)
    enc = LayerSpec(kind="attn", attn=attn, d_ff=8192, ffn_act="gelu")
    dec = LayerSpec(
        kind="attn", attn=attn, d_ff=8192, ffn_act="gelu", cross_attn=True
    )
    return ModelConfig(
        name=NAME,
        family="audio",
        d_model=1024,
        vocab_size=256256,  # padded from 256206 (multiple of 128)
        blocks=(dec,),
        n_repeat=24,
        enc_dec=True,
        enc_blocks=(enc,),
        enc_repeat=24,
        tie_embeddings=True,
        frontend="audio",
    )
