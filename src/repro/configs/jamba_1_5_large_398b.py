"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887 / 2408.12570.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Mamba : attention = 7 : 1 (one attention layer per 8-layer Jamba block,
at in-block index 4), MoE every second layer. No positional embedding —
Mamba layers carry position (hence attention rope_kind="none").
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, MambaConfig, ModelConfig, MoEConfig

NAME = "jamba-1.5-large-398b"


@register(NAME)
def config() -> ModelConfig:
    attn = AttnConfig(
        n_heads=64, n_kv_heads=8, head_dim=128, rope_kind="none"
    )
    mamba = MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256)
    moe = MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576)

    def layer(i: int) -> LayerSpec:
        kind = "attn" if i == 4 else "mamba"
        use_moe = i % 2 == 1
        return LayerSpec(
            kind=kind,
            attn=attn if kind == "attn" else None,
            mamba=mamba if kind == "mamba" else None,
            d_ff=0 if use_moe else 24576,
            moe=moe if use_moe else None,
        )

    return ModelConfig(
        name=NAME,
        family="hybrid",
        d_model=8192,
        vocab_size=65536,
        blocks=tuple(layer(i) for i in range(8)),
        n_repeat=9,  # 9 x 8 = 72 layers
        tie_embeddings=True,
        sub_quadratic=True,  # 7/8 of layers are Mamba -> long_500k eligible
    )
