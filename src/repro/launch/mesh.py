"""Production mesh definition.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips, axes
("data", "model"). Multi-pod: (2, 16, 16) = 512 chips, axes
("pod", "data", "model") — the "pod" axis is the slow inter-pod (DCN/ICI
cross-link) dimension and defaults to pure data parallelism.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # jax < 0.5: explicit axis types don't exist yet
    AxisType = None


def make_shard_mesh(n_shards: int):
    """1-D mesh over the first ``n_shards`` local devices, axis
    ``("shards",)`` — the stage-1 cache partition axis (DESIGN.md §13).
    Distinct from the model mesh: cache shards are data-parallel scan
    slices keyed by cluster ownership, not model-parallel weight
    shards. CI simulates 8 CPU devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    from jax.sharding import Mesh
    import numpy as np

    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"mesh needs {n_shards} devices, host has {len(devs)}"
        )
    return Mesh(np.array(devs[:n_shards]), ("shards",))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


# TPU v5e-class hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,            # bytes/s per chip
    "ici_bw": 50e9,             # bytes/s per link (~per chip, one direction)
    "hbm_bytes": 16 * 1024**3,  # 16 GiB per chip
}
