"""Sharded, atomic, mesh-elastic checkpointing (no orbax available).

Layout: one directory per step —
    step_000120.tmp/            (written, then atomically renamed)
      manifest.msgpack          treedef, shapes, dtypes, step metadata
      arr_00000.npy ...         one .npy per leaf (host-gathered)
    step_000120/

Properties needed at 1000-node scale, scaled-down faithfully here:
* atomic publish (tmp dir + rename) — a crash mid-write never corrupts
  the latest checkpoint;
* elastic restore — leaves are stored as *logical* (unsharded) arrays, so
  a checkpoint written on a (16,16) mesh restores onto (2,16,16), (1,1) or
  any other mesh (resharding happens at device_put with the new sharding);
* async save — the host gather happens synchronously (cheap), the file
  writes happen on a background thread so the train loop keeps stepping;
* retention — keep_last N checkpoints garbage-collected.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None,
         async_write: bool = True, keep_last: int = 3) -> threading.Thread | None:
    """Host-gather `tree` and write checkpoint `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]  # device->host gather (sync)
    paths = jax.tree.leaves(
        jax.tree.map(lambda *_: None, tree), is_leaf=lambda x: False
    )

    def write():
        name = f"step_{step:08d}"
        tmp = os.path.join(ckpt_dir, name + ".tmp")
        final = os.path.join(ckpt_dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "n_leaves": len(host),
            "treedef": str(treedef),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra or {},
        }
        for i, a in enumerate(host):
            if a.dtype.name == "bfloat16":  # npy can't round-trip bf16
                a = a.view(np.uint16)
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), a)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        _gc(ckpt_dir, keep_last)

    if async_write:
        th = threading.Thread(target=write, daemon=True)
        th.start()
        return th
    write()
    return None


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, MANIFEST))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *,
            shardings=None) -> tuple[Any, dict]:
    """Restore into the structure of `like_tree` (ShapeDtypeStructs or
    arrays). `shardings`: optional matching pytree of NamedShardings — this
    is where elastic resharding happens (any mesh shape)."""
    name = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(name, MANIFEST)) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"restore target has {len(leaves)}"
        )
    shard_leaves = (
        jax.tree.flatten(shardings)[0] if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        a = np.load(os.path.join(name, f"arr_{i:05d}.npy"))
        if manifest["dtypes"][i] == "bfloat16":
            import ml_dtypes

            a = a.view(ml_dtypes.bfloat16)
        if tuple(a.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {a.shape} != target {ref.shape}"
            )
        a = a.astype(ref.dtype)
        out.append(jax.device_put(a, shd) if shd is not None else
                   jax.device_put(a))
    return jax.tree.unflatten(treedef, out), manifest["extra"]
