"""Fault-tolerance harness: supervisor restart-from-checkpoint, fault
injection, and straggler mitigation — the pieces that make a 1000-node run
survive node churn, exercised here in-process.

* ``Supervisor`` wraps a step function: on (injected or real) failure it
  restores the latest checkpoint and replays — the train driver's crash
  semantics are therefore restart-idempotent.
* ``StragglerMonitor`` tracks per-step durations; a step exceeding
  ``deadline_factor`` × rolling-median is flagged (at scale the launcher
  uses this to evict/replace the slow host; here we log and count).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.train import checkpoint as ckpt


class FaultInjector:
    """Deterministic fault schedule for tests: fail at given steps."""

    def __init__(self, fail_at: set[int] | None = None):
        self.fail_at = set(fail_at or ())
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class StragglerMonitor:
    def __init__(self, window: int = 32, deadline_factor: float = 3.0):
        self.durations: deque[float] = deque(maxlen=window)
        self.deadline_factor = deadline_factor
        self.stragglers = 0

    def observe(self, dt: float) -> bool:
        flagged = False
        if len(self.durations) >= 8:
            med = float(np.median(self.durations))
            if dt > self.deadline_factor * med:
                self.stragglers += 1
                flagged = True
        self.durations.append(dt)
        return flagged


@dataclasses.dataclass
class RunResult:
    steps_done: int
    restarts: int
    stragglers: int
    losses: list


class Supervisor:
    def __init__(
        self,
        ckpt_dir: str,
        *,
        save_every: int = 10,
        max_restarts: int = 10,
        injector: Optional[FaultInjector] = None,
    ):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.injector = injector or FaultInjector()
        self.restarts = 0

    def run(
        self,
        *,
        init_state: Callable[[], tuple],
        step_fn: Callable,          # (state, step) -> (state, metrics)
        n_steps: int,
        restore_like: Callable[[], tuple] | None = None,
        shardings=None,
    ) -> RunResult:
        """Run n_steps with checkpoint/restart. state is any pytree."""
        monitor = StragglerMonitor()
        losses = []

        while True:
            last = ckpt.latest_step(self.ckpt_dir)
            if last is not None:
                like = (restore_like or init_state)()
                state, extra = ckpt.restore(
                    self.ckpt_dir, last, like, shardings=shardings
                )
                start = int(extra.get("next_step", last))
            else:
                state = init_state()
                start = 0
            try:
                for step in range(start, n_steps):
                    self.injector.maybe_fail(step)
                    t0 = time.monotonic()
                    state, metrics = step_fn(state, step)
                    monitor.observe(time.monotonic() - t0)
                    if metrics and "loss" in metrics:
                        losses.append(float(metrics["loss"]))
                    if (step + 1) % self.save_every == 0 or step == n_steps - 1:
                        th = ckpt.save(
                            self.ckpt_dir, step + 1, state,
                            extra={"next_step": step + 1},
                            async_write=True,
                        )
                        if step == n_steps - 1 and th is not None:
                            th.join()
                return RunResult(
                    steps_done=n_steps, restarts=self.restarts,
                    stragglers=monitor.stragglers, losses=losses,
                )
            except RuntimeError:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                # fall through: restore from latest checkpoint and replay
