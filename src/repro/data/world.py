"""Synthetic semantic world — the ground-truth universe behind the
behavioural experiments.

The paper evaluates on HotpotQA/Musique/2Wiki/Zilliz questions with a real
embedding model. Offline, we construct an equivalent *controlled* world:

* N intents; each has a unit-norm cluster center, an answer, a staticity
  class, a topic group (for correlated trends), and paraphrases.
* embed(query) = normalize(center + σ_para · noise) — paraphrases of one
  intent are tightly clustered (cos ≈ 0.97+).
* A fraction of intents come in *confusable pairs*: centers engineered to
  cosine ≈ confusable_cos (default 0.93 > τ_sim) with different answers —
  the "apple nutrition facts" vs "Apple stock price" failure mode that
  defeats pure-ANN caches and makes the semantic judge necessary (§6.6).

Query strings are structured ("q:<intent>:<paraphrase>") so ground truth
(same_intent, answer, staticity) is exact and experiments are reproducible.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Intent:
    iid: int
    answer: str
    staticity: int
    topic: int
    confusable_with: int | None = None


class SemanticWorld:
    def __init__(
        self,
        n_intents: int = 1000,
        dim: int = 128,
        *,
        n_topics: int = 10,
        confusable_frac: float = 0.2,
        confusable_cos: float = 0.93,
        sigma_para: float = 0.12,
        value_bytes: tuple[int, int] = (512, 4096),
        seed: int = 0,
    ):
        self.dim = dim
        self.sigma_para = sigma_para
        self.rng = np.random.default_rng(seed)
        self.n_intents = n_intents

        centers = self.rng.standard_normal((n_intents, dim)).astype(np.float32)
        centers /= np.linalg.norm(centers, axis=1, keepdims=True)
        # carve confusable pairs: c_b = cos·c_a + sin·orth
        n_pairs = int(n_intents * confusable_frac / 2)
        self.intents: list[Intent] = []
        pair_partner = {}
        for p in range(n_pairs):
            a, b = 2 * p, 2 * p + 1
            ca = centers[a]
            orth = self.rng.standard_normal(dim).astype(np.float32)
            orth -= (orth @ ca) * ca
            orth /= np.linalg.norm(orth)
            cos = confusable_cos
            centers[b] = cos * ca + np.sqrt(1 - cos * cos) * orth
            pair_partner[a] = b
            pair_partner[b] = a
        self.centers = centers

        stat_choices = np.array([1, 2, 3, 5, 7, 9, 10])
        stat_probs = np.array([0.1, 0.1, 0.15, 0.2, 0.15, 0.15, 0.15])
        for i in range(n_intents):
            self.intents.append(
                Intent(
                    iid=i,
                    answer=f"answer-{i}",
                    staticity=int(self.rng.choice(stat_choices, p=stat_probs)),
                    topic=int(self.rng.integers(0, n_topics)),
                    confusable_with=pair_partner.get(i),
                )
            )
        self.value_bytes = value_bytes
        self._sizes = self.rng.integers(
            value_bytes[0], value_bytes[1], size=n_intents
        )
        # heterogeneous tool economics: ~25% of intents come from an
        # expensive/slow tool (premium API), the rest from the cheap one —
        # the heterogeneity LCFU's cost-aware retention exploits (Table 6)
        premium = self.rng.random(n_intents) < 0.25
        self._cost_mult = np.where(premium, 8.0, 1.0)
        self._lat_mult = np.where(premium, 4.0, 1.0)

    # ------------------------------------------------------------ queries

    def query(self, intent: int, paraphrase: int) -> str:
        return f"q:{intent}:{paraphrase}"

    def intent_of(self, query: str) -> int:
        return int(query.split(":")[1])

    def same_intent(self, q1: str, q2: str) -> bool:
        return self.intent_of(q1) == self.intent_of(q2)

    def staticity(self, query: str) -> int:
        return self.intents[self.intent_of(query)].staticity

    def answer(self, query: str) -> str:
        return self.intents[self.intent_of(query)].answer

    def value_size(self, query: str) -> int:
        return int(self._sizes[self.intent_of(query)])

    def topic(self, query: str) -> int:
        return self.intents[self.intent_of(query)].topic

    def embed(self, query: str) -> np.ndarray:
        iid = self.intent_of(query)
        para = int(query.split(":")[2])
        # deterministic per (intent, paraphrase) noise, unit direction so
        # cos(paraphrase, center) ≈ 1/√(1+σ²) regardless of dim
        rng = np.random.default_rng((iid * 1_000_003 + para) & 0x7FFFFFFF)
        n = rng.standard_normal(self.dim).astype(np.float32)
        n /= np.linalg.norm(n)
        v = self.centers[iid] + self.sigma_para * n
        return (v / np.linalg.norm(v)).astype(np.float32)

    def cost_mult(self, query: str) -> float:
        return float(self._cost_mult[self.intent_of(query)])

    def latency_mult(self, query: str) -> float:
        return float(self._lat_mult[self.intent_of(query)])

    # the "live tool": ground truth fetch (used by recalibration too)
    def fetch(self, query: str) -> str:
        return self.answer(query)

    def equivalent(self, cached_value, ground_value) -> bool:
        return cached_value == ground_value
