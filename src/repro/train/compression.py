"""Gradient compression for slow inter-pod links.

Two composable schemes (the standard distributed-optimization toolbox for
1000-node DP over DCN-class links):

* ``topk_ef``   — per-tensor top-k magnitude sparsification with error
  feedback: the residual (dropped mass) is carried into the next step, so
  the compressed SGD provably tracks the dense trajectory.
* ``int8``      — per-block linear quantisation (absmax scales), 4x over
  f32 / 2x over bf16 on the wire.

Both operate on the *local* gradient before the DP all-reduce; tests check
exact round-trip bounds and error-feedback convergence on a quadratic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- top-k EF


def topk_compress(g: jax.Array, frac: float):
    """Keep the top `frac` fraction of entries by magnitude.
    Returns (values, flat_indices, shape)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    return kept, idx, g.shape


def topk_decompress(vals, idx, shape, dtype):
    flat = jnp.zeros(int(jnp.prod(jnp.array(shape))), dtype)
    flat = flat.at[idx].set(vals.astype(dtype))
    return flat.reshape(shape)


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree(grads, residuals, frac: float):
    """Error-feedback top-k over a grad pytree.
    Returns (compressed leaves, new residuals)."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        vals, idx, shape = topk_compress(corrected, frac)
        dense = topk_decompress(vals, idx, shape, jnp.float32)
        new_r = corrected - dense
        return (vals, idx), new_r, dense

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    comp, new_r, dense = [], [], []
    for g, r in zip(flat_g, flat_r):
        c, nr, d = one(g, r)
        comp.append(c)
        new_r.append(nr)
        dense.append(d.astype(g.dtype))
    return (
        comp,
        jax.tree.unflatten(treedef, new_r),
        jax.tree.unflatten(treedef, dense),
    )


# ------------------------------------------------------------- int8


@dataclasses.dataclass
class Quantized:
    q: Any       # int8 values
    scale: Any   # f32 per-block absmax scales
    shape: tuple


def int8_quantize(g: jax.Array, block: int = 256) -> Quantized:
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Quantized(q=q, scale=scale[:, 0], shape=tuple(g.shape))


def int8_dequantize(z: Quantized, dtype=jnp.float32) -> jax.Array:
    flat = (z.q.astype(jnp.float32) * z.scale[:, None]).reshape(-1)
    n = 1
    for d in z.shape:
        n *= d
    return flat[:n].reshape(z.shape).astype(dtype)


def wire_bytes_dense(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def wire_bytes_int8(tree, block: int = 256) -> int:
    total = 0
    for l in jax.tree.leaves(tree):
        nblk = -(-l.size // block)
        total += l.size + nblk * 4
    return total
