"""Pallas TPU flash-attention (forward) — the agent prefill / judge
prefill-only scoring hot spot.

Grid: (batch·kv_heads·groups, n_q_blocks, n_k_blocks); the last dim is
sequential ("arbitrary" semantics) so the online-softmax state (m, l, acc)
lives in VMEM scratch across k-blocks: initialised at k==0, folded every
step, written to the output block at the final k step. Causal/window masks
are computed from the grid coordinates; fully-masked (q,k) block pairs
still execute but contribute zeros — block-skipping via the index map is a
recorded hillclimb lever (EXPERIMENTS.md §Perf).

The pure-JAX oracle is kernels.ref.flash_attention_ref; the training path
uses nn.flash (same math, custom_vjp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, bq: int, bk: int,
                  nk: int):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0]          # (bq, dh)
    k = k_ref[0]          # (bk, dh)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale             # (bq, bk)
    qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask = kj <= qi
    if window is not None:
        mask = mask & (kj > qi - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(kb == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "bq", "bk", "interpret"),
)
def flash_attention_fwd(q, k, v, *, scale: float, causal: bool = True,
                        window=None, bq: int = 512, bk: int = 512,
                        interpret: bool = True):
    """q (B,Sq,KV,G,Dh); k/v (B,Sk,KV,Dh) -> (B,Sq,KV,G,Dh)."""
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk

    # flatten (B,KV,G) into one leading grid axis; per-head K/V reuse
    qf = jnp.moveaxis(q, 1, 3).reshape(b * kvh * g, sq, dh)
    kf = (
        jnp.moveaxis(k, 1, 2)[:, :, None]
        .repeat(g, axis=2)
        .reshape(b * kvh * g, sk, dh)
    )
    vf = (
        jnp.moveaxis(v, 1, 2)[:, :, None]
        .repeat(g, axis=2)
        .reshape(b * kvh * g, sk, dh)
    )

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, nk=nk,
        ),
        grid=(b * kvh * g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, qb, kb: (h, qb, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, qb, kb: (h, kb, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, qb, kb: (h, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, qb, kb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh * g, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),     # running max m
            pltpu.VMEM((bq,), jnp.float32),     # running denom l
            pltpu.VMEM((bq, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, kvh, g, sq, dh)
    return jnp.moveaxis(out, 3, 1)
