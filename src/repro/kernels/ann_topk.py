"""Pallas TPU kernel for Seri stage-1: fused cosine-similarity + top-k.

TPU adaptation of the paper's Faiss ANN stage (DESIGN.md §3): graph/IVF
traversal is pointer-chasing and MXU-hostile; on TPU, brute-force tiled
matmul over the embedding matrix hits ~peak MXU throughput for cache sizes
up to millions of entries and gives exact (recall=1.0) top-k.

Tiling: the embedding matrix (N, D) streams HBM→VMEM in (TILE_N, D) tiles;
the query block (B, D) stays resident in VMEM; each grid step computes a
(TILE_N, B) score tile on the MXU (fp32 accumulation), masks inactive rows,
and reduces it to per-tile top-K candidates (K passes of max/argmax on the
VPU — K is small). The (ntiles · K) finalists are merged by a single
lax.top_k outside the kernel (tiny).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512
NEG = -3.0e38  # plain float: jnp scalars would be captured consts in pallas


def _ann_kernel(q_ref, emb_ref, mask_ref, vals_ref, idx_ref, *, k: int,
                tile_n: int):
    """One grid step: scores for a (tile_n, D) slab; per-tile top-k."""
    emb = emb_ref[...]
    q = q_ref[...]
    s = jax.lax.dot_general(
        emb, q,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (tile_n, B)
    mask = mask_ref[...] > 0
    s = jnp.where(mask[:, None], s, NEG)
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    for j in range(k):
        v = jnp.max(s, axis=0)           # (B,)
        i = jnp.argmax(s, axis=0)        # (B,) row within tile
        vals_ref[0, j, :] = v
        idx_ref[0, j, :] = i.astype(jnp.int32)
        s = jnp.where(rows == i[None, :], NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "interpret", "tile_n"))
def ann_topk(emb, active, q, k: int = 4, *, interpret: bool = True,
             tile_n: int = TILE_N):
    """emb (N, D); active (N,); q (B, D) -> (vals (B,k), rows (B,k)).

    interpret=True executes the kernel body on CPU (this container);
    on TPU pass interpret=False for the Mosaic lowering.
    """
    n, d = emb.shape
    b = q.shape[0]
    pad = (-n) % tile_n
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0)))
        active = jnp.pad(active.astype(jnp.int32), (0, pad))
    active = active.astype(jnp.int32)
    ntiles = (n + pad) // tile_n

    vals, idx = pl.pallas_call(
        functools.partial(_ann_kernel, k=k, tile_n=tile_n),
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((b, d), lambda t: (0, 0)),            # q resident
            pl.BlockSpec((tile_n, d), lambda t: (t, 0)),       # emb slab
            pl.BlockSpec((tile_n,), lambda t: (t,)),           # active slab
        ],
        out_specs=[
            pl.BlockSpec((1, k, b), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, k, b), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ntiles, k, b), jnp.float32),
            jax.ShapeDtypeStruct((ntiles, k, b), jnp.int32),
        ],
        interpret=interpret,
    )(q, emb, active)

    # global row ids, then merge the ntiles*k finalists per query
    base = (jnp.arange(ntiles, dtype=jnp.int32) * tile_n)[:, None, None]
    gidx = idx + base                                  # (ntiles, k, b)
    flat_v = vals.reshape(ntiles * k, b).T             # (b, ntiles*k)
    flat_i = gidx.reshape(ntiles * k, b).T
    kk = min(k, ntiles * k)
    top_v, pos = jax.lax.top_k(flat_v, kk)
    top_i = jnp.take_along_axis(flat_i, pos, axis=1)
    return top_v, top_i
