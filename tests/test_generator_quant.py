"""Continuous-batching generator (real models, co-located judge) + 8-bit
AdamW tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, shrink
from repro.serving.generator import ContinuousBatcher, GenRequest
from repro.train.optim import AdamWConfig, adamw_update, init_state
from repro.train.quant_opt import adamw8_update, init_state8, state8_bytes


def test_continuous_batching_with_colocated_judge():
    cfg = shrink(get_config("search-r1-7b"), d_model=64, vocab=128,
                 n_repeat=2)
    judge_runs = []

    def judge():
        judge_runs.append(1)

    cb = ContinuousBatcher(cfg, slots=3, max_len=64, judge=judge)
    rng = np.random.default_rng(0)
    reqs = [
        GenRequest(i, rng.integers(1, 128, size=int(rng.integers(3, 8))),
                   max_new=5)
        for i in range(6)
    ]
    for r in reqs:
        cb.submit(r)
    ticks = cb.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    # determinism: same prompt in a fresh batcher gives the same tokens
    cb2 = ContinuousBatcher(cfg, slots=3, max_len=64)
    r2 = GenRequest(0, reqs[0].prompt, max_new=5)
    cb2.submit(r2)
    cb2.run()
    assert r2.out_tokens == reqs[0].out_tokens
    # priority rule: judge ran only on ticks with an empty admit queue
    assert cb.judge_batches_run > 0
    assert cb.judge_batches_run <= ticks


def test_adamw8_tracks_fp32_adamw():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      total_steps=300, schedule="const", grad_clip=0.0)
    target = jnp.array([1.0, -2.0, 0.5, 3.0] * 64)  # 256 = one block
    p32 = {"w": jnp.zeros(256)}
    p8 = {"w": jnp.zeros(256)}
    s32 = init_state(cfg, p32)
    s8 = init_state8(p8, block=64)
    for _ in range(300):
        g32 = {"w": 2 * (p32["w"] - target)}
        g8 = {"w": 2 * (p8["w"] - target)}
        p32, s32, _ = adamw_update(cfg, p32, g32, s32)
        p8, s8, _ = adamw8_update(cfg, p8, g8, s8)
    np.testing.assert_allclose(np.asarray(p32["w"]), np.asarray(target),
                               atol=1e-2)
    np.testing.assert_allclose(np.asarray(p8["w"]), np.asarray(target),
                               atol=5e-2)  # int8 states still converge


def test_state8_memory_wins():
    params = {"w": jnp.zeros((1024, 1024), jnp.bfloat16)}
    fp32_bytes = 2 * params["w"].size * 4
    assert state8_bytes(params) < 0.3 * fp32_bytes
