"""Multi-region federation demo (DESIGN.md §9).

Three agent regions — US (cheap fast WAN), EU, and APAC (slow expensive
WAN, tight rate limit) — each run their own Cortex cache against a
region-skewed workload with 60% shared-hot overlap, on ONE shared
virtual clock. On a local miss the federation router peeks sibling
caches at inter-region RTT and transfers the value (with provenance and
the source entry's remaining TTL) before paying the origin WAN fetch.

  PYTHONPATH=src python examples/multi_region.py
"""
import numpy as np

from repro.data.workloads import region_workloads
from repro.data.world import SemanticWorld
from repro.serving.federation import FederationRunner, RegionConfig

REGIONS = [
    RegionConfig(name="us", wan_lat_lo=0.25, wan_lat_hi=0.4,
                 wan_cost=0.004, qpm=120.0),
    RegionConfig(name="eu", wan_lat_lo=0.3, wan_lat_hi=0.5,
                 wan_cost=0.005, qpm=100.0),
    RegionConfig(name="apac", wan_lat_lo=0.45, wan_lat_hi=0.7,
                 wan_cost=0.008, qpm=60.0),
]

# asymmetric WAN: us<->eu is close, apac is far from both
RTT = np.array([
    [0.00, 0.07, 0.14],
    [0.07, 0.00, 0.16],
    [0.14, 0.16, 0.00],
])


def main():
    world = SemanticWorld(n_intents=500, dim=64, seed=42)
    streams = region_workloads(world, 250, len(REGIONS), overlap=0.6,
                               seed=43)
    print(f"{'topology':<8} {'lat_ms':>8} {'remote_ms':>10} {'hit':>6} "
          f"{'peer_hit':>9} {'api':>5} {'cost_$':>7}")
    for topo in ("local", "peered", "global"):
        runner = FederationRunner(
            world=world, region_requests=streams, topology=topo,
            region_cfgs=REGIONS, rtt=RTT, seed=44,
        )
        s = runner.run()
        a = s["aggregate"]
        print(f"{topo:<8} {a['latency_mean']*1e3:>8.1f} "
              f"{a['remote_time_mean']*1e3:>10.1f} {a['hit_rate']:>6.3f} "
              f"{a['peer_hit_rate']:>9.3f} {a['api_calls']:>5} "
              f"{a['api_cost']:>7.3f}")
        if topo == "peered":
            print("  per-region (peered):")
            for name, rs in s["regions"].items():
                print(f"    {name:<5} lat={rs['latency_mean']*1e3:.1f}ms "
                      f"remote={rs['remote_time_mean']*1e3:.1f}ms "
                      f"hit={rs['hit_rate']:.3f} "
                      f"peer_transfers={rs['peer_transfers']} "
                      f"api={rs['api_calls']}")
            fs = runner.federation.stats
            print(f"  federation: peeks={fs.peeks} "
                  f"peer_hits={fs.peer_hits} "
                  f"transfer_kb={fs.transfer_bytes/1e3:.1f} "
                  f"expired_leases={fs.expired_leases}")


if __name__ == "__main__":
    main()
