"""Declarative SLO monitoring over the telemetry sample stream
(DESIGN.md §16).

An :class:`SLO` names one metric inside a sample row (dotted path, e.g.
``window.latency_p99`` or ``gauges.judge_backlog``), an objective
direction, a bound, and hysteresis counts. The :class:`SLOMonitor`
consumes sample rows in virtual-time order and emits deterministic
breach / recovery alert events:

* **breach** — raised after ``breach_after`` *consecutive* violating
  samples while not currently breached;
* **recovery** — raised after ``recover_after`` consecutive OK samples
  while breached;
* samples where the metric is ``None``/missing (e.g. a windowed
  percentile over a window that completed nothing) are **skipped** —
  they advance neither counter, so an idle tail cannot fake a recovery.

Alerts are plain dicts stamped with the sample's virtual time — same
seed ⇒ byte-identical alert JSONL (see :func:`~repro.obs.export.
write_alerts`) — and, when a tracer is armed, each alert also lands in
the span stream as a zero-width BACKGROUND marker (``slo_breach`` /
``slo_recovery``, tagged with the SLO name) so breaches are visible in
Perfetto next to the request spans. The monitor only ever *reads* the
sample rows: monitoring is as observationally neutral as sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.trace import BACKGROUND

_OPS = ("<=", ">=")


@dataclasses.dataclass(frozen=True)
class SLO:
    """One objective: ``metric op bound`` must hold per sample.

    ``op`` is the *objective*, not the violation test: ``"<="`` is an
    upper bound (violating when value > bound, e.g. p99 latency);
    ``">="`` is a floor (violating when value < bound, e.g. accuracy).
    """

    name: str
    metric: str            # dotted path into a sample row
    op: str                # "<=" (upper bound) or ">=" (floor)
    bound: float
    breach_after: int = 2  # consecutive violating samples to raise
    recover_after: int = 2  # consecutive OK samples to clear

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"op must be one of {_OPS}, got {self.op!r}")
        if self.breach_after < 1 or self.recover_after < 1:
            raise ValueError("hysteresis counts must be >= 1")

    def violated(self, value: float) -> bool:
        return value > self.bound if self.op == "<=" else value < self.bound

    @classmethod
    def parse(cls, spec: str) -> "SLO":
        """Parse the CLI form
        ``name:metric:op:bound[:breach_after[:recover_after]]`` —
        e.g. ``p99:window.latency_p99:<=:3.0:2:2``."""
        parts = spec.split(":")
        if len(parts) < 4 or len(parts) > 6:
            raise ValueError(
                f"bad SLO spec {spec!r}; want "
                "name:metric:op:bound[:breach_after[:recover_after]]"
            )
        name, metric, op, bound = parts[:4]
        breach = int(parts[4]) if len(parts) > 4 else 2
        recover = int(parts[5]) if len(parts) > 5 else breach
        return cls(name=name, metric=metric, op=op, bound=float(bound),
                   breach_after=breach, recover_after=recover)


def _dig(row: dict, path: str):
    """Resolve a dotted path inside a sample row (None when absent)."""
    cur = row
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


@dataclasses.dataclass
class _SLOState:
    breached: bool = False
    bad: int = 0   # consecutive violating samples
    ok: int = 0    # consecutive OK samples


class SLOMonitor:
    """Evaluate a set of SLOs against the sample stream.

    Feed every sample (in order) to :meth:`observe` — a
    :class:`~repro.obs.sampler.TimeSeriesSampler` built with
    ``monitor=`` does this automatically. Alerts accumulate on
    ``self.alerts`` in emission order (deterministic: sample order ×
    declaration order).
    """

    def __init__(self, slos, tracer=None, region: int = 0):
        self.slos = [SLO.parse(s) if isinstance(s, str) else s
                     for s in slos]
        names = [s.name for s in self.slos]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names: {names}")
        self.tracer = tracer
        self.region = region
        self.alerts: list[dict] = []
        self._state = {s.name: _SLOState() for s in self.slos}

    def observe(self, sample: dict) -> None:
        t = sample["t"]
        for slo in self.slos:
            value = _dig(sample, slo.metric)
            if value is None:
                continue  # no data: advances neither counter
            st = self._state[slo.name]
            if slo.violated(value):
                st.bad += 1
                st.ok = 0
            else:
                st.ok += 1
                st.bad = 0
            if not st.breached and st.bad >= slo.breach_after:
                st.breached = True
                self._alert(t, slo, "breach", value)
            elif st.breached and st.ok >= slo.recover_after:
                st.breached = False
                self._alert(t, slo, "recovery", value)

    def _alert(self, t: float, slo: SLO, event: str, value) -> None:
        self.alerts.append({
            "t": float(t),
            "event": event,
            "slo": slo.name,
            "metric": slo.metric,
            "op": slo.op,
            "bound": slo.bound,
            "value": float(value),
        })
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.marker(BACKGROUND, f"slo_{event}", t,
                               self.region, tag=slo.name)

    # ------------------------------------------------------------ stats

    @property
    def breaches(self) -> int:
        return sum(1 for a in self.alerts if a["event"] == "breach")

    @property
    def recoveries(self) -> int:
        return sum(1 for a in self.alerts if a["event"] == "recovery")

    def active(self) -> list[str]:
        """Names of SLOs currently in breach."""
        return [n for n, st in self._state.items() if st.breached]
