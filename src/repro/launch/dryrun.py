"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production mesh, print memory/cost analysis, and derive the
roofline terms.

This file MUST set XLA_FLAGS before any other import (jax locks the
device count on first init): 512 placeholder host devices cover the
2-pod production mesh; the single-pod 16x16 mesh uses the first 256.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --json out.json
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

KV_QUANT = os.environ.get("REPRO_KV_QUANT", "0") == "1"
TRAIN_PLAN_ENV = os.environ.get("REPRO_TRAIN_PLAN", "")  # "" | "fsdp"

from repro.configs import ASSIGNED, get_config, input_specs
from repro.nn import runtime
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.roofline import (
    Roofline, model_flops, parse_collectives,
)
from repro.launch.steps import (
    make_decode_step, make_prefill_step, make_train_step,
)
from repro.models.lm import LM
from repro.nn.config import SHAPES, ModelConfig, ShapeCell
from repro.nn.param import struct_tree
from repro.nn.sharding import ShardingConfig, param_pspec
from repro.train.optim import AdamWConfig, state_specs

PAPER_HEADS = {"yi-34b": 56, "qwen2-vl-7b": 28}

# Per-arch training memory plan: (microbatches, optimizer-state dtype,
# grad-accumulation dtype). μ trades activation memory for ×μ FSDP
# all-gathers; bf16 states halve optimizer memory at the 100B+ scale —
# both choices are reported in the §Roofline table per cell.
TRAIN_PLAN = {
    "default": (4, "float32", jnp.float32),
    "jamba-1.5-large-398b": (8, "bfloat16", jnp.bfloat16),
    "qwen1.5-110b": (8, "bfloat16", jnp.bfloat16),
    "deepseek-v2-236b": (8, "bfloat16", jnp.bfloat16),
    "deepseek-v3-671b": (8, "bfloat16", jnp.bfloat16),
    "yi-34b": (8, "float32", jnp.float32),
    "xlstm-350m": (1, "float32", jnp.float32),
    "seamless-m4t-large-v2": (1, "float32", jnp.float32),
}


def train_plan(arch: str):
    mb, sdt, accum = TRAIN_PLAN.get(arch, TRAIN_PLAN["default"])
    return AdamWConfig(state_dtype=sdt), mb, accum


def skip_reason(cfg: ModelConfig, cell: ShapeCell) -> str | None:
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: long_500k skipped (DESIGN.md §4)"
    return None


def _structs(mesh, spec_tree, shard_cfg: ShardingConfig | None = None):
    shard_cfg = shard_cfg or ShardingConfig()
    resolve = lambda s: param_pspec(mesh, s, shard_cfg)
    return struct_tree(spec_tree, mesh, resolve)


def serve_shard_cfg(cfg: ModelConfig, mesh) -> ShardingConfig:
    """Serving parallelism plan (§Perf iteration 1): ZeRO-style param
    sharding over the data axis is a *training* memory optimization — at
    serve time it turns every step into a full-weight all-gather. When the
    TP-sharded weights fit HBM (≤8 GiB/chip budget), disable FSDP so
    weights replicate across data (zero per-step weight traffic); only the
    100B+ models keep FSDP at serve time."""
    from repro.launch.roofline import active_params

    _, total = active_params(cfg)
    tp = mesh.shape.get("model", 1)
    per_dev = total * 2 / tp  # bf16
    return ShardingConfig(enable_fsdp=per_dev > 8 * 2**30)


def _with_repeat(cfg: ModelConfig, n: int) -> ModelConfig:
    """Depth-n variant of a config (for metric extrapolation). The model is
    affine in n: metric(N) = metric(1) + (N-1)·[metric(2) - metric(1)]."""
    return dataclasses.replace(
        cfg,
        n_repeat=n,
        enc_repeat=n if cfg.enc_repeat else 0,
    )


def build_lowerable(arch: str, shape: str, mesh, cfg: ModelConfig = None,
                    force_mb1: bool = False, force_mb: int | None = None):
    """Returns (fn, args_structs, donate) ready for jit().lower()."""
    cfg = cfg or get_config(arch)
    cell = SHAPES[shape]
    lm = LM(cfg)
    batch = input_specs(cfg, cell, mesh)

    if cell.kind == "train":
        opt_cfg, mb, accum = train_plan(arch)
        shard_cfg = None
        if TRAIN_PLAN_ENV == "fsdp":
            shard_cfg = ShardingConfig.fsdp_only()
            mb = 1  # batch shards over all chips; no accumulation needed
        elif TRAIN_PLAN_ENV == "fsdp_hybrid":
            shard_cfg = ShardingConfig.fsdp_hybrid()
        if force_mb is not None:
            mb = force_mb
        elif force_mb1:
            mb = 1
        pspecs = lm.param_specs()
        params = _structs(mesh, pspecs, shard_cfg)
        opt = _structs(mesh, state_specs(opt_cfg, pspecs), shard_cfg)
        step = make_train_step(
            cfg, mesh, opt_cfg, remat="full", microbatches=mb,
            accum_dtype=accum, shard_cfg=shard_cfg,
        )
        return step, (params, opt, batch), (0, 1)
    scfg = serve_shard_cfg(cfg, mesh)
    if cell.kind == "prefill":
        params = _structs(mesh, lm.param_specs(), scfg)
        step = make_prefill_step(cfg, mesh)
        return step, (params, batch), ()
    # decode
    params = _structs(mesh, lm.param_specs(), scfg)
    caches = _structs(
        mesh,
        lm.cache_specs(
            cell.global_batch, cell.seq_len,
            enc_len=cell.seq_len if cfg.enc_dec else 0,
            kv_quant=KV_QUANT,
        ),
        scfg,
    )
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_decode_step(cfg, mesh)
    return step, (params, batch["tokens"], caches, pos), (2,)


def _compile_metrics(arch: str, shape: str, mesh, cfg, mb=None) -> dict:
    """flops / bytes / wire of one compile (per device)."""
    fn, args, donate = build_lowerable(
        arch, shape, mesh, cfg=cfg,
        force_mb1=mb is None, force_mb=mb,
    )
    with mesh:
        compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text(), mesh.size)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "wire": colls.wire_bytes,
        "by_op": colls.by_op,
        "counts": colls.counts,
    }


def _affine(key, lo, hi, steps):
    return lo[key] + steps * (hi[key] - lo[key])


def extrapolated_metrics(arch: str, shape: str, mesh) -> dict:
    """Exact per-device metrics via (depth × microbatch) extrapolation.

    XLA's cost analysis counts a while-loop body once, so rolled compiles
    undercount scanned superblocks; and collectives are NOT simply ×μ
    (XLA hoists loop-invariant weight gathers out of the grad-accum scan —
    measured, see EXPERIMENTS §Perf iteration 0). We therefore compile
    fully-unrolled variants at (n, μ) ∈ {1,2}² and extrapolate bilinearly:
       m(N, M) = m11 + (N−1)Δn + (M−1)Δμ + (N−1)(M−1)Δnμ
    (non-train cells have no μ axis; plain depth extrapolation applies;
    the sLSTM time scan stays rolled — its per-step FLOPs are negligible).
    """
    cfg = get_config(arch)
    cell = SHAPES[shape]
    n = cfg.n_repeat
    mu = train_plan(arch)[1] if cell.kind == "train" else 1
    if TRAIN_PLAN_ENV == "fsdp":
        mu = 1  # fsdp-only plan shards batch over all chips; no accumulation
    elif TRAIN_PLAN_ENV == "fsdp_hybrid":
        mu = train_plan(arch)[1]
    runtime.UNROLL = 1_000_000
    try:
        m11 = _compile_metrics(arch, shape, mesh, _with_repeat(cfg, 1), mb=1)
        m21 = (
            _compile_metrics(arch, shape, mesh, _with_repeat(cfg, 2), mb=1)
            if n > 1 else m11
        )
        if mu > 1:
            m12 = _compile_metrics(
                arch, shape, mesh, _with_repeat(cfg, 1), mb=2
            )
            m22 = (
                _compile_metrics(
                    arch, shape, mesh, _with_repeat(cfg, 2), mb=2
                ) if n > 1 else m12
            )
        else:
            m12, m22 = m11, m21
    finally:
        runtime.UNROLL = 1

    def bilinear(get):
        a = get(m11)
        dn = get(m21) - a
        dm = get(m12) - a
        dnm = get(m22) - get(m21) - get(m12) + a
        return a + (n - 1) * dn + (mu - 1) * dm + (n - 1) * (mu - 1) * dnm

    out = {}
    for key in ("flops", "bytes", "wire"):
        out[key] = bilinear(lambda m, k=key: m[k])
    ops = set().union(*[m["by_op"] for m in (m11, m21, m12, m22)])
    out["by_op"] = {
        o: bilinear(lambda m, o=o: m["by_op"].get(o, 0.0)) for o in ops
    }
    cts = set().union(*[m["counts"] for m in (m11, m21, m12, m22)])
    out["counts"] = {
        o: int(bilinear(lambda m, o=o: m["counts"].get(o, 0))) for o in cts
    }
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             metrics: bool = True):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    reason = skip_reason(cfg, cell)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if reason:
        rec["status"] = "SKIP"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    fn, args, donate = build_lowerable(arch, shape, mesh)
    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns [dict]
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo, n_dev)

    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mf = model_flops(
        cfg, cell.kind, tokens, paper_heads=PAPER_HEADS.get(arch)
    )
    if metrics:
        mx = extrapolated_metrics(arch, shape, mesh)
    else:
        mx = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": colls.wire_bytes,
            "by_op": colls.by_op,
            "counts": colls.counts,
        }
    rl = Roofline(
        flops=mx["flops"],
        bytes_accessed=mx["bytes"],
        wire_bytes=mx["wire"],
        n_devices=n_dev,
        model_flops=mf,
    )
    hbm = (
        ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes
    )
    rec.update(
        status="OK",
        t_lower_s=round(t_lower, 1),
        t_compile_s=round(t_compile, 1),
        arg_bytes=ma.argument_size_in_bytes,
        out_bytes=ma.output_size_in_bytes,
        temp_bytes=ma.temp_size_in_bytes,
        alias_bytes=ma.alias_size_in_bytes,
        hbm_per_device=hbm,
        fits_hbm=bool(hbm <= HW["hbm_bytes"]),
        flops_per_device=rl.flops,
        bytes_per_device=rl.bytes_accessed,
        wire_bytes_per_device=rl.wire_bytes,
        raw_flops_rolled=float(ca.get("flops", 0.0)),
        coll_by_op={k: round(v) for k, v in mx["by_op"].items()},
        coll_counts=mx["counts"],
        t_compute=rl.t_compute,
        t_memory=rl.t_memory,
        t_collective=rl.t_collective,
        bottleneck=rl.bottleneck,
        model_flops=mf,
        useful_flops_ratio=rl.useful_flops_ratio,
        mfu=rl.mfu,
    )
    if verbose:
        print(f"--- {arch} × {shape} × {rec['mesh']} ---")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(
            f"  memory/device: args {ma.argument_size_in_bytes/2**30:.2f}GiB "
            f"out {ma.output_size_in_bytes/2**30:.2f}GiB "
            f"temp {ma.temp_size_in_bytes/2**30:.2f}GiB "
            f"alias {ma.alias_size_in_bytes/2**30:.2f}GiB "
            f"-> {hbm/2**30:.2f}GiB "
            f"({'fits' if rec['fits_hbm'] else 'EXCEEDS'} 16GiB HBM)"
        )
        print(
            f"  per-device: {rl.flops/1e12:.2f} TFLOP, "
            f"{rl.bytes_accessed/2**30:.2f} GiB accessed, "
            f"{rl.wire_bytes/2**20:.1f} MiB on wire {mx['counts']}"
        )
        print(
            f"  roofline: compute {rl.t_compute*1e3:.2f}ms "
            f"memory {rl.t_memory*1e3:.2f}ms "
            f"collective {rl.t_collective*1e3:.2f}ms "
            f"-> bottleneck={rl.bottleneck} "
            f"useful={rl.useful_flops_ratio:.2f} mfu={rl.mfu:.3f}"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--json", default=None, help="append JSONL records here")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                if not args.single_pod_only:
                    cells.append((arch, shape, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        cells.append((args.arch, args.shape, args.multi_pod))

    records = []
    failed = []
    for arch, shape, mp in cells:
        try:
            # roofline metrics are a single-pod deliverable; the multi-pod
            # pass proves the pod axis shards (compile + memory only)
            rec = run_cell(arch, shape, mp, metrics=not mp)
        except Exception as e:  # noqa: BLE001 — report all cell failures
            traceback.print_exc()
            rec = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "FAIL", "error": f"{type(e).__name__}: {e}",
            }
            failed.append(rec)
        records.append(rec)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")

    ok = sum(1 for r in records if r["status"] == "OK")
    skip = sum(1 for r in records if r["status"] == "SKIP")
    print(f"\n=== dry-run: {ok} OK, {skip} SKIP, {len(failed)} FAIL ===")
    if failed:
        for r in failed:
            print(f"  FAIL {r['arch']} × {r['shape']} × {r['mesh']}: {r['error']}")
        sys.exit(1)


if __name__ == "__main__":
    main()
