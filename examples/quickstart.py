"""Quickstart: the Cortex semantic cache in ~60 lines.

Builds a synthetic semantic world, inserts a few tool results, and shows
the two-stage semantic hit pipeline, the confusable-pair rejection (why
the judge exists), LCFU eviction and TTL aging.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.cache import make_cache
from repro.core.judge import OracleJudge
from repro.data.world import SemanticWorld

world = SemanticWorld(n_intents=100, dim=64, seed=0)
judge = OracleJudge(world, accuracy=1.0, seed=0)
cache = make_cache(
    capacity_bytes=50_000, dim=world.dim, judge=judge,
    tau_sim=0.9, tau_lsm=0.9, max_ttl=600.0,
)

# 1. miss -> fetch remotely -> admit as a Semantic Element
q0 = world.query(intent=7, paraphrase=0)
emb0 = world.embed(q0)
res = cache.lookup(q0, emb0, now=0.0)
print(f"first lookup: hit={res.hit}  (cold cache)")
cache.insert(q0, emb0, world.fetch(q0), now=0.0, cost=0.005, latency=0.4,
             size=world.value_size(q0))

# 2. a *paraphrase* of the same intent -> semantic HIT (exact-match would miss)
q1 = world.query(intent=7, paraphrase=13)
res = cache.lookup(q1, world.embed(q1), now=1.0)
print(f"paraphrase lookup: hit={res.hit}  value={res.se.value!r}")

# 3. a confusable intent (cos ~ 0.93 > tau_sim!) -> ANN candidate, judge REJECTS
pair = world.intents[7].confusable_with
if pair is None:
    pair = next(i.iid for i in world.intents if i.confusable_with is not None)
    qx = world.query(pair, 0)
    cache.insert(qx, world.embed(qx), world.fetch(qx), now=1.0, cost=0.005,
                 latency=0.4, size=world.value_size(qx))
    pair = world.intents[pair].confusable_with
qc = world.query(pair, 2)
res = cache.lookup(qc, world.embed(qc), now=2.0)
print(f"confusable lookup: candidates={res.n_candidates} hit={res.hit} "
      f"(judge rejected a false positive)")

# 4. LCFU: fill beyond capacity; cheap/ephemeral items are evicted first
now = 3.0
for i in range(30, 60):
    q = world.query(i, 0)
    cache.insert(q, world.embed(q), world.fetch(q), now=now, cost=0.005,
                 latency=0.4, size=world.value_size(q))
    now += 0.1
print(f"after pressure: items={len(cache)} evictions={cache.stats.evictions} "
      f"usage={cache.usage}/{cache.capacity_bytes}B")

# 5. TTL aging: ephemeral items (staticity 1-3) expire quickly
expired = cache.purge_expired(now + 3600.0)
print(f"after 1h: {expired} items TTL-expired, {len(cache)} remain")
print("stats:", cache.stats)
