"""Tiered SE storage: fp32 HOT tier + int8/zlib WARM tier (DESIGN.md §10).

The single-tier cache discards every LCFU victim outright, so the next
semantically-equal query pays the full WAN fetch even when the SE's own
cost/latency metadata says it was worth keeping in a cheaper form. This
module turns eviction into a *lifecycle*:

  * **demote** — HOT LCFU victims move into the WARM tier: embedding
    int8 symmetric per-row quantized (4× rows per byte), value
    zlib-compressed, all SoA metadata (freq/cost/latency/staticity/
    provenance) carried over, **absolute expiry preserved** — demotion
    never extends a TTL, mirroring the federation lease rule.
  * **warm hit** — a query whose HOT stage 1 comes up empty runs the
    quantized coarse scan (``kernels/ann_topk_quant`` on TPU, the
    bit-matching numpy path on CPU) followed by an fp32 rescore of the
    top-R finalists, then the NORMAL judge gate — the two-stage Seri
    pipeline is exactly what makes a lossy tier safe, because every warm
    hit is re-validated before it counts.
  * **promote** — a validated warm hit moves the entry back to HOT
    (dequantized embedding, decompressed value), again at its original
    absolute expiry.
  * **true eviction** — only WARM LCFU victims (and victims too large
    for the warm tier) leave the system; those are what
    ``CacheStats.evictions`` counts under a :class:`TieredCache`.

Capacity accounting stays value-byte-based in both tiers (embeddings are
an HBM budget, not a cache-byte budget, matching the HOT tier's existing
convention): a warm entry charges ``ceil(size × value_ratio)`` bytes —
the compression-ratio-scaled footprint of its zlib'd payload — so at
equal total bytes the warm tier retains ~1/value_ratio× more entries.
"""
from __future__ import annotations

import dataclasses
import math
import pickle
import zlib
from typing import Any, Optional

import numpy as np

from repro.core.cache import CortexCache
from repro.core.clustering import ClusterConfig, ClusterRouter
from repro.core.se_store import SEStore
from repro.core.semantic_element import SemanticElement
from repro.core.seri import (RowIndex, Seri, VectorIndex, sharded_topk_merge,
                             topk_desc, topk_desc_stable)

NEG = -3.0e38  # matches kernels/ann_topk_quant.NEG (masked-row sentinel)


# --------------------------------------------------------------- quantize

def quantize_rows(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-row int8: scale = amax/127, q = rint(x/scale).

    Deterministic round-half-to-even (np.rint == jnp rounding), so the
    numpy and Pallas coarse paths score identical integers. All-zero rows
    get scale 1.0 to avoid 0/0."""
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=-1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(x / scale[..., None]), -127, 127).astype(np.int8)
    return q, scale


def _pack(value: Any) -> bytes:
    return zlib.compress(pickle.dumps(value, protocol=4), 6)


def _unpack(blob: bytes) -> Any:
    return pickle.loads(zlib.decompress(blob))


# ------------------------------------------------------------ quant index

class QuantIndex(RowIndex):
    """Fixed-capacity int8 embedding store with two-phase retrieval.

    Row management (free list, active mask, se_id mapping) comes from
    the :class:`~repro.core.seri.RowIndex` base the hot
    ``VectorIndex`` also uses, so the two tiers' row lifecycles agree by
    construction. Coarse: fully-quantized matmul (int8 emb × int8 query,
    int32 accumulate) selecting the top ``rescore_mult × k`` candidates
    per query. Fine: fp32 query · dequantized candidate rows, which
    removes the query-quantization error before the τ_sim gate. The
    numpy and ``kernel`` (Pallas) backends multiply the scale factors in
    the same order, so the coarse scores agree bit-for-bit.
    """

    def __init__(self, capacity: int, dim: int, backend: str = "numpy",
                 rescore_mult: int = 4, router=None):
        super().__init__(capacity, dim, router=router)
        self.backend = backend
        self.rescore_mult = rescore_mult
        self.emb_q = np.zeros((capacity, dim), np.int8)
        # int32 mirror of emb_q for the numpy coarse matmul (numpy would
        # otherwise overflow int8 accumulation — and per-search .astype
        # copies of the whole matrix are the hot-path cost to avoid).
        # On TPU the kernel reads the int8 matrix directly; the mirror is
        # a host-simulation artifact.
        self._emb_i32 = np.zeros((capacity, dim), np.int32)
        self.scale = np.zeros(capacity, np.float32)
        if backend == "kernel":
            from repro.kernels.ops import (ann_topk_ivf_quant_jit,
                                           ann_topk_ivf_quant_sharded_jit,
                                           ann_topk_quant_jit)

            self._kernel_fn = ann_topk_quant_jit
            self._ivf_kernel_fn = ann_topk_ivf_quant_jit
            self._ivf_sharded_fn = ann_topk_ivf_quant_sharded_jit

    def add(self, se_id: int, embedding: np.ndarray) -> int:
        row = self._alloc(se_id)
        q, s = quantize_rows(np.asarray(embedding, np.float32)[None])
        self.emb_q[row] = q[0]
        self._emb_i32[row] = q[0]
        self.scale[row] = s[0]
        if self.router is not None:
            self.router.note_add(
                row, np.asarray(embedding, np.float32), self
            )
        return row

    def _clear_rows(self, ra: np.ndarray) -> None:
        self.emb_q[ra] = 0
        self._emb_i32[ra] = 0
        self.scale[ra] = 0.0

    def route_embs(self, rows: np.ndarray) -> np.ndarray:
        """Dequantized, renormalized fp32 rows for centroid training —
        the router sees (near enough) the same vectors the fine rescore
        phase does, so quantization error cannot skew routing."""
        v = self.emb_q[rows].astype(np.float32) * self.scale[rows][:, None]
        n = np.linalg.norm(v, axis=1, keepdims=True)
        return v / np.maximum(n, 1e-30)

    def dequantize(self, row: int) -> np.ndarray:
        """fp32 reconstruction, renormalized to unit length (the hot
        index assumes unit-norm rows for cosine)."""
        v = self.emb_q[row].astype(np.float32) * float(self.scale[row])
        n = float(np.linalg.norm(v))
        return v / n if n > 0 else v

    # ----------------------------------------------------------- search

    def search(self, q: np.ndarray, k: int, tau_sim: float):
        return self.search_batch(q[None], k, tau_sim)[0]

    def _coarse_routed(self, qq, qs, r: int, routed):
        """Quantized coarse scan over the routed cluster union only —
        same int32 math and scale-multiply order as the brute path, so
        at nprobe=all the scored matrix is the brute matrix restricted
        to active rows (same values, same tie order)."""
        g_rows, allowed, self.last_scanned = routed
        rt = self.router
        s = (qq.astype(np.int32) @ self._emb_i32[g_rows].T
             ).astype(np.float32)
        s = s * self.scale[g_rows][None, :]
        s = s * qs[:, None]
        s = np.where(allowed, s, NEG)
        if rt.n_shards > 1:
            # same shard-parallel selection as the hot index — the
            # score matrix is identical, so the merge is bit-identical
            # to the unsharded coarse pass (DESIGN.md §13)
            owners = rt.shard_of[rt.assign[g_rows]]
            n_cent = self.last_scanned - len(g_rows)
            self.last_scanned_max_shard = n_cent + int(
                np.bincount(owners, minlength=rt.n_shards).max())
            lrows, vals = sharded_topk_merge(s, owners, rt.n_shards, r)
        else:
            self.last_scanned_max_shard = self.last_scanned
            lrows, vals = topk_desc(s, r)                     # (B, r)
        return g_rows[lrows], vals

    def _coarse_routed_kernel(self, q, qq, qs, r: int):
        """Routed coarse scan on the Pallas backend: routing runs inside
        the jit wrapper (fp32 query vs centroids), no host-side
        route()/gather; rows-scanned derives from the kernel's own
        cluster selection."""
        rt = self.router
        if rt.n_shards > 1 and self._ivf_sharded_fn is not None:
            return self._coarse_routed_kernel_sharded(q, qq, qs, r)
        (bq, bscale), bucket_rows, bucket_valid = \
            rt.kernel_buckets(self, quant=True)
        nprobe = rt.cfg.n_clusters if rt.cfg.nprobe is None \
            else min(rt.cfg.nprobe, rt.cfg.n_clusters)
        live = rt.counts > 0
        vals, rows, sel, en = self._ivf_kernel_fn(
            rt.centroids, live.astype(np.int32), bq,
            bscale, bucket_rows, bucket_valid, q, qq, qs, nprobe, r,
        )
        probed = np.unique(np.asarray(sel)[np.asarray(en) > 0])
        self.last_scanned = int(live.sum() + rt.counts[probed].sum())
        self.last_scanned_max_shard = self.last_scanned
        return np.asarray(rows), np.asarray(vals)

    def _coarse_routed_kernel_sharded(self, q, qq, qs, r: int):
        """Shard-parallel quantized coarse scan — the int8 sibling of
        ``VectorIndex._search_routed_kernel_sharded`` (DESIGN.md §13):
        global routing, per-shard Pallas scans under ``shard_map``, one
        cross-shard ``lax.top_k`` merge."""
        rt = self.router
        (bq, bscale), shard_rows, shard_valid, bounds = \
            rt.kernel_shard_buckets(self, quant=True)
        nprobe = rt.cfg.n_clusters if rt.cfg.nprobe is None \
            else min(rt.cfg.nprobe, rt.cfg.n_clusters)
        live = rt.counts > 0
        vals, rows, sel, en = self._ivf_sharded_fn(
            rt.centroids, live.astype(np.int32), bq, bscale,
            shard_rows, shard_valid, bounds, q, qq, qs, nprobe, r,
        )
        probed = np.unique(np.asarray(sel)[np.asarray(en) > 0])
        n_cent = int(live.sum())
        per_shard = np.bincount(
            rt.shard_of[probed], weights=rt.counts[probed],
            minlength=rt.n_shards)
        self.last_scanned = n_cent + int(rt.counts[probed].sum())
        self.last_scanned_max_shard = n_cent + int(per_shard.max())
        return np.asarray(rows), np.asarray(vals)

    def _coarse_brute(self, qq, qs, r: int):
        if self._kernel_fn is not None:
            vals, rows = self._kernel_fn(
                self.emb_q, self.scale, self.active, qq, qs, r
            )
            return np.asarray(rows), np.asarray(vals)
        # (B, N) row-major, same layout rationale as VectorIndex;
        # scale multiply order matches the kernel exactly
        s = (qq.astype(np.int32) @ self._emb_i32.T).astype(np.float32)
        s = s * self.scale[None, :]
        s = s * qs[:, None]
        s = np.where(self.active[None, :], s, NEG)
        rows, vals = topk_desc(s, r)                          # (B, r)
        return rows, vals

    def search_batch(self, q: np.ndarray, k: int, tau_sim: float):
        """q (B, dim) fp32 unit-norm -> list of B (se_ids, sims) pairs,
        similarity-descending, gated at tau_sim on the RESCORED sims."""
        b = q.shape[0]
        if len(self) == 0:
            self.last_scanned = 0
            self.last_scanned_max_shard = 0
            empty = ([], np.zeros(0, np.float32))
            return [empty] * b
        q = np.asarray(q, np.float32)
        r = max(k * self.rescore_mult, k)
        qq, qs = quantize_rows(q)
        rows, vals, routed = self._routed_dispatch(
            q,
            lambda: self._coarse_routed_kernel(q, qq, qs, r),
            lambda info: self._coarse_routed(qq, qs, r, info),
            lambda: self._coarse_brute(qq, qs, r),
        )
        out = []
        for i in range(b):
            keep = vals[i] > NEG / 2          # drop masked/duplicate slots
            if routed:
                keep &= rows[i] >= 0   # kernel NEG slots carry row -1
            rs = rows[i][keep]
            if not len(rs):
                out.append(([], np.zeros(0, np.float32)))
                continue
            # fine phase: exact fp32 query against dequantized rows
            deq = self.emb_q[rs].astype(np.float32) * \
                self.scale[rs][:, None]
            sims = deq @ q[i]
            # top-k of the R finalists via argpartition with exact
            # stable-argsort tie parity (the ISSUE 5 full-sort audit)
            order = topk_desc_stable(sims, min(k, len(rs)))
            sims_k = sims[order].astype(np.float32)
            gate = sims_k >= tau_sim
            # row→se_id as ONE int64 gather (no per-candidate loop)
            out.append((self.row_se[rs[order][gate]].tolist(),
                        sims_k[gate]))
        return out


# ------------------------------------------------------------ warm views

class WarmElement:
    """Read view onto one WARM-tier row. Mirrors the SemanticElement
    surface the judge/engine/federation paths touch (key, value, expiry,
    staticity, economics); ``value`` decompresses on access. A promotion
    retires the row, after which the view is dead (``valid`` is False) —
    consumers snapshot key/value before triggering hit accounting."""

    __slots__ = ("_tier", "_row", "se_id")
    tier = "warm"

    def __init__(self, tier: "WarmTier", row: int):
        self._tier = tier
        self._row = int(row)
        self.se_id = int(tier.soa.se_id[row])

    @property
    def valid(self) -> bool:
        return int(self._tier.soa.se_id[self._row]) == self.se_id

    @property
    def key(self) -> str:
        return self._tier.soa.key[self._row]

    @property
    def value(self) -> Any:
        return _unpack(self._tier.soa.value[self._row])

    @property
    def size(self) -> int:
        """ORIGINAL (uncompressed) byte size — what a transfer moves and
        what the entry will charge once promoted back to HOT."""
        return int(self._tier.orig_size[self._row])

    @property
    def warm_bytes(self) -> int:
        return int(self._tier.soa.size[self._row])

    @property
    def row(self) -> int:
        return self._row

    def expired(self, now: float) -> bool:
        return now >= self.expires_at

    def __repr__(self) -> str:
        return (f"WarmElement(se_id={self.se_id}, key={self.key!r}, "
                f"freq={self.freq}, warm_bytes={self.warm_bytes})")


def _warm_field(name, cast):
    def get(self):
        return cast(getattr(self._tier.soa, name)[self._row])

    return property(get)


for _name, _cast in (("freq", int), ("staticity", int), ("cost", float),
                     ("latency", float), ("created_at", float),
                     ("expires_at", float), ("last_access", float),
                     ("prefetched", bool), ("intent", lambda v: v),
                     ("origin", lambda v: v), ("version", int),
                     ("fetched_at", float)):
    setattr(WarmElement, _name, _warm_field(_name, _cast))


# -------------------------------------------------------------- warm tier

class WarmTier:
    """Quantized/compressed second tier with its own SoA metadata.

    Owns a :class:`QuantIndex` + :class:`SEStore` pair (row-aligned, same
    free-list discipline as the hot pair) and byte accounting over the
    COMPRESSED footprint. Mutations return counts so the owning
    :class:`TieredCache` does all stats bookkeeping in one place.
    """

    def __init__(self, capacity_bytes: int, dim: int, *,
                 index_capacity: int = 8192, backend: str = "numpy",
                 value_ratio: float = 0.4, rescore_mult: int = 4,
                 router=None):
        # NOTE: the warm tier's extra access latency is an ENGINE-side
        # virtual-time cost (EngineConfig.t_cache_warm, like t_cache_cpu)
        # — it is deliberately not duplicated here
        self.capacity_bytes = capacity_bytes
        self.value_ratio = value_ratio
        self.index = QuantIndex(index_capacity, dim, backend=backend,
                                rescore_mult=rescore_mult, router=router)
        self.soa = SEStore(index_capacity)
        # soa.size holds the WARM (compressed) footprint for capacity and
        # per-byte LCFU scoring; the original size rides alongside for
        # promotion and federation transfers
        self.orig_size = np.zeros(index_capacity, np.int64)
        self.usage = 0

    def __len__(self) -> int:
        return len(self.soa)

    def warm_size(self, orig_size: int) -> int:
        """ceil(size × value_ratio), as DESIGN.md §10 specifies — the
        charge never understates the compressed footprint."""
        return max(1, math.ceil(orig_size * self.value_ratio))

    def view(self, se_id: int) -> WarmElement:
        return WarmElement(self, self.soa.id2row[se_id])

    # --------------------------------------------------------- mutation

    def remove_row(self, row: int) -> None:
        """Free one warm row (promotion/purge/eviction tail; no stats)."""
        self.usage -= int(self.soa.size[row])
        self.index.remove_rows([row])
        self.soa.remove_row(row)
        self.orig_size[row] = 0

    def purge_expired(self, now: float) -> int:
        dead = self.soa.expired_rows(now)
        for r in dead:
            self.remove_row(int(r))
        return len(dead)

    def _make_room(self, incoming: int, now: float,
                   eviction: str) -> tuple[int, int]:
        """Free bytes for an incoming demotion. Returns (ttl_purged,
        evicted) — warm victims are the cache's TRUE evictions."""
        if self.usage + incoming <= self.capacity_bytes and \
                not self.index.full:
            return 0, 0
        ttl_n = self.purge_expired(now)
        need = self.usage + incoming - self.capacity_bytes
        ev = 0
        if need > 0:
            victims = self.soa.victim_rows(now, eviction, need_bytes=need)
            for r in victims:
                self.remove_row(int(r))
            ev += len(victims)
        if self.index.full:
            victims = self.soa.victim_rows(now, eviction, n=1)
            for r in victims:
                self.remove_row(int(r))
            ev += len(victims)
        return ttl_n, ev

    def admit(self, meta: dict, emb: np.ndarray, now: float,
              eviction: str) -> tuple[bool, int, int]:
        """Admit one demoted SE. Returns (admitted, ttl_purged, evicted).

        ``meta`` carries the full hot-tier SoA snapshot: expiry stays
        ABSOLUTE (never re-derived from staticity), freq/last_access/
        provenance ride along so a later promotion restores the entry
        exactly as it left."""
        wsize = self.warm_size(meta["size"])
        if wsize > self.capacity_bytes:
            return False, 0, 0
        ttl_n, ev = self._make_room(wsize, now, eviction)
        row = self.index.add(meta["se_id"], emb)
        # every field rides along verbatim; only the value representation
        # (compressed) and the charged size (compressed footprint) change
        self.soa.add_meta(
            row, {**meta, "value": _pack(meta["value"]), "size": wsize}
        )
        self.orig_size[row] = meta["size"]
        self.usage += wsize
        return True, ttl_n, ev

    def take(self, se_id: int) -> Optional[tuple[dict, np.ndarray]]:
        """Remove an entry and return its full metadata snapshot +
        dequantized embedding (the promotion handoff), or None if the
        entry vanished (evicted between stage 1 and judge completion)."""
        row = self.soa.id2row.get(se_id)
        if row is None:
            return None
        meta = self.soa.snapshot_row(row)
        meta["value"] = _unpack(meta["value"])
        meta["size"] = int(self.orig_size[row])
        emb = self.index.dequantize(row)
        self.remove_row(row)
        return meta, emb

    # ----------------------------------------------------------- search

    def search_batch(self, q_embs: np.ndarray, k: int, tau_sim: float,
                     now: float):
        """Stage-1 over the warm tier: per query (cands, sims), sims
        aligned with the surviving (unexpired) candidates."""
        found = self.index.search_batch(np.asarray(q_embs), k, tau_sim)
        out = []
        for se_ids, sims in found:
            keep = [
                j for j, i in enumerate(se_ids)
                if i in self.soa.id2row
                and now < self.soa.expires_at[self.soa.id2row[i]]
            ]
            cands = [WarmElement(self, self.soa.id2row[se_ids[j]])
                     for j in keep]
            out.append((cands, np.asarray(sims[keep], np.float32)))
        return out


# ------------------------------------------------------------ tiered cache

@dataclasses.dataclass
class TierStats:
    demotions: int = 0         # HOT victims rehomed in WARM
    promotions: int = 0        # validated warm hits moved back to HOT
    warm_lookups: int = 0      # queries whose stage 1 consulted WARM
    warm_hits: int = 0         # hits served from a WARM candidate
    warm_evictions: int = 0    # WARM LCFU victims (true evictions)
    warm_ttl_evictions: int = 0
    demote_drops: int = 0      # victims that could not fit in WARM


class TieredCache(CortexCache):
    """CortexCache whose LCFU victims demote to a WARM tier instead of
    vanishing. ``CacheStats.evictions`` keeps meaning "left the system"
    (warm victims + demote drops), so single-tier comparisons hold."""

    def __init__(self, seri: Seri, *, warm: WarmTier, **kw):
        super().__init__(seri, **kw)
        self.warm = warm
        self.tier_stats = TierStats()

    # --------------------------------------------------------- lifecycle

    def _demote_rows(self, rows: np.ndarray, now: float) -> None:
        """Move hot victims into the warm tier (Algorithm 2 victims, in
        eviction order). Already-expired victims just die (TTL count);
        victims the warm tier cannot hold at all are true evictions."""
        if not len(rows):
            return
        metas = [
            (self.soa.snapshot_row(int(r)),
             np.array(self.seri.index.emb[int(r)], copy=True),
             bool(self.soa.revalidating[int(r)]))
            for r in rows
        ]
        self._drop_rows(np.asarray(rows))
        for meta, emb, revalidating in metas:
            if revalidating:
                # KNOWN-stale victim (refetch in flight): demoting would
                # park the stale value in WARM where the refresh cannot
                # find it — it just leaves the system
                self.stats.invalidations += 1
                continue
            if meta["expires_at"] <= now:
                self.stats.ttl_evictions += 1
                continue
            ok, ttl_n, ev = self.warm.admit(meta, emb, now, self.eviction)
            self.stats.ttl_evictions += ttl_n
            self.tier_stats.warm_ttl_evictions += ttl_n
            self.stats.evictions += ev
            self.tier_stats.warm_evictions += ev
            if ok:
                self.tier_stats.demotions += 1
            else:
                self.stats.evictions += 1
                self.tier_stats.demote_drops += 1

    def _promote(self, we: WarmElement,
                 now: float) -> Optional[SemanticElement]:
        """Move a validated warm winner back to HOT with every field —
        including the ABSOLUTE expiry — exactly as it left. Returns the
        live hot view, or None if the entry vanished or expired."""
        taken = self.warm.take(we.se_id)
        if taken is None:
            return None
        meta, emb = taken
        if meta["expires_at"] <= now:
            self.stats.ttl_evictions += 1
            return None
        # hot admission may itself demote victims; the promoted entry is
        # already out of the warm tier, so no cycle
        self._make_room(meta["size"], now)
        if self.seri.index.full:
            self._evict_n(1, now)
        row = self.seri.index.add(meta["se_id"], emb)
        self.soa.add_meta(row, meta)
        self.usage += meta["size"]
        self.stats.bytes_stored = self.usage
        self.tier_stats.promotions += 1
        se = self.store[meta["se_id"]]
        if self.on_promote is not None:
            # refresh-ahead timers die during a warm sojourn — tell the
            # freshness layer this entry is hot (and renewable) again
            self.on_promote(se)
        return se

    # --------------------------------------------------- eviction hooks

    def _retire_victims(self, victims: np.ndarray, now: float) -> None:
        self._demote_rows(victims, now)

    def purge_expired(self, now: float) -> int:
        n = super().purge_expired(now)
        wn = self.warm.purge_expired(now)
        self.stats.ttl_evictions += wn
        self.tier_stats.warm_ttl_evictions += wn
        return n + wn

    # ------------------------------------------------------------ lookup

    def _stage1_blocks(self, q_embs: np.ndarray, now: float):
        """Per-query (cands, sims): HOT stage 1 for the whole block, then
        one batched WARM scan for exactly the queries HOT turned up empty
        — the warm tier sits BEHIND the hot tier, not beside it. Every
        lookup flavor (scalar, batched, engine staged) inherits this seam
        from CortexCache, so the tiers cannot diverge per path.

        Tier membership is observed at BLOCK START: a promotion triggered
        by query j lands after query j+1's stage 1 already ran, so j+1
        may hold a warm view of an entry that is hot by the time the
        judge returns — ``_rebind`` redirects those to the live hot row.
        Hit/miss outcomes match the scalar path; only the warm-consult
        COUNT is batch-granularity dependent."""
        q_embs = np.asarray(q_embs)
        out, flags = super()._stage1_blocks(q_embs, now)
        warm_qi = [bi for bi, (cands, _) in enumerate(out)
                   if not cands and len(self.warm)]
        if warm_qi:
            self.tier_stats.warm_lookups += len(warm_qi)
            wfound = self.warm.search_batch(
                q_embs[warm_qi], self.seri.top_k, self.seri.stage1_gate,
                now
            )
            # the warm coarse scan's rows join the pass's scan-
            # proportional latency term (DESIGN.md §12); its busiest
            # shard joins the max-over-shards critical path (§13)
            self.scan.add_warm_pass(
                self.warm.index.last_scanned,
                self.warm.index.last_scanned_max_shard,
            )
            for bi, (wc, wsims) in zip(warm_qi, wfound):
                # the consult FACT (flowing back through
                # stage1_batch_flagged) feeds the engine's per-tier
                # latency accounting — consults that come back empty
                # still paid the warm scan
                flags[bi] = True
                if wc:
                    out[bi] = (wc, wsims)
        return out, flags

    def _rebind(self, se, now: float):
        if se.tier == "warm":
            if se.se_id in self.store:
                # an earlier query in this batch (or judge micro-batch)
                # already promoted it — bind to the live hot view
                return self.store[se.se_id]
            pse = self._promote(se, now)
            if pse is not None:
                self.tier_stats.warm_hits += 1
            return pse
        if se.se_id in self.store:
            # always re-resolve through id2row: tier promotions reassign
            # rows, so a stage-1 view's row may now hold a DIFFERENT SE
            # (returning `se` here served the wrong entry's value once a
            # promote→demote cycle reused its row mid-batch)
            live = self.store[se.se_id]
            return None if live.revalidating else live
        if se.se_id in self.warm.soa.id2row:
            # a HOT candidate demoted mid-batch (an earlier promotion's
            # make_room): the entry is alive in WARM — pull it back
            # rather than scoring a spurious miss. Not a warm_hit: the
            # match was discovered by the hot stage 1.
            return self._promote(self.warm.view(se.se_id), now)
        return None

    def account_hit(self, se, now: float) -> None:
        """The nojudge ablation hands stage-1 winners straight here; a
        warm winner must still promote so the freq bump lands on a live
        hot row (callers snapshot key/value first — promotion retires
        the warm view)."""
        if getattr(se, "tier", "hot") == "warm":
            if se.se_id in self.store:      # already promoted this window
                se = self.store[se.se_id]
            else:
                pse = self._promote(se, now)
                if pse is None:
                    # vanished mid-flight: count the hit, nothing to mutate
                    self.stats.hits += 1
                    return
                self.tier_stats.warm_hits += 1
                se = pse
        super().account_hit(se, now)

    # --------------------------------------------------------- freshness

    def ses_for_intent(self, intent) -> list:
        """Hot views first (se_id order), then warm — a change-feed
        notice must reach BOTH tiers: a stale warm entry would otherwise
        promote with its stale value on the next judge-validated hit."""
        out = super().ses_for_intent(intent)
        wids = self.warm.soa.by_intent.get(intent)
        if wids:
            out.extend(self.warm.view(i) for i in sorted(wids))
        return out

    def has_intent(self, intent) -> bool:
        return super().has_intent(intent) or \
            intent in self.warm.soa.by_intent

    def invalidate_se(self, se_id: int, now: float) -> bool:
        if se_id in self.soa.id2row:
            return super().invalidate_se(se_id, now)
        row = self.warm.soa.id2row.get(se_id)
        if row is None:
            return False
        self.warm.remove_row(row)
        self.stats.invalidations += 1
        return True

    def peek_semantic_scored(self, query: str, q_emb: np.ndarray,
                             now: float):
        """Both tiers, hot first — federation peers can lease warm
        entries (a warm lease carries the ORIGINAL size/value; the warm
        copy stays put, only a promotion moves it). Overriding the
        SCORED peek means ``peek_semantic`` and ``peek_lease`` (the
        judge-pipeline-validated federation path) inherit warm-tier
        consultation for free."""
        hit = super().peek_semantic_scored(query, q_emb, now)
        if hit is not None or not len(self.warm):
            return hit
        (cands, sims), = self.warm.search_batch(
            q_emb[None], self.seri.top_k, self.seri.stage1_gate, now
        )
        return (cands[0], float(sims[0])) if cands else None

    @property
    def total_usage(self) -> int:
        """Bytes across both tiers (hot fp32 values + warm compressed)."""
        return self.usage + self.warm.usage


def make_tiered_cache(
    *,
    hot_bytes: int,
    warm_bytes: int,
    dim: int,
    judge,
    index_capacity: int = 8192,
    warm_index_capacity: Optional[int] = None,
    tau_sim: float = 0.9,
    tau_lsm: float = 0.9,
    top_k: int = 4,
    eviction: str = "lcfu",
    max_ttl: float = 3600.0,
    backend: str = "numpy",
    warm_backend: Optional[str] = None,
    warm_value_ratio: float = 0.4,
    rescore_mult: int = 4,
    cluster: Optional[ClusterConfig] = None,
) -> TieredCache:
    """Factory mirroring ``make_cache``: hot fp32 index + seri in front of
    an int8 warm tier. ``warm_backend`` defaults to the hot backend
    ("kernel" → the quantized Pallas kernel). ``cluster`` enables the
    clustered stage-1 routing (DESIGN.md §12) on BOTH tiers — each tier
    gets its own router instance (the warm seed offset by 1 so the two
    tiers' mini-batch draws are independent)."""
    hot_router = warm_router = None
    if cluster is not None:
        wcap = warm_index_capacity or index_capacity
        hot_router = ClusterRouter(index_capacity, dim, cluster)
        warm_router = ClusterRouter(
            wcap, dim,
            dataclasses.replace(cluster, seed=cluster.seed + 1),
        )
    index = VectorIndex(index_capacity, dim, backend=backend,
                        router=hot_router)
    seri = Seri(index, judge, tau_sim=tau_sim, tau_lsm=tau_lsm, top_k=top_k)
    warm = WarmTier(
        warm_bytes, dim,
        index_capacity=warm_index_capacity or index_capacity,
        backend=warm_backend or backend,
        value_ratio=warm_value_ratio,
        rescore_mult=rescore_mult,
        router=warm_router,
    )
    return TieredCache(
        seri, warm=warm, capacity_bytes=hot_bytes, max_ttl=max_ttl,
        eviction=eviction,
    )
