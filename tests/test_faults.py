"""Fault-injection tests (DESIGN.md §17): the FaultSchedule window
algebra + spec grammar, origin-brownout terminal failure in the remote
service, and the neutrality contract — an armed-but-inactive schedule
must leave every stream byte-identical to a fault-free run."""
import json

import pytest

from repro.launch.serve import run_once
from repro.serving.faults import FaultSchedule, FaultWindow
from repro.serving.remote import RemoteDataService


def _canon(s):
    return json.dumps(s, sort_keys=True, default=float)


# ------------------------------------------------------- window algebra


def test_windows_are_half_open_and_region_scoped():
    sched = FaultSchedule([
        FaultWindow("region_outage", 10.0, 20.0, region=1),
    ])
    assert sched.region_down(1, 10.0)          # closed at start
    assert sched.region_down(1, 19.999)
    assert not sched.region_down(1, 20.0)      # open at end
    assert not sched.region_down(1, 9.999)
    assert not sched.region_down(0, 15.0)      # other regions unaffected


def test_region_none_hits_every_region():
    sched = FaultSchedule([FaultWindow("region_outage", 0.0, 5.0)])
    assert sched.region_down(0, 1.0) and sched.region_down(7, 1.0)


def test_link_mult_composes_and_touches_either_endpoint():
    sched = FaultSchedule([
        FaultWindow("wan_degrade", 0.0, 10.0, region=1, mult=3.0),
        FaultWindow("wan_degrade", 0.0, 10.0, mult=2.0),  # all links
    ])
    assert sched.link_mult(0, 1, 5.0) == pytest.approx(6.0)  # both apply
    assert sched.link_mult(1, 2, 5.0) == pytest.approx(6.0)  # either end
    assert sched.link_mult(0, 2, 5.0) == pytest.approx(2.0)  # global only
    assert sched.link_mult(0, 1, 10.0) == 1.0                # expired


def test_judge_mult_and_brownout_queries():
    sched = FaultSchedule([
        FaultWindow("judge_slowdown", 0.0, 5.0, region=2, mult=4.0),
        FaultWindow("origin_brownout", 1.0, 3.0, error_rate=0.5,
                    throttle=0.25),
    ])
    assert sched.judge_mult(2, 1.0) == pytest.approx(4.0)
    assert sched.judge_mult(0, 1.0) == 1.0
    bw = sched.brownout(0, 2.0)
    assert bw is not None and bw.error_rate == 0.5 and bw.throttle == 0.25
    assert sched.brownout(0, 3.0) is None


# --------------------------------------------------------- spec grammar


def test_parse_full_grammar():
    sched = FaultSchedule.parse([
        "region_outage:60:120:region=1",
        "wan_degrade:30:90:region=1,mult=4",
        "origin_brownout:20:80:error_rate=0.6,throttle=0.2",
        "judge_slowdown:10:50:mult=3",
    ])
    assert len(sched) == 4
    assert sched.region_down(1, 60.0) and not sched.region_down(0, 60.0)
    assert sched.link_mult(1, 2, 40.0) == pytest.approx(4.0)
    assert sched.brownout(0, 20.0).error_rate == pytest.approx(0.6)
    assert sched.judge_mult(0, 10.0) == pytest.approx(3.0)


def test_parse_rejects_bad_specs():
    with pytest.raises(ValueError):
        FaultSchedule.parse(["region_outage:60"])          # too few parts
    with pytest.raises(ValueError):
        FaultSchedule.parse(["meteor_strike:0:10"])        # unknown kind
    with pytest.raises(ValueError):
        FaultSchedule.parse(["wan_degrade:0:10:speed=3"])  # unknown key
    with pytest.raises(ValueError):
        FaultSchedule.parse(["wan_degrade:10:10"])         # empty window


# -------------------------------------------- origin brownout (remote)


def test_brownout_exhausts_retries_into_terminal_failure():
    sched = FaultSchedule.parse(["origin_brownout:0:1e9:error_rate=1.0"])
    svc = RemoteDataService(qpm=None, seed=0, faults=sched)
    out = svc.fetch(0.0)
    assert out.failed
    assert out.retries == svc.max_retries + 1   # bounded, not forever
    assert out.cost == 0.0                       # a failure is not billed
    assert svc.failed == 1
    assert svc.calls == 0
    # the summary-facing counters moved even though the fetch failed
    assert svc.throttled_wait == pytest.approx(out.throttled_wait)


def test_fetch_outside_brownout_window_is_untouched():
    sched = FaultSchedule.parse(["origin_brownout:50:60:error_rate=1.0"])
    a = RemoteDataService(qpm=None, seed=0, faults=sched)
    b = RemoteDataService(qpm=None, seed=0)
    oa, ob = a.fetch(0.0), b.fetch(0.0)
    assert not oa.failed
    assert oa == ob   # same seed, window inactive -> identical outcome


def test_armed_empty_schedule_is_stream_neutral():
    """The §17 contract at the service level: an armed schedule that
    never activates must not advance any rng the fault-free service
    uses — every outcome stays bit-identical."""
    a = RemoteDataService(qpm=50.0, seed=4, faults=FaultSchedule())
    b = RemoteDataService(qpm=50.0, seed=4)
    for i in range(40):
        assert a.fetch(i * 0.1) == b.fetch(i * 0.1)
    assert (a.calls, a.retries, a.total_cost) == \
        (b.calls, b.retries, b.total_cost)


# ------------------------------------------------- end-to-end neutrality


def test_run_once_with_inactive_faults_matches_plain_summary():
    kw = dict(n_requests=120, n_intents=100, dim=64, concurrency=4, seed=3)
    plain = run_once(**kw)
    armed = run_once(faults=["origin_brownout:1e8:2e8:error_rate=1.0"],
                     **kw)
    # the §17 keys surface only when a schedule is armed; the window
    # never activates, so no fetch may fail and — those keys stripped —
    # the whole summary must be byte-identical to the fault-free run
    assert "fetch_failed" not in plain
    assert armed.pop("fetch_failed") == 0
    armed.pop("throttled_wait")
    assert _canon(armed) == _canon(plain)


def test_run_once_brownout_completes_with_degraded_paths():
    """A hard 100 s brownout mid-run: every request must still complete
    (bounded retries + §17 degraded answers), failures must be counted,
    and with the controller ON some failures resolve from stale cache
    entries instead of re-fetching."""
    kw = dict(n_requests=300, n_intents=200, dim=64, churn_period=20.0,
              qpm=None, faults=["origin_brownout:50:150:error_rate=1.0"],
              seed=3)
    on = run_once(overload="on", **kw)
    off = run_once(overload="off", **kw)
    assert on["n"] == off["n"] == 300
    assert on["fetch_failed"] > 0 and off["fetch_failed"] > 0
    assert on["overload"]["stale_served"] > 0      # §17 serve-stale
    assert off["overload"]["stale_served"] == 0    # off-switch honoured
