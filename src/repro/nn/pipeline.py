"""GPipe-style pipeline parallelism over a mesh axis (the "pod" axis).

``pipeline_apply`` runs `stage_fn` per pipeline stage with microbatch
rotation via ``jax.lax.ppermute`` inside a fully-manual ``shard_map``:
stage s holds layers [s·L/S, (s+1)·L/S); microbatches stream through the
classic GPipe schedule (S + M − 1 ticks, bubble fraction (S−1)/(S+M−1)).

Provided as a composable runner (mesh-axis-agnostic) + tests; the default
multi-pod dry-run keeps pod-as-DP (DESIGN.md §5 gives the bubble/link-speed
rationale), so this is the opt-in building block for deeper meshes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn.sharding import shard_map_compat


def _pcast_varying(x, axis: str):
    """`jax.lax.pcast` annotates device-varying values for the new (jax ≥
    0.5) shard_map rep checker; on older jax (check_rep=False fallback in
    shard_map_compat) it doesn't exist and isn't needed."""
    pcast = getattr(jax.lax, "pcast", None)
    return x if pcast is None else pcast(x, (axis,), to="varying")


def pipeline_apply(mesh, axis: str, stage_fn, stage_params, x_microbatches):
    """Run a pipeline over mesh axis `axis`.

    stage_fn(params_slice, x) -> x     (one stage's computation)
    stage_params: pytree whose leaves have a leading dim == n_stages
    x_microbatches: (M, mb, ...) microbatched input, replicated over `axis`

    Returns (M, mb, ...) outputs (each microbatch has passed through all
    stages, in order).
    """
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]
    ticks = n_stages + m - 1

    def inner(params, xs):
        # each shard holds a (1, ...) slice of the stacked stage params
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        # state: the activation currently held by this stage (pcast to
        # device-varying: the loop makes them differ per stage)
        buf = _pcast_varying(jnp.zeros_like(xs[0]), axis)
        outs = _pcast_varying(jnp.zeros_like(xs), axis)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when valid)
            feed = xs[jnp.clip(t, 0, m - 1)]
            cur = jnp.where(sid == 0, feed, buf)
            # compute this stage on its current microbatch
            y = stage_fn(params, cur)
            # pass to the next stage (ring; the wrap-around result is the
            # pipeline output, collected by the last stage)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            # the last stage's output for microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            done = y  # value produced by the LAST stage this tick
            outs = jnp.where(
                (sid == n_stages - 1) & (out_idx >= 0) & (out_idx < m),
                outs.at[jnp.clip(out_idx, 0, m - 1)].set(done),
                outs,
            )
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage collected outputs; psum replicates them
        return jax.lax.psum(outs, axis)

    return shard_map_compat(
        inner, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
        axis_names={axis},
    )(stage_params, x_microbatches)
