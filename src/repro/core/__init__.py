"""Cortex core: Semantic Elements, Seri two-stage retrieval, the semantic
cache (LCFU + TTL), Markov prefetching and threshold recalibration."""
from repro.core.cache import CacheStats, CortexCache, make_cache
from repro.core.prefetch import MarkovPrefetcher, Prediction
from repro.core.recalibrate import (
    EvalRecord, Recalibration, find_threshold, precision_curve, recalibrate,
)
from repro.core.semantic_element import SemanticElement, ttl_from_staticity
from repro.core.seri import Seri, SeriResult, VectorIndex

__all__ = [
    "CacheStats", "CortexCache", "make_cache",
    "MarkovPrefetcher", "Prediction",
    "EvalRecord", "Recalibration", "find_threshold", "precision_curve",
    "recalibrate",
    "SemanticElement", "ttl_from_staticity",
    "Seri", "SeriResult", "VectorIndex",
]
