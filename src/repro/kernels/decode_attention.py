"""Pallas TPU decode attention — one new query token against a long KV
cache (the serve_step hot spot for decode_32k / long_500k).

Decode is bandwidth-bound: the whole valid cache prefix streams HBM→VMEM
once per step. Grid: (batch·kv_heads, n_s_blocks) with the cache-block dim
sequential; online-softmax state (m, l, acc) for the G query-group rows of
one KV head lives in VMEM scratch. Positions > pos are masked (the caller
has already written the new token's K/V at index pos).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, bs: int, ns: int):
    sb = pl.program_id(1)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0]          # (G, Dh)
    k = k_ref[0]          # (bs, Dh)
    v = v_ref[0]
    pos = pos_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale             # (G, bs)
    kj = sb * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kj <= pos, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(sb == ns - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "bs", "interpret")
)
def decode_attention(q, k_cache, v_cache, pos, *, scale: float,
                     bs: int = 512, interpret: bool = True):
    """q (B,KV,G,Dh); caches (B,S,KV,Dh); pos scalar -> (B,KV,G,Dh)."""
    b, kvh, g, dh = q.shape
    s_cache = k_cache.shape[1]
    bs = min(bs, s_cache)
    assert s_cache % bs == 0
    ns = s_cache // bs

    qf = q.reshape(b * kvh, g, dh)
    kf = jnp.moveaxis(k_cache, 1, 2).reshape(b * kvh, s_cache, dh)
    vf = jnp.moveaxis(v_cache, 1, 2).reshape(b * kvh, s_cache, dh)
    pos_arr = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32)[None], (b * kvh,)
    )

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bs=bs, ns=ns),
        grid=(b * kvh, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda h, sb: (h,)),
            pl.BlockSpec((1, g, dh), lambda h, sb: (h, 0, 0)),
            pl.BlockSpec((1, bs, dh), lambda h, sb: (h, sb, 0)),
            pl.BlockSpec((1, bs, dh), lambda h, sb: (h, sb, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda h, sb: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qf, kf, vf)
    return out.reshape(b, kvh, g, dh)
