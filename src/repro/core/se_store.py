"""SEStore — structure-of-arrays runtime for Semantic Elements (DESIGN.md §8).

The cache core used to be a ``dict[int, SemanticElement]`` of dataclasses:
every TTL purge walked the dict in Python, every LCFU eviction pass did a
full ``sorted(...)`` with a per-item Python score, and stage-1 retrieval
touched one query at a time. This module flips the layout: one parallel
numpy array per SE field, row-aligned with the ``VectorIndex`` embedding
matrix, so

  * TTL purge is a boolean mask (``active & (expires_at <= now)``),
  * ``lcfu_score`` is one vectorized expression over all rows,
  * victim selection is ``argpartition`` (O(n) expected) instead of an
    O(n log n) sort — with exact tie-break parity against the legacy
    stable sort (score, then se_id == insertion order),
  * batched lookups score candidates for a whole query block at once.

``SemanticElement`` (semantic_element.py) remains the public per-item API,
now as a thin live view onto one row of this store.
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Iterator

import numpy as np

from repro.core.semantic_element import SemanticElement


class SEStore:
    """Per-field parallel arrays for up to ``capacity`` SEs.

    Rows are assigned by the companion ``VectorIndex`` (same free-list), so
    ``store.freq[r]`` and ``index.emb[r]`` always describe the same SE.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.se_id = np.full(capacity, -1, np.int64)
        self.freq = np.zeros(capacity, np.int64)
        self.size = np.zeros(capacity, np.int64)
        self.last_access = np.zeros(capacity, np.float64)
        self.created_at = np.zeros(capacity, np.float64)
        self.expires_at = np.zeros(capacity, np.float64)
        self.cost = np.zeros(capacity, np.float64)
        self.latency = np.zeros(capacity, np.float64)
        self.staticity = np.zeros(capacity, np.int32)
        # freshness metadata (core/freshness.py): the origin knowledge
        # version this value was fetched at, and when the fetch happened
        # (refreshes update it; created_at keeps the first admission).
        # ``revalidating`` marks a row KNOWN stale (change-feed notice)
        # whose refetch is in flight — non-servable until refreshed.
        # ``freq_at_fetch`` snapshots freq at the last (re)fetch, so
        # "hits earned since last renewal" is freq - freq_at_fetch —
        # the refresh-ahead worthiness signal (lifetime freq would renew
        # dead entries forever).
        self.version = np.zeros(capacity, np.int64)
        self.fetched_at = np.zeros(capacity, np.float64)
        self.freq_at_fetch = np.zeros(capacity, np.int64)
        self.revalidating = np.zeros(capacity, bool)
        self.prefetched = np.zeros(capacity, bool)
        self.active = np.zeros(capacity, bool)
        self.key = np.empty(capacity, object)
        self.value = np.empty(capacity, object)
        self.intent = np.empty(capacity, object)
        # provenance: region id the value was transferred from (None =
        # fetched from the origin service by this cache's own region)
        self.origin = np.empty(capacity, object)
        self.id2row: dict[int, int] = {}
        # intent -> live se_ids: O(matching) change-feed fan-out instead
        # of an O(n) scan per invalidation notice
        self.by_intent: dict = {}

    # ---------------------------------------------------------- mutation

    def add(self, row: int, se_id: int, *, key, value, staticity, cost,
            latency, size, created_at, expires_at, freq, last_access,
            prefetched, intent, origin=None, version=0,
            fetched_at=None, freq_at_fetch=None) -> SemanticElement:
        if self.active[row]:
            # a silent clobber would leave the displaced SE's id2row entry
            # pointing at a row that now describes a different element
            raise ValueError(
                f"row {row} already holds live SE {int(self.se_id[row])}"
            )
        self.se_id[row] = se_id
        self.freq[row] = freq
        self.size[row] = size
        self.last_access[row] = last_access
        self.created_at[row] = created_at
        self.expires_at[row] = expires_at
        self.cost[row] = cost
        self.latency[row] = latency
        self.staticity[row] = staticity
        self.version[row] = version
        self.fetched_at[row] = created_at if fetched_at is None else fetched_at
        self.freq_at_fetch[row] = freq if freq_at_fetch is None \
            else freq_at_fetch
        self.revalidating[row] = False
        self.prefetched[row] = prefetched
        self.active[row] = True
        self.key[row] = key
        self.value[row] = value
        self.intent[row] = intent
        self.origin[row] = origin
        self.id2row[se_id] = row
        if intent is not None:
            self.by_intent.setdefault(intent, set()).add(se_id)
        return SemanticElement(self, row)

    def add_block(self, rows, se_ids, *, keys, values, staticity, cost,
                  latency, size, created_at, expires_at, freq=1,
                  prefetched=False, version=0) -> None:
        """Vectorized :meth:`add` for a uniform block (bulk prefill —
        ``CortexCache.insert_block``): per-row ids/keys/values, scalar
        economics broadcast, one fancy-indexed store per field instead
        of n scalar calls. Freshness metadata takes the same defaults
        scalar ``add`` derives (fetched_at = created_at, freq_at_fetch
        = freq); intent/origin stay None (bulk fills carry no
        change-feed subscription)."""
        ra = np.asarray(rows, np.int64)
        ids = np.asarray(se_ids, np.int64)
        if self.active[ra].any():
            raise ValueError("add_block would clobber live rows")
        self.se_id[ra] = ids
        self.freq[ra] = freq
        self.size[ra] = size
        self.last_access[ra] = created_at
        self.created_at[ra] = created_at
        self.expires_at[ra] = expires_at
        self.cost[ra] = cost
        self.latency[ra] = latency
        self.staticity[ra] = staticity
        self.version[ra] = version
        self.fetched_at[ra] = created_at
        self.freq_at_fetch[ra] = freq
        self.revalidating[ra] = False
        self.prefetched[ra] = prefetched
        self.active[ra] = True
        ko = np.empty(len(ra), object)
        ko[:] = list(keys)
        vo = np.empty(len(ra), object)
        vo[:] = list(values)
        self.key[ra] = ko
        self.value[ra] = vo
        self.intent[ra] = None
        self.origin[ra] = None
        self.id2row.update(zip(ids.tolist(), ra.tolist()))

    def snapshot_row(self, row: int) -> dict:
        """Full metadata copy of one live row as python scalars, keyed by
        the ``add`` kwarg names plus ``se_id`` — the tier-lifecycle
        handoff (core/tiers.py). Paired with ``add_meta`` so a
        demote/promote round trip copies every field by construction."""
        s = self
        return dict(
            se_id=int(s.se_id[row]), key=s.key[row], value=s.value[row],
            staticity=int(s.staticity[row]), cost=float(s.cost[row]),
            latency=float(s.latency[row]), size=int(s.size[row]),
            created_at=float(s.created_at[row]),
            expires_at=float(s.expires_at[row]),
            freq=int(s.freq[row]), last_access=float(s.last_access[row]),
            prefetched=bool(s.prefetched[row]), intent=s.intent[row],
            origin=s.origin[row], version=int(s.version[row]),
            fetched_at=float(s.fetched_at[row]),
            freq_at_fetch=int(s.freq_at_fetch[row]),
        )

    def add_meta(self, row: int, meta: dict) -> SemanticElement:
        """Re-home a ``snapshot_row`` dict at ``row``."""
        m = dict(meta)
        return self.add(row, m.pop("se_id"), **m)

    def remove_row(self, row: int) -> int:
        """Deactivate one row; returns the freed byte count."""
        size = int(self.size[row])
        se_id = int(self.se_id[row])
        del self.id2row[se_id]
        intent = self.intent[row]
        if intent is not None:
            ids = self.by_intent.get(intent)
            if ids is not None:
                ids.discard(se_id)
                if not ids:
                    del self.by_intent[intent]
        self.active[row] = False
        self.se_id[row] = -1
        self.key[row] = None
        self.value[row] = None
        self.intent[row] = None
        self.origin[row] = None
        return size

    # ------------------------------------------------------------ views

    def view(self, se_id: int) -> SemanticElement:
        return SemanticElement(self, self.id2row[se_id])

    # --------------------------------------------------------- vectorized

    def expired_rows(self, now: float) -> np.ndarray:
        """Row indices of all expired live SEs — the TTL-purge mask."""
        return np.flatnonzero(self.active & (now >= self.expires_at))

    def lcfu_scores(self, rows: np.ndarray, now: float) -> np.ndarray:
        """Algorithm 2 CalScore for a row block, one vector expression."""
        score = (
            np.log(self.freq[rows] + 1.0)
            * np.log(self.cost[rows] * 1e3 + 1.0)
            * np.log(self.latency[rows] + 1.0)
            * np.log(self.staticity[rows] + 1.0)
        )
        size = self.size[rows]
        live = (size > 0) & (self.expires_at[rows] - now > 0)
        return np.where(live, score / np.maximum(size, 1), 0.0)

    def _victim_keys(self, rows: np.ndarray, now: float, policy: str):
        """(primary, minor-tie keys) replicating the legacy sort orders:
        lcfu -> (score, se_id); lru -> (last_access, se_id);
        lfu -> (freq, last_access, se_id). se_id ascending == the old
        stable sort over dict insertion order."""
        if policy == "lru":
            return self.last_access[rows], (self.se_id[rows],)
        if policy == "lfu":
            return (self.freq[rows].astype(np.float64),
                    (self.last_access[rows], self.se_id[rows]))
        return self.lcfu_scores(rows, now), (self.se_id[rows],)

    def _smallest_in_order(self, rows, primary, ties, k: int) -> np.ndarray:
        """The k globally-smallest rows by (primary, *ties), in eviction
        order. argpartition selects a candidate superset (expanded to cover
        boundary ties), then only that superset is key-sorted."""
        m = len(rows)
        k = min(k, m)
        if k <= 0:
            return rows[:0]
        if k >= m:
            sel = np.arange(m)
        else:
            part = np.argpartition(primary, k - 1)[:k]
            thr = primary[part].max()
            sel = np.flatnonzero(primary <= thr)
        # np.lexsort keys: minor first, primary last
        order = np.lexsort(
            tuple(t[sel] for t in reversed(ties)) + (primary[sel],)
        )
        return rows[sel[order][:k]]

    def victim_rows(self, now: float, policy: str, *, n: int = 0,
                    need_bytes: int = 0) -> np.ndarray:
        """Rows to evict, in order: either exactly ``n`` victims, or just
        enough to free ``need_bytes``. Expected O(n_live) via argpartition
        with doubling-k, vs the legacy full sort."""
        rows = np.flatnonzero(self.active)
        if len(rows) == 0:
            return rows
        primary, ties = self._victim_keys(rows, now, policy)
        if n:
            return self._smallest_in_order(rows, primary, ties, n)
        k = min(32, len(rows))
        while True:
            cand = self._smallest_in_order(rows, primary, ties, k)
            freed = np.cumsum(self.size[cand])
            if freed[-1] >= need_bytes or len(cand) == len(rows):
                cut = int(np.searchsorted(freed, need_bytes)) + 1
                return cand[:cut] if freed[-1] >= need_bytes else cand
            k *= 2

    @property
    def usage(self) -> int:
        return int(self.size[self.active].sum())

    def __len__(self) -> int:
        return len(self.id2row)


class SEStoreMapping(Mapping):
    """dict-compatible read view (``cache.store``): se_id -> live SE view.

    Keeps the legacy ``dict[int, SemanticElement]`` API working — iteration
    order is insertion order (se_id ascending), membership is O(1)."""

    def __init__(self, store: SEStore):
        self._s = store

    def __getitem__(self, se_id: int) -> SemanticElement:
        return self._s.view(se_id)

    def __iter__(self) -> Iterator[int]:
        return iter(list(self._s.id2row))

    def __len__(self) -> int:
        return len(self._s.id2row)

    def __contains__(self, se_id) -> bool:
        return se_id in self._s.id2row
