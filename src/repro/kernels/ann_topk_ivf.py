"""Pallas TPU kernels for the clustered (IVF) stage-1 routed scan.

Brute-force ``ann_topk`` streams the WHOLE embedding matrix HBM→VMEM on
every lookup; at million-entry cache sizes stage 1 becomes bandwidth-
bound on its own index (DESIGN.md §12). The IVF layout fixes the
bytes-moved term: embeddings live in **cluster-major buckets** (C,
bucket_cap, D) maintained by ``core/clustering.py``, and the kernel
scans only the ``nprobe`` buckets each query routed to.

Routing is data-dependent, so the scan uses
``pltpu.PrefetchScalarGridSpec``: the per-(query, probe) selected
cluster ids ``sel`` are scalar-prefetched, and the bucket BlockSpec's
index map reads ``sel[b, j]`` to DMA exactly the selected bucket for
grid step (b, j) — the TPU equivalent of Faiss's inverted-list gather.
The centroid scoring + top-``nprobe`` selection happens in the same jit
scope (``kernels/ops.py`` wrappers) with a plain MXU matmul: it cannot
live inside the scan's ``pallas_call`` because the grid's index maps
need ``sel`` before the first step launches.

Two variants share the structure (mirroring ``ann_topk`` vs
``ann_topk_quant``):

  * ``ann_topk_ivf``       — fp32 buckets, exact scores (HOT tier);
  * ``ann_topk_ivf_quant`` — int8 buckets + per-row scales, int32
    accumulate, approximate coarse scores for the WARM tier's
    coarse/rescore pipeline (the host rescores finalists in fp32).

Per grid step: one (bucket_cap, D) slab · one query row on the MXU,
invalid slots and disabled probes masked to NEG, per-step top-k via k
max/argmax passes (the ``ann_topk`` idiom). The (nprobe · k) finalists
per query merge in one ``lax.top_k`` outside the kernel. Disabled
probes (query routed to fewer than ``nprobe`` non-empty clusters) emit
NEG rows that callers drop via ``vals > NEG / 2``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3.0e38  # plain float: jnp scalars would be captured consts in pallas


def _ivf_kernel(sel_ref, en_ref, q_ref, bucket_ref, valid_ref, vals_ref,
                idx_ref, *, k: int):
    """Grid step (b, j): scan bucket ``sel[b, j]`` for query b."""
    b = pl.program_id(0)
    j = pl.program_id(1)
    bucket = bucket_ref[0]                   # (cap, D)
    q = q_ref[...]                           # (1, D)
    s = jax.lax.dot_general(
        bucket, q,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (cap, 1)
    ok = (valid_ref[...] > 0)[0] & (en_ref[b, j] > 0)
    s = jnp.where(ok[:, None], s, NEG)
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    for t in range(k):
        v = jnp.max(s, axis=0)               # (1,)
        i = jnp.argmax(s, axis=0)            # (1,) slot within bucket
        vals_ref[0, 0, t] = v[0]
        idx_ref[0, 0, t] = i.astype(jnp.int32)[0]
        s = jnp.where(rows == i[None, :], NEG, s)


def _ivf_quant_kernel(sel_ref, en_ref, qq_ref, qs_ref, bucket_ref,
                      scale_ref, valid_ref, vals_ref, idx_ref, *, k: int):
    """int8 variant: int32-exact scores rescaled like ann_topk_quant
    (row scale first, then query scale — bit-matching the numpy path)."""
    b = pl.program_id(0)
    j = pl.program_id(1)
    bucket = bucket_ref[0]                   # (cap, D) int8
    qq = qq_ref[...]                         # (1, D) int8
    s = jax.lax.dot_general(
        bucket, qq,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )                                        # (cap, 1) exact int32
    s = s.astype(jnp.float32) * scale_ref[...][0][:, None]
    s = s * qs_ref[b]
    ok = (valid_ref[...] > 0)[0] & (en_ref[b, j] > 0)
    s = jnp.where(ok[:, None], s, NEG)
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    for t in range(k):
        v = jnp.max(s, axis=0)
        i = jnp.argmax(s, axis=0)
        vals_ref[0, 0, t] = v[0]
        idx_ref[0, 0, t] = i.astype(jnp.int32)[0]
        s = jnp.where(rows == i[None, :], NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ann_topk_ivf(sel, enabled, q, buckets, bucket_valid, k: int = 4, *,
                 interpret: bool = True):
    """Routed fp32 scan. sel/enabled (B, nprobe) int32; q (B, D);
    buckets (C, cap, D); bucket_valid (C, cap) -> per-probe finalists
    (vals (B, nprobe, k), slots (B, nprobe, k)).

    interpret=True executes the kernel body on CPU (this container);
    on TPU pass interpret=False for the Mosaic lowering.
    """
    b, nprobe = sel.shape
    _, cap, d = buckets.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # sel, enabled
        grid=(b, nprobe),
        in_specs=[
            pl.BlockSpec((1, d), lambda bi, j, sel, en: (bi, 0)),
            pl.BlockSpec((1, cap, d),
                         lambda bi, j, sel, en: (sel[bi, j], 0, 0)),
            pl.BlockSpec((1, cap),
                         lambda bi, j, sel, en: (sel[bi, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda bi, j, sel, en: (bi, j, 0)),
            pl.BlockSpec((1, 1, k), lambda bi, j, sel, en: (bi, j, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ivf_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, nprobe, k), jnp.float32),
            jax.ShapeDtypeStruct((b, nprobe, k), jnp.int32),
        ],
        interpret=interpret,
    )(sel, enabled, q, buckets, bucket_valid)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ann_topk_ivf_quant(sel, enabled, qq, q_scales, buckets_q, bucket_scale,
                       bucket_valid, k: int = 16, *,
                       interpret: bool = True):
    """Routed int8 coarse scan. qq (B, D) int8; q_scales (B,) f32;
    buckets_q (C, cap, D) int8; bucket_scale (C, cap) f32 -> per-probe
    coarse finalists (vals, slots) as in :func:`ann_topk_ivf`. ``vals``
    are approximate — callers rescore in fp32 before the τ_sim gate.
    """
    b, nprobe = sel.shape
    _, cap, d = buckets_q.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nprobe),
        in_specs=[
            pl.BlockSpec((1, d), lambda bi, j, sel, en: (bi, 0)),
            pl.BlockSpec((b,), lambda bi, j, sel, en: (0,)),
            pl.BlockSpec((1, cap, d),
                         lambda bi, j, sel, en: (sel[bi, j], 0, 0)),
            pl.BlockSpec((1, cap),
                         lambda bi, j, sel, en: (sel[bi, j], 0)),
            pl.BlockSpec((1, cap),
                         lambda bi, j, sel, en: (sel[bi, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda bi, j, sel, en: (bi, j, 0)),
            pl.BlockSpec((1, 1, k), lambda bi, j, sel, en: (bi, j, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ivf_quant_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, nprobe, k), jnp.float32),
            jax.ShapeDtypeStruct((b, nprobe, k), jnp.int32),
        ],
        interpret=interpret,
    )(sel, enabled, qq, q_scales, buckets_q, bucket_scale, bucket_valid)
