"""Periodic threshold recalibration — Algorithm 1 (paper §4.2).

Offline, decoupled from the serving path: sample recent (query, cached)
pairs from the eval log, fetch ground truth by re-issuing the query to the
live tool, label semantic equivalence, sweep the judge's precision curve,
and pick the smallest threshold achieving the target precision.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass
class EvalRecord:
    query: str
    cached_key: str
    cached_value: object
    score: float  # S_lsm the judge emitted online
    # stage-1 cosine of the candidate the judge scored; -1.0 = not
    # recorded (pre-band logs). Lets the same tick recalibrate the
    # admission band's trust edge alongside τ_lsm (DESIGN.md §14).
    sim: float = -1.0


@dataclasses.dataclass
class Recalibration:
    tau: float
    precision: float
    n_samples: int
    curve: list  # (threshold, precision, recall)
    # smallest stage-1 similarity whose prefix precision ≥ P_target —
    # the admission band's recalibrated trust edge; None when the
    # sampled records carry no sims
    sim_tau: float | None = None


def precision_curve(scores: np.ndarray, labels: np.ndarray):
    """Sweep thresholds (descending scores); precision/recall at each."""
    # full-sort audit (ISSUE 5): the cumulative TP/FP sweep needs EVERY
    # threshold in order (find_threshold scans the whole curve), so this
    # is not a top-k selection — and it runs off the serving path, once
    # per recal tick over ≤ 512 samples. argsort stays.
    order = np.argsort(-scores)
    s = scores[order]
    l = labels[order].astype(np.float64)
    tp = np.cumsum(l)
    fp = np.cumsum(1.0 - l)
    prec = tp / np.maximum(tp + fp, 1)
    rec = tp / max(l.sum(), 1)
    return [(float(s[i]), float(prec[i]), float(rec[i])) for i in range(len(s))]


def find_threshold(curve, p_target: float, default: float = 0.99) -> float:
    """Smallest threshold whose prefix precision ≥ P_target (max recall)."""
    best = None
    for thr, prec, _rec in curve:
        if prec >= p_target:
            best = thr
    return best if best is not None else default


def recalibrate(
    log: Sequence[EvalRecord],
    fetch_ground_truth: Callable[[str], object],
    evaluate_equiv: Callable[[object, object], bool],
    *,
    p_target: float = 0.99,
    sample_size: int = 64,
    rng: np.random.Generator | None = None,
) -> Recalibration:
    """Algorithm 1. fetch_ground_truth re-issues the query to the live tool
    (costed by the caller); evaluate_equiv compares cached vs ground."""
    rng = rng or np.random.default_rng(0)
    if not log:
        return Recalibration(0.9, 1.0, 0, [])
    idx = rng.permutation(len(log))[: min(sample_size, len(log))]
    sample = [log[i] for i in idx]
    labels = np.array([
        evaluate_equiv(r.cached_value, fetch_ground_truth(r.query))
        for r in sample
    ])
    scores = np.array([r.score for r in sample], np.float64)
    curve = precision_curve(scores, labels)
    tau = find_threshold(curve, p_target)
    # realised precision at tau
    keep = scores >= tau
    prec = float(labels[keep].mean()) if keep.any() else 1.0
    # the SAME labeled sample re-sweeps the stage-1 similarity axis:
    # above sim_tau the ANN alone meets the precision target, which is
    # exactly the "trust" region the admission band may bypass
    sims = np.array([r.sim for r in sample], np.float64)
    sim_tau = None
    if (sims >= 0).all() and len(sims):
        sim_tau = float(find_threshold(precision_curve(sims, labels),
                                       p_target, default=1.0))
    return Recalibration(float(tau), prec, len(sample), curve,
                         sim_tau=sim_tau)
