"""granite-3-8b [dense] — hf:ibm-granite/granite-3.0 family. GQA.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155 (padded to 49280
for 16-way vocab sharding divisibility; labels never reach pad ids).
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig

NAME = "granite-3-8b"
PAPER_VOCAB = 49155


@register(NAME)
def config() -> ModelConfig:
    attn = AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                      rope_theta=10_000_000.0)
    return ModelConfig(
        name=NAME,
        family="dense",
        d_model=4096,
        vocab_size=49280,  # padded from 49155 (multiple of 128)
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=12800),),
        n_repeat=40,
        tie_embeddings=True,
    )
