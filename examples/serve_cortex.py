"""End-to-end serving driver (the paper's primary scenario): a search
agent serving batched requests behind the Cortex cache, compared against
the vanilla and exact-match baselines on a Zipf-0.99 workload.

Run:  PYTHONPATH=src python examples/serve_cortex.py
"""
from repro.launch.serve import run_once

print(f"{'mode':16s} {'thpt':>6s} {'lat':>7s} {'p99':>7s} {'hit%':>6s} "
      f"{'API':>5s} {'$':>7s} {'EM':>5s}")
for mode in ("vanilla", "exact", "cortex"):
    s = run_once(
        workload="zipf", mode=mode, n_requests=600, cache_ratio=0.4,
        concurrency=8, seed=0,
    )
    print(f"{mode:16s} {s['throughput_rps']:6.2f} {s['latency_mean']:7.2f} "
          f"{s['latency_p99']:7.2f} {s['hit_rate']*100:6.1f} "
          f"{s['api_calls']:5d} {s['cost_total']:7.2f} {s['em']:5.3f}")
print("\n(cortex converts paraphrase locality into hits; vanilla/exact are "
      "pinned by the 100 QPM rate limit — paper Figs 7/10)")
