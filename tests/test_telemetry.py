"""Continuous telemetry (DESIGN.md §16): sampler, SLO monitor,
critical-path analyzer, and the supporting metrics-layer changes.

The §16 contract, each leg tested here:

* **neutrality** — sampling (and SLO monitoring) is strictly
  observational: a sampled run's summary, minus the telemetry-only
  keys, is byte-identical to the unsampled run at the same seed, over a
  matrix of engine configurations and all three federation topologies;
* **reconciliation** — per-window integer deltas telescope exactly:
  ``sum(window deltas) == final cumulative row == summary totals``;
* **determinism** — same seed ⇒ byte-identical timeseries and alerts
  JSONL artifacts;
* **hysteresis** — breach after N consecutive violating samples,
  recovery after M consecutive OK samples, ``None`` samples advance
  neither counter, alert ordering pinned;
* **critical path** — span trees fold into per-class aggregates whose
  ``total_s`` telescopes to the class's total latency (the conservation
  law), with deterministic flamegraph output;
* **registry / histogram** — idempotent ``register``, ``unregister``,
  and the bounded-reservoir histogram mode (raw mode stays bit-exact).
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.data.workloads import region_workloads
from repro.data.world import SemanticWorld
from repro.launch.serve import run_once
from repro.obs.analyze import (critical_path, flamegraph_folded,
                               format_critical_path)
from repro.obs.metrics import FixedHistogram, MetricsRegistry
from repro.obs.slo import SLO, SLOMonitor
from repro.obs.trace import BACKGROUND, Tracer
from repro.serving.federation import FederationRunner

# keys a telemetry-enabled run_once adds on top of the plain summary
TELE_KEYS = ("timeseries_samples", "slo_breaches", "slo_recoveries",
             "timeseries_path", "alerts_path")


def _canon(s: dict) -> str:
    return json.dumps(s, sort_keys=True, default=float)


def _strip(s: dict) -> dict:
    return {k: v for k, v in s.items() if k not in TELE_KEYS}


# ------------------------------------------------------------ neutrality

# the golden-config matrix: one row per engine feature that could
# plausibly interact with a sampler riding the same virtual clock
MATRIX = {
    "closed_loop": dict(concurrency=4),
    "open_loop": dict(concurrency=None),
    "tiered_longtail": dict(workload="longtail", tail_len=30,
                            warm_frac=0.5, concurrency=4),
    "churn_refresh": dict(churn_period=30.0, invalidation=True,
                          refresh_ahead=True, concurrency=4),
    "ivf_sharded": dict(cluster=True, n_clusters=16, nprobe=4, shards=2,
                        t_shard_merge=1e-4, t_cache_per_row=1e-6,
                        concurrency=4),
    "judge_band": dict(judge_band=0.1, concurrency=4),
    "exact": dict(mode="exact", concurrency=4),
    "nojudge": dict(mode="cortex-nojudge", concurrency=4),
    "vanilla": dict(mode="vanilla", concurrency=4),
}
_BASE = dict(n_requests=60, n_intents=150, dim=32, seed=5)


@pytest.mark.parametrize("name", sorted(MATRIX))
def test_sampler_is_observationally_neutral(name):
    kw = {**_BASE, **MATRIX[name]}
    plain = run_once(**kw)
    sampled = run_once(sample_interval=2.0,
                       slo=["p99:window.latency_p99:<=:1e9"], **kw)
    assert sampled["timeseries_samples"] > 0
    assert _canon(_strip(sampled)) == _canon(plain)


@pytest.mark.parametrize("topology", ["local", "peered", "global"])
def test_federation_sampler_is_neutral(topology):
    world = SemanticWorld(n_intents=200, dim=32, seed=5)

    def runner(**extra):
        reqs = region_workloads(world, n_regions=3, n_per_region=40,
                                seed=6)
        return FederationRunner(world=world, region_requests=reqs,
                                topology=topology, seed=7, **extra)

    plain = runner().run()
    fr = runner(sample_interval=5.0,
                slos=["p99:window.latency_p99:<=:1e9"])
    sampled = fr.run()
    assert sampled["aggregate"]["timeseries_samples"] > 0
    sampled["aggregate"] = _strip(sampled["aggregate"])
    assert _canon(sampled) == _canon(plain)
    # fleet samples carry per-region blocks + federation queue gauges
    row = fr.sampler.samples[-1]
    assert set(row["regions"]) == {"0", "1", "2"}
    assert "fed_inflight_peeks" in row["gauges"]


def test_federation_summary_attributes_p99_by_region():
    world = SemanticWorld(n_intents=200, dim=32, seed=5)
    reqs = region_workloads(world, n_regions=3, n_per_region=40, seed=6)
    fr = FederationRunner(world=world, region_requests=reqs,
                          topology="local", seed=7)
    s = fr.run()
    by_region = s["aggregate"]["latency_p99_by_region"]
    assert len(by_region) == 3
    # shared-percentile attribution over records_by_region()
    from repro.obs.metrics import percentile
    for rid, rrecs in fr.records_by_region().items():
        name = fr.regions[rid].cfg.name
        assert by_region[name] == percentile(
            [r.latency for r in rrecs], 99)


# ----------------------------------------------- reconciliation, timing

def test_window_deltas_telescope_to_summary_totals(tmp_path):
    s = run_once(sample_interval=2.0,
                 timeseries=str(tmp_path / "ts"), **_BASE)
    rows = [json.loads(l) for l in
            open(s["timeseries_path"]).read().splitlines()]
    cum = rows[-1]["cum"]
    for key, total in cum.items():
        assert sum(r["window"].get(key, 0) or 0 for r in rows) == total, key
    assert cum["n_done"] == s["n"]
    assert cum["api_calls"] == s["api_calls"]
    assert cum["judge_calls"] == s["judge_calls"]
    assert cum["rows_scanned"] == s["rows_scanned"]
    assert cum["stale_hits"] == s["stale_hits"]


def test_samples_land_on_the_virtual_time_grid(tmp_path):
    interval = 2.0
    s = run_once(sample_interval=interval,
                 timeseries=str(tmp_path / "ts"), **_BASE)
    rows = [json.loads(l) for l in
            open(s["timeseries_path"]).read().splitlines()]
    # every sample except a final partial window sits exactly on the
    # grid; durations cover the run with no gap
    for k, r in enumerate(rows[:-1]):
        assert r["t"] == (k + 1) * interval
    assert rows[-1]["t"] >= rows[-2]["t"] + 0 if len(rows) > 1 else True
    assert rows[0]["dur"] == rows[0]["t"]
    for a, b in zip(rows, rows[1:]):
        assert b["dur"] == b["t"] - a["t"]
    # gauges ride every sample
    assert "inflight" in rows[0]["gauges"]
    assert "limiter_headroom" in rows[0]["gauges"]
    assert "agent_active" in rows[0]["gauges"]


def test_same_seed_artifacts_are_byte_identical(tmp_path):
    kw = dict(sample_interval=2.0,
              slo=["p99:window.latency_p99:<=:0.5"], **_BASE)
    a = run_once(timeseries=str(tmp_path / "a"), **kw)
    b = run_once(timeseries=str(tmp_path / "b"), **kw)
    assert (tmp_path / "a.timeseries.jsonl").read_bytes() \
        == (tmp_path / "b.timeseries.jsonl").read_bytes()
    assert (tmp_path / "a.alerts.jsonl").read_bytes() \
        == (tmp_path / "b.alerts.jsonl").read_bytes()
    assert a["timeseries_samples"] == b["timeseries_samples"] > 0


def test_slo_without_interval_is_rejected():
    with pytest.raises(ValueError):
        run_once(slo=["p99:window.latency_p99:<=:1.0"], **_BASE)
    with pytest.raises(ValueError):
        run_once(timeseries="/tmp/nope", **_BASE)


# ------------------------------------------------------------ hysteresis

def _sample(t, value):
    return {"t": float(t), "window": {"m": value}}


def test_slo_spec_parsing():
    s = SLO.parse("p99:window.latency_p99:<=:3.0")
    assert (s.name, s.metric, s.op, s.bound) \
        == ("p99", "window.latency_p99", "<=", 3.0)
    assert s.breach_after == s.recover_after == 2
    s = SLO.parse("acc:window.info_accuracy:>=:0.9:3:1")
    assert (s.breach_after, s.recover_after) == (3, 1)
    with pytest.raises(ValueError):
        SLO.parse("bad:only:three")
    with pytest.raises(ValueError):
        SLO(name="x", metric="m", op="<", bound=1.0)
    with pytest.raises(ValueError):
        SLO(name="x", metric="m", op="<=", bound=1.0, breach_after=0)


def test_hysteresis_breach_recovery_ordering():
    mon = SLOMonitor([SLO("lat", "window.m", "<=", 1.0,
                          breach_after=2, recover_after=2)])
    vals = [0.5, 2.0, 0.5,      # lone violation: no breach
            2.0, 2.0,           # 2 consecutive -> breach at t=4
            0.5, 2.0,           # recovery streak broken
            0.5, 0.5,           # 2 consecutive OK -> recovery at t=8
            2.0, 2.0]           # breach again at t=10
    for t, v in enumerate(vals):
        mon.observe(_sample(t, v))
    assert [(a["t"], a["event"]) for a in mon.alerts] \
        == [(4.0, "breach"), (8.0, "recovery"), (10.0, "breach")]
    assert mon.breaches == 2 and mon.recoveries == 1
    assert mon.active() == ["lat"]


def test_hysteresis_skips_none_samples():
    mon = SLOMonitor([SLO("lat", "window.m", "<=", 1.0)])
    seq = [2.0, None, 2.0]      # None must not reset the bad streak
    for t, v in enumerate(seq):
        mon.observe(_sample(t, v))
    assert [(a["t"], a["event"]) for a in mon.alerts] == [(2.0, "breach")]
    # ...and an idle all-None tail must not fake a recovery
    for t in range(3, 10):
        mon.observe(_sample(t, None))
    assert mon.recoveries == 0 and mon.active() == ["lat"]


def test_floor_objective_and_breach_after_one():
    mon = SLOMonitor([SLO("acc", "window.m", ">=", 0.9,
                          breach_after=1, recover_after=1)])
    for t, v in enumerate([0.95, 0.5, 0.95]):
        mon.observe(_sample(t, v))
    assert [(a["t"], a["event"]) for a in mon.alerts] \
        == [(1.0, "breach"), (2.0, "recovery")]


def test_monitor_emits_trace_markers():
    tr = Tracer()
    mon = SLOMonitor([SLO("lat", "window.m", "<=", 1.0,
                          breach_after=1, recover_after=1)],
                     tracer=tr, region=2)
    mon.observe(_sample(1, 5.0))
    mon.observe(_sample(2, 0.5))
    names = [(s[0], s[1], s[4], s[5]) for s in tr.spans]
    assert (BACKGROUND, "slo_breach", 2, "lat") in names
    assert (BACKGROUND, "slo_recovery", 2, "lat") in names


def test_duplicate_slo_names_rejected():
    with pytest.raises(ValueError):
        SLOMonitor(["a:m:<=:1", "a:n:<=:2"])


# --------------------------------------------------------- critical path

def test_critical_path_folds_span_trees():
    tr = Tracer()

    class Rec:
        def __init__(self, rid, arrival, t_done, remote_calls,
                     peer_transfers=0):
            self.rid, self.arrival, self.t_done = rid, arrival, t_done
            self.latency = t_done - arrival
            self.remote_calls = remote_calls
            self.peer_transfers = peer_transfers

    # hit: queue 1s + cache 2s; miss: queue 1s + remote 3s + remote 1s
    tr.span(0, "queue", 0.0, 1.0)
    tr.span(0, "cache", 1.0, 3.0)
    tr.span(1, "queue", 10.0, 11.0)
    tr.span(1, "remote", 11.0, 14.0)
    tr.span(1, "remote", 14.0, 15.0)
    recs = [Rec(0, 0.0, 3.0, 0), Rec(1, 10.0, 15.0, 2)]
    rep = critical_path(tr, recs)
    assert set(rep) == {"hit", "miss"}
    hit, miss = rep["hit"], rep["miss"]
    assert hit["n_requests"] == 1 and hit["total_latency_s"] == 3.0
    assert hit["segments"]["cache"]["frac"] == pytest.approx(2 / 3)
    assert hit["ranked"] == ["cache", "queue"]
    # the remote segment occurs twice in one request: leverage 2.0
    seg = miss["segments"]["remote"]
    assert (seg["occurrences"], seg["n_requests"]) == (2, 1)
    assert seg["leverage"] == 2.0
    assert seg["total_s"] == 4.0
    assert miss["ranked"][0] == "remote"
    # conservation: per class, segment seconds tile the latency total
    for blk in rep.values():
        assert sum(s["total_s"] for s in blk["segments"].values()) \
            == pytest.approx(blk["total_latency_s"])
    folded = flamegraph_folded(tr, recs)
    assert folded == sorted(["hit;queue 1000000", "hit;cache 2000000",
                             "miss;queue 1000000",
                             "miss;remote 4000000"])
    txt = format_critical_path(rep)
    assert "[miss]" in txt and "remote" in txt


def test_critical_path_on_a_real_traced_run(tmp_path):
    kw = dict(n_requests=80, concurrency=4, judge_band=0.1, seed=3)
    run_once(trace=str(tmp_path / "t"), **kw)
    # rebuild the analyzer inputs from the exported span JSONL
    rows = [json.loads(l) for l in
            open(str(tmp_path / "t.jsonl")).read().splitlines()]
    tr = Tracer()
    for r in rows:
        tr.span(r["rid"], r["name"], r["t0"], r["t1"],
                region=r["region"], tag=r.get("tag"))

    class Rec:
        pass

    recs = []
    per_req = tr.request_spans()
    for (region, rid), spans in per_req.items():
        if rid < 0:
            continue
        spans = sorted(spans, key=lambda s: s[2])
        rec = Rec()
        rec.rid = rid
        rec.arrival = spans[0][2]
        rec.t_done = spans[-1][3]
        rec.latency = rec.t_done - rec.arrival
        names = [s[1] for s in spans]
        rec.remote_calls = sum(n == "origin_fetch" for n in names)
        rec.peer_transfers = 0
        recs.append(rec)
    rep = critical_path(tr, recs)
    assert rep
    for blk in rep.values():
        total = sum(s["total_s"] for s in blk["segments"].values())
        assert total == pytest.approx(blk["total_latency_s"])
        assert abs(sum(s["frac"] for s in blk["segments"].values()) - 1.0) \
            < 1e-9
    assert len(flamegraph_folded(tr, recs)) \
        == sum(len(b["segments"]) for b in rep.values())


# ------------------------------------------- registry / histogram modes

def test_registry_register_is_idempotent_and_unregisterable():
    reg = MetricsRegistry()
    reg.register("a", lambda: {"x": 1})
    reg.register("b", lambda: {"y": 2})
    # replace semantics: same namespace re-registered wins, position kept
    reg.register("a", lambda: {"x": 10})
    snap = reg.snapshot()
    assert snap["a.x"] == 10 and snap["b.y"] == 2
    assert list(snap) == ["a.x", "b.y"]
    assert reg.unregister("b") is True
    assert reg.unregister("b") is False
    assert "b.y" not in reg.snapshot()


def test_histogram_raw_mode_is_bit_exact_legacy():
    h_old = FixedHistogram([1.0, 2.0])
    h_new = FixedHistogram([1.0, 2.0], max_samples=None)
    rng = np.random.default_rng(0)
    vals = rng.exponential(1.0, 500)
    for v in vals:
        h_old.add(float(v))
        h_new.add(float(v))
    assert h_new.to_dict() == h_old.to_dict()
    # raw mode must keep the exact np.mean-over-raw-values code path
    assert h_new.mean == float(np.mean(vals))
    assert len(h_new) == 500


def test_histogram_reservoir_mode_bounds_memory_exactly():
    h = FixedHistogram([1.0, 2.0], max_samples=64, seed=7)
    rng = np.random.default_rng(1)
    vals = [float(v) for v in rng.exponential(1.0, 1000)]
    for v in vals:
        h.add(v)
    assert len(h.values) == 64          # bounded retention
    assert len(h) == 1000               # exact count preserved
    assert set(h.values) <= set(vals)
    # bucket counts and mean stay exact (incremental, not sampled)
    d = h.to_dict()
    assert d["0-1"] == sum(v < 1.0 for v in vals)
    assert d["1-2"] == sum(1.0 <= v < 2.0 for v in vals)
    assert d["2+"] == sum(v >= 2.0 for v in vals)
    assert h.mean == pytest.approx(sum(vals) / len(vals))
    # seeded: same stream -> same reservoir
    h2 = FixedHistogram([1.0, 2.0], max_samples=64, seed=7)
    for v in vals:
        h2.add(v)
    assert h2.values == h.values


def test_engine_reservoir_mode_preserves_behavior():
    kw = dict(churn_period=30.0, invalidation=True, **_BASE)
    full = run_once(**kw)
    capped = run_once(stale_age_reservoir=8, **kw)
    # the reservoir only bounds raw retention — event flow, counters,
    # and the histogram's exact bucket counts are unchanged; only
    # stale_age_mean may differ in the last float bit (np.mean over raw
    # values vs the incremental _sum/count)
    assert capped["stale_age_mean"] \
        == pytest.approx(full["stale_age_mean"])
    a = {k: v for k, v in capped.items() if k != "stale_age_mean"}
    b = {k: v for k, v in full.items() if k != "stale_age_mean"}
    assert _canon(a) == _canon(b)
