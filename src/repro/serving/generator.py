"""Real (non-simulated) continuous-batching generation loop.

This is the concrete JAX runtime behind the DES model: fixed decode slots
with per-slot KV caches, jit-compiled batched decode step, prefill-on-admit,
and the co-located judge actually executing between decode steps under the
paper's priority rule (judge batches run only when no agent request is
waiting for a slot). Runs real (reduced) models end-to-end on CPU; on TPU
the same loop runs the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM
from repro.nn.param import init_tree
from repro.nn.sharding import ShardCtx


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching for a decoder-only LM."""

    def __init__(self, cfg, params=None, *, slots: int = 4,
                 max_len: int = 128, seed: int = 0,
                 judge: Optional[Callable[[], None]] = None):
        self.cfg = cfg
        self.lm = LM(cfg)
        self.ctx = ShardCtx(None)
        self.slots = slots
        self.max_len = max_len
        self.judge = judge
        self.params = params if params is not None else init_tree(
            jax.random.PRNGKey(seed), self.lm.param_specs()
        )
        caches = init_tree(
            jax.random.PRNGKey(1), self.lm.cache_specs(slots, max_len)
        )
        self.caches = jax.tree.map(jnp.zeros_like, caches)
        self.pos = np.zeros(slots, np.int32)          # next write index
        self.active: list[Optional[GenRequest]] = [None] * slots
        self.queue: list[GenRequest] = []
        self.judge_batches_run = 0
        self.decode_steps = 0

        def decode_step(params, tokens, caches, pos_vec):
            # per-slot positions: embed with per-slot rope positions
            positions = pos_vec[:, None]
            logits, new_caches = self.lm.decode(
                self.ctx, params, tokens, caches,
                jnp.max(pos_vec), positions=positions,
            )
            next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
            return next_tok.astype(jnp.int32), new_caches

        self._decode = jax.jit(decode_step)

    # ---------------------------------------------------------- admit

    def submit(self, req: GenRequest):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                # sequential prefill through the decode path (teacher-forced)
                for t, tok in enumerate(req.prompt):
                    self._step_slot(s, int(tok), t)
                self.pos[s] = len(req.prompt)

    def _step_slot(self, s: int, token: int, t: int):
        """Feed one prompt token into slot s's cache (prefill-by-decode)."""
        toks = np.zeros((self.slots, 1), np.int32)
        toks[s, 0] = token
        pos_vec = self.pos.copy()
        pos_vec[s] = t
        _, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(pos_vec),
        )

    # ---------------------------------------------------------- run

    def step(self):
        """One scheduler tick: admit, batched decode, judge-if-idle."""
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if live:
            toks = np.zeros((self.slots, 1), np.int32)
            for s in live:
                req = self.active[s]
                toks[s, 0] = (
                    req.out_tokens[-1] if req.out_tokens
                    else int(req.prompt[-1])
                )
            nxt, self.caches = self._decode(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(self.pos),
            )
            nxt = np.asarray(nxt)
            self.decode_steps += 1
            for s in live:
                req = self.active[s]
                req.out_tokens.append(int(nxt[s]))
                self.pos[s] += 1
                if len(req.out_tokens) >= req.max_new or \
                        self.pos[s] >= self.max_len - 1:
                    req.done = True
                    self.active[s] = None
        # priority rule (paper §4.4): judge work only when no request is
        # waiting for a slot
        if self.judge is not None and not self.queue:
            self.judge()
            self.judge_batches_run += 1

    def run(self, until_drained: bool = True, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
