"""Mixture-of-Experts channel mixer.

Design (TPU-native, see DESIGN.md §5):

* Tokens are reshaped into G groups aligned with the dp sharding, so all
  routing / dispatch-index math and the dispatch gathers are *local* to a
  data shard (GSPMD never needs to move tokens for dispatch).
* Expert weights are sharded over the ``model`` axis (expert parallelism).
  Expert compute runs inside a ``shard_map`` over {"model"}: each shard
  gathers the tokens routed to *its* experts (local — tokens are replicated
  across the model axis), runs its expert FFNs, scatter-gathers the weighted
  outputs back to token positions, and one ``psum`` over the model axis
  combines partial token outputs. Collective cost per MoE layer is one
  all-reduce of (tokens × d_model) — identical to dense-FFN Megatron TP and
  independent of n_experts.
* Capacity: per-group per-expert slots C = ceil(Tg·K/E · capacity_factor);
  overflow tokens are dropped (zero combine weight) — GShard/Switch
  semantics. Tests use a high factor to validate against the dense oracle.
* Decode note: when Tg·K < E the slot tensor is padded up to E slots/group.
  The padding wastes MXU flops but moves no extra bytes; decode MoE is
  weight-bandwidth-bound, so the memory roofline term is unaffected (the
  MODEL_FLOPS/HLO_FLOPS ratio in EXPERIMENTS.md surfaces the waste).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.basic import ffn, ffn_specs
from repro.nn.config import MoEConfig
from repro.nn.param import ParamSpec
from repro.nn.sharding import ShardCtx, shard_map_compat


def moe_specs(cfg: MoEConfig, d_model: int, dtype) -> dict:
    e, f = cfg.n_experts, cfg.d_ff_expert
    out = {
        "router": ParamSpec((d_model, e), jnp.float32, (None, None), scale=0.02),
        "w_gate": ParamSpec((e, d_model, f), dtype, ("expert", "fsdp", None)),
        "w_up": ParamSpec((e, d_model, f), dtype, ("expert", "fsdp", None)),
        "w_down": ParamSpec((e, f, d_model), dtype, ("expert", None, "fsdp")),
    }
    if cfg.router_fn == "sigmoid":
        # deepseek-v3 aux-loss-free balancing bias (updated out-of-band)
        out["router_bias"] = ParamSpec((e,), jnp.float32, (None,), init="zeros")
    if cfg.n_shared:
        d_sh = cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared
        out["shared"] = ffn_specs(d_model, d_sh, dtype, act="swiglu")
    return out


def _route(p, cfg: MoEConfig, x):
    """x: (G, Tg, D) -> weights (G,Tg,K) f32, idx (G,Tg,K) i32, aux scalar."""
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32), p["router"])
    if cfg.router_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"][None, None, :]
        _, idx = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_scale:
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balance aux loss: E * sum_e f_e * p_e
    probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))
    counts = (
        jnp.zeros((cfg.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    )
    frac = counts / (idx.size + 1e-9)
    aux = cfg.n_experts * jnp.sum(frac * probs_mean) * cfg.aux_loss_coef
    return w, idx, aux


def _dispatch_indices_1g(top_k: int, n_experts: int, capacity: int, idx):
    """Per-group dispatch plan. idx: (Tg, K) expert choices.

    Returns:
      slot_src: (E*C,) source-token index per slot (Tg = dummy/empty)
      tok_slot: (Tg, K) slot id per (token, choice) (E*C = dropped)
    """
    t, k = idx.shape
    e, cap = n_experts, capacity
    flat_e = idx.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted]
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, e * cap)
    slot_src = jnp.full((e * cap + 1,), t, jnp.int32)
    slot_src = slot_src.at[slot].set(jnp.where(keep, tok_sorted, t))[:-1]
    tok_slot_flat = jnp.full((t * k,), e * cap, jnp.int32)
    tok_slot_flat = tok_slot_flat.at[order].set(
        jnp.where(keep, slot, e * cap)
    )
    return slot_src, tok_slot_flat.reshape(t, k)


def _expert_ffn(pw, xe):
    """xe: (G, E_loc, C, D) -> through per-expert SwiGLU."""
    h = jnp.einsum("gecd,edf->gecf", xe, pw["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, pw["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(xe.dtype) * u
    return jnp.einsum("gecf,efd->gecd", h, pw["w_down"])


def _moe_body(pw, cfg, xg, w, slot_src, tok_slot, cap, e_lo, e_local):
    g, t, d = xg.shape
    lo = e_lo * cap
    span = e_local * cap
    src = jax.lax.dynamic_slice_in_dim(slot_src, lo, span, axis=1)  # (G, span)
    x_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(x_pad, src[..., None], axis=1)  # (G, span, D)
    xe = xe.reshape(g, e_local, cap, d)
    ye = _expert_ffn(pw, xe).reshape(g, span, d)
    flat_slot = tok_slot.reshape(g, t * cfg.top_k)
    local = (flat_slot >= lo) & (flat_slot < lo + span)
    loc_slot = jnp.where(local, flat_slot - lo, span)
    y_pad = jnp.concatenate([ye, jnp.zeros((g, 1, d), ye.dtype)], axis=1)
    contrib = jnp.take_along_axis(y_pad, loc_slot[..., None], axis=1)
    contrib = contrib.reshape(g, t, cfg.top_k, d)
    wk = jnp.where(
        local.reshape(g, t, cfg.top_k), w.astype(jnp.float32), 0.0
    ).astype(xg.dtype)
    return jnp.einsum("gtkd,gtk->gtd", contrib, wk)


def moe_apply(ctx: ShardCtx, p, cfg: MoEConfig, x):
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    t_total = b * s
    dp = ctx.dp_size()
    n_groups = dp if t_total % dp == 0 else 1
    tg = t_total // n_groups
    xg = x.reshape(n_groups, tg, d)
    xg = ctx.constrain(xg, "dp", None, None)

    w, idx, aux = _route(p, cfg, xg)
    cap = int(
        max(1, round(tg * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    )
    slot_src, tok_slot = jax.vmap(
        lambda i: _dispatch_indices_1g(cfg.top_k, cfg.n_experts, cap, i)
    )(idx)

    e = cfg.n_experts
    tp = ctx.tp_size()
    use_ep = ctx.mesh is not None and tp > 1 and e % tp == 0
    pw = {"w_gate": p["w_gate"], "w_up": p["w_up"], "w_down": p["w_down"]}

    if use_ep:
        out = _moe_shardmap(ctx, pw, cfg, xg, w, slot_src, tok_slot, cap, e // tp)
    else:
        out = _moe_body(pw, cfg, xg, w, slot_src, tok_slot, cap, 0, e)

    out = out.reshape(b, s, d)
    if cfg.n_shared:
        out = out + ffn(ctx, p["shared"], x, act="swiglu")
    return ctx.constrain(out, "dp", None, None), aux


def _moe_shardmap(ctx, pw, cfg, xg, w, slot_src, tok_slot, cap, e_local):
    """Expert-parallel path: experts sharded over the model axis, tokens
    sharded over dp (groups are dp-aligned), partial token outputs
    psum-combined over the model axis.

    Fully-manual over every mesh axis — half-manual (auto-dp) shard_maps
    trip an XLA SPMD-partitioner check failure at 512 devices. The entry
    reshard of the expert weights (FSDP dim gathered on entry, transposed
    to a reduce-scatter in the backward) IS the explicit ZeRO-3 gather.
    """
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    axis = ctx.cfg.mesh_axes("model")[0]
    n_groups = xg.shape[0]
    dp_axes = [
        a for a in ctx.cfg.mesh_axes("dp") if a in mesh.shape
    ]
    kept, prod = [], 1
    for a in dp_axes:
        if n_groups % (prod * mesh.shape[a]) == 0:
            kept.append(a)
            prod *= mesh.shape[a]
    dp = tuple(kept) if kept else None

    def inner(pw_, xg_, w_, slot_src_, tok_slot_):
        eidx = jax.lax.axis_index(axis)
        out = _moe_body(
            pw_, cfg, xg_, w_, slot_src_, tok_slot_, cap, eidx * e_local, e_local
        )
        return jax.lax.psum(out, axis)

    return shard_map_compat(
        inner,
        mesh=mesh,
        in_specs=(
            P(axis), P(dp, None, None), P(dp, None, None),
            P(dp, None), P(dp, None, None),
        ),
        out_specs=P(dp, None, None),
        axis_names=set(mesh.axis_names),
    )(pw, xg, w, slot_src, tok_slot)
