"""Perf-regression harness over BENCH_*.json artifacts (DESIGN.md §16).

Diffs the BENCH_<name>.json files a benchmark run just produced against
the committed per-metric baselines under ``benchmarks/baselines/`` and
fails (exit 1) when any whitelisted metric regresses past its
direction-aware tolerance:

  PYTHONPATH=src python -m benchmarks.compare \\
      [--baseline benchmarks/baselines] [--current .] \\
      [--names tiered,freshness] [--self-test]

Design choices, deliberately conservative:

* Only metrics named in ``RULES`` are compared.  Everything else —
  wall-clock stamps (``us_per_call`` on kernel rows, ``wall_s``,
  ``brute_us``/``ivf_us``), free-form counts without a "better"
  direction (evictions, transfers, refreshes), and metrics added after
  a baseline was committed — is ignored, so the gate never flakes on
  machine speed and never blocks a new metric from landing before its
  baseline does.
* Every engine-derived metric in RULES is computed in *virtual* time
  from a seeded discrete-event run, so at equal code it is
  bit-reproducible; the tolerances exist to absorb intentional
  behaviour changes that are small enough not to count as regressions.
  A change past tolerance is exactly the thing this gate exists to
  surface: fix it or re-baseline deliberately (see README: "read a
  compare report").
* Rows are matched by (row name, occurrence index) within a benchmark.
  A baseline row whose config stamp (seed/shards/nprobe/judge_model/
  band) disagrees with the current run is *skipped with a warning* —
  config drift means the numbers answer different questions and a
  numeric diff would be noise.  A baseline row with no current
  counterpart is a violation: silently dropping a measured row is how
  coverage regressions hide.

Exit codes: 0 = all compared metrics within tolerance; 1 = at least
one regression (or a missing row); 2 = usage/environment error (no
baseline files, unreadable JSON).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# metric -> (direction, rel_tol, abs_tol)
#   direction "higher": regression when current < baseline - tol
#   direction "lower":  regression when current > baseline + tol
# with tol = max(abs_tol, rel_tol * |baseline|).
RULES: dict[str, tuple[str, float, float]] = {
    # throughput / quality — higher is better
    "thpt":            ("higher", 0.10, 0.05),
    "hit":             ("higher", 0.00, 0.02),
    "hit_steady":      ("higher", 0.00, 0.02),
    "peer_hit":        ("higher", 0.00, 0.02),
    "warm_hits":       ("higher", 0.10, 2.0),
    "recall_at_4":     ("higher", 0.00, 0.01),
    "em":              ("higher", 0.00, 0.05),
    "info_acc":        ("higher", 0.00, 0.02),
    "info_accuracy":   ("higher", 0.00, 0.02),
    "acc_recovered":   ("higher", 0.00, 0.02),
    "remote_time_reduction": ("higher", 0.00, 0.02),
    # latency (virtual-time ms) — lower is better
    "lat_ms":          ("lower", 0.10, 2.0),
    "p99_ms":          ("lower", 0.20, 10.0),
    "remote_ms":       ("lower", 0.10, 2.0),
    "hitpath_p50_ms":  ("lower", 0.10, 2.0),
    "hitpath_mean_ms": ("lower", 0.10, 2.0),
    # spend — lower is better
    "api":             ("lower", 0.10, 5.0),
    "api_cost":        ("lower", 0.10, 0.05),
    "cost":            ("lower", 0.10, 0.05),
    "refresh_cost":    ("lower", 0.10, 0.05),
    "judge_calls":     ("lower", 0.15, 10.0),
    "rows_per_lookup": ("lower", 0.10, 5.0),
    "scan_ratio":      ("lower", 0.10, 0.02),
    # freshness — lower is better
    "stale_rate":      ("lower", 0.00, 0.02),
    "stale_hits":      ("lower", 0.00, 2.0),
    # robustness (§17) — SLO-violating windows and worst windowed p99
    # must not creep back up; hung peeks are a hard zero; breaker must
    # keep opening AND re-closing under the committed outage scenario
    "breach_windows":  ("lower", 0.00, 2.0),
    "max_win_p99_s":   ("lower", 0.20, 10.0),
    "hung_peeks":      ("lower", 0.00, 0.0),
    "peek_timeouts":   ("lower", 0.50, 5.0),
    "breaker_opens":   ("higher", 0.50, 0.0),
    "breaker_closes":  ("higher", 0.50, 0.0),
}

# emit()'s first-class config stamps: a mismatch means the two rows
# measured different configurations, not different code.
CONFIG_FIELDS = ("seed", "shards", "nprobe", "judge_model", "band")


def load_bench(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _index_rows(rows: list[dict]) -> dict[tuple[str, int], dict]:
    """Key rows by (name, occurrence index) so repeated names — e.g.
    per-sweep-point rows — match positionally."""
    seen: dict[str, int] = {}
    out = {}
    for r in rows:
        k = seen.get(r["name"], 0)
        seen[r["name"]] = k + 1
        out[(r["name"], k)] = r
    return out


def compare_rows(bench: str, base_rows: list[dict], cur_rows: list[dict],
                 out: list[str]) -> list[str]:
    """Returns violation strings; appends informational lines to out."""
    violations: list[str] = []
    cur = _index_rows(cur_rows)
    n_cmp = 0
    for key, brow in _index_rows(base_rows).items():
        name, idx = key
        label = name if idx == 0 else f"{name}#{idx}"
        crow = cur.get(key)
        if crow is None:
            violations.append(
                f"{bench}: row {label!r} present in baseline but missing "
                "from the current run")
            continue
        drift = [f for f in CONFIG_FIELDS
                 if brow.get(f) != crow.get(f)]
        if drift:
            out.append(
                f"  ~ {bench}/{label}: skipped (config drift on "
                + ", ".join(f"{f}: {brow.get(f)!r}->{crow.get(f)!r}"
                            for f in drift) + ")")
            continue
        bder = brow.get("derived") or {}
        cder = crow.get("derived") or {}
        for metric, (direction, rel, abs_tol) in RULES.items():
            if metric not in bder or metric not in cder:
                continue
            try:
                b = float(bder[metric])
                c = float(cder[metric])
            except (TypeError, ValueError):
                continue
            n_cmp += 1
            tol = max(abs_tol, rel * abs(b))
            bad = (c < b - tol) if direction == "higher" else (c > b + tol)
            if bad:
                violations.append(
                    f"{bench}/{label}: {metric} regressed "
                    f"{b:g} -> {c:g} ({direction} is better, "
                    f"tolerance {tol:g})")
    out.append(f"  {bench}: {n_cmp} metric(s) compared, "
               f"{len(violations)} violation(s)")
    return violations


def run_compare(baseline_dir: str, current_dir: str,
                names: list[str] | None = None) -> int:
    paths = sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json")))
    if names is not None:
        want = {f"BENCH_{n}.json" for n in names}
        paths = [p for p in paths if os.path.basename(p) in want]
    if not paths:
        print(f"compare: no baseline BENCH_*.json under {baseline_dir!r}"
              + (f" matching {names}" if names else ""), file=sys.stderr)
        return 2
    all_violations: list[str] = []
    report: list[str] = []
    for bpath in paths:
        fname = os.path.basename(bpath)
        bench = fname[len("BENCH_"):-len(".json")]
        cpath = os.path.join(current_dir, fname)
        try:
            base = load_bench(bpath)
        except (OSError, json.JSONDecodeError) as e:
            print(f"compare: cannot read baseline {bpath}: {e}",
                  file=sys.stderr)
            return 2
        if not os.path.exists(cpath):
            # only gate benchmarks the current run actually produced —
            # CI legs run disjoint subsets, each against the same
            # committed baseline directory
            report.append(f"  - {bench}: no current BENCH file, skipped")
            continue
        try:
            curr = load_bench(cpath)
        except (OSError, json.JSONDecodeError) as e:
            print(f"compare: cannot read current {cpath}: {e}",
                  file=sys.stderr)
            return 2
        all_violations.extend(
            compare_rows(bench, base.get("rows", []),
                         curr.get("rows", []), report))
    print("compare report "
          f"(baseline={baseline_dir}, current={current_dir}):")
    for line in report:
        print(line)
    if all_violations:
        print(f"\n{len(all_violations)} regression(s):")
        for v in all_violations:
            print(f"  ! {v}")
        return 1
    print("\nall compared metrics within tolerance")
    return 0


def self_test() -> int:
    """Exercise the harness against synthetic artifacts: an identical
    compare must pass, and each class of injected fault must fail."""
    import tempfile

    def write(d, bench, rows):
        with open(os.path.join(d, f"BENCH_{bench}.json"), "w") as f:
            json.dump({"name": bench, "rows": rows}, f)

    def row(name, seed=7, **derived):
        return {"name": name, "us_per_call": 1.0, "seed": seed,
                "shards": None, "nprobe": None, "judge_model": None,
                "band": None, "wall_s": 0.1, "trace_path": None,
                "derived": derived}

    rows = [row("x/a", thpt=10.0, hit=0.9, lat_ms=120.0, wall_s=3.0),
            row("x/a", thpt=9.0, hit=0.8, lat_ms=150.0),  # dup name
            row("x/b", api=100, em=0.7)]
    failures = []
    with tempfile.TemporaryDirectory() as base, \
            tempfile.TemporaryDirectory() as cur:
        write(base, "x", rows)
        # 1. identical -> pass
        write(cur, "x", json.loads(json.dumps(rows)))
        if run_compare(base, cur) != 0:
            failures.append("identical artifacts must compare clean")
        # 2. regression on a 'higher' metric -> fail
        bad = json.loads(json.dumps(rows))
        bad[0]["derived"]["thpt"] = 5.0
        write(cur, "x", bad)
        if run_compare(base, cur) != 1:
            failures.append("thpt drop must be flagged")
        # 3. regression on a 'lower' metric (2nd occurrence) -> fail
        bad = json.loads(json.dumps(rows))
        bad[1]["derived"]["lat_ms"] = 500.0
        write(cur, "x", bad)
        if run_compare(base, cur) != 1:
            failures.append("lat_ms rise on row#1 must be flagged")
        # 4. within tolerance -> pass
        ok = json.loads(json.dumps(rows))
        ok[0]["derived"]["lat_ms"] = 121.0   # +1ms < max(2.0, 12.0)
        write(cur, "x", ok)
        if run_compare(base, cur) != 0:
            failures.append("in-tolerance drift must pass")
        # 5. improvement -> pass
        ok = json.loads(json.dumps(rows))
        ok[2]["derived"]["api"] = 10
        write(cur, "x", ok)
        if run_compare(base, cur) != 0:
            failures.append("improvement must pass")
        # 6. missing row -> fail
        write(cur, "x", json.loads(json.dumps(rows))[:2])
        if run_compare(base, cur) != 1:
            failures.append("missing row must be flagged")
        # 7. config drift -> skip (pass), even with a huge delta
        drift = json.loads(json.dumps(rows))
        drift[0]["seed"] = 8
        drift[0]["derived"]["thpt"] = 0.1
        write(cur, "x", drift)
        if run_compare(base, cur) != 0:
            failures.append("config-drift row must be skipped, not judged")
        # 8. ignored metrics never gate
        wall = json.loads(json.dumps(rows))
        wall[0]["derived"]["wall_s"] = 9999.0
        wall[0]["us_per_call"] = 9999.0
        write(cur, "x", wall)
        if run_compare(base, cur) != 0:
            failures.append("wall-clock fields must be ignored")
        # 9. no current BENCH file at all -> pass with a skip note
        os.remove(os.path.join(cur, "BENCH_x.json"))
        if run_compare(base, cur) != 0:
            failures.append("absent current benchmark must be skipped")
        # 10. empty baseline dir -> usage error
        if run_compare(cur, base) != 2:
            failures.append("empty baseline dir must exit 2")
    if failures:
        print("\ncompare --self-test FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  ! {f}", file=sys.stderr)
        return 1
    print("\ncompare --self-test passed (10/10 scenarios)")
    return 0


def main(argv=None) -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ap = argparse.ArgumentParser(
        description="diff BENCH_*.json against committed baselines")
    ap.add_argument("--baseline",
                    default=os.path.join(repo, "benchmarks", "baselines"),
                    help="directory of committed baseline BENCH_*.json")
    ap.add_argument("--current", default=".",
                    help="directory the current run wrote BENCH_*.json to")
    ap.add_argument("--names", default=None,
                    help="comma-separated benchmark subset to compare")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the harness itself on synthetic "
                         "artifacts and exit")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    names = args.names.split(",") if args.names else None
    return run_compare(args.baseline, args.current, names)


if __name__ == "__main__":
    sys.exit(main())
