"""Perf-regression harness (benchmarks/compare.py, DESIGN.md §16):
exit-code contract over synthetic BENCH artifacts, plus the committed
baselines comparing clean against themselves."""
from __future__ import annotations

import json
import os

import pytest

from benchmarks.compare import RULES, main, run_compare, self_test

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINES = os.path.join(REPO, "benchmarks", "baselines")


def _row(name, seed=7, **derived):
    return {"name": name, "us_per_call": 1.0, "seed": seed,
            "shards": None, "nprobe": None, "judge_model": None,
            "band": None, "wall_s": 0.1, "trace_path": None,
            "derived": derived}


def _write(d, bench, rows):
    with open(os.path.join(d, f"BENCH_{bench}.json"), "w") as f:
        json.dump({"name": bench, "rows": rows}, f)


@pytest.fixture
def dirs(tmp_path):
    base = tmp_path / "base"
    cur = tmp_path / "cur"
    base.mkdir()
    cur.mkdir()
    rows = [_row("a", thpt=10.0, lat_ms=100.0),
            _row("a", thpt=8.0, lat_ms=120.0),
            _row("b", hit=0.9, api=50)]
    _write(str(base), "x", rows)
    _write(str(cur), "x", json.loads(json.dumps(rows)))
    return str(base), str(cur), rows


def test_identical_artifacts_pass(dirs):
    base, cur, _ = dirs
    assert run_compare(base, cur) == 0


def test_higher_metric_drop_fails(dirs):
    base, cur, rows = dirs
    rows[2]["derived"]["hit"] = 0.5
    _write(cur, "x", rows)
    assert run_compare(base, cur) == 1


def test_lower_metric_rise_on_repeated_row_fails(dirs):
    base, cur, rows = dirs
    rows[1]["derived"]["lat_ms"] = 400.0   # second occurrence of "a"
    _write(cur, "x", rows)
    assert run_compare(base, cur) == 1


def test_within_tolerance_and_improvements_pass(dirs):
    base, cur, rows = dirs
    rows[0]["derived"]["lat_ms"] = 101.0   # inside max(2.0, 10%)
    rows[2]["derived"]["api"] = 5          # improvement
    _write(cur, "x", rows)
    assert run_compare(base, cur) == 0


def test_missing_row_fails(dirs):
    base, cur, rows = dirs
    _write(cur, "x", rows[:2])
    assert run_compare(base, cur) == 1


def test_config_drift_skips_instead_of_judging(dirs):
    base, cur, rows = dirs
    rows[0]["seed"] = 99
    rows[0]["derived"]["thpt"] = 0.001
    _write(cur, "x", rows)
    assert run_compare(base, cur) == 0


def test_unlisted_metrics_are_ignored(dirs):
    base, cur, rows = dirs
    rows[0]["derived"]["wall_s"] = 1e9
    rows[0]["derived"]["novel_metric"] = -1e9
    rows[0]["us_per_call"] = 1e9
    _write(cur, "x", rows)
    assert run_compare(base, cur) == 0


def test_absent_current_bench_is_skipped(dirs):
    base, cur, _ = dirs
    os.remove(os.path.join(cur, "BENCH_x.json"))
    assert run_compare(base, cur) == 0


def test_empty_baseline_dir_is_usage_error(tmp_path):
    empty = tmp_path / "empty"
    empty.mkdir()
    assert run_compare(str(empty), str(tmp_path)) == 2


def test_names_filter(dirs):
    base, cur, rows = dirs
    rows[2]["derived"]["hit"] = 0.0
    _write(cur, "x", rows)
    assert run_compare(base, cur, names=["x"]) == 1
    assert run_compare(base, cur, names=["y"]) == 2  # nothing matched


def test_rules_are_direction_complete():
    assert RULES and all(
        d in ("higher", "lower") and rel >= 0 and abs_tol >= 0
        for d, rel, abs_tol in RULES.values())


def test_self_test_passes():
    assert self_test() == 0


def test_main_entrypoint(dirs):
    base, cur, _ = dirs
    assert main(["--baseline", base, "--current", cur]) == 0


@pytest.mark.skipif(not os.path.isdir(BASELINES),
                    reason="no committed baselines")
def test_committed_baselines_compare_clean_against_themselves():
    assert run_compare(BASELINES, BASELINES) == 0
