"""Composable language-model assembly.

A model is `prefix layers (unrolled) + superblock × n_repeat (lax.scan)`,
optionally with an encoder stack (enc-dec) and a modality-frontend stub.
Scan-over-superblocks keeps the HLO O(1) in depth — a 80-layer qwen1.5-110b
and a 24-layer xlstm-350m compile to similarly-sized modules, which is what
makes 40 (arch × shape) dry-run cells tractable.

Steps exposed:
  * ``loss_and_aux``   — train forward (+ MoE aux, + MTP loss)
  * ``prefill``        — returns logits + populated caches
  * ``decode``         — one token with a seq_len KV cache (serve_step)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import attention as att
from repro.nn import basic, moe as moe_mod, ssm, xlstm as xl
from repro.nn.config import LayerSpec, ModelConfig
from repro.nn.param import ParamSpec, stack_tree
from repro.nn.sharding import ShardCtx, shard_map_compat

from repro.nn import runtime as _runtime

# ----------------------------------------------------------- layer specs


def layer_specs(spec: LayerSpec, d_model: int, dtype, norm_eps: float) -> dict:
    p: dict[str, Any] = {"norm1": basic.rmsnorm_specs(d_model)}
    if spec.kind == "attn":
        if spec.attn.kind == "mla":
            p["mixer"] = att.mla_specs(spec.attn, d_model, dtype)
        else:
            p["mixer"] = att.gqa_specs(spec.attn, d_model, dtype)
    elif spec.kind == "mamba":
        p["mixer"] = ssm.mamba_specs(spec.mamba, d_model, dtype)
    elif spec.kind == "mlstm":
        p["mixer"] = xl.mlstm_specs(spec.xlstm, d_model, dtype)
    elif spec.kind == "slstm":
        p["mixer"] = xl.slstm_specs(spec.xlstm, d_model, dtype)
    else:
        raise ValueError(spec.kind)
    if spec.cross_attn:
        p["cross_norm"] = basic.rmsnorm_specs(d_model)
        p["cross"] = att.gqa_specs(
            dataclasses.replace(spec.attn, rope_kind="none"), d_model, dtype
        )
    if spec.moe is not None:
        p["norm2"] = basic.rmsnorm_specs(d_model)
        p["moe"] = moe_mod.moe_specs(spec.moe, d_model, dtype)
    elif spec.d_ff:
        p["norm2"] = basic.rmsnorm_specs(d_model)
        p["ffn"] = basic.ffn_specs(d_model, spec.d_ff, dtype, spec.ffn_act)
    return p


def layer_cache_specs(
    spec: LayerSpec, d_model: int, batch: int, s_cache: int, dtype,
    enc_len: int = 0, kv_quant: bool = False,
) -> dict:
    out: dict[str, Any] = {}
    if spec.kind == "attn":
        if spec.attn.kind == "mla":
            out["mixer"] = att.mla_cache_specs(spec.attn, batch, s_cache, dtype)
        else:
            out["mixer"] = att.gqa_cache_specs(
                spec.attn, batch, s_cache, dtype, quant=kv_quant
            )
    elif spec.kind == "mamba":
        out["mixer"] = ssm.mamba_cache_specs(spec.mamba, d_model, batch)
    elif spec.kind == "mlstm":
        out["mixer"] = xl.mlstm_cache_specs(spec.xlstm, d_model, batch)
    elif spec.kind == "slstm":
        out["mixer"] = xl.slstm_cache_specs(spec.xlstm, d_model, batch)
    if spec.cross_attn:
        kv, dh = spec.attn.n_kv_heads, spec.attn.head_dim
        shp = (batch, enc_len, kv, dh)
        axes = ("dp", "seq" if batch == 1 else "kv_seq", None, None)
        out["cross_kv"] = {
            "k": ParamSpec(shp, dtype, axes, init="zeros"),
            "v": ParamSpec(shp, dtype, axes, init="zeros"),
        }
    return out


def apply_layer(
    ctx: ShardCtx,
    spec: LayerSpec,
    p,
    x,
    positions,
    *,
    cache=None,
    cache_pos=None,
    causal: bool = True,
    enc_out=None,
    norm_eps: float = 1e-6,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = basic.rmsnorm(p["norm1"], x, norm_eps)
    mix_cache = cache.get("mixer") if cache else None
    if spec.kind == "attn":
        if spec.attn.kind == "mla":
            y, new_mix = att.mla_apply(
                ctx, p["mixer"], spec.attn, h, positions,
                cache=mix_cache, cache_pos=cache_pos, eps=norm_eps,
            )
        else:
            if not causal and mix_cache is None:
                # encoder self-attention: full bidirectional
                y, new_mix = _bidir_attn(ctx, p["mixer"], spec.attn, h, positions)
            else:
                y, new_mix = att.gqa_apply(
                    ctx, p["mixer"], spec.attn, h, positions,
                    cache=mix_cache, cache_pos=cache_pos,
                )
    elif spec.kind == "mamba":
        y, new_mix = ssm.mamba_apply(ctx, p["mixer"], spec.mamba, h, cache=mix_cache)
    elif spec.kind == "mlstm":
        y, new_mix = xl.mlstm_apply(ctx, p["mixer"], spec.xlstm, h, cache=mix_cache)
    elif spec.kind == "slstm":
        y, new_mix = xl.slstm_apply(ctx, p["mixer"], spec.xlstm, h, cache=mix_cache)
    else:
        raise ValueError(spec.kind)
    # named for the "save_outs" remat policy: saving the two post-AR layer
    # outputs lets backward recompute skip re-running the matmul+all-reduce
    # (§Perf: trades ~2 activations/layer of memory for 1/3 of TP traffic)
    y = jax.ad_checkpoint.checkpoint_name(y, "mixer_out")
    x = x + y
    new_cache: dict[str, Any] = {"mixer": new_mix} if new_mix is not None else {}

    if spec.cross_attn:
        hc = basic.rmsnorm(p["cross_norm"], x, norm_eps)
        if cache is not None and "cross_kv" in cache:
            kvp = (cache["cross_kv"]["k"], cache["cross_kv"]["v"])
        else:
            kvp = att.cross_kv(
                ctx, {"wk": p["cross"]["wk"], "wv": p["cross"]["wv"]},
                spec.attn, enc_out,
            )
        yc, _ = att.gqa_apply(
            ctx, p["cross"],
            dataclasses.replace(spec.attn, rope_kind="none"),
            hc, positions, kv_override=kvp,
        )
        x = x + yc
        new_cache["cross_kv"] = {"k": kvp[0], "v": kvp[1]}

    if spec.moe is not None:
        h2 = basic.rmsnorm(p["norm2"], x, norm_eps)
        y2, aux = moe_mod.moe_apply(ctx, p["moe"], spec.moe, h2)
        x = x + jax.ad_checkpoint.checkpoint_name(y2, "ffn_out")
    elif spec.d_ff:
        h2 = basic.rmsnorm(p["norm2"], x, norm_eps)
        y2 = basic.ffn(ctx, p["ffn"], h2, spec.ffn_act)
        x = x + jax.ad_checkpoint.checkpoint_name(y2, "ffn_out")
    return x, new_cache, aux


def _bidir_attn(ctx, p, cfg, x, positions):
    """Encoder self-attention (no causal mask)."""
    import math

    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = att._split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), h, dh)
    k = att._split_heads(jnp.einsum("bsd,dh->bsh", x, p["wk"]), kv, dh)
    v = att._split_heads(jnp.einsum("bsd,dh->bsh", x, p["wv"]), kv, dh)
    if cfg.rope_kind != "none":
        q = basic.apply_rope(cfg, q, positions)
        k = basic.apply_rope(cfg, k, positions)
    if s > att.FLASH_THRESHOLD:
        from repro.nn.flash import sdpa_flash

        out = sdpa_flash(
            q, k, v, 1.0 / math.sqrt(dh), causal=False,
            chunk=min(att.flash_chunk(s), s),
        )
    else:
        mask = jnp.ones((b, s, s), bool)
        out = att._sdpa(ctx, q, k, v, mask, 1.0 / math.sqrt(dh))
    out = out.reshape(b, s, h * dh)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return ctx.constrain(y, "dp", None, None), None


# ----------------------------------------------------------- model


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------- parameter tree

    def param_specs(self) -> dict:
        cfg = self.cfg
        dt = cfg.pdt
        tree: dict[str, Any] = {
            "embed": basic.embedding_specs(cfg.vocab_size, cfg.d_model, dt),
            "final_norm": basic.rmsnorm_specs(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            tree["head"] = {
                "table": ParamSpec(
                    (cfg.vocab_size, cfg.d_model), dt, ("model", "fsdp"),
                    scale=0.02,
                )
            }
        if cfg.prefix:
            tree["prefix"] = [
                layer_specs(sp, cfg.d_model, dt, cfg.norm_eps) for sp in cfg.prefix
            ]
        if cfg.blocks and cfg.n_repeat:
            one = {
                f"l{i}": layer_specs(sp, cfg.d_model, dt, cfg.norm_eps)
                for i, sp in enumerate(cfg.blocks)
            }
            tree["blocks"] = (
                stack_tree(one, cfg.n_repeat) if cfg.n_repeat > 1 else one
            )
        if cfg.enc_dec:
            enc_one = {
                f"l{i}": layer_specs(sp, cfg.d_model, dt, cfg.norm_eps)
                for i, sp in enumerate(cfg.enc_blocks)
            }
            tree["enc_blocks"] = (
                stack_tree(enc_one, cfg.enc_repeat)
                if cfg.enc_repeat > 1 else enc_one
            )
            tree["enc_norm"] = basic.rmsnorm_specs(cfg.d_model)
        if cfg.frontend:
            tree["frontend_proj"] = {
                "w": ParamSpec((cfg.d_model, cfg.d_model), dt, ("fsdp", "model"))
            }
        if cfg.mtp:
            mtp_layer = cfg.blocks[-1]
            tree["mtp"] = {
                "norm_h": basic.rmsnorm_specs(cfg.d_model),
                "norm_e": basic.rmsnorm_specs(cfg.d_model),
                "proj": ParamSpec(
                    (2 * cfg.d_model, cfg.d_model), dt, ("fsdp", "model")
                ),
                "block": layer_specs(mtp_layer, cfg.d_model, dt, cfg.norm_eps),
            }
        return tree

    # ---------------- forward pieces

    def _embed(self, ctx, params, tokens):
        return _sharded_embed(ctx, params["embed"]["table"], tokens)

    def _logits(self, ctx, params, x):
        cfg = self.cfg
        x = basic.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        table = (
            params["embed"]["table"] if cfg.tie_embeddings
            else params["head"]["table"]
        )
        logits = jnp.einsum("...d,vd->...v", x, table)
        logits = ctx.constrain(logits, "dp", None, "model")
        if cfg.logit_softcap:
            c = cfg.logit_softcap
            logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
        return logits

    def _positions(self, tokens, offset=0):
        b, s = tokens.shape[:2]
        pos = offset + jnp.arange(s, dtype=jnp.int32)[None, :]
        return jnp.broadcast_to(pos, (b, s))

    def _run_stack(
        self, ctx, params, x, positions, *, caches=None, cache_pos=None,
        causal=True, enc_out=None, remat: str = "none",
    ):
        """prefix (unrolled) + scan over stacked superblocks."""
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        new_prefix_caches = []
        if cfg.prefix:
            for i, sp in enumerate(cfg.prefix):
                c_i = caches["prefix"][i] if caches else None
                x, nc, aux = apply_layer(
                    ctx, sp, params["prefix"][i], x, positions,
                    cache=c_i, cache_pos=cache_pos, causal=causal,
                    enc_out=enc_out, norm_eps=cfg.norm_eps,
                )
                aux_total += aux
                new_prefix_caches.append(nc)

        want_cache = caches is not None
        if cfg.blocks and cfg.n_repeat:
            block_params = params["blocks"]
            block_caches = caches["blocks"] if caches else None

            def superblock(x, p_sb, c_sb):
                new_c = {}
                aux_sb = jnp.zeros((), jnp.float32)
                for i, sp in enumerate(self.cfg.blocks):
                    c_i = c_sb.get(f"l{i}") if c_sb else None
                    x, nc, aux = apply_layer(
                        ctx, sp, p_sb[f"l{i}"], x, positions,
                        cache=c_i, cache_pos=cache_pos, causal=causal,
                        enc_out=enc_out, norm_eps=self.cfg.norm_eps,
                    )
                    if want_cache:
                        new_c[f"l{i}"] = nc
                    aux_sb += aux
                return x, new_c, aux_sb

            if cfg.n_repeat > 1 and block_caches is not None:
                # decode/refill with existing caches: the stacked cache
                # tree rides the scan CARRY (while-loop carries alias in
                # place) instead of xs/ys, which would copy the whole
                # cache per layer (§Perf iteration 3: 2.5x decode temp)
                def body_c(carry, xs):
                    x, aux_acc, cache_all = carry
                    i, p_sb = xs
                    c_sb = jax.tree.map(
                        lambda c: jax.lax.dynamic_index_in_dim(
                            c, i, 0, keepdims=False
                        ),
                        cache_all,
                    )
                    x, new_c, aux_sb = superblock(x, p_sb, c_sb)
                    cache_all = jax.tree.map(
                        lambda buf, nc: jax.lax.dynamic_update_index_in_dim(
                            buf, nc.astype(buf.dtype), i, 0
                        ),
                        cache_all, new_c,
                    )
                    return (x, aux_acc + aux_sb, cache_all), None

                (x, aux_sb, new_block_caches), _ = jax.lax.scan(
                    body_c, (x, aux_total, block_caches),
                    (jnp.arange(cfg.n_repeat), block_params),
                    unroll=_runtime.unroll_for(cfg.n_repeat),
                )
                aux_total = aux_sb
            elif cfg.n_repeat > 1:
                def body(carry, p_sb):
                    x, aux_acc = carry
                    x, new_c, aux_sb = superblock(x, p_sb, None)
                    return (x, aux_acc + aux_sb), new_c

                if remat != "none":
                    if remat == "dots":
                        policy = (jax.checkpoint_policies
                                  .dots_with_no_batch_dims_saveable)
                    elif remat == "save_outs":
                        policy = jax.checkpoint_policies.save_only_these_names(
                            "mixer_out", "ffn_out"
                        )
                    else:
                        policy = None
                    body = jax.checkpoint(body, policy=policy)
                (x, aux_sb), new_block_caches = jax.lax.scan(
                    body, (x, aux_total), block_params,
                    unroll=_runtime.unroll_for(cfg.n_repeat),
                )
                aux_total = aux_sb
            else:
                sb = superblock
                if remat != "none":
                    if remat == "dots":
                        policy = (jax.checkpoint_policies
                                  .dots_with_no_batch_dims_saveable)
                    elif remat == "save_outs":
                        policy = jax.checkpoint_policies.save_only_these_names(
                            "mixer_out", "ffn_out"
                        )
                    else:
                        policy = None
                    sb = jax.checkpoint(superblock, policy=policy)
                x, new_block_caches, aux_sb = sb(
                    x, block_params, block_caches
                )
                aux_total = aux_total + aux_sb
        else:
            new_block_caches = None

        new_caches = None
        if caches is not None:
            new_caches = {"blocks": new_block_caches}
            if cfg.prefix:
                new_caches["prefix"] = new_prefix_caches
        return x, new_caches, aux_total

    def _encode(self, ctx, params, enc_emb):
        """Encoder stack over precomputed frontend embeddings (audio)."""
        cfg = self.cfg
        x = enc_emb
        positions = self._positions(enc_emb[..., 0])

        def superblock(x, p_sb):
            for i, sp in enumerate(cfg.enc_blocks):
                x, _, _ = apply_layer(
                    ctx, sp, p_sb[f"l{i}"], x, positions,
                    causal=False, norm_eps=cfg.norm_eps,
                )
            return x

        if cfg.enc_repeat > 1:
            def body(x, p_sb):
                return superblock(x, p_sb), None
            x, _ = jax.lax.scan(
                body, x, params["enc_blocks"],
                unroll=_runtime.unroll_for(cfg.enc_repeat),
            )
        else:
            x = superblock(x, params["enc_blocks"])
        return basic.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    # ---------------- public steps

    def loss_and_aux(self, ctx, params, batch, remat: str = "none"):
        """batch: tokens (B,S), labels (B,S), optional frontend_emb,
        frontend_mask, positions (mrope), enc_emb."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(ctx, params, tokens)
        if cfg.frontend == "vision":
            fe = jnp.einsum(
                "bsd,de->bse", batch["frontend_emb"], params["frontend_proj"]["w"]
            )
            x = jnp.where(batch["frontend_mask"][..., None], fe, x)
        positions = batch.get("positions")
        if positions is None:
            positions = self._positions(tokens)
        enc_out = None
        if cfg.enc_dec:
            enc_out = self._encode(ctx, params, batch["enc_emb"])
        x, _, aux = self._run_stack(
            ctx, params, x, positions, enc_out=enc_out, remat=remat
        )
        loss = self._loss_from_hidden(ctx, params, x, batch["labels"])
        if cfg.mtp:
            loss = loss + 0.3 * self._mtp_loss(ctx, params, x, tokens, batch)
        return loss + aux, {"aux": aux}

    def _loss_from_hidden(self, ctx, params, x, labels):
        """Cross-entropy from final hidden states. Without TP the fused
        chunked-vocab loss avoids materialising (tokens x vocab) logits
        (§Perf iteration 5); with TP the Megatron vocab-sharded path runs."""
        cfg = self.cfg
        table = (
            params["embed"]["table"] if cfg.tie_embeddings
            else params["head"]["table"]
        )
        if ctx.mesh is None or ctx.tp_size() == 1:
            from repro.nn.xent import chunked_xent

            xn = basic.rmsnorm(params["final_norm"], x, cfg.norm_eps)
            t = xn.shape[0] * xn.shape[1]
            return chunked_xent(
                xn.reshape(t, cfg.d_model), table, labels.reshape(t),
                16384, cfg.logit_softcap,
            )
        logits = self._logits(ctx, params, x)
        return _sharded_xent(ctx, logits, labels)

    def _mtp_loss(self, ctx, params, h, tokens, batch):
        """DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1})."""
        cfg = self.cfg
        p = params["mtp"]
        emb_next = self._embed(ctx, params, jnp.roll(tokens, -1, axis=1))
        z = jnp.concatenate(
            [basic.rmsnorm(p["norm_h"], h, cfg.norm_eps),
             basic.rmsnorm(p["norm_e"], emb_next, cfg.norm_eps)], axis=-1
        )
        z = jnp.einsum("bsd,de->bse", z, p["proj"])
        positions = self._positions(tokens)
        z, _, _ = (
            apply_layer(
                ctx, cfg.blocks[-1], p["block"], z, positions,
                norm_eps=cfg.norm_eps,
            )
        )
        labels2 = jnp.roll(batch["labels"], -1, axis=1)
        return self._loss_from_hidden(ctx, params, z, labels2)

    def prefill(self, ctx, params, batch, s_cache: Optional[int] = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(ctx, params, tokens)
        if cfg.frontend == "vision":
            fe = jnp.einsum(
                "bsd,de->bse", batch["frontend_emb"], params["frontend_proj"]["w"]
            )
            x = jnp.where(batch["frontend_mask"][..., None], fe, x)
        positions = batch.get("positions")
        if positions is None:
            positions = self._positions(tokens)
        enc_out = None
        if cfg.enc_dec:
            enc_out = self._encode(ctx, params, batch["enc_emb"])
        # prefill runs cache-less (train-path mixers) and returns final
        # mixer states; attention K/V are emitted by the mixers themselves.
        caches = self._empty_cache_tree()
        x, new_caches, _ = self._run_stack(
            ctx, params, x, positions, caches=caches, cache_pos=None,
            causal=True, enc_out=enc_out,
        )
        logits = self._logits(ctx, params, x[:, -1:, :])
        return logits, new_caches

    def prefill_flops(self, tokens: int) -> float:
        """Forward prefill FLOPs over ``tokens`` tokens (2·N_active·T,
        the roofline model). The JudgePipeline derives the judge's
        token-equivalent serving cost from this — see DESIGN.md §14."""
        from repro.launch.roofline import model_flops

        return model_flops(self.cfg, "prefill", tokens)

    def _empty_cache_tree(self):
        cfg = self.cfg
        tree: dict[str, Any] = {"blocks": None}
        if cfg.prefix:
            tree["prefix"] = [None] * len(cfg.prefix)
        return tree

    def decode(self, ctx, params, tokens, caches, pos, enc_out=None,
               positions=None):
        """tokens: (B,1); caches from cache_specs; pos: scalar write index."""
        cfg = self.cfg
        x = self._embed(ctx, params, tokens)
        if positions is None:
            b = tokens.shape[0]
            positions = jnp.broadcast_to(
                jnp.asarray(pos, jnp.int32)[None, None], (b, 1)
            )
        x, new_caches, _ = self._run_stack(
            ctx, params, x, positions, caches=caches, cache_pos=pos,
            enc_out=enc_out,
        )
        logits = self._logits(ctx, params, x)
        return logits, new_caches

    # ---------------- cache tree

    def cache_specs(self, batch: int, s_cache: int, enc_len: int = 0,
                    kv_quant: bool = False) -> dict:
        cfg = self.cfg
        dt = cfg.pdt
        tree: dict[str, Any] = {}
        if cfg.prefix:
            tree["prefix"] = [
                layer_cache_specs(sp, cfg.d_model, batch, s_cache, dt,
                                  enc_len, kv_quant)
                for sp in cfg.prefix
            ]
        one = {
            f"l{i}": layer_cache_specs(
                sp, cfg.d_model, batch, s_cache, dt, enc_len, kv_quant
            )
            for i, sp in enumerate(cfg.blocks)
        }
        tree["blocks"] = stack_tree(one, cfg.n_repeat) if cfg.n_repeat > 1 else one
        return tree


# ----------------------------------------------------------- shard helpers


def _dp_entry(ctx: ShardCtx, dim: int):
    """Mesh-axis tuple to shard a batch dim of the given size, or None."""
    axes = [
        a for a in ctx.cfg.mesh_axes("dp") if a in ctx.mesh.shape
    ]
    kept, prod = [], 1
    for a in axes:
        if dim % (prod * ctx.mesh.shape[a]) == 0:
            kept.append(a)
            prod *= ctx.mesh.shape[a]
    return tuple(kept) if kept else None


def _sharded_embed(ctx: ShardCtx, table, tokens):
    """Megatron vocab-parallel embedding: masked local gather + psum.

    Fully-manual shard_map over every mesh axis — the half-manual (auto-dp)
    variant trips an XLA SPMD-partitioner check failure at 512 devices
    (b/433785288-adjacent); fully-manual regions bypass GSPMD entirely.
    """
    if ctx.mesh is None or ctx.tp_size() == 1 or \
            table.shape[0] % ctx.tp_size() != 0:
        out = jnp.take(table, tokens, axis=0)
        return ctx.constrain(out, "dp", None, None)
    axis = ctx.cfg.mesh_axes("model")[0]
    v_local = table.shape[0] // ctx.tp_size()
    dp = _dp_entry(ctx, tokens.shape[0])

    def inner(tbl, tok):
        lo = jax.lax.axis_index(axis) * v_local
        loc = tok - lo
        ok = (loc >= 0) & (loc < v_local)
        loc = jnp.clip(loc, 0, v_local - 1)
        out = jnp.take(tbl, loc, axis=0) * ok[..., None].astype(tbl.dtype)
        return jax.lax.psum(out, axis)

    out = shard_map_compat(
        inner, mesh=ctx.mesh,
        in_specs=(P(axis, None), P(dp, None)),
        out_specs=P(dp, None, None),
        axis_names=set(ctx.mesh.axis_names),
    )(table, tokens)
    return ctx.constrain(out, "dp", None, None)


def _sharded_xent(ctx: ShardCtx, logits, labels):
    """Cross-entropy over vocab-sharded logits without materialising the
    gathered vocab axis (Megatron-style: local max/sumexp + label pick).
    Fully-manual shard_map (see _sharded_embed note)."""
    if ctx.mesh is None or ctx.tp_size() == 1 or \
            logits.shape[-1] % ctx.tp_size() != 0:
        lgf = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(lgf, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(lgf - m), axis=-1)) + m[..., 0]
        picked = jnp.take_along_axis(lgf, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - picked)
    axis = ctx.cfg.mesh_axes("model")[0]
    v_local = logits.shape[-1] // ctx.tp_size()
    dp = _dp_entry(ctx, logits.shape[0])
    n_tokens = logits.shape[0] * logits.shape[1]

    def inner(lg, lb):
        lgf = lg.astype(jnp.float32)
        # stabiliser max carries no gradient (it cancels in softmax algebra)
        local_max = jax.lax.stop_gradient(jnp.max(lgf, axis=-1))
        gmax = jax.lax.pmax(local_max, axis)
        se = jnp.sum(jnp.exp(lgf - gmax[..., None]), axis=-1)
        lse = jnp.log(jax.lax.psum(se, axis)) + gmax
        lo = jax.lax.axis_index(axis) * v_local
        loc = lb - lo
        ok = (loc >= 0) & (loc < v_local)
        loc = jnp.clip(loc, 0, v_local - 1)
        picked = jnp.take_along_axis(lgf, loc[..., None], axis=-1)[..., 0]
        picked = jax.lax.psum(picked * ok.astype(jnp.float32), axis)
        total = jnp.sum(lse - picked)
        if dp:
            total = jax.lax.psum(total, dp)
        return total / n_tokens

    return shard_map_compat(
        inner, mesh=ctx.mesh,
        in_specs=(P(dp, None, axis), P(dp, None)),
        out_specs=P(),
        axis_names=set(ctx.mesh.axis_names),
    )(logits, labels)
