"""Embedding front-end for Seri stage 1.

Two implementations behind one interface:

* ``ModelEmbedder`` — a real (small, e.g. qwen3-0.6b-class) JAX encoder:
  byte-level tokens → transformer → masked mean-pool → L2-normalise. With
  random init it still yields a deterministic, locality-free fingerprint;
  it exists to measure the true compute cost of the embedding stage and to
  exercise the co-location path. (No pretrained weights exist offline.)
* ``WorldEmbedder`` — the synthetic-semantic-world embedder used for the
  paper's behavioural experiments: paraphrases of one intent share a
  cluster center, hard negatives sit at a controlled cosine distance —
  giving ANN realistic true/false-positive structure (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp


def l2_normalize(x: np.ndarray, axis: int = -1) -> np.ndarray:
    n = np.linalg.norm(x, axis=axis, keepdims=True)
    return x / np.maximum(n, 1e-9)


def byte_tokens(text: str, max_len: int) -> np.ndarray:
    raw = np.frombuffer(text.encode("utf-8")[:max_len], dtype=np.uint8)
    out = np.zeros(max_len, np.int32)
    out[: len(raw)] = raw.astype(np.int32) + 3  # 0 = pad
    return out


class ModelEmbedder:
    def __init__(self, cfg=None, dim: int = 256, max_len: int = 64, seed=0):
        from repro.configs import get_config, shrink
        from repro.models.lm import LM
        from repro.nn.param import init_tree
        from repro.nn.sharding import ShardCtx

        cfg = cfg or shrink(get_config("qwen3-0.6b"), d_model=dim, vocab=512,
                            n_repeat=2)
        self.cfg = cfg
        self.max_len = max_len
        self.lm = LM(cfg)
        self.ctx = ShardCtx(None)
        self.params = init_tree(jax.random.PRNGKey(seed), self.lm.param_specs())

        def encode(params, tokens):
            x = self.lm._embed(self.ctx, params, tokens)
            pos = self.lm._positions(tokens)
            x, _, _ = self.lm._run_stack(self.ctx, params, x, pos)
            mask = (tokens > 0).astype(jnp.float32)[..., None]
            pooled = jnp.sum(x * mask, axis=1) / jnp.maximum(
                jnp.sum(mask, axis=1), 1.0
            )
            return pooled / jnp.maximum(
                jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-6
            )

        self._encode = jax.jit(encode)

    @property
    def dim(self) -> int:
        return self.cfg.d_model

    def embed_batch(self, texts: Sequence[str]) -> np.ndarray:
        toks = np.stack(
            [byte_tokens(t % self.cfg.vocab_size if isinstance(t, int)
                         else t, self.max_len) for t in texts]
        ) % self.cfg.vocab_size
        return np.asarray(self._encode(self.params, jnp.asarray(toks)),
                          np.float32)


class WorldEmbedder:
    """Looks up embeddings from a synthetic semantic world (data.world)."""

    def __init__(self, world):
        self.world = world

    @property
    def dim(self) -> int:
        return self.world.dim

    def embed_batch(self, texts: Sequence[str]) -> np.ndarray:
        return np.stack([self.world.embed(t) for t in texts])
