"""Norms, embeddings, rotary embeddings (RoPE + M-RoPE), dense FFNs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.config import AttnConfig
from repro.nn.param import ParamSpec
from repro.nn.sharding import ShardCtx

# ---------------------------------------------------------------- norms


def rmsnorm_specs(d: int) -> dict:
    return {"scale": ParamSpec((d,), jnp.float32, (None,), init="ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def layernorm_specs(d: int) -> dict:
    return {
        "scale": ParamSpec((d,), jnp.float32, (None,), init="ones"),
        "bias": ParamSpec((d,), jnp.float32, (None,), init="zeros"),
    }


def layernorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embedding


def embedding_specs(vocab: int, d: int, dtype) -> dict:
    # vocab-sharded over the model axis (Megatron-style), fsdp over d
    return {
        "table": ParamSpec((vocab, d), dtype, ("model", "fsdp"), scale=0.02)
    }


def embed(ctx: ShardCtx, p, tokens):
    out = jnp.take(p["table"], tokens, axis=0)
    return ctx.constrain(out, "dp", None, None)


def unembed(ctx: ShardCtx, p, x):
    logits = jnp.einsum("...d,vd->...v", x, p["table"])
    return ctx.constrain(logits, "dp", None, "model")


# ---------------------------------------------------------------- RoPE


def rope_freqs(cfg: AttnConfig, rot_dim: int):
    half = rot_dim // 2
    return 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )


def _rotate(x, sin, cos):
    # x: (..., rot_dim); sin/cos: (..., rot_dim/2)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(cfg: AttnConfig, x, positions, rot_dim: int | None = None):
    """x: (B, S, H, Dh) [rope applied to the first rot_dim dims];
    positions: (B, S) int32 or (3, B, S) for M-RoPE."""
    rot = rot_dim or x.shape[-1]
    inv = rope_freqs(cfg, rot)  # (rot/2,)
    if cfg.rope_kind == "mrope":
        # positions (3, B, S): temporal / height / width streams; the
        # frequency bands are split between the three streams (Qwen2-VL §3).
        # Text-only steps may pass (B, S): all three streams coincide.
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None], (3, *positions.shape))
        ang = positions[..., None].astype(jnp.float32) * inv  # (3, B, S, rot/2)
        secs = cfg.mrope_sections
        # build per-band selector: band i belongs to stream s(i)
        idx = jnp.concatenate(
            [jnp.full((n,), i, jnp.int32) for i, n in enumerate(secs)]
        )
        idx = idx[: rot // 2]
        sel = jax.nn.one_hot(idx, len(secs), dtype=jnp.float32)  # (rot/2, 3)
        ang = jnp.einsum("sbtf,fs->btf", ang, sel)
    else:
        ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, rot/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[:, :, None, :].astype(x.dtype)
    cos = cos[:, :, None, :].astype(x.dtype)
    if rot == x.shape[-1]:
        return _rotate(x, sin, cos)
    xr, xp = x[..., :rot], x[..., rot:]
    return jnp.concatenate([_rotate(xr, sin, cos), xp], axis=-1)


# ---------------------------------------------------------------- dense FFN


def ffn_specs(d: int, d_ff: int, dtype, act: str = "swiglu") -> dict:
    if act == "swiglu":
        return {
            "w_gate": ParamSpec((d, d_ff), dtype, ("fsdp", "model")),
            "w_up": ParamSpec((d, d_ff), dtype, ("fsdp", "model")),
            "w_down": ParamSpec((d_ff, d), dtype, ("model", "fsdp")),
        }
    return {
        "w_up": ParamSpec((d, d_ff), dtype, ("fsdp", "model")),
        "b_up": ParamSpec((d_ff,), jnp.float32, ("model",), init="zeros"),
        "w_down": ParamSpec((d_ff, d), dtype, ("model", "fsdp")),
        "b_down": ParamSpec((d,), jnp.float32, (None,), init="zeros"),
    }


def ffn(ctx: ShardCtx, p, x, act: str = "swiglu"):
    if act == "swiglu":
        h = jnp.einsum("...d,df->...f", x, p["w_gate"])
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["w_up"]) + p["b_up"].astype(x.dtype)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = ctx.constrain(h, "dp", None, "model")
    out = jnp.einsum("...f,fd->...d", h, p["w_down"])
    if act != "swiglu":
        out = out + p["b_down"].astype(x.dtype)
    return ctx.constrain(out, "dp", None, None)
