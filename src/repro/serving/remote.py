"""Remote data service emulation: WAN latency, per-call cost, API rate
limits with retry/backoff — the paper's cross-region deployment constants
(300–500 ms, $0.005/call, 100 QPM — §2.2, §6.1). All in virtual time."""
from __future__ import annotations

import dataclasses

import numpy as np


class TokenBucket:
    """QPM rate limiter in virtual time."""

    def __init__(self, qpm: float, burst: float | None = None):
        self.rate = qpm / 60.0
        self.capacity = burst if burst is not None else max(qpm / 12.0, 1.0)
        self.tokens = self.capacity
        self.t_last = 0.0

    def _refill(self, now: float):
        # Clamp to monotonic time: interleaved fetches resolve future
        # retry instants (fetch() advances its local `t` through backoff),
        # so a later-issued fetch can legally arrive with an *earlier*
        # timestamp. Refilling with a negative dt would subtract tokens
        # and drag t_last backwards (double-crediting the next refill);
        # out-of-order callers simply see the bucket as of t_last.
        if now <= self.t_last:
            return
        self.tokens = min(
            self.capacity, self.tokens + (now - self.t_last) * self.rate
        )
        self.t_last = now

    def try_acquire(self, now: float) -> bool:
        self._refill(now)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def headroom(self, now: float) -> float:
        self._refill(now)
        return self.tokens / self.capacity


@dataclasses.dataclass
class FetchOutcome:
    finish: float          # virtual completion time
    cost: float
    retries: int
    throttled_wait: float
    failed: bool = False   # terminal failure (brownout + retries exhausted)


class RemoteDataService:
    """Latency ~ U(lat_lo, lat_hi); throttle -> exponential backoff retry."""

    def __init__(
        self,
        *,
        lat_lo: float = 0.3,
        lat_hi: float = 0.5,
        cost_per_call: float = 0.005,
        qpm: float | None = 100.0,
        backoff0: float = 0.5,
        backoff_mult: float = 2.0,
        max_retries: int = 8,
        seed: int = 0,
        faults=None,
        region: int = 0,
    ):
        self.lat_lo = lat_lo
        self.lat_hi = lat_hi
        self.cost_per_call = cost_per_call
        self.limiter = TokenBucket(qpm) if qpm else None
        self.backoff0 = backoff0
        self.backoff_mult = backoff_mult
        self.max_retries = max_retries
        self.rng = np.random.default_rng(seed)
        # fault injection (DESIGN.md §17): brownout error/throttle draws
        # come from a dedicated rng that is only advanced inside an
        # active origin_brownout window, so the main latency stream —
        # and therefore every fault-free run — is byte-identical.
        self.faults = faults
        self.region = region
        self.fault_rng = np.random.default_rng(seed + 7919)
        # counters
        self.calls = 0
        self.attempts = 0
        self.retries = 0
        self.failed = 0
        self.total_cost = 0.0
        self.throttled_wait = 0.0

    def sample_latency(self) -> float:
        return float(self.rng.uniform(self.lat_lo, self.lat_hi))

    def fetch(self, now: float, *, latency_mult: float = 1.0,
              cost_mult: float = 1.0) -> FetchOutcome:
        """One logical fetch (may include throttled retries). The
        multipliers model heterogeneous tools (premium/slow vs cheap)."""
        t = now
        backoff = self.backoff0
        retries = 0
        waited = 0.0
        while True:
            self.attempts += 1
            # origin brownout (DESIGN.md §17): the active window elevates
            # per-attempt error and throttle rates; the dedicated fault
            # rng is only drawn inside a window so fault-free runs keep
            # every stream untouched.
            bw = (self.faults.brownout(self.region, t)
                  if self.faults is not None else None)
            errored = (bw is not None and bw.error_rate > 0.0
                       and float(self.fault_rng.random()) < bw.error_rate)
            choked = (bw is not None and bw.throttle > 0.0
                      and float(self.fault_rng.random()) < bw.throttle)
            if not errored and not choked:
                if self.limiter is None or self.limiter.try_acquire(t):
                    lat = self.sample_latency() * latency_mult
                    cost = self.cost_per_call * cost_mult
                    self.calls += 1
                    self.total_cost += cost
                    self.throttled_wait += waited
                    return FetchOutcome(t + lat, cost, retries, waited)
            # throttled (or brownout error / spurious 429)
            retries += 1
            self.retries += 1
            if retries > self.max_retries:
                if bw is not None:
                    # retries exhausted inside a brownout: terminal
                    # failure — the engine must answer through a
                    # degraded path, not wait the window out here.
                    self.failed += 1
                    self.throttled_wait += waited
                    return FetchOutcome(t, 0.0, retries, waited,
                                        failed=True)
                # final forced wait until a token is definitely available
                wait = max(1.0 / self.limiter.rate, backoff)
            else:
                wait = backoff * float(self.rng.uniform(0.8, 1.2))
            t += wait
            waited += wait
            backoff = min(backoff * self.backoff_mult, 8.0)

    def headroom(self, now: float) -> float:
        return 1.0 if self.limiter is None else self.limiter.headroom(now)

    @property
    def retry_ratio(self) -> float:
        return self.retries / self.attempts if self.attempts else 0.0
