"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step-per-device:

  compute    = HLO_FLOPs(per device)      / peak_FLOP/s
  memory     = HLO_bytes(per device)      / HBM_bw
  collective = wire_bytes(per device)     / link_bw

``cost_analysis()`` is per-partition under SPMD (verified empirically).
Collective bytes are NOT in cost_analysis: we parse the optimized HLO and
apply per-op ring-cost models using the parsed replica-group size n:

  all-reduce       2 * size * (n-1)/n     (result size = full tensor)
  all-gather       size * (n-1)/n         (result = gathered tensor)
  reduce-scatter   size * (n-1)            (result = shard; input n*size)
  all-to-all       size * (n-1)/n
  collective-permute  size
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict

from repro.launch.mesh import HW
from repro.nn.config import LayerSpec, ModelConfig

_DT_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_op: dict = dataclasses.field(default_factory=dict)
    counts: dict = dataclasses.field(default_factory=dict)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats(by_op=defaultdict(float), counts=Counter())
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(\([^)]*\)|\S+)\s+([\w-]+)\(", line)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "")
        if base not in _COLL_OPS or op.endswith("-done"):
            continue
        size = _shape_bytes(m.group(1))
        g = _GROUPS_RE.search(line)
        if g:
            n = int(g.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            n = len(gl.group(1).split(",")) if gl else n_devices
        n = max(n, 1)
        if base == "all-reduce":
            wire = 2 * size * (n - 1) / n
        elif base == "all-gather":
            wire = size * (n - 1) / n
        elif base == "reduce-scatter":
            wire = size * (n - 1)
        elif base == "all-to-all":
            wire = size * (n - 1) / n
        else:  # collective-permute
            wire = size
        stats.wire_bytes += wire
        stats.by_op[base] += wire
        stats.counts[base] += 1
    stats.by_op = dict(stats.by_op)
    stats.counts = dict(stats.counts)
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float           # per device
    bytes_accessed: float  # per device
    wire_bytes: float      # per device
    n_devices: int
    model_flops: float     # global useful flops (6·N_active·tokens etc.)

    @property
    def t_compute(self) -> float:
        return self.flops / HW["peak_flops_bf16"]

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HW["hbm_bw"]

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / HW["ici_bw"]

    @property
    def bottleneck(self) -> str:
        ts = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(ts, key=ts.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilisation at the roofline step time."""
        denom = self.step_time * HW["peak_flops_bf16"] * self.n_devices
        return self.model_flops / denom if denom else 0.0


# ------------------------------------------------------- model flops


def _layer_params(l: LayerSpec, d: int, paper_heads: int | None = None) -> tuple[int, int]:
    """(active_params, total_params) of one layer (channel+seq mixers)."""
    act = tot = 0
    if l.kind == "attn":
        a = l.attn
        if a.kind == "mla":
            p = d * (a.kv_lora_rank + a.qk_rope_dim)
            if a.q_lora_rank:
                p += d * a.q_lora_rank + a.q_lora_rank * a.n_heads * (
                    a.qk_nope_dim + a.qk_rope_dim
                )
            else:
                p += d * a.n_heads * (a.qk_nope_dim + a.qk_rope_dim)
            p += a.n_heads * a.qk_nope_dim * a.kv_lora_rank
            p += a.n_heads * a.kv_lora_rank * a.v_head_dim
            p += a.n_heads * a.v_head_dim * d
        else:
            h = paper_heads or a.n_heads
            p = d * h * a.head_dim * 2 + d * a.n_kv_heads * a.head_dim * 2
        act += p
        tot += p
        if l.cross_attn:
            ca = d * a.n_heads * a.head_dim * 2 + d * a.n_kv_heads * a.head_dim * 2
            act += ca
            tot += ca
    elif l.kind == "mamba":
        m = l.mamba
        di = m.expand * d
        dtr = m.dt_rank or -(-d // 16)
        p = d * 2 * di + m.d_conv * di + di * (dtr + 2 * m.d_state) + \
            dtr * di + di * d
        act += p
        tot += p
    elif l.kind == "mlstm":
        xc = l.xlstm
        di = int(xc.proj_factor * d)
        p = d * 2 * di + 3 * di * di + di * d
        act += p
        tot += p
    elif l.kind == "slstm":
        xc = l.xlstm
        dh = d // xc.n_heads
        p = d * 4 * d + xc.n_heads * dh * 4 * dh + d * d
        act += p
        tot += p
    if l.moe is not None:
        mo = l.moe
        routed_one = 3 * d * mo.d_ff_expert
        act += mo.top_k * routed_one
        tot += mo.n_experts * routed_one
        act += d * mo.n_experts  # router
        tot += d * mo.n_experts
        if mo.n_shared:
            sh = 3 * d * (mo.d_ff_shared or mo.d_ff_expert * mo.n_shared)
            act += sh
            tot += sh
    elif l.d_ff:
        n_mats = 3 if l.ffn_act == "swiglu" else 2
        act += n_mats * d * l.d_ff
        tot += n_mats * d * l.d_ff
    return act, tot


def active_params(cfg: ModelConfig, paper_heads: int | None = None) -> tuple[int, int]:
    """(active, total) parameter counts — analytic, from the config."""
    act = tot = 0
    for l in cfg.layer_iter():
        a, t = _layer_params(l, cfg.d_model, paper_heads)
        act += a
        tot += t
    if cfg.enc_dec:
        for _ in range(cfg.enc_repeat):
            for l in cfg.enc_blocks:
                a, t = _layer_params(l, cfg.d_model, paper_heads)
                act += a
                tot += t
    # unembedding projection participates in compute
    act += cfg.d_model * cfg.vocab_size
    tot += cfg.d_model * cfg.vocab_size
    if not cfg.tie_embeddings:
        tot += cfg.d_model * cfg.vocab_size  # input table (gather only)
    return act, tot


def model_flops(cfg: ModelConfig, kind: str, tokens: int,
                paper_heads: int | None = None) -> float:
    """MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens
    (prefill/decode forward). Attention score/value FLOPs are intentionally
    excluded (the brief's 6·N·D definition); the useful-flops ratio then
    also exposes quadratic-attention overhead at long context."""
    act, _ = active_params(cfg, paper_heads)
    mult = 6.0 if kind == "train" else 2.0
    return mult * act * tokens
