"""Freshness subsystem tests (DESIGN.md §11): mutable world schedule,
change feed, refresh-ahead, invalidation propagation, and the engine's
staleness accounting."""
import json

import numpy as np
import pytest

from repro.core.cache import make_cache
from repro.core.freshness import ChangeFeed, FreshnessConfig, FreshnessManager
from repro.core.judge import OracleJudge
from repro.data.world import MutableWorld, SemanticWorld
from repro.launch.serve import run_once
from repro.serving.clock import VirtualClock
from repro.serving.remote import RemoteDataService

MW = MutableWorld(n_intents=80, dim=32, churn_min_period=10.0,
                  churn_max_period=80.0, seed=3)


# ------------------------------------------------------------- world


def test_mutable_world_versions_monotone_and_deterministic():
    w2 = MutableWorld(n_intents=80, dim=32, churn_min_period=10.0,
                      churn_max_period=80.0, seed=3)
    for iid in range(0, 80, 7):
        prev = -1
        for t in np.linspace(0.0, 300.0, 40):
            v = MW.intent_version(iid, float(t))
            assert v >= prev
            assert v == w2.intent_version(iid, float(t))  # same seed
            prev = v


def test_mutable_world_answer_changes_exactly_at_updates():
    iid = next(i for i in range(80)
               if np.isfinite(MW._phase[i]) and MW._phase[i] < 100.0)
    q = MW.query(iid, 0)
    u1 = MW.next_update(iid, 0.0)
    eps = 1e-6
    assert MW.answer_at(q, u1 - eps) == f"answer-{iid}"
    assert MW.answer_at(q, u1 + eps) == f"answer-{iid}-v1"
    u2 = MW.next_update(iid, u1 + eps)
    assert u2 > u1
    assert MW.answer_at(q, u2 + eps) == f"answer-{iid}-v2"
    # fetch is the time-aware ground truth the origin serves
    assert MW.fetch(q, u1 + eps) == MW.answer_at(q, u1 + eps)


def test_mutable_world_staticity_drives_period_inversely():
    stats = np.array([it.staticity for it in MW.intents])
    per = MW._period
    finite = np.isfinite(per)
    lo = per[finite & (stats == stats[finite].min())]
    hi = per[finite & (stats == stats[finite].max())]
    assert lo.max() < hi.min()  # ephemeral classes update faster
    assert per[finite].min() >= 10.0 - 1e-9


def test_mutable_world_next_update_strictly_advances():
    """Regression: at an exact update instant the floor in
    intent_version could round short and freeze the change feed at a
    constant virtual time."""
    for iid in range(80):
        if not np.isfinite(MW._phase[iid]):
            continue
        t = 0.0
        for _ in range(50):
            nxt = MW.next_update(iid, t)
            assert nxt > t
            t = nxt


def test_static_world_freshness_surface_is_inert():
    w = SemanticWorld(n_intents=10, dim=16, seed=0)
    q = w.query(3, 0)
    assert w.version_at(q, 1e9) == 0
    assert w.next_update(3, 0.0) == float("inf")
    assert w.answer_at(q, 1e9) == w.answer(q)


def test_churn_frac_zero_is_static():
    w = MutableWorld(n_intents=40, dim=16, churn_min_period=5.0,
                     churn_frac=0.0, seed=1)
    for i in range(40):
        assert w.intent_version(i, 1e6) == 0
        assert w.next_update(i, 0.0) == float("inf")


# --------------------------------------------------------- change feed


def test_change_feed_notice_carries_wan_delay():
    clock = VirtualClock()
    feed = ChangeFeed(MW, clock)
    got = []
    feed.subscribe(lambda i, v, t: got.append((clock.now, i, v, t)), 0.5)
    iid = next(i for i in range(80)
               if np.isfinite(MW._phase[i]) and MW._phase[i] < 50.0)
    feed.watch(iid)
    feed.watch(iid)  # idempotent
    u1 = MW.next_update(iid, 0.0)
    while clock.pending and clock.now < u1 + 1.0:
        clock.step()
    assert got, "no notice delivered"
    t_recv, i, v, t_up = got[0]
    assert i == iid and v == 1
    assert t_up == pytest.approx(u1)
    assert t_recv == pytest.approx(u1 + 0.5)  # one-way WAN delay


def test_change_feed_ignores_static_intents():
    clock = VirtualClock()
    w = MutableWorld(n_intents=20, dim=16, churn_frac=0.0, seed=2)
    feed = ChangeFeed(w, clock)
    feed.subscribe(lambda *a: None, 0.1)
    for i in range(20):
        feed.watch(i)
    assert clock.pending == 0  # nothing scheduled, nothing leaks


# ------------------------------------------------- cache refresh APIs


def fresh_cache(world, **kw):
    judge = OracleJudge(world, accuracy=1.0, seed=1)
    return make_cache(capacity_bytes=50_000, dim=world.dim, judge=judge,
                      index_capacity=128, **kw)


def test_live_view_survives_in_place_refresh():
    """Rebind under churn: a refresh renews value/version/expiry IN the
    row, so SemanticElement views taken before the refresh (e.g. held by
    an in-flight judge micro-batch) stay valid and see the new value."""
    cache = fresh_cache(MW)
    q = MW.query(1, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100, version=0)
    view = cache.store[se.se_id]  # independent live view
    old_expiry = view.expires_at
    got = cache.refresh_entry(se.se_id, value="fresh-v3", version=3,
                              now=50.0)
    assert got is not None
    assert view.valid
    assert view.value == "fresh-v3"
    assert view.version == 3
    assert view.fetched_at == 50.0
    assert view.expires_at > old_expiry
    assert not view.revalidating
    # row/se_id/freq untouched: LCFU standing survives the refresh
    assert view.row == se.row and view.freq == se.freq


def test_revalidating_entry_is_not_servable():
    cache = fresh_cache(MW)
    q = MW.query(2, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100)
    q2 = MW.query(2, 1)
    assert cache.lookup(q2, MW.embed(q2), 1.0).hit
    se.revalidating = True
    res = cache.lookup(q2, MW.embed(q2), 2.0)
    assert not res.hit  # known-stale: miss now, correct answer later
    assert cache.peek_semantic(q2, MW.embed(q2), 2.0) is None
    cache.refresh_entry(se.se_id, value="v1", version=1, now=3.0)
    assert cache.lookup(q2, MW.embed(q2), 4.0).hit  # servable again


def test_rebind_skips_candidate_invalidated_mid_batch():
    """A stage-1 candidate dropped by a change-feed notice between
    stage 1 and judge completion must finalize as a miss, not serve a
    freed row."""
    cache = fresh_cache(MW)
    q = MW.query(4, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100)
    q2 = MW.query(4, 1)
    cands = cache.stage1(q2, MW.embed(q2), 1.0)
    assert cands and cands[0].se_id == se.se_id
    assert cache.invalidate_se(se.se_id, 1.5)
    scores = np.ones(len(cands), np.float32)
    res = cache.finalize(q2, cands, scores, 2.0)
    assert not res.hit
    assert cache.stats.invalidations == 1


def test_ses_for_intent_and_invalidate():
    cache = fresh_cache(MW)
    for i, para in ((7, 0), (7, 1), (9, 0)):
        q = MW.query(i, para)
        cache.insert(q, MW.embed(q), "v", now=0.0, cost=0.01, latency=0.3,
                     size=50, intent=i)
    ses = cache.ses_for_intent(7)
    assert [se.intent for se in ses] == [7, 7]
    for se in ses:
        assert cache.invalidate_se(se.se_id, 1.0)
    assert cache.ses_for_intent(7) == []
    assert len(cache.ses_for_intent(9)) == 1
    assert cache.stats.invalidations == 2
    assert not cache.invalidate_se(12345, 1.0)  # unknown id: no-op


# ------------------------------------------------- manager lifecycle


def build_manager(world, cfg=None, qpm=None):
    clock = VirtualClock()
    cache = fresh_cache(world)
    remote = RemoteDataService(qpm=qpm, seed=0)
    feed = ChangeFeed(world, clock)
    mgr = FreshnessManager(cache=cache, remote=remote, world=world,
                           clock=clock, cfg=cfg, feed=feed)
    return clock, cache, remote, feed, mgr


def test_refresh_ahead_renews_before_expiry():
    cfg = FreshnessConfig(refresh_margin=0.2, refresh_min_freq=1)
    clock, cache, remote, feed, mgr = build_manager(MW, cfg)
    q = MW.query(1, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100,
                      version=MW.version_at(q, 0.0))
    mgr.on_insert(se)
    # one validated hit since the fetch: the entry earns its renewal
    q2 = MW.query(1, 1)
    assert cache.lookup(q2, MW.embed(q2), 1.0).hit
    expiry0 = se.expires_at
    while clock.pending and clock.now < expiry0 + 1.0 and \
            mgr.stats.refreshes == 0:
        clock.step()
    assert mgr.stats.refreshes == 1
    assert se.valid  # never purged: renewed in place
    assert se.expires_at > expiry0
    assert se.version == MW.version_at(q, clock.now)
    assert mgr.stats.refresh_cost > 0.0


def test_refresh_chain_stops_when_hits_stop():
    """Regression: worthiness is hits SINCE THE LAST renewal, not
    lifetime freq — one early hit must not buy perpetual renewals."""
    cfg = FreshnessConfig(invalidation=False, refresh_margin=0.2,
                          refresh_min_freq=1)
    clock, cache, remote, feed, mgr = build_manager(MW, cfg)
    q = MW.query(1, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100)
    mgr.on_insert(se)
    q2 = MW.query(1, 1)
    assert cache.lookup(q2, MW.embed(q2), 1.0).hit   # earns renewal #1
    # no invalidation feed: the only events are the refresh timers —
    # renewal #1 fires, re-arms, then the cold tick declines and the
    # chain dies (the event heap drains instead of ticking forever)
    while clock.pending:
        clock.step()
    assert mgr.stats.refreshes == 1      # renewed once, then went cold
    assert se.valid
    assert se.expired(se.expires_at + 1e-6)  # left to age out normally


def test_cold_entries_expire_instead_of_refreshing():
    cfg = FreshnessConfig(refresh_margin=0.2, refresh_min_freq=5)
    clock, cache, remote, feed, mgr = build_manager(MW, cfg)
    q = MW.query(1, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100)
    mgr.on_insert(se)  # freq=1 < 5: not earning its keep
    expiry0 = se.expires_at
    while clock.pending and clock.now <= expiry0:
        clock.step()
    assert mgr.stats.refreshes == 0


def test_notice_drops_federated_copy_refreshes_own(monkeypatch):
    """Provenance rule: on a change notice the locally-fetched entry
    revalidates in place; the federated copy (se.origin set) drops —
    its source region is the one responsible for refreshing it."""
    cfg = FreshnessConfig(refresh_margin=0.1, refresh_min_freq=0,
                          feed_delay=0.05)
    clock, cache, remote, feed, mgr = build_manager(MW, cfg)
    iid = next(i for i in range(80)
               if np.isfinite(MW._phase[i]) and 5.0 < MW._phase[i] < 60.0)
    q_own = MW.query(iid, 0)
    q_copy = MW.query(iid, 1)
    own = cache.insert(q_own, MW.embed(q_own), MW.fetch(q_own, 0.0),
                       now=0.0, cost=0.01, latency=0.3, size=100,
                       intent=iid, version=0)
    copy = cache.insert(q_copy, MW.embed(q_copy), MW.fetch(q_copy, 0.0),
                        now=0.0, cost=0.001, latency=0.05, size=100,
                        intent=iid, version=0, origin=2)
    mgr.on_insert(own)
    mgr.on_insert(copy)
    own_id, copy_id = own.se_id, copy.se_id
    u1 = MW.next_update(iid, 0.0)
    while clock.pending and clock.now < u1 + 5.0:
        clock.step()
    assert mgr.stats.notices >= 1
    assert copy_id not in cache.store          # dropped (provenance)
    assert own_id in cache.store               # refreshed in place
    assert cache.store[own_id].version >= 1
    assert cache.stats.invalidations >= 1
    assert mgr.stats.refreshes >= 1


def test_feed_unwatches_intent_no_longer_cached():
    """Once every entry for an intent is gone, the feed stops firing
    for it (interest predicate) — feed work is bounded by live cached
    knowledge. The next admission re-watches."""
    cfg = FreshnessConfig(refresh_ahead=False, feed_delay=0.05)
    clock, cache, remote, feed, mgr = build_manager(MW, cfg)
    iid = next(i for i in range(80)
               if np.isfinite(MW._phase[i]) and MW._phase[i] < 50.0)
    q = MW.query(iid, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100, intent=iid)
    mgr.on_insert(se)
    assert iid in feed._watched
    # the first notice drops the (refresh_ahead=False) entry; the fire
    # after that sees no interest and lapses the watch
    period = float(MW._period[iid])
    u1 = MW.next_update(iid, 0.0)
    while clock.pending and clock.now < u1 + 2 * period + 1.0:
        clock.step()
    assert se.se_id not in cache.store
    assert iid not in feed._watched
    # re-admission re-arms the watch
    se2 = cache.insert(MW.query(iid, 1), MW.embed(MW.query(iid, 1)),
                       "v", now=clock.now, cost=0.01, latency=0.3,
                       size=100, intent=iid)
    mgr.on_insert(se2)
    assert iid in feed._watched


def test_promotion_rearms_refresh_timer():
    """An entry whose refresh timer died while it sat in the WARM tier
    gets a new one when it promotes back to HOT."""
    from repro.core.tiers import make_tiered_cache

    clock = VirtualClock()
    judge = OracleJudge(MW, accuracy=1.0, seed=1)
    cache = make_tiered_cache(hot_bytes=50_000, warm_bytes=50_000,
                              dim=MW.dim, judge=judge, index_capacity=128)
    remote = RemoteDataService(qpm=None, seed=0)
    mgr = FreshnessManager(
        cache=cache, remote=remote, world=MW, clock=clock,
        cfg=FreshnessConfig(invalidation=False, refresh_margin=0.2,
                            refresh_min_freq=0),
    )
    assert cache.on_promote is not None   # manager claimed the hook
    q = MW.query(1, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100, intent=1)
    se_id = se.se_id
    cache._evict_n(1, 0.5)                # demote: timer target leaves HOT
    assert se_id in cache.warm.soa.id2row
    q2 = MW.query(1, 1)
    res = cache.lookup(q2, MW.embed(q2), 1.0)   # warm hit -> promotion
    assert res.hit and se_id in cache.store
    # the promotion hook must have armed a timer that renews the entry
    while clock.pending and mgr.stats.refreshes == 0:
        clock.step()
    assert mgr.stats.refreshes >= 1
    assert se_id in cache.store


def test_refresh_skipped_under_rate_limit_pressure():
    cfg = FreshnessConfig(refresh_margin=0.2, refresh_min_freq=0,
                          refresh_min_headroom=2.0)  # impossible bar
    clock, cache, remote, feed, mgr = build_manager(MW, cfg, qpm=60.0)
    q = MW.query(1, 0)
    se = cache.insert(q, MW.embed(q), MW.fetch(q, 0.0), now=0.0,
                      cost=0.01, latency=0.3, size=100)
    mgr.on_insert(se)
    expiry0 = se.expires_at
    while clock.pending and clock.now <= expiry0:
        clock.step()
    assert mgr.stats.refreshes == 0
    assert mgr.stats.refresh_skipped >= 1


# -------------------------------------------------------- engine e2e


E2E = dict(workload="churn", mode="cortex", n_requests=160, n_intents=120,
           dim=32, concurrency=8, seed=11, churn_period=12.0,
           churn_max_period=96.0, max_ttl=60.0, qpm=None, judge_acc=1.0,
           prefetch=False)


def test_engine_stale_hits_zero_without_churn():
    s = run_once(**{**E2E, "churn_period": None, "churn_max_period": None})
    assert s["stale_hits"] == 0
    assert s["stale_age_hist"]["0-30"] == 0


def test_engine_invalidation_cuts_stale_hits():
    ttl_only = run_once(**E2E)
    inval = run_once(invalidation=True, refresh_ahead=True, **E2E)
    assert ttl_only["stale_hits"] > 0
    assert inval["stale_hit_rate"] < ttl_only["stale_hit_rate"]
    assert inval["info_accuracy"] > ttl_only["info_accuracy"]
    assert inval["refreshes"] > 0
    # the histogram is populated for the policy that serves stale
    assert sum(ttl_only["stale_age_hist"].values()) == ttl_only["stale_hits"]


def test_engine_same_seed_bit_identical_under_churn():
    a = run_once(invalidation=True, refresh_ahead=True, **E2E)
    b = run_once(invalidation=True, refresh_ahead=True, **E2E)
    assert json.dumps(a, sort_keys=True, default=float) == \
        json.dumps(b, sort_keys=True, default=float)


def test_federation_invalidation_propagates():
    """Multi-region: a shared mutable world + per-region change-feed
    subscriptions — federated copies drop on notice, staleness exposure
    stays bounded, and the run is deterministic."""
    from repro.data.workloads import region_workloads
    from repro.serving.federation import FederationRunner

    world = MutableWorld(n_intents=100, dim=32, churn_min_period=15.0,
                         churn_max_period=120.0, seed=5)
    streams = region_workloads(world, 40, 2, overlap=0.7, seed=6)

    def run():
        return FederationRunner(
            world=world, region_requests=streams, topology="peered",
            freshness=FreshnessConfig(refresh_min_freq=1), seed=7,
        ).run()["aggregate"]

    a = run()
    assert a["peer_transfers"] > 0
    assert a["invalidations"] + a["refreshes"] > 0
    b = run()
    assert json.dumps(a, sort_keys=True, default=float) == \
        json.dumps(b, sort_keys=True, default=float)


def test_federation_without_freshness_unchanged():
    """No freshness config => no feed, no manager, stale accounting all
    zeros (static world) — the pre-§11 federation behaviour."""
    from repro.data.workloads import region_workloads
    from repro.serving.federation import FederationRunner

    world = SemanticWorld(n_intents=80, dim=32, seed=5)
    streams = region_workloads(world, 25, 2, overlap=0.6, seed=6)
    r = FederationRunner(world=world, region_requests=streams,
                         topology="peered", seed=7)
    a = r.run()["aggregate"]
    assert a["stale_hits"] == 0
    assert a["refreshes"] == 0 and a["invalidations"] == 0


# ------------------------------------------------- exact-cache parity


def test_exact_cache_ttl_from_staticity():
    from repro.core.semantic_element import ttl_from_staticity
    from repro.serving.engine import ExactCache

    c = ExactCache(10_000, max_ttl=600.0, min_ttl=30.0)
    c.insert("ephemeral", "v", 100, now=0.0, staticity=1)
    c.insert("stable", "v", 100, now=0.0, staticity=10)
    c.insert("legacy", "v", 100, now=0.0)  # no staticity: full max_ttl
    assert c.d["ephemeral"][1] == pytest.approx(30.0)
    assert c.d["stable"][1] == pytest.approx(600.0)
    assert c.d["legacy"][1] == pytest.approx(600.0)
    mid = c.d["ephemeral"][1]
    assert mid == pytest.approx(
        ttl_from_staticity(1, c.max_ttl, c.min_ttl)
    )
    assert c.lookup("ephemeral", now=31.0) is None   # aged out
    assert c.lookup("stable", now=31.0) == "v"
