"""Shared helpers for the per-figure/table benchmarks."""
from __future__ import annotations

import os
import time

from repro.launch.serve import run_once

# The paper's four search benchmarks, as synthetic-world profiles: the
# skew/locality and the no-cache EM baseline differ per dataset (Fig 7/13;
# EM baselines follow published Search-R1-7B numbers).
DATASETS = {
    "zilliz": dict(zipf_s=1.10, em_p_base=0.80, seed=11),
    "hotpotqa": dict(zipf_s=0.99, em_p_base=0.62, seed=12),
    "musique": dict(zipf_s=0.99, em_p_base=0.35, seed=13),
    "2wiki": dict(zipf_s=0.99, em_p_base=0.52, seed=14),
    "strategyqa": dict(zipf_s=0.99, em_p_base=0.79, seed=15),
}


# rows emitted by the current benchmark, captured for --json output
# (benchmarks/run.py clears this before each benchmark and snapshots it
# after, so regression gates that SystemExit still leave their rows)
ROWS: list[dict] = []

# When set (benchmarks/run.py --trace DIR), engine runs driven through
# run_ds() are traced: §15 span JSONL + Chrome-trace artifacts land in
# this directory as TRACE_<dataset>_<mode>_<k>.* files, next to the
# BENCH_*.json the runner writes. None (the default) keeps every
# benchmark untraced — and because tracing is event-neutral in virtual
# time, the measured numbers are identical either way.
TRACE_DIR: str | None = None
_TRACE_SEQ = 0  # disambiguates repeated (dataset, mode) runs

# wall clock at the last reset_rows() — emit() stamps each row with the
# seconds elapsed since, so BENCH_*.json rows record how much real time
# the benchmark spent producing them (virtual-time metrics can't).
_T0 = time.time()


def reset_rows() -> None:
    """Clear ROWS and restart the per-benchmark ``wall_s`` clock.
    benchmarks/run.py calls this before each benchmark function."""
    global _T0
    ROWS.clear()
    _T0 = time.time()


def emit(name: str, us_per_call: float, *, seed=None, shards=None,
         nprobe=None, judge_model=None, band=None, wall_s=None,
         trace_path=None, **derived):
    """One benchmark row. ``seed`` lands as a first-class field in the
    --json BENCH_*.json rows (alongside the git_sha and device count
    benchmarks/run.py stamps at write time) so cross-PR trajectory
    diffs can tell a code change from a seed change; None = not
    seed-parameterized. ``shards``/``nprobe`` are likewise first-class
    (None = not shard/probe-parameterized): the mesh-sharded stage-1
    rows (DESIGN.md §13) must be groupable by shard/mesh config without
    parsing the free-form derived dict. ``judge_model``/``band`` do the
    same for the judge-colocation frontier rows (§14): the throughput-
    vs-judge-accuracy frontier must be reconstructable from the
    artifacts alone — judge_model names the stage-2 cost/compute config
    (e.g. "oracle+flops:d128"), band is the admission-band width.

    Every row is additionally stamped with ``wall_s`` (real seconds
    since this benchmark started — auto-measured from the last
    ``reset_rows()`` unless the caller passes an explicit value) and
    ``trace_path`` (the §15 span-JSONL artifact behind this row, when
    the run was traced; None otherwise). Both land only in the
    BENCH_*.json rows, not the printed CSV, so stdout stays
    deterministic across machines."""
    first = {k: v for k, v in (("shards", shards), ("nprobe", nprobe),
                               ("judge_model", judge_model),
                               ("band", band))
             if v is not None}
    kv = " ".join(f"{k}={v}" for k, v in {**first, **derived}.items())
    print(f"{name},{us_per_call:.1f},{kv}")
    if wall_s is None:
        wall_s = time.time() - _T0
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "seed": seed, "shards": shards, "nprobe": nprobe,
                 "judge_model": judge_model, "band": band,
                 "wall_s": round(float(wall_s), 3),
                 "trace_path": trace_path, "derived": derived})


def run_ds(dataset: str, mode: str, **kw):
    global _TRACE_SEQ
    prof = DATASETS[dataset]
    import repro.serving.engine as eng_mod

    base = dict(
        workload="zipf", mode=mode, n_requests=500, n_intents=800,
        concurrency=8, seed=prof["seed"],
    )
    base.update(kw)
    if TRACE_DIR is not None and base.get("trace") is None:
        base["trace"] = os.path.join(
            TRACE_DIR, f"TRACE_{dataset}_{mode}_{_TRACE_SEQ}")
        _TRACE_SEQ += 1
    s = run_once(**base)
    return s


def fmt(s: dict) -> dict:
    return dict(
        thpt=round(s["throughput_rps"], 3),
        hit=round(s.get("hit_rate", 0.0), 3),
        lat_ms=round(s["latency_mean"] * 1e3, 1),
        p99_ms=round(s["latency_p99"] * 1e3, 1),
        api=s["api_calls"],
        em=round(s["em"], 3),
    )
