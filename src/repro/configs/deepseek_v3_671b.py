"""deepseek-v3-671b [moe] — arXiv:2412.19437.

61L d_model=7168 128H, MLA (kv_lora=512, q_lora=1536, qk_nope=128,
qk_rope=64, v_head=128), d_ff_expert=2048, MoE 256 routed top-8 (sigmoid
router, aux-loss-free bias balancing) + 1 shared expert, first 3 layers
dense (d_ff=18432), vocab=129280, MTP (multi-token prediction) head.
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig, MoEConfig

NAME = "deepseek-v3-671b"


def _mla() -> AttnConfig:
    return AttnConfig(
        n_heads=128, n_kv_heads=128, head_dim=128, kind="mla",
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    )


@register(NAME)
def config() -> ModelConfig:
    moe = MoEConfig(
        n_experts=256, top_k=8, d_ff_expert=2048,
        n_shared=1, d_ff_shared=2048, router_fn="sigmoid",
    )
    dense = LayerSpec(kind="attn", attn=_mla(), d_ff=18432)
    moel = LayerSpec(kind="attn", attn=_mla(), moe=moe)
    return ModelConfig(
        name=NAME,
        family="moe",
        d_model=7168,
        vocab_size=129280,
        prefix=(dense,) * 3,
        blocks=(moel,),
        n_repeat=58,  # 3 dense + 58 MoE = 61 layers
        tie_embeddings=False,
        mtp=True,
    )
