"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly sequential scan) — arXiv:2405.04517.

mLSTM keeps a matrix memory C (B,H,dk,dv) with exponential input/forget
gates and a normaliser state; training uses a chunkwise formulation
(intra-chunk attention-like term + inter-chunk recurrent carry), which is
the TPU-friendly re-expression of the paper's parallel form. sLSTM is a
sequential ``lax.scan`` — the paper itself notes it is not parallelisable;
its state is O(d) so the scan body is tiny.

Decode: O(1) recurrent updates for both (the long_500k story for xlstm).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.config import XLSTMConfig
from repro.nn.param import ParamSpec
from repro.nn.sharding import ShardCtx


# ================================================================= mLSTM


def mlstm_specs(cfg: XLSTMConfig, d_model: int, dtype) -> dict:
    h = cfg.n_heads
    d_in = int(cfg.proj_factor * d_model)
    dh = d_in // h
    return {
        "w_up": ParamSpec((d_model, 2 * d_in), dtype, ("fsdp", "model")),
        "w_q": ParamSpec((d_in, d_in), dtype, ("fsdp", "model")),
        "w_k": ParamSpec((d_in, d_in), dtype, ("fsdp", "model")),
        "w_v": ParamSpec((d_in, d_in), dtype, ("fsdp", "model")),
        "w_if": ParamSpec((d_in, 2 * h), jnp.float32, (None, None), scale=0.02),
        "b_if": ParamSpec((2 * h,), jnp.float32, (None,), init="zeros"),
        "gn_scale": ParamSpec((d_in,), jnp.float32, ("model",), init="ones"),
        "w_down": ParamSpec((d_in, d_model), dtype, ("model", "fsdp")),
    }


def _headwise_norm(x, scale, eps=1e-6):
    # x: (B, S, H, Dh) — GroupNorm per head as in the paper
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    b, s, h, dh = x.shape
    return (out.reshape(b, s, h * dh) * scale).reshape(b, s, h, dh)


def mlstm_apply(
    ctx: ShardCtx,
    p,
    cfg: XLSTMConfig,
    x,
    cache: Optional[dict] = None,
):
    """x: (B,S,D) -> (y, cache). cache = {c (B,H,dk,dv), n (B,H,dk), m (B,H)}."""
    b, s, d_model = x.shape
    h = cfg.n_heads
    d_in = int(cfg.proj_factor * d_model)
    dh = d_in // h

    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    up = ctx.constrain(up, "dp", None, "model")
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", xi, p["w_q"]).reshape(b, s, h, dh)
    k = jnp.einsum("bse,ef->bsf", xi, p["w_k"]).reshape(b, s, h, dh)
    v = jnp.einsum("bse,ef->bsf", xi, p["w_v"]).reshape(b, s, h, dh)
    k = k / jnp.sqrt(jnp.float32(dh)).astype(k.dtype)
    gates = (
        jnp.einsum("bse,ef->bsf", xi.astype(jnp.float32), p["w_if"]) + p["b_if"]
    )  # (B,S,2H)
    i_pre, f_pre = gates[..., :h], gates[..., h:]  # log-space gates
    logf = jax.nn.log_sigmoid(f_pre)

    if cache is None and s > 1:
        y = _mlstm_chunked(cfg, q, k, v, i_pre, logf)
        new_cache = _mlstm_final_state(cfg, k, v, i_pre, logf)
    else:
        c_prev = (
            cache["c"] if cache is not None
            else jnp.zeros((b, h, dh, dh), jnp.float32)
        )
        n_prev = (
            cache["n"] if cache is not None else jnp.zeros((b, h, dh), jnp.float32)
        )
        m_prev = (
            cache["m"] if cache is not None
            else jnp.full((b, h), -1e30, jnp.float32)
        )
        i1, f1 = i_pre[:, 0], logf[:, 0]  # (B,H)
        m = jnp.maximum(f1 + m_prev, i1)
        fi = jnp.exp(f1 + m_prev - m)
        ii = jnp.exp(i1 - m)
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        c = fi[..., None, None] * c_prev + ii[..., None, None] * (
            kf[..., :, None] * vf[..., None, :]
        )
        n = fi[..., None] * n_prev + ii[..., None] * kf
        qf = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhd,bhdv->bhv", qf, c)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
        yt = num / jnp.maximum(den, jnp.exp(-m))[..., None]
        y = yt[:, None].astype(x.dtype).reshape(b, 1, h, dh)
        new_cache = {"c": c, "n": n, "m": m}

    y = _headwise_norm(y, p["gn_scale"]).astype(x.dtype)
    y = y.reshape(b, s, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_down"])
    return ctx.constrain(out, "dp", None, None), new_cache


def _mlstm_chunked(cfg, q, k, v, i_pre, logf):
    """Chunkwise-parallel mLSTM (stabilised linear-attention form)."""
    b, s, h, dh = q.shape
    cs = min(cfg.chunk, s)
    assert s % cs == 0, f"seq {s} must divide chunk {cs}"
    nc = s // cs

    def reshape_c(t):
        return t.reshape(b, nc, cs, *t.shape[2:])

    qc, kc, vc = map(reshape_c, (q, k, v))
    ic = i_pre.reshape(b, nc, cs, h)
    fc = logf.reshape(b, nc, cs, h)

    def chunk(carry, xs):
        c_prev, n_prev, m_prev = carry  # (B,H,dk,dv), (B,H,dk), (B,H)
        qb, kb, vb, ib, fb = xs  # (B,cs,...)
        fcum = jnp.cumsum(fb, axis=1)  # (B,cs,H) inclusive log-forget
        ftot = fcum[:, -1]  # (B,H)
        # log weight of state contributions at each t
        lam = fcum + m_prev[:, None, :]  # contribution of carry at step t
        # intra-chunk pairwise: D[t,t'] = sum_{j>t'} f_j + i_{t'} for t'<=t
        dmat = (
            fcum[:, :, None, :] - fcum[:, None, :, :] + ib[:, None, :, :]
        )  # (B,t,t',H)
        tri = jnp.tril(jnp.ones((cs, cs), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)  # (B,t,H)
        m_t = jnp.maximum(lam, m_intra)  # running stabiliser per step
        # carry term
        qf = qb.astype(jnp.float32)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        w_carry = jnp.exp(lam - m_t)  # (B,t,H)
        num_carry = jnp.einsum("bthd,bhdv->bthv", qf, c_prev) * w_carry[..., None]
        den_carry = jnp.einsum("bthd,bhd->bth", qf, n_prev) * w_carry
        # intra term
        wmat = jnp.exp(dmat - m_t[:, :, None, :])  # (B,t,t',H)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * wmat
        num_intra = jnp.einsum("btsh,bshv->bthv", scores, vf)
        den_intra = jnp.sum(scores, axis=2)
        num = num_carry + num_intra
        den = den_carry + den_intra
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- update carry to end of chunk
        m_new = jnp.maximum(ftot + m_prev, jnp.max(ib + (ftot[:, None] - fcum), axis=1))
        wi = jnp.exp(ib + (ftot[:, None] - fcum) - m_new[:, None])  # (B,t,H)
        c_new = jnp.exp(ftot + m_prev - m_new)[:, :, None, None] * c_prev + \
            jnp.einsum("bthd,bth,bthv->bhdv", kf, wi, vf)
        n_new = jnp.exp(ftot + m_prev - m_new)[..., None] * n_prev + \
            jnp.einsum("bthd,bth->bhd", kf, wi)
        return (c_new, n_new, m_new), y

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), 0.0, jnp.float32)
    xs = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(ic, 1, 0), jnp.moveaxis(fc, 1, 0),
    )
    _, ys = jax.lax.scan(chunk, (c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dh)
    return y


def _mlstm_final_state(cfg, k, v, i_pre, logf):
    """Final (c, n, m) after a full prefill — for decode continuation."""
    b, s, h, dh = k.shape
    fcum = jnp.cumsum(logf, axis=1)
    ftot = fcum[:, -1]  # (B,H)
    w_log = i_pre + (ftot[:, None] - fcum)  # (B,S,H)
    m = jnp.max(w_log, axis=1)  # (B,H)
    wi = jnp.exp(w_log - m[:, None])
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c = jnp.einsum("bshd,bsh,bshv->bhdv", kf, wi, vf)
    n = jnp.einsum("bshd,bsh->bhd", kf, wi)
    return {"c": c, "n": n, "m": m}


def mlstm_cache_specs(cfg: XLSTMConfig, d_model: int, batch: int) -> dict:
    h = cfg.n_heads
    d_in = int(cfg.proj_factor * d_model)
    dh = d_in // h
    return {
        "c": ParamSpec((batch, h, dh, dh), jnp.float32, ("dp", None, None, None), init="zeros"),
        "n": ParamSpec((batch, h, dh), jnp.float32, ("dp", None, None), init="zeros"),
        "m": ParamSpec((batch, h), jnp.float32, ("dp", None), init="zeros"),
    }


# ================================================================= sLSTM


def slstm_specs(cfg: XLSTMConfig, d_model: int, dtype) -> dict:
    h = cfg.n_heads
    dh = d_model // h
    # 4 gates (i, f, z, o), input + recurrent weights (block-diag per head)
    return {
        "w_gates": ParamSpec((d_model, 4 * d_model), dtype, ("fsdp", "model")),
        "r_gates": ParamSpec((h, dh, 4 * dh), jnp.float32, (None, None, None)),
        "b_gates": ParamSpec((4 * d_model,), jnp.float32, ("model",), init="zeros"),
        "gn_scale": ParamSpec((d_model,), jnp.float32, ("model",), init="ones"),
        "w_down": ParamSpec((d_model, d_model), dtype, ("model", "fsdp")),
    }


def slstm_apply(
    ctx: ShardCtx,
    p,
    cfg: XLSTMConfig,
    x,
    cache: Optional[dict] = None,
):
    """x: (B,S,D). cache = {h, c, n, m} each (B,H,Dh). Sequential scan."""
    b, s, d_model = x.shape
    nh = cfg.n_heads
    dh = d_model // nh

    wx = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p["w_gates"].astype(jnp.float32))
    wx = wx + p["b_gates"]
    wx = wx.reshape(b, s, nh, 4 * dh)

    h0 = cache["h"] if cache is not None else jnp.zeros((b, nh, dh), jnp.float32)
    c0 = cache["c"] if cache is not None else jnp.zeros((b, nh, dh), jnp.float32)
    n0 = cache["n"] if cache is not None else jnp.ones((b, nh, dh), jnp.float32)
    m0 = cache["m"] if cache is not None else jnp.zeros((b, nh, dh), jnp.float32)

    r = p["r_gates"]  # (H, Dh, 4Dh)

    def step(carry, wx_t):
        h_prev, c_prev, n_prev, m_prev = carry
        g = wx_t + jnp.einsum("bhd,hdg->bhg", h_prev, r)  # (B,H,4Dh)
        i_pre, f_pre, z_pre, o_pre = jnp.split(g, 4, axis=-1)
        m_t = jnp.maximum(f_pre + m_prev, i_pre)
        i_g = jnp.exp(i_pre - m_t)
        f_g = jnp.exp(f_pre + m_prev - m_t)
        z_g = jnp.tanh(z_pre)
        o_g = jax.nn.sigmoid(o_pre)
        c_t = f_g * c_prev + i_g * z_g
        n_t = f_g * n_prev + i_g
        h_t = o_g * c_t / jnp.maximum(n_t, 1e-6)
        return (h_t, c_t, n_t, m_t), h_t

    (hf, cf, nf, mf), ys = jax.lax.scan(
        step, (h0, c0, n0, m0), jnp.moveaxis(wx, 1, 0)
    )
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,Dh)
    y = _headwise_norm(y, p["gn_scale"]).astype(x.dtype).reshape(b, s, d_model)
    out = jnp.einsum("bsd,de->bse", y, p["w_down"])
    new_cache = {"h": hf, "c": cf, "n": nf, "m": mf}
    return ctx.constrain(out, "dp", None, None), new_cache


def slstm_cache_specs(cfg: XLSTMConfig, d_model: int, batch: int) -> dict:
    nh = cfg.n_heads
    dh = d_model // nh
    mk = lambda init: ParamSpec(
        (batch, nh, dh), jnp.float32, ("dp", None, None), init=init
    )
    return {"h": mk("zeros"), "c": mk("zeros"), "n": mk("ones"), "m": mk("zeros")}
