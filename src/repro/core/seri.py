"""Seri — the Semantic Retrieval Index (paper §4.2).

Stage 1 (coarse): exact cosine top-k over the SE embedding matrix with the
τ_sim gate. On TPU this runs as the Pallas ``ann_topk`` kernel (brute-force
MXU matmul — the TPU-idiomatic replacement for Faiss graph traversal, see
DESIGN.md §3); on CPU the numpy path is bit-identical.

Stage 2 (fine): the semantic judge validates each candidate's *result*
against the new query; the first candidate with S_lsm ≥ τ_lsm is a
semantic-aware cache hit.

Both stages are batched (DESIGN.md §8): ``search_batch`` pushes a whole
(B, D) query block through one masked matmul (or one ``ann_topk`` launch,
which always had the B dimension), and ``CortexCache._judge_blocks``
scores the candidates of *all* queries in a single ``judge.score_pairs``
call. The scalar entry points are one-query wrappers over the batched
path, so scalar and batched execution are the same code and produce
identical results.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.semantic_element import SemanticElement


class RowIndex:
    """Fixed-capacity free-list row allocator — the management half
    shared by the fp32 hot index below and the int8 warm index
    (``core/tiers.py::QuantIndex``): active mask, row→se_id mapping, row
    alloc/free. Subclasses own the storage arrays and zero them in
    ``_clear_rows``, so the two tiers' row lifecycles cannot drift."""

    def __init__(self, capacity: int, dim: int):
        self.capacity = capacity
        self.dim = dim
        self.active = np.zeros(capacity, bool)
        self.row_se: list[Optional[int]] = [None] * capacity
        self._free = list(range(capacity - 1, -1, -1))

    def __len__(self) -> int:
        return int(self.active.sum())

    @property
    def full(self) -> bool:
        return not self._free

    def _alloc(self, se_id: int) -> int:
        if not self._free:
            raise RuntimeError("index full — evict first")
        row = self._free.pop()
        self.active[row] = True
        self.row_se[row] = se_id
        return row

    def _clear_rows(self, ra: np.ndarray) -> None:
        raise NotImplementedError

    def remove_rows(self, rows) -> None:
        """Batched removal: one fancy-indexed store per field."""
        rows = [r for r in rows if self.active[r]]
        if not rows:
            return
        ra = np.asarray(rows)
        self.active[ra] = False
        self._clear_rows(ra)
        for r in rows:
            self.row_se[r] = None
            self._free.append(r)


def topk_desc(s: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-row top-k, similarity-descending, over a (B, N) score matrix
    (mutates ``s``): negate in place, ``argpartition``, stable argsort —
    the one selection idiom both the fp32 and int8 (core/tiers.py)
    indexes use, so their tie-break semantics cannot drift. Returns
    (rows (B, k), vals (B, k))."""
    np.negative(s, out=s)                             # sort ascending
    k_eff = min(k, s.shape[1])
    part = np.argpartition(s, k_eff - 1, axis=1)[:, :k_eff]
    psc = np.take_along_axis(s, part, axis=1)
    order = np.argsort(psc, axis=1, kind="stable")
    rows = np.take_along_axis(part, order, axis=1)
    vals = -np.take_along_axis(psc, order, axis=1)
    return rows, vals


class VectorIndex(RowIndex):
    """Fixed-capacity embedding store with free-list row management."""

    def __init__(self, capacity: int, dim: int, backend: str = "numpy"):
        super().__init__(capacity, dim)
        self.backend = backend
        self.emb = np.zeros((capacity, dim), np.float32)
        self._kernel_fn = None
        if backend == "kernel":
            from repro.kernels.ops import ann_topk_jit

            self._kernel_fn = ann_topk_jit

    def add(self, se_id: int, embedding: np.ndarray) -> int:
        row = self._alloc(se_id)
        self.emb[row] = embedding
        return row

    def _clear_rows(self, ra: np.ndarray) -> None:
        self.emb[ra] = 0.0

    # ----------------------------------------------------------- search

    def search(self, q: np.ndarray, k: int, tau_sim: float):
        """Top-k rows with cosine ≥ tau_sim. q: (dim,) unit-norm.
        Returns (se_ids, sims) sorted by similarity desc."""
        return self.search_batch(q[None], k, tau_sim)[0]

    def search_batch(self, q: np.ndarray, k: int, tau_sim: float):
        """Batched stage-1: q (B, dim) -> list of B (se_ids, sims) pairs.

        One masked matmul over the whole query block; per-column top-k via
        ``argpartition`` along axis 0. Each column's result is identical to
        the single-query path (numpy partitions/sorts each 1-D lane
        independently), so batching never changes retrieval semantics.
        """
        b = q.shape[0]
        if len(self) == 0:
            empty = ([], np.zeros(0, np.float32))
            return [empty] * b
        if self._kernel_fn is not None:
            sims, rows = self._kernel_fn(self.emb, self.active, q, k)
            sims = np.asarray(sims)
            rows = np.asarray(rows)
        else:
            # (B, N) row-major so the per-query partition/sort below runs
            # over contiguous lanes (axis=0 on (N, B) is strided and ~3×
            # slower at large N·B)
            s = np.where(self.active[None, :], q @ self.emb.T, -1.0)
            rows, sims = topk_desc(s, k)                       # (B, k)
        out = []
        for i in range(b):
            keep = sims[i] >= tau_sim
            r = rows[i][keep]
            out.append(([self.row_se[j] for j in r],
                        sims[i][keep].astype(np.float32)))
        return out


@dataclasses.dataclass
class SeriResult:
    hit: bool
    se: Optional[SemanticElement]
    n_candidates: int
    judge_calls: int
    best_score: float
    # stage-1 similarities ALIGNED with the surviving candidate list:
    # sims[j] is the cosine of the j-th candidate the judge scored
    # (expired stage-1 matches are dropped from both)
    sims: np.ndarray


class Seri:
    """Two-stage retrieval configuration over a SE store.

    Holds the stage-1 index, the judge, and the thresholds. The
    retrieval pipeline itself lives in ``CortexCache._stage1_blocks`` /
    ``_judge_blocks`` (one implementation for the scalar, batched, and
    engine-staged paths — and the seam the tiered cache overrides);
    keeping a second copy here is how sims/candidate misalignment bugs
    happen twice."""

    def __init__(self, index: VectorIndex, judge, *, tau_sim: float = 0.9,
                 tau_lsm: float = 0.9, top_k: int = 4):
        self.index = index
        self.judge = judge
        self.tau_sim = tau_sim
        self.tau_lsm = tau_lsm
        self.top_k = top_k
