"""Training driver: any registered arch (reduced or full), synthetic
bigram data, AdamW, remat, microbatching, checkpoint/restart via the
Supervisor, optional fault injection and gradient compression.

CPU example (a few minutes):
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --smoke \
      --steps 60 --batch 8 --seq 128 --ckpt-dir /tmp/ck

On a real cluster the same driver runs the full config on the production
mesh (--mesh single|multi).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, shrink
from repro.launch.steps import make_train_step
from repro.models.lm import LM
from repro.nn.param import init_tree, struct_tree
from repro.nn.sharding import ShardCtx, ShardingConfig, param_pspec
from repro.train import checkpoint as ckpt_mod
from repro.train.data import BigramStream
from repro.train.optim import AdamWConfig, init_state
from repro.train.supervisor import FaultInjector, Supervisor


def build(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = shrink(cfg, d_model=args.d_model, vocab=args.vocab,
                     n_repeat=args.n_repeat)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    lm = LM(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    step = make_train_step(
        cfg, mesh, opt_cfg, remat=args.remat, microbatches=args.microbatches
    )
    return cfg, lm, opt_cfg, jax.jit(step, donate_argnums=(0, 1))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink to a CPU-feasible same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--n-repeat", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject node failures at these steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, lm, opt_cfg, jstep = build(args)
    stream = BigramStream(cfg.vocab_size, seed=args.seed)
    print(f"arch={cfg.name} layers={cfg.n_layers} vocab={cfg.vocab_size}")

    def init_state_fn():
        params = init_tree(jax.random.PRNGKey(args.seed), lm.param_specs())
        opt = init_state(opt_cfg, params)
        return {"params": params, "opt": opt}

    t_step = [time.monotonic()]

    def step_fn(state, step):
        batch = stream.batch(step, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = jstep(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        dt = time.monotonic() - t_step[0]
        t_step[0] = time.monotonic()
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} {dt:.2f}s")
        return {"params": params, "opt": opt}, {"loss": loss}

    sup = Supervisor(
        args.ckpt_dir, save_every=args.save_every,
        injector=FaultInjector(set(args.fail_at)),
    )
    res = sup.run(
        init_state=init_state_fn, step_fn=step_fn, n_steps=args.steps,
    )
    print(
        f"done: {res.steps_done} steps, {res.restarts} restarts, "
        f"{res.stragglers} stragglers, final loss {res.losses[-1]:.4f} "
        f"(unigram entropy {stream.unigram_entropy:.2f}, "
        f"bigram entropy {stream.bigram_entropy:.2f})"
    )
    return res


if __name__ == "__main__":
    main()
