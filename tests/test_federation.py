"""Cross-region federation tests (DESIGN.md §9): the router's
local-hit / peer-hit / origin-fetch decision tree, transfer admission
(provenance + adjusted TTL), shared-clock determinism, and the
region-skewed workload generator."""
import dataclasses

import numpy as np
import pytest

from repro.core.cache import make_cache
from repro.core.judge import OracleJudge
from repro.data.workloads import region_workloads
from repro.data.world import SemanticWorld
from repro.obs.trace import NULL_TRACER
from repro.serving.clock import VirtualClock
from repro.serving.engine import EngineConfig
from repro.serving.federation import (
    Federation, FederationRunner, Region, RegionConfig,
)
from repro.serving.remote import RemoteDataService

WORLD = SemanticWorld(n_intents=60, dim=32, seed=3)


# --------------------------------------------------------------- harness


class _StubEngine:
    """Minimal engine surface the router touches: lets the decision-tree
    tests drive Federation.route with exact, hand-chosen timing."""

    def __init__(self, world, remote, region_id):
        self.world = world
        self.remote = remote
        self.region_id = region_id
        self.results = []
        self.trace = NULL_TRACER  # router emits §15 spans when armed

    def remote_done(self, st, q, t0, now, **kw):
        self.results.append(dict(q=q, t0=t0, now=now, **kw))


def _mk_region(rid, seed=0):
    judge = OracleJudge(WORLD, accuracy=1.0, seed=seed + rid)
    cache = make_cache(capacity_bytes=500_000, dim=WORLD.dim, judge=judge,
                       index_capacity=128)
    remote = RemoteDataService(qpm=None, seed=seed + 50 + rid)
    return Region(rid, RegionConfig(name=f"r{rid}"), cache, remote, gpu=None)


def _mk_federation(n_regions=2, rtt=0.08, bandwidth=1e9, **kw):
    clock = VirtualClock()
    regions = [_mk_region(i) for i in range(n_regions)]
    fed = Federation(regions, clock, rtt=rtt, bandwidth=bandwidth, **kw)
    engines = [
        _StubEngine(WORLD, regions[i].remote, i) for i in range(n_regions)
    ]
    return fed, clock, regions, engines


def _drain(clock):
    guard = 0
    while clock.pending:
        clock.step()
        guard += 1
        assert guard < 10_000


def _seed_peer(region, q, *, now=0.0, ttl=1000.0, staticity=7):
    return region.cache.insert(
        q, WORLD.embed(q), WORLD.fetch(q), now=now, cost=0.005,
        latency=0.4, size=WORLD.value_size(q), staticity=staticity, ttl=ttl,
    )


# ---------------------------------------------------------- decision tree


def test_peer_hit_transfers_value_with_provenance_and_ttl():
    fed, clock, regions, engines = _mk_federation(rtt=0.08)
    q = WORLD.query(5, 0)
    src = _seed_peer(regions[1], q, ttl=500.0)
    fed.route(engines[0], st=None, q=q, t0=0.0)
    _drain(clock)

    assert fed.stats.peeks == 1
    assert fed.stats.peer_hits == 1
    assert fed.stats.transfers == 1
    assert fed.stats.origin_fetches == 0
    [res] = engines[0].results
    assert res["value"] == WORLD.fetch(q)
    assert res["origin"] == 1                      # provenance
    assert res["staticity"] == 7                   # carried on the lease
    assert res["size"] == src.size                 # bytes actually moved
    assert res["cost"] == pytest.approx(fed.transfer_cost)
    # response at rtt, data lands one half-RTT + serialization later
    t_arrive = 0.08 + 0.04 + WORLD.value_size(q) / fed.bandwidth
    assert res["now"] == pytest.approx(t_arrive)
    # adjusted TTL: the copy must expire exactly when the source does
    assert res["ttl"] == pytest.approx(float(src.expires_at) - t_arrive)
    assert res["ttl"] < 500.0


def test_all_peers_nak_falls_back_to_origin():
    fed, clock, regions, engines = _mk_federation(rtt=0.08)
    q = WORLD.query(5, 0)
    fed.route(engines[0], st=None, q=q, t0=0.0)
    _drain(clock)

    assert fed.stats.peer_misses == 1
    assert fed.stats.origin_fetches == 1
    assert fed.stats.transfers == 0
    [res] = engines[0].results
    assert res["value"] is None                   # engine fetches world
    assert res["cost"] > fed.transfer_cost        # origin call price
    # origin fetch starts only after the last NAK (one full RTT)
    assert res["now"] >= 0.08 + regions[0].remote.lat_lo


def test_lease_expiring_in_flight_is_a_miss():
    fed, clock, regions, engines = _mk_federation(rtt=0.08)
    q = WORLD.query(5, 0)
    # live at the probe instant (rtt/2 = 0.04) but dead before the data
    # could arrive (rtt * 1.5 = 0.12)
    _seed_peer(regions[1], q, ttl=0.10)
    fed.route(engines[0], st=None, q=q, t0=0.0)
    _drain(clock)

    assert fed.stats.expired_leases == 1
    assert fed.stats.transfers == 0
    assert fed.stats.origin_fetches == 1


def test_nearest_holder_wins():
    fed, clock, regions, engines = _mk_federation(
        n_regions=3,
        rtt=np.array([[0.0, 0.2, 0.05],
                      [0.2, 0.0, 0.22],
                      [0.05, 0.22, 0.0]]),
    )
    q = WORLD.query(5, 0)
    _seed_peer(regions[1], q)
    _seed_peer(regions[2], q)
    fed.route(engines[0], st=None, q=q, t0=0.0)
    _drain(clock)

    assert fed.stats.transfers == 1              # only one transfer
    [res] = engines[0].results
    assert res["origin"] == 2                    # the 0.05s peer, not 0.2s


def test_peer_leases_warm_tier_entry():
    """peek_semantic consults BOTH tiers (DESIGN.md §10): a sibling's
    warm entry is leasable — the lease carries the decompressed value
    and ORIGINAL size, and the source copy stays warm (peer peeks never
    promote)."""
    from repro.core.tiers import make_tiered_cache

    fed, clock, regions, engines = _mk_federation(rtt=0.08)
    judge = OracleJudge(WORLD, accuracy=1.0, seed=1)
    tiered = make_tiered_cache(hot_bytes=500, warm_bytes=50_000,
                               dim=WORLD.dim, judge=judge,
                               index_capacity=128)
    regions[1].cache = tiered
    q = WORLD.query(5, 0)
    se = tiered.insert(q, WORLD.embed(q), WORLD.fetch(q), now=0.0,
                       cost=0.005, latency=0.4, size=100, staticity=7,
                       ttl=500.0)
    for i in range(6, 12):   # hot pressure pushes intent 5 into WARM
        qi = WORLD.query(i, 0)
        tiered.insert(qi, WORLD.embed(qi), WORLD.fetch(qi), now=1.0,
                      cost=0.005, latency=0.4, size=100, staticity=7,
                      ttl=500.0)
    assert se.se_id in tiered.warm.soa.id2row
    fed.route(engines[0], object(), WORLD.query(5, 1), 0.0)
    _drain(clock)
    res = engines[0].results[-1]
    assert res["value"] == WORLD.fetch(q)
    assert res["size"] == 100                 # original, not compressed
    assert res["origin"] == 1
    assert fed.stats.warm_leases == 1
    assert fed.stats.peer_hits == 1
    assert se.se_id in tiered.warm.soa.id2row  # source copy stayed warm


def test_peering_disabled_goes_straight_to_origin():
    fed, clock, regions, engines = _mk_federation(peering=False)
    _seed_peer(regions[1], WORLD.query(5, 0))
    fed.route(engines[0], st=None, q=WORLD.query(5, 0), t0=0.0)
    _drain(clock)
    assert fed.stats.peeks == 0
    assert fed.stats.origin_fetches == 1


# ------------------------------------------------------- runner / engine


def _tiny_runner(topology, *, overlap=0.8, seed=0, n_per_region=40):
    world = SemanticWorld(n_intents=80, dim=32, seed=9)
    streams = region_workloads(world, n_per_region, 2, overlap=overlap,
                               seed=10)
    return FederationRunner(
        world=world, region_requests=streams, topology=topology,
        engine_cfg=EngineConfig(prefetch=False), seed=seed,
    )


def test_local_hit_never_consults_the_router():
    """A request whose intent is already cached locally must resolve
    without a peek broadcast: peeks count only actual local misses."""
    runner = _tiny_runner("peered")
    s = runner.run()
    fed = runner.federation.stats
    hits = s["aggregate"]["cache_hits"]
    assert hits > 0
    # every peek corresponds to one routed miss; hits bypass the router
    total_rounds = sum(rec.rounds for e in runner.engines
                      for rec in e.records)
    assert fed.peeks == total_rounds - hits
    assert fed.peer_hits + fed.peer_misses == fed.peeks


def test_transferred_entries_carry_provenance_in_cache():
    runner = _tiny_runner("peered")
    runner.run()
    origins = [
        se.origin
        for r in runner.regions
        for se in (r.cache.store[i] for i in r.cache.store)
    ]
    transferred = [o for o in origins if o is not None]
    assert transferred, "peered run should admit at least one transfer"
    assert all(o in (0, 1) for o in transferred)


def test_peered_beats_local_on_overlapping_workload():
    local = _tiny_runner("local").run()["aggregate"]
    peered = _tiny_runner("peered").run()["aggregate"]
    assert peered["remote_time_mean"] < local["remote_time_mean"]
    assert peered["api_calls"] < local["api_calls"]


def test_shared_clock_determinism():
    """Same seeds -> bit-identical aggregate and per-region summaries,
    regardless of how region events interleave on the shared clock."""
    a = _tiny_runner("peered", seed=4).run()
    b = _tiny_runner("peered", seed=4).run()
    assert a == b
    c = _tiny_runner("global", seed=4).run()
    d = _tiny_runner("global", seed=4).run()
    assert c == d


def test_global_topology_shares_one_cache_and_pays_rtt():
    runner = _tiny_runner("global")
    assert runner.regions[0].cache is runner.regions[1].cache
    assert runner.engines[0].cfg.cache_access_latency == 0.0
    assert runner.engines[1].cfg.cache_access_latency == pytest.approx(0.08)
    s = runner.run()
    assert s["aggregate"]["peer_transfers"] == 0
    assert runner.federation.stats.peeks == 0


# ------------------------------------------------------- region workloads


def test_region_workloads_structure():
    world = SemanticWorld(n_intents=200, dim=32, seed=1)
    streams = region_workloads(world, 100, 3, overlap=0.5, seed=2)
    assert len(streams) == 3
    rids = [r.rid for s in streams for r in s]
    assert len(set(rids)) == len(rids)           # globally unique
    for s in streams:
        assert all(
            a.arrival <= b.arrival for a, b in zip(s, s[1:])
        )


def test_region_workload_overlap_controls_sharing():
    world = SemanticWorld(n_intents=200, dim=32, seed=1)

    def intent_sets(overlap):
        streams = region_workloads(world, 200, 2, overlap=overlap, seed=3)
        return [
            {world.intent_of(r.query) for r in s} for s in streams
        ]

    a0, a1 = intent_sets(0.0)
    assert not a0 & a1                           # disjoint private pools
    b0, b1 = intent_sets(0.9)
    inter = len(b0 & b1) / min(len(b0), len(b1))
    assert inter > 0.5                           # heavy sharing at 0.9


# ------------------------------------- peek timeouts + circuit breaker


def _mk_timeout_federation(faults=None, peek_timeout=0.25, **kw):
    from repro.serving.faults import FaultSchedule

    if isinstance(faults, list):
        faults = FaultSchedule.parse(faults)
    return _mk_federation(rtt=0.08, peek_timeout=peek_timeout,
                          faults=faults, **kw)


def test_peek_timeout_naks_dark_peer_and_decrements_inflight_once():
    fed, clock, regions, engines = _mk_timeout_federation(
        faults=["region_outage:0:1000:region=1"])
    q = WORLD.query(5, 0)
    _seed_peer(regions[1], q)       # the peer HAS it, but answers nothing
    fed.route(engines[0], st=None, q=q, t0=0.0)
    assert fed._inflight_peeks[0] == 1
    _drain(clock)

    assert fed.stats.peek_timeouts == 1
    assert fed.stats.peer_hits == 0
    assert fed.stats.peer_misses == 1
    assert fed.stats.origin_fetches == 1         # degraded, not wedged
    assert fed._inflight_peeks == [0, 0]         # decremented exactly once
    assert len(engines[0].results) == 1          # resolved exactly once


def test_late_response_after_timeout_is_ignored():
    # deadline (0.05) fires before the response (rtt 0.08): the peer's
    # lease arrives late and must not double-resolve the broadcast
    fed, clock, regions, engines = _mk_timeout_federation(
        peek_timeout=0.05)
    q = WORLD.query(5, 0)
    _seed_peer(regions[1], q)
    fed.route(engines[0], st=None, q=q, t0=0.0)
    _drain(clock)

    assert fed.stats.peek_timeouts == 1
    assert fed.stats.peer_hits == 0              # the late lease is dead
    assert fed.stats.transfers == 0
    assert fed.stats.origin_fetches == 1
    assert fed._inflight_peeks == [0, 0]
    assert len(engines[0].results) == 1


def test_response_before_timeout_keeps_legacy_path():
    fed, clock, regions, engines = _mk_timeout_federation(
        peek_timeout=5.0)
    q = WORLD.query(5, 0)
    _seed_peer(regions[1], q)
    fed.route(engines[0], st=None, q=q, t0=0.0)
    _drain(clock)

    assert fed.stats.peek_timeouts == 0
    assert fed.stats.peer_hits == 1
    assert fed.stats.transfers == 1
    assert fed._inflight_peeks == [0, 0]
    assert len(engines[0].results) == 1


def test_breaker_opens_after_k_timeouts_then_recloses_via_half_open():
    fed, clock, regions, engines = _mk_timeout_federation(
        faults=["region_outage:0:5:region=1"])
    assert fed.breaker_k == 3

    def one_round(q):
        fed.route(engines[0], st=None, q=q, t0=clock.now)
        _drain(clock)

    # three consecutive timeouts open the r0->r1 circuit
    for i in range(3):
        one_round(WORLD.query(5 + i, 0))
    br = fed._breaker[(0, 1)]
    assert br["state"] == "open"
    assert fed.stats.breaker_opens == 1
    assert fed.stats.peek_timeouts == 3

    # while open (cooldown not elapsed) peeks skip straight to origin
    peeks_before = fed.stats.peeks
    one_round(WORLD.query(8, 0))
    assert fed.stats.peeks == peeks_before       # no broadcast at all
    assert fed.stats.breaker_skips == 1

    # cooldown elapses AND the outage window ends: the next broadcast
    # rides one half-open probe, the response re-closes the circuit
    clock.push(clock.now + fed.breaker_cooldown + 1.0, lambda now: None)
    _drain(clock)
    one_round(WORLD.query(9, 0))
    assert br["state"] == "closed"
    assert br["consec"] == 0
    assert fed.stats.breaker_closes == 1
    assert fed._inflight_peeks == [0, 0]


def test_half_open_probe_timeout_reopens_immediately():
    fed, clock, regions, engines = _mk_timeout_federation(
        faults=["region_outage:0:1000:region=1"])

    def one_round(q):
        fed.route(engines[0], st=None, q=q, t0=clock.now)
        _drain(clock)

    for i in range(3):
        one_round(WORLD.query(5 + i, 0))
    br = fed._breaker[(0, 1)]
    assert br["state"] == "open"

    clock.push(clock.now + fed.breaker_cooldown + 1.0, lambda now: None)
    _drain(clock)
    one_round(WORLD.query(9, 0))                 # half-open probe times out
    assert br["state"] == "open"                 # ONE failure re-opens
    assert fed.stats.breaker_opens == 2
    assert fed._inflight_peeks == [0, 0]


def test_outage_runner_drains_with_zero_hung_peeks():
    world = SemanticWorld(n_intents=60, dim=32, seed=3)
    reqs = region_workloads(world, 30, 3, overlap=0.5, seed=4)
    fr = FederationRunner(
        world=world, region_requests=reqs, topology="peered",
        faults=["region_outage:2:6:region=1"], peek_timeout=0.25, seed=0)
    agg = fr.run()["aggregate"]
    assert agg["n"] == sum(len(r) for r in reqs)
    assert agg["hung_peeks"] == 0
    assert agg["peek_timeouts"] > 0
