"""Tiered SE storage (DESIGN.md §10): quantization parity, the
demote/promote lifecycle, TTL preservation, and batched-path equivalence.

Follows the test_soa_batch.py pattern: plain randomized tests, fixed
seeds, exact equality where the design promises it.
"""
import numpy as np
import pytest

from repro.core.judge import OracleJudge
from repro.core.seri import VectorIndex
from repro.core.tiers import (QuantIndex, TieredCache, WarmTier,
                              make_tiered_cache, quantize_rows)
from repro.data.world import SemanticWorld

WORLD = SemanticWorld(n_intents=120, dim=48, seed=7)


def _fresh(seed=3, hot=15_000, warm=15_000, max_ttl=400.0, eviction="lcfu",
           **kw):
    judge = OracleJudge(WORLD, accuracy=0.98, seed=seed)
    return make_tiered_cache(
        hot_bytes=hot, warm_bytes=warm, dim=WORLD.dim, judge=judge,
        index_capacity=256, max_ttl=max_ttl, eviction=eviction, **kw,
    )


def _insert(cache, intent, para=0, *, now, size=100, **kw):
    q = WORLD.query(intent, para)
    kw.setdefault("cost", 0.01)
    kw.setdefault("latency", 0.4)
    return cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=now,
                        size=size, **kw)


# ------------------------------------------------------------ quantization

def _unit_rows(rng, n, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def test_quantize_rows_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = _unit_rows(rng, 64, 48)
    q, s = quantize_rows(x)
    assert q.dtype == np.int8 and s.dtype == np.float32
    deq = q.astype(np.float32) * s[:, None]
    # max per-element error is half an int8 step of the row's scale
    assert np.max(np.abs(deq - x)) <= 0.5 * s.max() + 1e-7


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_int8_stage1_recall_vs_fp32(seed):
    """Warm-tier coarse+rescore retrieval keeps recall@k ≥ 0.95 against
    the exact fp32 index on the synthetic world (the §10 floor)."""
    world = SemanticWorld(n_intents=150, dim=64, seed=seed)
    embs = np.stack([world.embed(world.query(i, 0)) for i in range(150)])
    vi = VectorIndex(256, 64)
    qi = QuantIndex(256, 64)
    for i in range(150):
        vi.add(i, embs[i])
        qi.add(i, embs[i])
    recalls = []
    for i in range(0, 150, 3):
        q = world.embed(world.query(i, 1))
        ids_f, _ = vi.search(q, 4, tau_sim=0.0)
        ids_q, _ = qi.search(q, 4, tau_sim=0.0)
        if ids_f:
            recalls.append(len(set(ids_f) & set(ids_q)) / len(ids_f))
    assert float(np.mean(recalls)) >= 0.95


def test_quant_scalar_search_is_batched_row():
    rng = np.random.default_rng(3)
    emb = _unit_rows(rng, 200, 32)
    qi = QuantIndex(256, 32)
    for i in range(200):
        qi.add(i, emb[i])
    q = _unit_rows(rng, 8, 32)
    batched = qi.search_batch(q, 4, tau_sim=0.3)
    for i in range(8):
        ids_s, sims_s = qi.search(q[i], 4, tau_sim=0.3)
        assert ids_s == batched[i][0]
        np.testing.assert_array_equal(sims_s, batched[i][1])


def test_quant_numpy_matches_pallas_kernel_rowwise():
    """The numpy coarse+rescore path and the ``ann_topk_quant`` Pallas
    kernel return the same rows in the same order for a query block —
    both score the SAME int8 integers with the same scale-multiply
    order (DESIGN.md §10)."""
    rng = np.random.default_rng(0)
    n, d, b, k = 300, 32, 16, 4
    emb = _unit_rows(rng, n, d)
    qi_np = QuantIndex(512, d, backend="numpy")
    qi_kr = QuantIndex(512, d, backend="kernel")
    for i in range(n):
        qi_np.add(i, emb[i])
        qi_kr.add(i, emb[i])
    pick = rng.integers(0, n, b)
    q = emb[pick] + 0.05 * rng.standard_normal((b, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    res_np = qi_np.search_batch(q, k, tau_sim=0.5)
    res_kr = qi_kr.search_batch(q, k, tau_sim=0.5)
    assert any(ids for ids, _ in res_np)
    for (ids_n, sims_n), (ids_k, sims_k) in zip(res_np, res_kr):
        assert ids_n == ids_k
        np.testing.assert_allclose(sims_n, sims_k, atol=2e-5)


def test_quant_index_row_reuse_after_removal():
    rng = np.random.default_rng(5)
    emb = _unit_rows(rng, 8, 32)
    qi = QuantIndex(8, 32)
    rows = [qi.add(i, emb[i]) for i in range(8)]
    assert qi.full
    qi.remove_rows(rows[:4])
    assert len(qi) == 4 and not qi.full
    r = qi.add(99, emb[0])
    assert qi.row_se[r] == 99


# --------------------------------------------------------------- lifecycle

def test_lcfu_victims_demote_not_evict():
    """HOT pressure rehomes victims in WARM; nothing leaves the system
    until the WARM tier itself overflows."""
    cache = _fresh(hot=500, warm=10_000, max_ttl=800.0)
    now = 0.0
    for i in range(12):
        _insert(cache, i, now=now)
        now += 1.0
    assert len(cache) == 5                    # 500 bytes / 100
    assert cache.tier_stats.demotions == 7
    assert len(cache.warm) == 7
    assert cache.stats.evictions == 0         # no true evictions yet
    # demoted entries keep their metadata verbatim
    for se_id, row in cache.warm.soa.id2row.items():
        assert cache.warm.orig_size[row] == 100
        assert cache.warm.soa.size[row] == cache.warm.warm_size(100)


def test_warm_hit_promotes_and_preserves_absolute_expiry():
    cache = _fresh(hot=500, warm=10_000, max_ttl=800.0, seed=1)
    now = 0.0
    expiry = {}
    for i in range(12):
        se = _insert(cache, i, now=now)
        expiry[se.se_id] = se.expires_at
        now += 1.0
    # pick a warm resident, look it up via a fresh paraphrase
    row = next(iter(cache.warm.soa.id2row.values()))
    key = cache.warm.soa.key[row]
    intent = WORLD.intent_of(key)
    se_id = int(cache.warm.soa.se_id[row])
    q2 = WORLD.query(intent, 5)
    res = cache.lookup(q2, WORLD.embed(q2), now)
    assert res.hit and res.se.se_id == se_id
    assert res.se.key == key                   # promoted entry, same SE
    assert se_id in cache.store                # back in HOT
    assert se_id not in cache.warm.soa.id2row  # out of WARM
    # the §10 invariant: demotion/promotion never extends TTL
    assert res.se.expires_at == expiry[se_id]
    assert cache.tier_stats.promotions == 1
    assert cache.tier_stats.warm_hits == 1
    # sims aligned with the judged candidates (satellite: alignment)
    assert len(res.sims) == res.n_candidates


def test_warm_value_roundtrips_compression():
    cache = _fresh(hot=500, warm=10_000, max_ttl=800.0, seed=2)
    now = 0.0
    payload = {"answer": "x" * 500, "n": 7}
    q = WORLD.query(0, 0)
    cache.insert(q, WORLD.embed(q), payload, now=now, cost=0.01,
                 latency=0.4, size=100)
    for i in range(1, 12):   # push intent 0 out of HOT
        _insert(cache, i, now=now + i)
    we = cache.warm.view(0)
    assert we.tier == "warm"
    assert we.value == payload                # zlib+pickle round trip
    assert we.size == 100                     # original bytes
    assert we.warm_bytes == cache.warm.warm_size(100)


def test_warm_overflow_is_true_eviction():
    # warm holds 2 compressed entries (2 × 40); the third demotion evicts
    cache = _fresh(hot=200, warm=80, max_ttl=800.0, seed=3)
    now = 0.0
    for i in range(6):
        _insert(cache, i, now=now)
        now += 1.0
    assert len(cache) == 2
    assert len(cache.warm) == 2
    assert cache.tier_stats.warm_evictions == 2
    assert cache.stats.evictions == 2          # counted as leaving the system
    assert cache.tier_stats.demotions == 4


def test_oversized_victim_drops_when_warm_cannot_hold_it():
    cache = _fresh(hot=500, warm=30, max_ttl=800.0, seed=4)
    now = 0.0
    for i in range(7):
        _insert(cache, i, now=now, size=100)   # warm_size 40 > 30
        now += 1.0
    assert len(cache.warm) == 0
    assert cache.tier_stats.demote_drops == 2
    assert cache.stats.evictions == 2


def test_expired_entries_never_demote_and_warm_purges():
    cache = _fresh(hot=500, warm=10_000, max_ttl=100.0, seed=5)
    now = 0.0
    for i in range(12):
        _insert(cache, i, now=now)
    # far future: pressure at a time every entry is dead
    n_live_hot = len(cache)
    n_warm = len(cache.warm)
    purged = cache.purge_expired(1e6)
    assert purged == n_live_hot + n_warm
    assert len(cache) == 0 and len(cache.warm) == 0
    assert cache.warm.usage == 0
    assert cache.tier_stats.warm_ttl_evictions == n_warm


def test_peek_semantic_consults_warm_without_bookkeeping():
    cache = _fresh(hot=500, warm=10_000, max_ttl=800.0, seed=6)
    now = 0.0
    for i in range(12):
        _insert(cache, i, now=now)
        now += 1.0
    row = next(iter(cache.warm.soa.id2row.values()))
    intent = WORLD.intent_of(cache.warm.soa.key[row])
    q = WORLD.query(intent, 9)
    before = (cache.stats.lookups, cache.stats.hits,
              cache.tier_stats.promotions, len(cache.warm))
    se = cache.peek_semantic(q, WORLD.embed(q), now)
    assert se is not None and se.tier == "warm"
    assert se.value == WORLD.fetch(q)
    after = (cache.stats.lookups, cache.stats.hits,
             cache.tier_stats.promotions, len(cache.warm))
    assert before == after                     # pure peek, no mutation


def test_nojudge_account_hit_promotes_warm_winner():
    cache = _fresh(hot=500, warm=10_000, max_ttl=800.0, seed=7)
    now = 0.0
    for i in range(12):
        _insert(cache, i, now=now)
        now += 1.0
    row = next(iter(cache.warm.soa.id2row.values()))
    se_id = int(cache.warm.soa.se_id[row])
    intent = WORLD.intent_of(cache.warm.soa.key[row])
    q = WORLD.query(intent, 3)
    cands = cache.stage1(q, WORLD.embed(q), now)
    assert cands and cands[0].tier == "warm"
    key, value = cands[0].key, cands[0].value  # snapshot like the engine
    cache.account_hit(cands[0], now)
    assert se_id in cache.store
    assert cache.store[se_id].freq == 2        # insert freq=1, hit +1
    assert cache.stats.hits == 1
    assert value == WORLD.fetch(q) and WORLD.intent_of(key) == intent


def test_rebind_survives_mid_batch_row_reuse():
    """A promote→demote cycle inside one batch reuses hot rows: a
    stage-1 view captured before the shuffle must re-resolve through
    id2row, never serve another SE's row (previously q3 below could get
    hit=True with the WRONG entry's value)."""
    judge = OracleJudge(WORLD, accuracy=1.0, seed=9)
    cache = make_tiered_cache(
        hot_bytes=100, warm_bytes=10_000, dim=WORLD.dim, judge=judge,
        index_capacity=256, max_ttl=800.0,
    )
    # intents 30/40 sit outside the world's confusable-pair block, so
    # q1's hot stage 1 is genuinely empty and the warm tier is consulted
    _insert(cache, 30, now=0.0)  # W: hot
    _insert(cache, 40, now=1.0)  # A: demotes W; hot=[A], warm=[W]
    assert sorted(WORLD.intent_of(cache.warm.soa.key[r])
                  for r in cache.warm.soa.id2row.values()) == [30]
    # one batch: q1 warm-hits W (its promotion demotes A and reuses A's
    # row); q2's rebind re-promotes A (demoting W again); q3 holds a hot
    # stage-1 view of A whose row has been reassigned TWICE by then
    w_id, a_id = 0, 1
    qs = [WORLD.query(30, 1), WORLD.query(40, 1), WORLD.query(40, 2)]
    embs = np.stack([WORLD.embed(q) for q in qs])
    results = cache.lookup_batch(qs, embs, 2.0)
    assert [r.hit for r in results] == [True, True, True]
    # hit-time identity: se_id is snapshotted at view creation, so it is
    # reliable even though the VIEW may go stale once later queries in
    # the same batch reshuffle rows (documented live-view semantics —
    # the engine consumes each result before the next finalize)
    assert [r.se.se_id for r in results] == [w_id, a_id, a_id]
    # every freq bump landed on the right entry, wherever it lives now
    assert cache.store[a_id].freq == 3        # insert + q2 + q3
    assert cache.store[a_id].value == WORLD.fetch(qs[1])
    w_row = cache.warm.soa.id2row[w_id]       # demoted again by q2
    assert int(cache.warm.soa.freq[w_row]) == 2   # insert + q1
    assert cache.warm.view(w_id).value == WORLD.fetch(qs[0])


# ----------------------------------------------------- batched equivalence

def _run_workload(batched: bool, *, seed: int):
    """Tiered analogue of test_soa_batch._run_workload: small HOT slice
    (just above the max single value size, so one item never exceeds
    capacity) — the stream constantly demotes/promotes on both paths."""
    cache = _fresh(seed=seed, hot=5_000, warm=5_000, max_ttl=400.0)
    rng = np.random.default_rng(seed)
    now, hit_seq = 0.0, []
    for _ in range(40):
        now += float(rng.random() * 30)
        bs = int(rng.integers(1, 9))
        qs = [WORLD.query(int(rng.integers(0, 120)), int(rng.integers(0, 30)))
              for _ in range(bs)]
        embs = np.stack([WORLD.embed(q) for q in qs])
        if batched:
            results = cache.lookup_batch(qs, embs, now)
        else:
            results = [cache.lookup(q, e, now) for q, e in zip(qs, embs)]
        hit_seq.extend(r.hit for r in results)
        for r in results:   # sims stay aligned with judged candidates
            assert len(r.sims) == r.n_candidates
        misses = [(q, e) for (q, e), r in zip(zip(qs, embs), results)
                  if not r.hit]
        if batched:
            cache.insert_batch(
                [dict(query=q, q_emb=e, value=WORLD.fetch(q), cost=0.005,
                      latency=0.4, size=WORLD.value_size(q))
                 for q, e in misses],
                now=now,
            )
        else:
            for q, e in misses:
                cache.insert(q, e, WORLD.fetch(q), now=now, cost=0.005,
                             latency=0.4, size=WORLD.value_size(q))
    return hit_seq, cache


@pytest.mark.parametrize("seed", [3, 11, 29])
def test_tiered_batched_path_equivalent_to_scalar(seed):
    """lookup_batch reproduces the scalar hit/miss/demote/promote
    sequence exactly — scalar IS the B=1 batched path, and the judge's
    per-pair seeding keeps scores independent of batching.

    ``warm_lookups`` is excluded: the batched path decides warm consults
    against BLOCK-START tier membership, so a promotion by query j can
    spare the scalar path (but not the batched one) query j+1's warm
    scan. Outcomes still match — ``_rebind`` redirects stale warm views
    to the already-promoted hot row."""
    import dataclasses as dc

    seq_a, cache_a = _run_workload(False, seed=seed)
    seq_b, cache_b = _run_workload(True, seed=seed)
    assert seq_a == seq_b
    assert cache_a.stats == cache_b.stats
    assert dc.replace(cache_a.tier_stats, warm_lookups=0) == \
        dc.replace(cache_b.tier_stats, warm_lookups=0)
    assert sorted(cache_a.store) == sorted(cache_b.store)
    assert sorted(cache_a.warm.soa.id2row) == sorted(cache_b.warm.soa.id2row)
    assert cache_a.usage == cache_b.usage
    assert cache_a.warm.usage == cache_b.warm.usage


def test_tiered_invariants_under_pressure():
    _, cache = _run_workload(True, seed=5)
    assert cache.usage <= cache.capacity_bytes
    assert cache.warm.usage <= cache.warm.capacity_bytes
    assert cache.usage == sum(se.size for se in cache.store.values())
    w = cache.warm
    assert w.usage == int(w.soa.size[w.soa.active].sum())
    assert len(w.soa) == len(w.index)
    # no SE lives in both tiers at once
    assert not set(cache.store) & set(w.soa.id2row)
    assert cache.total_usage == cache.usage + w.usage


# ------------------------------------------------------------- end-to-end

def test_engine_tiered_run_summary_and_determinism():
    """A small closed-loop engine run on the capacity-pressure workload:
    the tiered path exercises demote/promote under virtual time, reports
    tier stats in summary(), and two same-seed runs are bit-identical."""
    from repro.launch.serve import run_once

    kw = dict(workload="longtail", mode="cortex", n_requests=120,
              n_intents=168, dim=48, tail_len=120, cache_ratio=0.18,
              concurrency=8, max_ttl=1800.0, seed=31)
    hot = run_once(**kw)
    a = run_once(warm_frac=0.5, **kw)
    b = run_once(warm_frac=0.5, **kw)
    assert a == b
    assert a["demotions"] > 0
    assert a["promotions"] > 0
    # every warm hit promotes; rebinds of mid-batch demotions can add a
    # few promotions that are not warm-discovered hits
    assert a["promotions"] >= a["warm_hits"] > 0
    assert a["hit_rate"] > hot["hit_rate"]
