import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------- shim
# `hypothesis` is a dev-only dependency (requirements-dev.txt). When it is
# absent, install a stub so the property-test modules still *collect*: the
# @given tests turn into explicit skips and every non-hypothesis test in
# those modules keeps running.
try:
    import hypothesis  # noqa: F401
except ImportError:
    class _AnyStrategy:
        """Stands in for any strategy object/combinator at collect time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    _ANY = _AnyStrategy()

    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _st.__getattr__ = lambda name: _ANY
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def world():
    from repro.data.world import SemanticWorld

    return SemanticWorld(n_intents=200, dim=64, seed=0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
