"""One benchmark function per paper table/figure (§6). Each prints CSV
rows ``name,us_per_call,derived-metrics``; benchmarks.run drives them."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DATASETS, emit, fmt, run_ds
from repro.launch.serve import run_once
from repro.obs.metrics import percentile

RATIOS = (0.1, 0.2, 0.4, 0.6)


def fig7_skewed():
    """Throughput/hit/latency vs cache ratio on 4 skewed search datasets."""
    for ds in ("zilliz", "hotpotqa", "musique", "2wiki"):
        prof = DATASETS[ds]
        v = run_ds(ds, "vanilla", em_p_base=prof["em_p_base"],
                   zipf_s=prof["zipf_s"])
        emit(f"fig7/{ds}/vanilla", v["latency_mean"] * 1e6, **fmt(v))
        for ratio in RATIOS:
            for mode in ("exact", "cortex"):
                s = run_ds(ds, mode, cache_ratio=ratio,
                           em_p_base=prof["em_p_base"], zipf_s=prof["zipf_s"])
                emit(f"fig7/{ds}/{mode}@{ratio}", s["latency_mean"] * 1e6,
                     **fmt(s))


def fig8_trend():
    """Bursty trend-driven workload vs cache ratio (LCFU absorbs waves)."""
    v = run_once(workload="trend", mode="vanilla", n_requests=600,
                 concurrency=8, seed=21)
    emit("fig8/vanilla", v["latency_mean"] * 1e6, **fmt(v))
    for ratio in RATIOS:
        for mode in ("exact", "cortex"):
            s = run_once(workload="trend", mode=mode, n_requests=600,
                         cache_ratio=ratio, concurrency=8, max_ttl=900.0,
                         seed=21)
            emit(f"fig8/{mode}@{ratio}", s["latency_mean"] * 1e6, **fmt(s))


def fig9_swebench():
    """Code-agent workload (SWE-bench file-access pattern)."""
    v = run_once(workload="swe", mode="vanilla", n_requests=500,
                 concurrency=8, seed=22)
    emit("fig9/vanilla", v["latency_mean"] * 1e6, **fmt(v))
    for ratio in RATIOS:
        for mode in ("exact", "cortex"):
            s = run_once(workload="swe", mode=mode, n_requests=500,
                         cache_ratio=ratio, concurrency=8, seed=22)
            emit(f"fig9/{mode}@{ratio}", s["latency_mean"] * 1e6, **fmt(s))


def fig10_concurrency():
    """Throughput scaling vs request concurrency (musique, ratio 0.4)."""
    prof = DATASETS["musique"]
    for conc in (1, 2, 4, 8, 16, 32):
        for mode in ("vanilla", "exact", "cortex"):
            s = run_ds("musique", mode, cache_ratio=0.4, concurrency=conc,
                       em_p_base=prof["em_p_base"])
            emit(f"fig10/{mode}@c{conc}", s["latency_mean"] * 1e6, **fmt(s))


def fig11_breakdown():
    """Per-request latency breakdown at low concurrency (steady state:
    30% warmup excluded; the cortex row also reports the pure hit path)."""
    for mode in ("vanilla", "cortex"):
        s = run_ds("musique", mode, cache_ratio=0.6, concurrency=1,
                   n_requests=400, warmup_frac=0.3)
        emit(
            f"fig11/{mode}", s["latency_mean"] * 1e6,
            agent_s=round(s["agent_time_mean"], 3),
            cache_s=round(s["cache_time_mean"], 3),
            remote_s=round(s["remote_time_mean"], 3),
            total_s=round(s["latency_mean"], 3),
            hitpath_s=round(s.get("hitpath_latency", float("nan")), 3),
        )


def fig12_ratelimit():
    """External call counts + retry ratios under the 100 QPM cap."""
    for mode in ("vanilla", "cortex"):
        s = run_ds("musique", mode, cache_ratio=0.4, concurrency=8,
                   warmup_frac=0.3)
        emit(
            f"fig12/{mode}", s["latency_mean"] * 1e6,
            api_calls=s["api_calls"], attempts=s["api_attempts"],
            retry_ratio=round(s["retry_ratio"], 4),
        )


def table4_ratelimit_ablation():
    """Normalized throughput with vs without the API rate limit."""
    rows = {}
    for qpm, tag in ((100.0, "limited"), (None, "unlimited")):
        for mode in ("vanilla", "cortex"):
            s = run_ds("musique", mode, cache_ratio=0.4, concurrency=4,
                       qpm=qpm, warmup_frac=0.3)
            rows[(tag, mode)] = s["throughput_rps"]
    for tag in ("unlimited", "limited"):
        ratio = rows[(tag, "cortex")] / rows[(tag, "vanilla")]
        emit(f"table4/{tag}", 0.0,
             vanilla=round(rows[(tag, 'vanilla')], 3),
             cortex=round(rows[(tag, 'cortex')], 3),
             cortex_over_vanilla=round(ratio, 2))


def table5_cost():
    """Cost analysis: vanilla, Cortex w/o sharing (2 chips), Cortex."""
    confs = [
        ("vanilla", dict(mode="vanilla")),
        ("cortex_dedicated", dict(mode="cortex", colocated=False)),
        ("cortex", dict(mode="cortex", colocated=True)),
    ]
    # paper §6.5 runs this controlled comparison against the self-deployed
    # RAG service (no public-API rate cap) — otherwise the faster front-end
    # merely floods the throttle queue
    for name, kw in confs:
        s = run_ds("musique", cache_ratio=0.6, concurrency=16,
                   n_requests=600, qpm=None, warmup_frac=0.2, **kw)
        emit(
            f"table5/{name}", s["latency_mean"] * 1e6,
            api_cost=round(s["api_cost"], 3),
            gpu_cost=round(s["gpu_cost"], 4),
            total=round(s["cost_total"], 3),
            thpt=round(s["throughput_rps"], 3),
            thpt_per_dollar=round(s["thpt_per_dollar"], 3),
        )


def fig13_accuracy():
    """EM accuracy: vanilla vs Cortex vs Cortex-w/o-judge per dataset."""
    for ds in ("hotpotqa", "musique", "2wiki", "strategyqa"):
        prof = DATASETS[ds]
        row = {}
        for mode in ("vanilla", "cortex", "cortex-nojudge"):
            s = run_ds(ds, mode, cache_ratio=0.6,
                       em_p_base=prof["em_p_base"], concurrency=8)
            row[mode] = s
        emit(
            f"fig13/{ds}", 0.0,
            vanilla_em=round(row["vanilla"]["em"], 3),
            cortex_em=round(row["cortex"]["em"], 3),
            nojudge_em=round(row["cortex-nojudge"]["em"], 3),
            cortex_info_acc=round(row["cortex"]["info_accuracy"], 3),
            nojudge_info_acc=round(
                row["cortex-nojudge"]["info_accuracy"], 3
            ),
        )


def table6_lcfu():
    """LCFU vs LRU vs LFU on the HotpotQA-profile skewed workload (the
    paper's Table 6 setting): heterogeneous tool costs mean LCFU trades a
    little hit rate for keeping expensive-to-refetch items — lower mean
    miss cost, higher end-to-end throughput."""
    prof = DATASETS["hotpotqa"]
    for ev in ("lru", "lfu", "lcfu"):
        s = run_ds("hotpotqa", "cortex", cache_ratio=0.2, eviction=ev,
                   n_requests=900, warmup_frac=0.25, concurrency=4,
                   qpm=200.0, em_p_base=prof["em_p_base"])
        emit(f"table6/{ev}", s["latency_mean"] * 1e6,
             hit=round(s["hit_rate"], 3),
             thpt=round(s["throughput_rps"], 3),
             lat_ms=round(s["latency_mean"] * 1e3, 1),
             cost_per_call=round(
                 s["api_cost"] / max(s["api_calls"], 1) * 1e3, 2
             ),
             evictions=s["evictions"])


def table7_colocation():
    """Co-located (MPS-style 80/20) vs dedicated judge chip."""
    for name, co in (("dedicated_2chip", False), ("colocated_80_20", True)):
        s = run_ds("musique", "cortex", cache_ratio=0.6, concurrency=16,
                   colocated=co, qpm=None, warmup_frac=0.2)
        emit(f"table7/{name}", s["latency_mean"] * 1e6,
             thpt=round(s["throughput_rps"], 3),
             p99_ms=round(s["latency_p99"] * 1e3, 1),
             chips=1 if co else 2,
             thpt_per_dollar=round(s["thpt_per_dollar"], 3))


def federation_sweep(smoke: bool = False):
    """Cross-region 3-way sweep (DESIGN.md §9): per-region caches alone
    vs the peered federation vs one shared global cache. Region-skewed
    workload with a shared-hot overlap, so peering has reuse to capture.
    ``smoke`` shrinks everything for the CI topology-regression gate."""
    from repro.data.workloads import region_workloads
    from repro.data.world import SemanticWorld
    from repro.serving.federation import FederationRunner

    n_intents = 120 if smoke else 600
    n_per_region = 40 if smoke else 400
    n_regions = 2 if smoke else 3
    world = SemanticWorld(n_intents=n_intents, dim=64, seed=21)
    streams = region_workloads(
        world, n_per_region, n_regions, overlap=0.6, seed=22,
    )
    results = {}
    for topo in ("local", "peered", "global"):
        r = FederationRunner(
            world=world, region_requests=streams, topology=topo, seed=23,
        )
        a = r.run()["aggregate"]
        results[topo] = a
        emit(f"federation/{topo}", a["latency_mean"] * 1e6,
             seed=23,
             lat_ms=round(a["latency_mean"] * 1e3, 1),
             remote_ms=round(a["remote_time_mean"] * 1e3, 1),
             hit=round(a["hit_rate"], 3),
             peer_hit=round(a["peer_hit_rate"], 3),
             transfers=a["peer_transfers"],
             api=a["api_calls"],
             cost=round(a["api_cost"], 3))
    gain = 1 - results["peered"]["remote_time_mean"] / max(
        results["local"]["remote_time_mean"], 1e-9
    )
    emit("federation/peering_gain", 0.0, seed=23,
         remote_time_reduction=round(gain, 4))
    if results["peered"]["remote_time_mean"] >= \
            results["local"]["remote_time_mean"]:
        raise SystemExit(
            "federation regression: peered mean remote_time "
            f"({results['peered']['remote_time_mean']:.4f}s) is not below "
            f"local-only ({results['local']['remote_time_mean']:.4f}s)"
        )
    return results


def tiered_sweep(smoke: bool = False):
    """Tiered-storage sweep (DESIGN.md §10): hot-only vs hot+warm at
    EQUAL total cache bytes on the long-tail capacity-pressure workload,
    sweeping the tail length (= reuse distance). The warm tier must win
    on hit rate AND API spend, the int8 coarse index must keep
    recall@k ≥ 0.95 vs fp32, and two same-seed tiered runs must produce
    bit-identical summaries — any violation exits nonzero (CI gate).
    """
    import json as _json

    from repro.core.seri import VectorIndex
    from repro.core.tiers import QuantIndex
    from repro.data.world import SemanticWorld

    # --- int8 stage-1 recall@k vs the fp32 index, across seeds
    recalls = []
    for seed in (0, 1, 2):
        world = SemanticWorld(n_intents=200, dim=64, seed=seed)
        embs = np.stack([
            world.embed(world.query(i, 0)) for i in range(200)
        ])
        vi = VectorIndex(256, 64)
        qi = QuantIndex(256, 64)
        for i in range(200):
            vi.add(i, embs[i])
            qi.add(i, embs[i])
        qs = np.stack([
            world.embed(world.query(i, 1)) for i in range(0, 200, 4)
        ])
        for i in range(qs.shape[0]):
            ids_f, _ = vi.search(qs[i], 4, tau_sim=0.0)
            ids_q, _ = qi.search(qs[i], 4, tau_sim=0.0)
            if ids_f:
                recalls.append(
                    len(set(ids_f) & set(ids_q)) / len(ids_f)
                )
    recall = float(np.mean(recalls))
    emit("tiered/int8_recall", 0.0, recall_at_4=round(recall, 4),
         n_queries=len(recalls))
    if recall < 0.95:
        raise SystemExit(
            f"tiered regression: int8 stage-1 recall@4 ({recall:.3f}) "
            "below the 0.95 floor"
        )

    # --- hot-only vs hot+warm at equal total bytes, sweeping tail length
    tails = (160,) if smoke else (160, 320, 640)
    n_req = 160 if smoke else 700
    results = {}
    for tail in tails:
        common_kw = dict(
            workload="longtail", n_requests=n_req,
            n_intents=48 + max(tails), dim=64, tail_len=tail,
            cache_ratio=0.18, concurrency=8, max_ttl=1800.0, seed=31,
        )
        hot = run_once(mode="cortex", **common_kw)
        warm = run_once(mode="cortex", warm_frac=0.5, **common_kw)
        warm2 = run_once(mode="cortex", warm_frac=0.5, **common_kw)
        if _json.dumps(warm, sort_keys=True, default=float) != \
                _json.dumps(warm2, sort_keys=True, default=float):
            raise SystemExit(
                "tiered regression: two same-seed hot+warm runs diverged "
                f"(tail={tail}) — summaries must be bit-identical"
            )
        results[tail] = (hot, warm)
        emit(f"tiered/hot_only@t{tail}", hot["latency_mean"] * 1e6,
             seed=31,
             hit=round(hot["hit_rate"], 3),
             api=hot["api_calls"],
             api_cost=round(hot["api_cost"], 3),
             evictions=hot["evictions"])
        emit(f"tiered/hot_warm@t{tail}", warm["latency_mean"] * 1e6,
             seed=31,
             hit=round(warm["hit_rate"], 3),
             api=warm["api_calls"],
             api_cost=round(warm["api_cost"], 3),
             demotions=warm["demotions"],
             promotions=warm["promotions"],
             warm_hits=warm["warm_hits"],
             warm_items=warm["warm_items"])
    for tail, (hot, warm) in results.items():
        if warm["hit_rate"] <= hot["hit_rate"] or \
                warm["api_cost"] >= hot["api_cost"]:
            raise SystemExit(
                "tiered regression: hot+warm must beat hot-only on hit "
                f"rate AND api cost at equal bytes (tail={tail}: "
                f"hit {warm['hit_rate']:.3f} vs {hot['hit_rate']:.3f}, "
                f"cost {warm['api_cost']:.3f} vs {hot['api_cost']:.3f})"
            )
    return results


def stage1_scaling(smoke: bool = False):
    """Sublinear stage-1 sweep (DESIGN.md §12): brute force vs the
    clustered (IVF) index over N ∈ {1k…64k} rows of intent-structured
    embeddings, then an end-to-end engine comparison at the largest N
    under the scan-proportional stage-1 latency model
    (``t_cache_cpu + t_cache_per_row · rows_scanned``).

    Gates (CI runs ``--smoke``): IVF recall@k ≥ 0.95 vs brute force at
    every N; ≥ 3× fewer rows scanned at the largest N; e2e p50
    cache-hit latency at the largest N lower with IVF than brute; and
    nprobe=all bit-identical to the brute path — per-search (ids AND
    sims) and across a full same-seed engine run.

    Mesh-sharded sweep (DESIGN.md §13): the index is partitioned by
    contiguous cluster ownership across S ∈ {1, 2, 8} shards at
    ``shard_n`` rows (2^20 full, 65536 smoke). Gates: recall@k ≥ 0.95
    vs a same-size brute reference; balance efficiency
    ``rows_total / (S · rows_max_shard)`` ≥ 0.7 at S=8 (the ideal
    rows/sec scaling floor under the max-over-shards latency model);
    search results AND trained centroids identical across shard counts
    (zero float tolerance on the host path); nprobe=all at S=1
    bit-identical to brute; sharded e2e mean hit-path latency (per-row
    cost + ``t_shard_merge``) below the unsharded IVF mean; and the
    Pallas-backend sharded scan matching the numpy sharded path
    (``shard_map`` over the device mesh when ≥ 8 devices are visible —
    run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    like CI — unrolled per-shard loop otherwise; rows carry a
    mesh_used flag).
    """
    import dataclasses as _dc
    import json as _json

    from repro.core.cache import make_cache
    from repro.core.clustering import ClusterConfig, ClusterRouter
    from repro.core.judge import OracleJudge
    from repro.core.seri import VectorIndex
    from repro.data.workloads import zipf_workload
    from repro.data.world import SemanticWorld
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.gpu import GPU, GPUConfig
    from repro.serving.remote import RemoteDataService

    import time as _time

    dim, k, b = 64, 4, 8
    paras = 8                       # stored paraphrases per intent
    ns = (1024, 4096) if smoke else (1024, 4096, 16384, 65536)

    def _best_of(fn, reps=5):
        # min-of-N: this host's wall clock jitters under time-sharing
        fn()  # warm
        best = float("inf")
        for _ in range(reps):
            t0 = _time.perf_counter()
            fn()
            best = min(best, _time.perf_counter() - t0)
        return best

    # ---- index microbench: recall, rows scanned, host latency --------
    ratios = {}
    for n in ns:
        world = SemanticWorld(n_intents=n // paras, dim=dim, seed=61)
        embs = np.stack([
            world.embed(world.query(i // paras, i % paras))
            for i in range(n)
        ])
        ccfg = ClusterConfig(
            n_clusters=max(8, min(512, int(2 * np.sqrt(n)))),
            nprobe=max(4, int(np.sqrt(n)) // 16),
            refresh_every=max(2048, n // 2), seed=62,
        )
        brute = VectorIndex(n, dim)
        ivf = VectorIndex(n, dim,
                          router=ClusterRouter(n, dim, ccfg))
        for i in range(n):
            brute.add(i, embs[i])
            ivf.add(i, embs[i])
        ivf.router.refresh(ivf)     # settle centroids post-build
        rng = np.random.default_rng(63)
        nq = 64 if smoke else 256
        qs = np.stack([
            world.embed(world.query(int(i), 99))
            for i in rng.integers(0, n // paras, nq)
        ])
        recalls, rows_brute, rows_ivf = [], 0, 0
        for off in range(0, nq, b):
            blk = qs[off:off + b]
            rb = brute.search_batch(blk, k, 0.0)
            rows_brute += brute.last_scanned
            ri = ivf.search_batch(blk, k, 0.0)
            rows_ivf += ivf.last_scanned
            recalls.extend(
                len(set(ids_b) & set(ids_i)) / len(ids_b)
                for (ids_b, _), (ids_i, _) in zip(rb, ri) if ids_b
            )
        recall = float(np.mean(recalls))

        blk = qs[:b]
        t_brute = _best_of(lambda: brute.search_batch(blk, k, 0.0))
        t_ivf = _best_of(lambda: ivf.search_batch(blk, k, 0.0))
        ratio = rows_brute / max(rows_ivf, 1)
        ratios[n] = ratio
        emit(f"stage1_scaling/N{n}", t_ivf * 1e6, seed=62,
             recall_at_4=round(recall, 4),
             rows_brute=rows_brute, rows_ivf=rows_ivf,
             scan_ratio=round(ratio, 2),
             brute_us=round(t_brute * 1e6, 1),
             ivf_us=round(t_ivf * 1e6, 1),
             nclusters=ccfg.n_clusters, nprobe=ccfg.nprobe)
        if recall < 0.95:
            raise SystemExit(
                f"stage1 regression: IVF recall@{k} ({recall:.3f}) below "
                f"the 0.95 floor at N={n}"
            )
        # nprobe=all must reproduce brute force bit-for-bit (same B)
        ivf.router.cfg.nprobe = None
        for off in range(0, nq, b):
            blk = qs[off:off + b]
            for (ids_b, sims_b), (ids_a, sims_a) in zip(
                brute.search_batch(blk, k, 0.0),
                ivf.search_batch(blk, k, 0.0),
            ):
                if ids_b != ids_a or not np.array_equal(sims_b, sims_a):
                    raise SystemExit(
                        "stage1 regression: nprobe=all diverged from "
                        f"brute force at N={n}"
                    )
    top = ns[-1]
    if ratios[top] < 3.0:
        raise SystemExit(
            f"stage1 regression: rows-scanned reduction at N={top} "
            f"({ratios[top]:.2f}×) below the 3× floor"
        )

    # ---- end-to-end at the largest N: scan-proportional latency ------
    n_fill = 4096 if smoke else 65536
    n_req = 150 if smoke else 300
    # scaled so a full brute pass costs ≈ +33 ms at either fill size —
    # the smoke gate then exercises the same latency-model contrast as
    # the full run instead of drowning in ms-level scheduling jitter
    per_row = 5e-7 * (65536 / n_fill)
    # refresh_every < n_fill: the router must re-train AND re-cut the
    # shard bounds a few times while the fill mass arrives — bounds
    # balanced on the 512-row training snapshot alone leave the §13
    # shards arbitrarily lopsided once 65k more rows land
    e2e_cfg = ClusterConfig(
        n_clusters=64 if smoke else 256, nprobe=8 if smoke else 16,
        min_train=512, refresh_every=max(2048, n_fill // 4), seed=64,
    )

    def e2e(cluster_cfg, t_per_row, t_shard_merge=0.0):
        """One engine run over a cache prepopulated with ``n_fill``
        filler entries (far from every query in embedding space, huge
        TTL/capacity — pure stage-1 scan load, no behavior change).
        The fill goes through ``insert_block`` (one index ``add_batch``
        + one SoA ``add_block``), which is bit-equivalent to n scalar
        inserts — the million-entry fills would take minutes row by
        row."""
        world = SemanticWorld(n_intents=300, dim=dim, seed=65)
        reqs = zipf_workload(world, n_req, seed=66)
        judge = OracleJudge(world, accuracy=0.98, seed=67)
        cache = make_cache(
            capacity_bytes=1 << 40, dim=dim, judge=judge,
            index_capacity=n_fill + 4096, cluster=cluster_cfg,
        )
        frng = np.random.default_rng(68)
        fills = frng.standard_normal((n_fill, dim)).astype(np.float32)
        fills /= np.linalg.norm(fills, axis=1, keepdims=True)
        cache.insert_block(
            [f"fill:{i}:0" for i in range(n_fill)], fills,
            list(range(n_fill)), now=0.0, cost=0.001, latency=0.1,
            size=64, staticity=10, ttl=1e8,
        )
        eng = Engine(
            world=world, requests=reqs, mode="cortex", cache=cache,
            remote=RemoteDataService(qpm=None, seed=69),
            gpu=GPU(GPUConfig()),
            # open loop: the scan delay lands on request latency instead
            # of being absorbed by closed-loop self-pacing
            cfg=EngineConfig(prefetch=False,
                             t_cache_per_row=t_per_row,
                             t_shard_merge=t_shard_merge, seed=70),
        )
        s = eng.run()
        hits = [r.latency for r in eng.records if r.remote_calls == 0]
        p50 = percentile(hits, 50) if hits else float("nan")
        mean = float(np.mean(hits)) if hits else float("nan")
        return s, p50, mean

    sb, p50_brute, _ = e2e(None, per_row)
    si, p50_ivf, hm_ivf = e2e(e2e_cfg, per_row)
    for name, s, p50 in (("brute", sb, p50_brute), ("ivf", si, p50_ivf)):
        emit(f"stage1_scaling/e2e_{name}@N{n_fill}",
             s["latency_mean"] * 1e6, seed=65,
             hitpath_p50_ms=round(p50 * 1e3, 2),
             lat_ms=round(s["latency_mean"] * 1e3, 1),
             hit=round(s["hit_rate"], 3),
             rows_per_lookup=round(s["rows_per_lookup"], 1),
             cache_s=round(s["cache_time_mean"], 4))
    if not p50_ivf < p50_brute:
        raise SystemExit(
            "stage1 regression: e2e p50 cache-hit latency with IVF "
            f"({p50_ivf:.4f}s) is not below brute force "
            f"({p50_brute:.4f}s) at N={n_fill}"
        )
    # sharded e2e (§13): same IVF config split across 8 shards; stage-1
    # latency becomes max-over-shards + one cross-shard merge, so the
    # hit-path MEAN must drop below the unsharded IVF run even after
    # paying the merge term on every pass. (The mean, not the p50: the
    # per-pass saving is a couple ms against an ~800 ms hit path, and
    # the p50 of this discrete-event queue shifts by more than that
    # from flush-boundary realignment alone — the mean is monotone in
    # the scan savings.)
    t_merge = 1e-4
    sm, p50_shard, hm_shard = e2e(_dc.replace(e2e_cfg, n_shards=8),
                                  per_row, t_shard_merge=t_merge)
    emit(f"stage1_scaling/e2e_sharded@N{n_fill}",
         sm["latency_mean"] * 1e6, seed=65, shards=8,
         nprobe=e2e_cfg.nprobe,
         hitpath_p50_ms=round(p50_shard * 1e3, 2),
         hitpath_mean_ms=round(hm_shard * 1e3, 2),
         ivf_hitpath_mean_ms=round(hm_ivf * 1e3, 2),
         lat_ms=round(sm["latency_mean"] * 1e3, 1),
         hit=round(sm["hit_rate"], 3),
         rows_per_lookup=round(sm["rows_per_lookup"], 1),
         rows_scanned_max_shard=sm["rows_scanned_max_shard"],
         rebalances=sm["shard_rebalances"],
         migrated_rows=sm["shard_migrated_rows"])
    if not hm_shard < hm_ivf:
        raise SystemExit(
            "stage1 regression: sharded e2e mean cache-hit latency "
            f"({hm_shard:.4f}s) is not below the unsharded IVF mean "
            f"({hm_ivf:.4f}s) at N={n_fill} under max-over-shards + "
            f"t_shard_merge={t_merge}"
        )
    # nprobe=all engine run must be bit-identical to brute (the scan
    # instrumentation fields are the one legitimate difference)
    s0, _, _ = e2e(None, 0.0)
    s1, _, _ = e2e(_dc.replace(e2e_cfg, nprobe=None), 0.0)

    def strip(s):
        return {k: v for k, v in s.items()
                if k not in ("rows_scanned", "rows_per_lookup")}

    if _json.dumps(strip(s0), sort_keys=True, default=float) != \
            _json.dumps(strip(s1), sort_keys=True, default=float):
        raise SystemExit(
            "stage1 regression: nprobe=all engine run diverged from the "
            "brute-force run on the same seed"
        )

    # ---- §13 mesh-sharded sweep: contiguous cluster ownership --------
    # Synthetic intent-structured rows (paras_s paraphrases per center,
    # generated vectorized — world.embed row by row would dominate the
    # million-entry build). One full-batch search block: the engine
    # batches stage-1 the same way, and the per-shard scan accounting
    # over the block's probe union is what the balance gate measures.
    shard_n = 65536 if smoke else 1 << 20
    paras_s = 16
    c_s = 256 if smoke else 1024
    nprobe_s = 32 if smoke else 64
    shard_counts = (1, 2, 8)
    nq_s = 64
    sig = 0.12   # SemanticWorld.sigma_para: cos(row, center) ≈ 1/√(1+σ²)
    srng = np.random.default_rng(71)
    centers = srng.standard_normal(
        (shard_n // paras_s, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    nz = srng.standard_normal((shard_n, dim)).astype(np.float32)
    nz /= np.linalg.norm(nz, axis=1, keepdims=True)
    sembs = np.repeat(centers, paras_s, axis=0) + sig * nz
    sembs /= np.linalg.norm(sembs, axis=1, keepdims=True)
    qz = srng.standard_normal((nq_s, dim)).astype(np.float32)
    qz /= np.linalg.norm(qz, axis=1, keepdims=True)
    sqs = centers[srng.integers(0, len(centers), nq_s)] + sig * qz
    sqs /= np.linalg.norm(sqs, axis=1, keepdims=True)
    sids = np.arange(shard_n, dtype=np.int64)

    sbrute = VectorIndex(shard_n, dim)
    sbrute.add_batch(sids, sembs)
    ref = sbrute.search_batch(sqs, k, 0.0)

    centroids0 = None
    shard_res, shard_eff = {}, {}
    for s_cnt in shard_counts:
        scfg = ClusterConfig(
            n_clusters=c_s, nprobe=nprobe_s, seed=72, n_shards=s_cnt,
            refresh_every=max(4096, shard_n // 8),
        )
        idx = VectorIndex(
            shard_n, dim, router=ClusterRouter(shard_n, dim, scfg))
        t0 = _time.perf_counter()
        idx.add_batch(sids, sembs)
        idx.router.refresh(idx)     # settle centroids post-build
        t_build = _time.perf_counter() - t0
        rt = idx.router
        # deterministic seeding: shard count must never touch training
        if centroids0 is None:
            centroids0 = rt.centroids.copy()
        elif not np.array_equal(centroids0, rt.centroids):
            raise SystemExit(
                f"stage1 regression: centroids at S={s_cnt} diverged "
                "from the S=1 build on the same seed (sharding must "
                "not touch training)"
            )
        res = idx.search_batch(sqs, k, 0.0)
        rows_total = idx.last_scanned
        rows_max = idx.last_scanned_max_shard
        eff = rows_total / max(1, s_cnt * rows_max)
        recall = float(np.mean([
            len(set(ib) & set(ii)) / len(ib)
            for (ib, _), (ii, _) in zip(ref, res) if ib
        ]))
        t_search = _best_of(lambda: idx.search_batch(sqs, k, 0.0))
        shard_res[s_cnt] = res
        shard_eff[s_cnt] = eff
        emit(f"stage1_scaling/shard@S{s_cnt}", t_search * 1e6, seed=72,
             shards=s_cnt, nprobe=nprobe_s, n=shard_n,
             recall_at_4=round(recall, 4),
             rows_total=rows_total, rows_max_shard=rows_max,
             balance_eff=round(eff, 3), build_s=round(t_build, 1),
             rebalances=rt.rebalances,
             migrated_rows=rt.migrated_rows,
             migration_chunks=rt.migration_chunks)
        if recall < 0.95:
            raise SystemExit(
                f"stage1 regression: sharded recall@{k} ({recall:.3f}) "
                f"below the 0.95 floor at S={s_cnt}, N={shard_n}"
            )
        if s_cnt == 1:
            # nprobe=all at S=1 must reproduce brute force bit-for-bit
            rt.cfg.nprobe = None
            for (ib, vb), (ia, va) in zip(
                    ref, idx.search_batch(sqs, k, 0.0)):
                if ib != ia or not np.array_equal(vb, va):
                    raise SystemExit(
                        "stage1 regression: sharded nprobe=all at S=1 "
                        f"diverged from brute force at N={shard_n}"
                    )
            rt.cfg.nprobe = nprobe_s
        del idx
    # shard-count invariance: identical ids AND sims — the host sharded
    # path selects over one global score matrix, so the float-reduction
    # tolerance across shard counts is zero by construction
    for s_cnt in shard_counts[1:]:
        for (i0, v0), (i1, v1) in zip(shard_res[1], shard_res[s_cnt]):
            if i0 != i1 or not np.array_equal(v0, v1):
                raise SystemExit(
                    f"stage1 regression: S={s_cnt} search results "
                    "diverged from S=1 (the host sharded path must be "
                    "bit-identical across shard counts)"
                )
    if shard_eff[8] < 0.7:
        raise SystemExit(
            "stage1 regression: balance efficiency at S=8 "
            f"({shard_eff[8]:.3f}) below the 0.7 ideal-scaling floor "
            f"at N={shard_n}"
        )

    # ---- Pallas-backend sharded parity (mesh when ≥ 8 devices) -------
    # shard_map over the device mesh when the platform exposes ≥ 8
    # devices (CI sets XLA_FLAGS=--xla_force_host_platform_device_count
    # =8), the unrolled per-shard loop otherwise — the emitted row says
    # which one actually ran.
    from repro.kernels.ann_topk_sharded import mesh_available

    n_k = 2048
    kargs = dict(n_clusters=32, nprobe=8, seed=73, n_shards=8,
                 refresh_every=1024)
    knp = VectorIndex(
        n_k, dim, router=ClusterRouter(n_k, dim, ClusterConfig(**kargs)))
    kkr = VectorIndex(
        n_k, dim, backend="kernel",
        router=ClusterRouter(n_k, dim, ClusterConfig(**kargs)))
    knp.add_batch(sids[:n_k], sembs[:n_k])
    kkr.add_batch(sids[:n_k], sembs[:n_k])
    mesh_used = mesh_available(8)
    bq = sqs[:b]
    rn = knp.search_batch(bq, k, 0.0)
    t_k = _best_of(lambda: kkr.search_batch(bq, k, 0.0))
    rk = kkr.search_batch(bq, k, 0.0)
    for (inp, _vn), (ik, _vk) in zip(rn, rk):
        if sorted(inp) != sorted(ik) or not np.allclose(
                np.sort(_vn), np.sort(_vk), atol=2e-6):
            raise SystemExit(
                "stage1 regression: Pallas sharded scan diverged from "
                f"the numpy sharded path (mesh_used={mesh_used})"
            )
    if kkr.last_scanned_max_shard != knp.last_scanned_max_shard:
        raise SystemExit(
            "stage1 regression: Pallas sharded max-shard scan count "
            f"({kkr.last_scanned_max_shard}) disagrees with the numpy "
            f"path ({knp.last_scanned_max_shard})"
        )
    emit("stage1_scaling/shard_kernel@S8", t_k * 1e6, seed=73,
         shards=8, nprobe=8, n=n_k, backend="kernel",
         mesh_used=mesh_used,
         rows_max_shard=kkr.last_scanned_max_shard)
    return ratios


def freshness_sweep(smoke: bool = False):
    """Freshness frontier (DESIGN.md §11): churn rate × TTL policy on the
    churn workload against a MutableWorld, charting accuracy vs hit rate.

    Three policies at each churn period (class-1 intents update every
    ``churn`` seconds, class-10 every ``8×churn``):

      * ``ttl``    — staticity TTLs only, no invalidation (the pre-§11
                     cache: stale values serve until they age out);
      * ``inval``  — change-feed invalidation, stale entries dropped;
      * ``refresh``— invalidation + refresh-ahead (hot entries
                     revalidate in place; TTL expiry renews instead of
                     purging).

    ``judge_acc=1.0`` so info_accuracy isolates STALENESS (judge-error
    accuracy is fig13's axis). Gates (CI runs ``--smoke``):
    ``stale_hit_rate(inval) < stale_hit_rate(ttl)``, refresh must hold
    info_accuracy within 2 points of the no-cache baseline while
    keeping steady-state hit rate ABOVE ttl-only, two same-seed refresh
    runs must be bit-identical, and a static-world run must report
    exactly 0 stale hits.
    """
    import json as _json

    seed = 41
    churns = (20.0,) if smoke else (10.0, 20.0, 40.0)
    base = dict(
        workload="churn", mode="cortex", n_requests=500, n_intents=200,
        dim=64, concurrency=8, seed=seed, max_ttl=60.0, qpm=None,
        judge_acc=1.0, prefetch=False, warmup_frac=0.3,
    )
    policies = (
        ("ttl", dict()),
        ("inval", dict(invalidation=True)),
        ("refresh", dict(invalidation=True, refresh_ahead=True)),
    )

    # static-world regression guard: churn off => stale_hits exactly 0
    static = run_once(invalidation=True, refresh_ahead=True, **base)
    emit("freshness/static_guard", 0.0, seed=seed,
         stale_hits=static["stale_hits"], refreshes=static["refreshes"])
    if static["stale_hits"] != 0:
        raise SystemExit(
            "freshness regression: stale_hits must be exactly 0 when "
            f"churn is disabled (got {static['stale_hits']})"
        )

    # one no-cache baseline at the gate cell: vanilla always fetches
    # fresh, so its info_accuracy doesn't depend on the policy grid
    van = run_once(**{**base, "mode": "vanilla", "churn_period": 20.0,
                      "churn_max_period": 160.0})

    results = {}
    for churn in churns:
        ck = dict(base, churn_period=churn, churn_max_period=churn * 8.0)
        for name, pol in policies:
            s = run_once(**ck, **pol)
            results[(churn, name)] = s
            emit(f"freshness/{name}@c{churn:g}", s["latency_mean"] * 1e6,
                 seed=seed,
                 hit_steady=round(s["hit_rate_steady"], 3),
                 stale_rate=round(s["stale_hit_rate"], 3),
                 info_acc=round(s["info_accuracy"], 3),
                 refreshes=s.get("refreshes", 0),
                 invalidations=s["invalidations"],
                 refresh_cost=round(s.get("refresh_cost", 0.0), 3),
                 api_cost=round(s["api_cost"], 3))
        s2 = run_once(**ck, invalidation=True, refresh_ahead=True)
        if _json.dumps(results[(churn, "refresh")], sort_keys=True,
                       default=float) != \
                _json.dumps(s2, sort_keys=True, default=float):
            raise SystemExit(
                "freshness regression: two same-seed refresh runs "
                f"diverged (churn={churn:g}) — summaries must be "
                "bit-identical"
            )

    for churn in churns:
        ttl = results[(churn, "ttl")]
        inval = results[(churn, "inval")]
        refresh = results[(churn, "refresh")]
        emit(f"freshness/frontier@c{churn:g}", 0.0, seed=seed,
             ttl_acc=round(ttl["info_accuracy"], 3),
             refresh_acc=round(refresh["info_accuracy"], 3),
             acc_recovered=round(
                 refresh["info_accuracy"] - ttl["info_accuracy"], 3
             ),
             hit_delta=round(
                 refresh["hit_rate_steady"] - ttl["hit_rate_steady"], 3
             ))
        if inval["stale_hit_rate"] >= ttl["stale_hit_rate"]:
            raise SystemExit(
                "freshness regression: invalidation must cut the stale-"
                f"hit rate below the no-invalidation baseline (churn="
                f"{churn:g}: {inval['stale_hit_rate']:.3f} vs "
                f"{ttl['stale_hit_rate']:.3f})"
            )
    # frontier gate on the reference cell (churn=20): refresh-ahead must
    # recover accuracy to within 2 points of no-cache WITHOUT giving up
    # the hit rate the ttl-only policy only achieves by serving stale
    ttl = results[(20.0, "ttl")]
    refresh = results[(20.0, "refresh")]
    if refresh["info_accuracy"] < van["info_accuracy"] - 0.02:
        raise SystemExit(
            "freshness regression: invalidation+refresh info_accuracy "
            f"({refresh['info_accuracy']:.3f}) fell more than 2 points "
            f"below the no-cache baseline ({van['info_accuracy']:.3f})"
        )
    if refresh["hit_rate_steady"] <= ttl["hit_rate_steady"]:
        raise SystemExit(
            "freshness regression: invalidation+refresh steady-state hit "
            f"rate ({refresh['hit_rate_steady']:.3f}) must exceed "
            f"ttl-only ({ttl['hit_rate_steady']:.3f})"
        )
    return results


def recalibration_overhead():
    """§6.6: periodic threshold recalibration cost + drift adaptation."""
    base = run_ds("hotpotqa", "cortex", cache_ratio=0.5, concurrency=8)
    recal = run_ds("hotpotqa", "cortex", cache_ratio=0.5, concurrency=8,
                   recalibrate_every=30.0)
    drop = 1 - recal["throughput_rps"] / base["throughput_rps"]
    emit("recal/overhead", 0.0,
         base_thpt=round(base["throughput_rps"], 3),
         recal_thpt=round(recal["throughput_rps"], 3),
         thpt_drop=round(drop, 4),
         em_base=round(base["em"], 3), em_recal=round(recal["em"], 3))


def judge_colocation(smoke=False):
    """§14 / paper Fig 6: throughput-vs-judge-accuracy frontier for the
    co-located JudgePipeline at matched GPU budget.

    Five gates (SystemExit on regression):
      1. width-0 admission band reproduces the judge-everything engine
         event-for-event (bit-identical summary at the same seed);
      2. an armed band strictly reduces judge calls at equal-or-better
         info accuracy;
      3. co-located serving (1 chip, shared lanes) sustains >= the
         throughput of a dedicated judge chip at matched total budget
         (2 x half-capacity chips);
      4. judge token cost derives from the judge model config: growing
         d_model 128 -> 256 doubles the FLOPs-derived base cost and
         strictly increases measured judge-lane token load;
      5. the calibration shim is virtual-time neutral: running the real
         tiny-LM compute path yields a summary bit-identical to the
         oracle-compute path (model-faithful compute, ground-truth-
         faithful decisions).
    """
    import json

    from repro.core.judge_pipeline import default_judge_cfg, judge_token_cost

    n = 300 if smoke else 800
    base = dict(
        workload="zipf", mode="cortex", n_requests=n,
        n_intents=2 * n, cache_ratio=0.6, concurrency=12,
        qpm=None, prefetch=False, seed=17,
    )

    def canon(s):
        return json.dumps(s, sort_keys=True, default=float)

    # --- gate 1: width-0 band == no band, event for event -------------
    s_none = run_once(**base)                    # band machinery absent
    s_zero = run_once(**base, judge_band=0.0)    # band armed but degenerate
    if canon(s_none) != canon(s_zero):
        raise SystemExit("judge_colocation: width-0 band is not "
                         "bit-identical to the judge-everything engine")

    # --- gate 2: armed band cuts judge calls, keeps accuracy ----------
    s_band = run_once(**base, judge_band=0.1)
    if not (s_band["judge_calls"] < s_none["judge_calls"]):
        raise SystemExit(
            f"judge_colocation: band did not reduce judge calls "
            f"({s_band['judge_calls']} vs {s_none['judge_calls']})")
    if s_band["info_accuracy"] + 1e-9 < s_none["info_accuracy"]:
        raise SystemExit(
            f"judge_colocation: band hurt info accuracy "
            f"({s_band['info_accuracy']:.4f} < {s_none['info_accuracy']:.4f})")

    # --- gate 3: co-located >= dedicated at matched GPU budget --------
    # co-located: one 3000-token chip shared by agent+judge lanes;
    # dedicated: agent chip + judge chip of 1500 tokens each (same total).
    s_dedic = run_once(**base, judge_band=0.1,
                       colocated=False, gpu_capacity=1500.0)
    if s_band["throughput_rps"] + 1e-9 < s_dedic["throughput_rps"]:
        raise SystemExit(
            f"judge_colocation: co-located throughput "
            f"{s_band['throughput_rps']:.3f} < dedicated-matched "
            f"{s_dedic['throughput_rps']:.3f}")

    # --- gate 4: token cost derives from the judge model config ------
    c128 = judge_token_cost(default_judge_cfg(d_model=128))
    c256 = judge_token_cost(default_judge_cfg(d_model=256))
    if not (c256 > c128 > 0):
        raise SystemExit("judge_colocation: FLOPs-derived token cost is "
                         "not monotone in d_model")
    s_big = run_once(**base, judge_band=0.1, judge_d_model=256)
    if not (s_big["judge_tokens_base"]
            > s_band["judge_tokens_base"]):
        raise SystemExit("judge_colocation: engine judge cost did not "
                         "track judge d_model")
    if not (s_big["judge_lane_tokens"]
            > s_band["judge_lane_tokens"]):
        raise SystemExit("judge_colocation: judge-lane load did not "
                         "grow with the larger judge model")

    # --- gate 5: real tiny-LM compute is virtual-time neutral --------
    # Smoke keeps the LM small; the full run pays the default config.
    dm = 64 if smoke else 128
    s_lm = run_once(**base, judge_band=0.1, judge_d_model=dm,
                    judge_compute="model")
    s_ref = run_once(**base, judge_band=0.1, judge_d_model=dm)
    if canon(s_lm) != canon(s_ref):
        raise SystemExit("judge_colocation: model-compute summary "
                         "diverges from oracle-compute summary")

    rows = [
        ("judge/everything", s_none, None, "oracle+flops:d128"),
        ("judge/band0", s_zero, 0.0, "oracle+flops:d128"),
        ("judge/band", s_band, 0.1, "oracle+flops:d128"),
        ("judge/dedicated", s_dedic, 0.1, "oracle+flops:d128"),
        ("judge/d256", s_big, 0.1, "oracle+flops:d256"),
        ("judge/lm-compute", s_lm, 0.1, f"model+flops:d{dm}"),
    ]
    for name, s, band, jm in rows:
        emit(name, s["latency_mean"] * 1e6, seed=base["seed"],
             judge_model=jm, band=band,
             thpt=round(s["throughput_rps"], 3),
             hit=round(s["hit_rate"], 3),
             judge_calls=s["judge_calls"],
             info_acc=round(s["info_accuracy"], 4),
             jtok_base=round(s["judge_tokens_base"], 2),
             jtok_lane=round(s["judge_lane_tokens"], 1),
             bypass=s.get("band_bypass_hits", 0))


def obs_trace(smoke: bool = False):
    """§15 observability gate: traced engine + federation runs.

    Four properties, each a hard gate (SystemExit on violation):
      1. conservation — every request's span segments tile [arrival,
         t_done] with exact float equality at every boundary, so the
         telescoped total == rec.latency bit-for-bit, on both a tiered
         banded engine run and a 3-region peered federation run;
      2. neutrality — the traced engine run's summary is byte-identical
         to the untraced run (tracing must not perturb virtual time);
      3. determinism — same seed => byte-identical span JSONL artifact;
      4. artifacts — the emitted rows carry ``trace_path``, so a CI
         `--json --trace .` invocation leaves Perfetto-loadable TRACE_*
         files next to the BENCH_*.json it uploads.

    The benchmark is already CI-sized; ``smoke`` only halves the request
    counts.
    """
    import json
    import os
    import tempfile

    from benchmarks import common
    from repro.data.workloads import region_workloads
    from repro.data.world import SemanticWorld
    from repro.obs.analyze import attribution, check_conservation
    from repro.obs.export import export_trace
    from repro.obs.trace import Tracer
    from repro.serving.federation import FederationRunner

    out_dir = common.TRACE_DIR or tempfile.mkdtemp(prefix="obs_trace_")
    n = 80 if smoke else 150
    kw = dict(n_requests=n, concurrency=4, warm_frac=0.5,
              workload="longtail", tail_len=40, judge_band=0.1, seed=3)

    def canon(s):
        return json.dumps(s, sort_keys=True, default=float)

    # --- gates 1-3 on the engine: run_once(trace=...) itself raises on
    # conservation violations, so finishing at all is gate 1 -----------
    s_plain = run_once(**kw)
    s1 = run_once(trace=os.path.join(out_dir, "TRACE_engine"), **kw)
    s2 = run_once(trace=os.path.join(out_dir, "TRACE_engine_rerun"), **kw)
    trace_keys = ("trace_jsonl", "trace_chrome", "trace_spans",
                  "trace_conservation_violations")
    if canon({k: v for k, v in s1.items() if k not in trace_keys}) \
            != canon(s_plain):
        raise SystemExit("obs_trace: traced summary diverges from the "
                         "untraced run — tracing is not event-neutral")
    with open(s1["trace_jsonl"], "rb") as f1, \
            open(s2["trace_jsonl"], "rb") as f2:
        if f1.read() != f2.read():
            raise SystemExit("obs_trace: same-seed runs produced "
                             "different span JSONL")
    emit("obs_trace/engine", s1["latency_mean"] * 1e6, seed=kw["seed"],
         band=kw["judge_band"], trace_path=s1["trace_jsonl"],
         spans=s1["trace_spans"], violations=0,
         lat_ms=round(s1["latency_mean"] * 1e3, 1),
         hit=round(s1["hit_rate"], 3))

    # --- gate 1 on federation: one Tracer shared by three regions -----
    world = SemanticWorld(n_intents=300, dim=64, seed=5)
    reqs = region_workloads(world, n_regions=3,
                            n_per_region=(40 if smoke else 80), seed=6)
    tracer = Tracer()
    fr = FederationRunner(world=world, region_requests=reqs,
                          topology="peered", seed=7, tracer=tracer)
    s_fed = fr.run()
    recs = fr.records_by_region()
    violations = check_conservation(tracer, recs)
    if violations:
        raise SystemExit(
            "obs_trace: federation conservation violations:\n  "
            + "\n  ".join(violations[:10]))
    paths = export_trace(tracer, os.path.join(out_dir, "TRACE_federation"))
    report = attribution(tracer, recs)
    fed = report.get("federated", {})
    emit("obs_trace/federation",
         s_fed["aggregate"]["latency_p50"] * 1e6, seed=7,
         trace_path=paths["jsonl"], spans=len(tracer.spans), violations=0,
         fed_requests=fed.get("n", 0),
         fed_p99_ms=round(fed.get("latency_p99", float("nan")) * 1e3, 1),
         hit=round(s_fed["aggregate"]["hit_rate"], 3))


def obs_timeseries(smoke: bool = False):
    """§16 continuous-telemetry gate: sampler + SLO monitor end to end.

    Scenario: the same 400-request trending workload run open-loop twice
    — once at its natural 600 s spread (steady) and once compressed into
    70 s (a flash crowd at ~8.6x QPS, the --trend-duration knob).  A
    windowed-p99 SLO (5 s) watches both through the 5 s-interval
    sampler.  Six hard gates (SystemExit on violation):

      1. neutrality — the sampled steady run's summary, minus the
         telemetry-only keys, is byte-identical to the unsampled run
         (sampling must not perturb virtual time);
      2. steady is clean — zero breach/recovery alerts at natural QPS,
         and the alerts JSONL artifact is empty;
      3. the monitor catches the burst — the compressed run must raise
         a breach, and a later recovery once the first wave's queue
         drains (the committed profile: breach@30s, recovery@50s,
         re-breach@60s as the next wave lands);
      4. alert ordering — alerts are virtual-time-sorted, the first is
         a breach, and breach/recovery strictly alternate (hysteresis
         can't emit two of the same state in a row);
      5. reconciliation — per-window integer deltas in the timeseries
         JSONL telescope exactly: sum over windows == final cumulative
         row == the engine summary's end-of-run totals, for every
         counter (n/api_calls/judge_calls/rows_scanned/stale_hits);
      6. determinism — same seed => byte-identical timeseries AND
         alerts JSONL artifacts.

    Artifacts (TS_*.timeseries.jsonl / TS_*.alerts.jsonl) land in the
    --trace directory when set, next to the TRACE_*/BENCH_* files CI
    uploads.  Already CI-sized; ``smoke`` changes nothing.
    """
    import json
    import os
    import tempfile

    from benchmarks import common

    out_dir = common.TRACE_DIR or tempfile.mkdtemp(prefix="obs_ts_")
    base = dict(workload="trend", n_requests=400, n_intents=300, dim=64,
                concurrency=None, qpm=400.0, seed=9)
    slo = ["p99:window.latency_p99:<=:5.0"]
    interval = 5.0
    tele_keys = ("timeseries_samples", "slo_breaches", "slo_recoveries",
                 "timeseries_path", "alerts_path")

    def canon(s):
        return json.dumps(s, sort_keys=True, default=float)

    def read_jsonl(path):
        with open(path) as f:
            return [json.loads(line) for line in f]

    # --- gates 1-2: steady run, sampled vs unsampled ------------------
    s_plain = run_once(**base)
    s_steady = run_once(sample_interval=interval, slo=slo,
                        timeseries=os.path.join(out_dir, "TS_steady"),
                        **base)
    if canon({k: v for k, v in s_steady.items() if k not in tele_keys}) \
            != canon(s_plain):
        raise SystemExit("obs_timeseries: sampled summary diverges from "
                         "the unsampled run — sampling is not "
                         "observationally neutral")
    if s_steady["slo_breaches"] or s_steady["slo_recoveries"]:
        raise SystemExit(
            "obs_timeseries: steady run raised alerts "
            f"({s_steady['slo_breaches']} breaches) — the SLO bound is "
            "mis-tuned or latency regressed at natural QPS")
    if os.path.getsize(s_steady["alerts_path"]) != 0:
        raise SystemExit("obs_timeseries: steady alerts artifact is "
                         "non-empty despite zero alerts")

    # --- gates 3-4: burst run must breach, then recover ---------------
    s_b1 = run_once(sample_interval=interval, slo=slo, trend_duration=70.0,
                    timeseries=os.path.join(out_dir, "TS_burst"), **base)
    alerts = read_jsonl(s_b1["alerts_path"])
    if s_b1["slo_breaches"] < 1 or s_b1["slo_recoveries"] < 1:
        raise SystemExit(
            "obs_timeseries: burst run must show breach AND recovery "
            f"(got {s_b1['slo_breaches']} breaches, "
            f"{s_b1['slo_recoveries']} recoveries)")
    if alerts[0]["event"] != "breach":
        raise SystemExit("obs_timeseries: first alert must be a breach, "
                         f"got {alerts[0]['event']!r}")
    for prev, cur in zip(alerts, alerts[1:]):
        if cur["t"] <= prev["t"]:
            raise SystemExit("obs_timeseries: alerts not strictly "
                             "ordered in virtual time")
        if cur["event"] == prev["event"]:
            raise SystemExit("obs_timeseries: consecutive "
                             f"{cur['event']!r} alerts — hysteresis "
                             "must alternate breach/recovery")

    # --- gate 5: windowed deltas telescope to end-of-run totals -------
    rows = read_jsonl(s_b1["timeseries_path"])
    cum = rows[-1]["cum"]
    for key, total in cum.items():
        win_sum = sum(r["window"].get(key, 0) or 0 for r in rows)
        if win_sum != total:
            raise SystemExit(
                f"obs_timeseries: window deltas for {key!r} sum to "
                f"{win_sum}, final cumulative row says {total} — "
                "windows must tile the run exactly")
    for cum_key, sum_key in (("n_done", "n"), ("api_calls", "api_calls"),
                             ("judge_calls", "judge_calls"),
                             ("rows_scanned", "rows_scanned"),
                             ("stale_hits", "stale_hits")):
        if cum[cum_key] != s_b1[sum_key]:
            raise SystemExit(
                f"obs_timeseries: cumulative {cum_key}={cum[cum_key]} "
                f"!= summary {sum_key}={s_b1[sum_key]}")

    # --- gate 6: same seed => byte-identical artifacts ----------------
    s_b2 = run_once(sample_interval=interval, slo=slo, trend_duration=70.0,
                    timeseries=os.path.join(out_dir, "TS_burst_rerun"),
                    **base)
    for k in ("timeseries_path", "alerts_path"):
        with open(s_b1[k], "rb") as f1, open(s_b2[k], "rb") as f2:
            if f1.read() != f2.read():
                raise SystemExit("obs_timeseries: same-seed runs "
                                 f"produced different {k} artifacts")

    win_p99 = [r["window"]["latency_p99"] for r in rows
               if r["window"]["latency_p99"] is not None]
    emit("obs_timeseries/steady", s_steady["latency_mean"] * 1e6,
         seed=base["seed"], trace_path=s_steady["timeseries_path"],
         samples=s_steady["timeseries_samples"], breaches=0, recoveries=0,
         lat_ms=round(s_steady["latency_mean"] * 1e3, 1),
         p99_ms=round(s_steady["latency_p99"] * 1e3, 1),
         hit=round(s_steady["hit_rate"], 3),
         api=s_steady["api_calls"])
    emit("obs_timeseries/burst", s_b1["latency_mean"] * 1e6,
         seed=base["seed"], trace_path=s_b1["timeseries_path"],
         samples=s_b1["timeseries_samples"],
         breaches=s_b1["slo_breaches"], recoveries=s_b1["slo_recoveries"],
         first_breach_t=alerts[0]["t"],
         max_win_p99_ms=round(max(win_p99) * 1e3, 1),
         lat_ms=round(s_b1["latency_mean"] * 1e3, 1),
         hit=round(s_b1["hit_rate"], 3),
         api=s_b1["api_calls"])


def overload(smoke: bool = False):
    """§17 robustness gate: fault injection + overload control end to end.

    Three legs, each with hard gates (SystemExit on violation):

    1. **neutrality** — a run with the controller armed but ``off`` must
       match the controller-free run byte-for-byte once the (all-zero)
       ``overload`` counter block is stripped; an off controller that
       actuates anything is a §17 contract violation.
    2. **flash crowd** — the 400-request trend workload compressed into
       12 s (~50x natural QPS) behind a 5 s windowed-p99 SLO, controller
       off vs on.  The controller must strictly reduce the number of
       SLO-violating sample windows AND the worst windowed p99, while
       holding hit rate >= the uncontrolled run and info-accuracy >= the
       no-cache floor minus 0.02 (sheds only widen the trust edge past
       tau + margin, so quality must survive).
    3. **region outage** — three peered regions, region 1 dark over
       t in [20,45) virtual seconds, peeks armed with a 0.25 s deadline
       and a K=3 circuit breaker.  The run must complete every request
       with zero hung peeks, and the breaker must both open and re-close
       (trace markers ``circuit_open`` / ``circuit_close``), proving the
       half-open probe path re-admits the region after the window.

    Timeseries artifacts (TS_overload_*.jsonl) land in --trace for CI
    upload.  Already CI-sized; ``smoke`` changes nothing.
    """
    import json
    import os
    import tempfile

    from benchmarks import common
    from repro.data.workloads import region_workloads
    from repro.data.world import SemanticWorld
    from repro.obs.trace import Tracer
    from repro.serving.federation import FederationRunner

    out_dir = common.TRACE_DIR or tempfile.mkdtemp(prefix="overload_")
    base = dict(workload="trend", n_requests=400, n_intents=300, dim=64,
                concurrency=None, qpm=400.0, seed=9)
    slo_bound = 5.0
    slo = [f"p99:window.latency_p99:<=:{slo_bound}"]

    def canon(s):
        return json.dumps(s, sort_keys=True, default=float)

    def window_stats(path):
        """(violating-window count, worst windowed p99) from a §16
        timeseries artifact; empty windows carry p99=None and don't
        count either way."""
        with open(path) as f:
            p99s = [json.loads(line)["window"].get("latency_p99")
                    for line in f]
        vals = [p for p in p99s if p is not None]
        return sum(1 for p in vals if p > slo_bound), max(vals, default=0.0)

    # --- leg 1: armed-but-off controller is byte-neutral --------------
    s_plain = run_once(**base)
    s_off0 = run_once(overload="off", **base)
    if any(s_off0["overload"].values()):
        raise SystemExit("overload: off controller actuated "
                         f"({s_off0['overload']}) — every policy must "
                         "be inert behind the off-switch")
    if canon({k: v for k, v in s_off0.items() if k != "overload"}) \
            != canon(s_plain):
        raise SystemExit("overload: armed-but-off run diverges from the "
                         "controller-free run — §17 neutrality broken")

    # --- leg 2: 50x flash crowd, controller off vs on -----------------
    burst = dict(base, trend_duration=12.0, sample_interval=5.0, slo=slo)
    s_off = run_once(overload="off",
                     timeseries=os.path.join(out_dir, "TS_overload_off"),
                     **burst)
    s_on = run_once(overload="on",
                    timeseries=os.path.join(out_dir, "TS_overload_on"),
                    **burst)
    bw_off, max_off = window_stats(s_off["timeseries_path"])
    bw_on, max_on = window_stats(s_on["timeseries_path"])
    if bw_on >= bw_off:
        raise SystemExit(
            "overload: controller-on run must violate the SLO in "
            f"strictly fewer windows (on={bw_on} vs off={bw_off})")
    if max_on >= max_off:
        raise SystemExit(
            "overload: controller-on worst windowed p99 must improve "
            f"(on={max_on:.1f}s vs off={max_off:.1f}s)")
    if s_on["hit_rate"] < s_off["hit_rate"]:
        raise SystemExit(
            "overload: shedding must not cost hit rate "
            f"(on={s_on['hit_rate']:.3f} vs off={s_off['hit_rate']:.3f})")
    if s_on["info_accuracy"] < 0.98:
        raise SystemExit(
            "overload: controller-on info-accuracy "
            f"{s_on['info_accuracy']:.3f} below the no-cache floor - "
            "0.02 — shed eligibility is admitting bad matches")
    if s_on["overload"]["shed_hits"] == 0:
        raise SystemExit("overload: burst run never shed — the "
                         "controller is not reacting to the crowd")

    # --- leg 3: region outage with peek deadline + circuit breaker ----
    world = SemanticWorld(n_intents=200, dim=64, seed=3)
    reqs = region_workloads(world, 150, 3, overlap=0.5, seed=4)
    tracer = Tracer()
    fr = FederationRunner(
        world=world, region_requests=reqs, topology="peered",
        tracer=tracer, sample_interval=5.0,
        faults=["region_outage:20:45:region=1"],
        peek_timeout=0.25, breaker_k=3, breaker_cooldown=5.0, seed=0)
    agg = fr.run()["aggregate"]
    n_sent = sum(len(r) for r in reqs)
    if agg["n"] != n_sent:
        raise SystemExit(
            f"overload: outage run completed {agg['n']}/{n_sent} "
            "requests — the outage wedged the federation")
    if agg["hung_peeks"] != 0:
        raise SystemExit(
            f"overload: {agg['hung_peeks']} peeks still in flight after "
            "drain — a timeout or response leaked its inflight slot")
    marks = {s[1] for s in tracer.spans}
    for needed in ("circuit_open", "circuit_close"):
        if needed not in marks:
            raise SystemExit(
                f"overload: no {needed!r} marker in the trace — the "
                "breaker lifecycle did not complete "
                f"(opens={agg['breaker_opens']}, "
                f"closes={agg['breaker_closes']})")
    if agg["peek_timeouts"] == 0:
        raise SystemExit("overload: outage run recorded zero peek "
                         "timeouts — the fault windows never bit")

    emit("overload/burst_off", s_off["latency_mean"] * 1e6,
         seed=base["seed"], trace_path=s_off["timeseries_path"],
         breach_windows=bw_off, max_win_p99_s=round(max_off, 2),
         lat_ms=round(s_off["latency_mean"] * 1e3, 1),
         hit=round(s_off["hit_rate"], 3),
         info_acc=round(s_off["info_accuracy"], 3), sheds=0)
    emit("overload/burst_on", s_on["latency_mean"] * 1e6,
         seed=base["seed"], trace_path=s_on["timeseries_path"],
         breach_windows=bw_on, max_win_p99_s=round(max_on, 2),
         lat_ms=round(s_on["latency_mean"] * 1e3, 1),
         hit=round(s_on["hit_rate"], 3),
         info_acc=round(s_on["info_accuracy"], 3),
         sheds=s_on["overload"]["shed_hits"])
    emit("overload/outage", agg["latency_p50"] * 1e6, seed=0,
         n=agg["n"], hung_peeks=agg["hung_peeks"],
         peek_timeouts=agg["peek_timeouts"],
         breaker_opens=agg["breaker_opens"],
         breaker_closes=agg["breaker_closes"],
         fetch_failed=agg["fetch_failed"],
         p99_ms=round(agg["latency_p99"] * 1e3, 1),
         hit=round(agg["hit_rate"], 3))
