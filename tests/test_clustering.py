"""Clustered (IVF-style) stage-1 index — DESIGN.md §12.

Covers the ISSUE 5 test checklist: nprobe=all bit-parity with brute
force (fp32 and int8, numpy and Pallas backends), the recall floor at
nprobe < nclusters, centroid-refresh/free-list invariants under
insert/evict/demote/promote churn, scalar-vs-batch equivalence, the
``topk_desc_stable`` tie-parity contract, and the engine's
scan-proportional stage-1 latency model.
"""
import json

import numpy as np
import pytest

from repro.core.clustering import ClusterConfig, ClusterRouter
from repro.core.seri import VectorIndex, topk_desc_stable
from repro.core.tiers import QuantIndex


def _clustered_embs(n, dim, seed=0, paras=8):
    """Intent-structured rows (tight paraphrase clusters), like the
    production distribution: n//paras centers × paras paraphrases."""
    from repro.data.world import SemanticWorld

    n_int = max(n // paras, 1)
    world = SemanticWorld(n_intents=n_int, dim=dim, seed=seed)
    return world, np.stack([
        world.embed(world.query((i // paras) % n_int, i % paras))
        for i in range(n)
    ])


def _build(cls, n, dim, embs, cfg, backend="numpy"):
    router = ClusterRouter(n + 32, dim, cfg) if cfg else None
    ix = cls(n + 32, dim, backend=backend, router=router)
    for i in range(n):
        ix.add(i, embs[i])
    return ix


CFG_ALL = dict(n_clusters=16, nprobe=None, min_train=64, seed=3)
CFG_SUB = dict(n_clusters=16, nprobe=4, min_train=64, seed=3)


@pytest.mark.parametrize("cls", [VectorIndex, QuantIndex])
def test_nprobe_all_bit_parity_numpy(cls, rng):
    """Probing every cluster scans exactly the active row set in brute
    scan order → ids AND sims bit-identical to the un-routed index."""
    n, dim, k = 600, 32, 4
    _, embs = _clustered_embs(n, dim, seed=1)
    brute = _build(cls, n, dim, embs, None)
    ivf = _build(cls, n, dim, embs, ClusterConfig(**CFG_ALL))
    assert ivf.router.ready
    q = embs[rng.integers(0, n, 16)] + 0.03 * rng.standard_normal(
        (16, dim)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    for (ids_b, sims_b), (ids_a, sims_a) in zip(
        brute.search_batch(q, k, 0.5), ivf.search_batch(q, k, 0.5)
    ):
        assert ids_b == ids_a
        assert np.array_equal(sims_b, sims_a)


@pytest.mark.parametrize("cls", [VectorIndex, QuantIndex])
def test_routed_kernel_matches_numpy(cls, rng):
    """The scalar-prefetch Pallas routed scan (interpret mode) agrees
    with the numpy routed path — candidates and sims."""
    n, dim, k = 500, 32, 4
    _, embs = _clustered_embs(n, dim, seed=2)
    np_ix = _build(cls, n, dim, embs, ClusterConfig(**CFG_SUB))
    kr_ix = _build(cls, n, dim, embs, ClusterConfig(**CFG_SUB),
                   backend="kernel")
    q = embs[rng.integers(0, n, 8)] + 0.03 * rng.standard_normal(
        (8, dim)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    for (ids_n, sims_n), (ids_k, sims_k) in zip(
        np_ix.search_batch(q, k, 0.0), kr_ix.search_batch(q, k, 0.0)
    ):
        assert ids_n == ids_k
        np.testing.assert_allclose(sims_n, sims_k, atol=2e-6)


def test_recall_floor_at_subset_probe():
    """nprobe < nclusters keeps recall@4 ≥ 0.95 on intent-structured
    data (paraphrase clusters land whole in one bucket)."""
    n, dim, k = 800, 32, 4
    world, embs = _clustered_embs(n, dim, seed=4)
    brute = _build(VectorIndex, n, dim, embs, None)
    ivf = _build(VectorIndex, n, dim, embs, ClusterConfig(**CFG_SUB))
    rng = np.random.default_rng(5)
    recalls = []
    for iid in rng.integers(0, n // 8, 64):
        q = world.embed(world.query(int(iid), 99))
        ids_b, _ = brute.search(q, k, 0.0)
        ids_i, _ = ivf.search(q, k, 0.0)
        if ids_b:
            recalls.append(len(set(ids_b) & set(ids_i)) / len(ids_b))
    assert np.mean(recalls) >= 0.95
    # and the routed scan really is sublinear
    assert ivf.last_scanned < brute.last_scanned / 2


@pytest.mark.parametrize("cls", [VectorIndex, QuantIndex])
def test_scalar_equals_batch_routed(cls, rng):
    """search == search_batch row under routing: identical candidates;
    sims to fp ulp (the BLAS B=1/B>1 kernel split, same bar as the
    brute path's decision-level scalar/batch equivalence)."""
    n, dim, k = 400, 32, 4
    _, embs = _clustered_embs(n, dim, seed=6)
    ivf = _build(cls, n, dim, embs, ClusterConfig(**CFG_SUB))
    q = embs[rng.integers(0, n, 8)] + 0.03 * rng.standard_normal(
        (8, dim)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    batched = ivf.search_batch(q, k, 0.0)
    for i in range(8):
        ids_s, sims_s = ivf.search(q[i], k, 0.0)
        assert ids_s == batched[i][0]
        np.testing.assert_allclose(sims_s, batched[i][1], atol=2e-6)


def _router_invariants(ix):
    """The free-list composition contract: assignments partition exactly
    the active rows, counts match, members() is consistent."""
    rt = ix.router
    active = np.flatnonzero(ix.active)
    assigned = np.flatnonzero(rt.assign >= 0)
    assert np.array_equal(active, assigned)
    counts = np.bincount(rt.assign[active], minlength=rt.cfg.n_clusters)
    assert np.array_equal(counts, rt.counts)
    mem = rt.members()
    flat = np.sort(np.concatenate([m for m in mem if len(m)])) \
        if any(len(m) for m in mem) else np.zeros(0, np.int64)
    assert np.array_equal(flat, active)
    for c, m in enumerate(mem):
        assert np.all(rt.assign[m] == c)


def test_refresh_invariants_under_churn(rng):
    """Insert/remove churn across several refresh cycles keeps the
    router's buckets exactly aligned with the index free list."""
    n, dim = 300, 16
    _, embs = _clustered_embs(n, dim, seed=7)
    cfg = ClusterConfig(n_clusters=8, nprobe=3, min_train=32,
                        refresh_every=64, seed=8)
    ix = VectorIndex(n, dim, router=ClusterRouter(n, dim, cfg))
    live = []
    nxt = 0
    for step in range(900):
        if live and (ix.full or rng.random() < 0.35):
            kill = rng.choice(len(live), size=min(2, len(live)),
                              replace=False)
            rows = [live[i][1] for i in kill]
            ix.remove_rows(rows)
            live = [e for j, e in enumerate(live) if j not in set(kill)]
        else:
            row = ix.add(nxt, embs[nxt % n])
            live.append((nxt, row))
            nxt += 1
        if step % 137 == 0 and ix.router.ready:
            _router_invariants(ix)
    assert ix.router.refreshes >= 2
    _router_invariants(ix)
    # retrieval still works and reports plausible scan volumes
    out = ix.search_batch(embs[:4], 4, 0.0)
    assert len(out) == 4
    assert 0 < ix.last_scanned <= len(ix) + cfg.n_clusters


def test_tiered_lifecycle_with_clustered_tiers():
    """Demote/promote churn through a TieredCache with routers on BOTH
    tiers: every embedding lands in the right tier's buckets, and both
    routers keep their free-list invariants."""
    from repro.core.judge import OracleJudge
    from repro.core.tiers import make_tiered_cache
    from repro.data.world import SemanticWorld

    world = SemanticWorld(n_intents=120, dim=32, seed=9)
    judge = OracleJudge(world, accuracy=1.0, seed=10)
    cfg = ClusterConfig(n_clusters=8, nprobe=None, min_train=24,
                        refresh_every=48, seed=11)
    cache = make_tiered_cache(
        hot_bytes=4000, warm_bytes=4000, dim=32, judge=judge,
        index_capacity=512, cluster=cfg, tau_sim=0.85,
    )
    now = 0.0
    rng = np.random.default_rng(12)
    for i in range(400):
        iid = int(rng.integers(0, 120))
        q = world.query(iid, int(rng.integers(0, 4)))
        emb = world.embed(q)
        res = cache.lookup(q, emb, now)
        if not res.hit:
            cache.insert(q, emb, world.answer(q), now=now, cost=0.01,
                         latency=0.2, size=int(world.value_size(q)),
                         staticity=world.staticity(q),
                         intent=iid)
        now += 0.25
    ts = cache.tier_stats
    assert ts.demotions > 0 and ts.promotions > 0
    for ix in (cache.seri.index, cache.warm.index):
        if ix.router.ready:
            _router_invariants(ix)
    # warm consults under routing report their scan volume
    assert cache.rows_scanned > 0


def test_topk_desc_stable_exact_parity(rng):
    """argpartition-based selection == np.argsort(-v, 'stable')[:k],
    including engineered tie groups split by the partition boundary."""
    for trial in range(50):
        m = int(rng.integers(1, 40))
        k = int(rng.integers(1, m + 1))
        if trial % 2:
            # heavy ties: values drawn from a tiny alphabet
            v = rng.choice(
                np.array([0.1, 0.5, 0.5, 0.9], np.float32), size=m
            ).astype(np.float32)
        else:
            v = rng.standard_normal(m).astype(np.float32)
        want = np.argsort(-v, kind="stable")[:k]
        got = topk_desc_stable(v, k)
        assert np.array_equal(want, got), (v, k)
    assert topk_desc_stable(np.zeros(5, np.float32), 0).size == 0


def test_row_se_is_int64_gather(rng):
    """row→se_id resolution is a vectorized int64 array (-1 = free), not
    a per-candidate Python list walk."""
    ix = VectorIndex(8, 4)
    assert ix.row_se.dtype == np.int64
    r = ix.add(99, np.ones(4, np.float32) / 2.0)
    assert ix.row_se[r] == 99
    ix.remove_rows([r])
    assert ix.row_se[r] == -1


def test_engine_scan_proportional_latency():
    """t_cache_per_row > 0 charges stage-1 time per scanned row: the
    same run is strictly slower on the cache path than the flat model,
    deterministic across repeats, and the IVF router reduces both the
    scanned rows and the end-to-end cache time."""
    from repro.launch.serve import run_once

    kw = dict(workload="zipf", mode="cortex", n_requests=120,
              n_intents=200, dim=32, concurrency=4, seed=13,
              cache_ratio=0.9)
    flat = run_once(**kw)
    slow = run_once(t_cache_per_row=1e-4, **kw)
    slow2 = run_once(t_cache_per_row=1e-4, **kw)
    assert json.dumps(slow, sort_keys=True, default=float) == \
        json.dumps(slow2, sort_keys=True, default=float)
    assert slow["cache_time_mean"] > flat["cache_time_mean"]
    # NOTE: scan volume is pass-granularity dependent, and the slower
    # latency model re-times the passes — counts are close, not equal
    assert slow["rows_scanned"] > 0 and flat["rows_scanned"] > 0
    routed = run_once(t_cache_per_row=1e-4, cluster=True, n_clusters=16,
                      nprobe=4, **kw)
    # compare scan volume against the run under the SAME latency model
    # (`slow`), not `flat`: pass granularity — and therefore rows per
    # pass — depends on event timing, so flat's count is not a routing
    # baseline. Only when routing actually cut the scan does the
    # cache-time win follow.
    if routed["rows_scanned"] < slow["rows_scanned"]:
        assert routed["cache_time_mean"] < slow["cache_time_mean"]


def test_engine_nprobe_all_bit_identical_to_brute():
    """An engine run with cluster routing at nprobe=all is bit-identical
    to the brute-force run on the same seed (the scan-volume
    instrumentation is the one legitimate difference)."""
    from repro.launch.serve import run_once

    kw = dict(workload="zipf", mode="cortex", n_requests=120,
              n_intents=200, dim=32, concurrency=4, seed=14,
              cache_ratio=0.9)
    a = run_once(**kw)
    b = run_once(cluster=True, n_clusters=8, nprobe=None, **kw)

    def strip(s):
        return {k: v for k, v in s.items()
                if k not in ("rows_scanned", "rows_per_lookup")}

    assert json.dumps(strip(a), sort_keys=True, default=float) == \
        json.dumps(strip(b), sort_keys=True, default=float)


def test_federation_clustered_caches_deterministic():
    """Per-region clustered caches: peer peeks route through the same
    sublinear scan, transfers still flow, and two same-seed runs are
    bit-identical (the router's seeded mini-batch draws included)."""
    from repro.data.workloads import region_workloads
    from repro.data.world import SemanticWorld
    from repro.serving.federation import FederationRunner

    world = SemanticWorld(n_intents=100, dim=32, seed=15)
    streams = region_workloads(world, 60, 2, overlap=0.6, seed=16)
    cfg = ClusterConfig(n_clusters=8, nprobe=4, min_train=24,
                        refresh_every=48, seed=17)

    def run():
        return FederationRunner(
            world=world, region_requests=streams, topology="peered",
            cluster=cfg, seed=18,
        ).run()["aggregate"]

    a, b = run(), run()
    assert json.dumps(a, sort_keys=True, default=float) == \
        json.dumps(b, sort_keys=True, default=float)
    assert a["hit_rate"] > 0


@pytest.mark.parametrize("cls", [VectorIndex, QuantIndex])
def test_nprobe_all_parity_with_duplicate_ties(cls, rng):
    """Exact-duplicate embeddings tying at the k boundary (judge
    false-negative re-inserts) must not break nprobe=all bit-identity:
    topk_desc's tie rule is ascending row, independent of the scored
    matrix's layout (capacity columns vs routed union)."""
    dim, k, cap = 16, 4, 64
    dup = rng.standard_normal(dim).astype(np.float32)
    dup /= np.linalg.norm(dup)
    embs = []
    for i in range(24):
        if 5 <= i <= 10:
            embs.append(dup)
        else:
            e = rng.standard_normal(dim).astype(np.float32)
            embs.append(e / np.linalg.norm(e))
    brute = cls(cap, dim)
    ivf = cls(cap, dim, router=ClusterRouter(cap, dim, ClusterConfig(
        n_clusters=4, nprobe=None, min_train=8, seed=1)))
    for ix in (brute, ivf):
        for i, e in enumerate(embs):
            ix.add(i, e)
        ix.remove_rows([2, 12, 20])  # free-list holes change the layout
    ivf.router.refresh(ivf)
    ids_b, sims_b = brute.search(dup, k, 0.0)
    ids_a, sims_a = ivf.search(dup, k, 0.0)
    assert ids_b == ids_a
    assert np.array_equal(sims_b, sims_a)
    # the tie rule itself: duplicates surface in ascending row order
    assert ids_b[:3] == [5, 6, 7]


def test_routed_kernel_duplicate_tie_order_matches_numpy(rng):
    """Same-cluster duplicate embeddings: the kernel buckets are built
    in ascending row order, so its per-bucket argmax breaks exact-score
    ties by lowest row — the same rule as topk_desc. (Ties BETWEEN
    buckets merge in centroid-score order — a documented kernel-backend
    caveat; identical embeddings always share a cluster, so the
    duplicate-re-insert case is covered.)"""
    dim, k, cap = 16, 4, 96
    dup = rng.standard_normal(dim).astype(np.float32)
    dup /= np.linalg.norm(dup)
    embs = []
    for i in range(40):
        if i in (7, 21, 33):   # duplicates inserted out of row order
            embs.append(dup)
        else:
            e = rng.standard_normal(dim).astype(np.float32)
            embs.append(e / np.linalg.norm(e))
    cfg = ClusterConfig(n_clusters=4, nprobe=2, min_train=8, seed=2)
    np_ix = VectorIndex(cap, dim, router=ClusterRouter(cap, dim, cfg))
    kr_ix = VectorIndex(cap, dim, router=ClusterRouter(cap, dim, cfg),
                        backend="kernel")
    for ix in (np_ix, kr_ix):
        for i, e in enumerate(embs):
            ix.add(i, e)
        # recycle a low row so the member list is NOT in row order
        ix.remove_rows([2])
        ix.add(40, dup)
    ids_n, sims_n = np_ix.search(dup, k, 0.0)
    ids_k, sims_k = kr_ix.search(dup, k, 0.0)
    assert ids_n == ids_k
    np.testing.assert_allclose(sims_n, sims_k, atol=2e-6)


def test_cross_shard_topk_merge_boundary_ties():
    """§13 cross-shard merge tie-breaking: every row scores EXACTLY 0.5
    against the query (first component 0.5, rest on orthogonal axes —
    bitwise-equal fp32 dots, no tolerance). The k-boundary tie group
    therefore spans shard ownership, and the sharded merge must pick
    the same lowest-row winners as the single-shard path and brute
    force — rows [0, 1, 2, 3], interleaved across the duplicate groups
    and, at S>1, across shard boundaries."""
    dim, n, k = 16, 64, 4
    embs = np.zeros((n, dim), np.float32)
    for i in range(n):
        # row i joins duplicate group i%4: 0.5·e0 + sqrt(.75)·e_{1+g}
        embs[i, 0] = 0.5
        embs[i, 1 + i % 4] = np.float32(np.sqrt(0.75))
    q = np.zeros(dim, np.float32)
    q[0] = 1.0

    def build(shards):
        cfg = ClusterConfig(n_clusters=4, nprobe=None, min_train=8,
                            seed=5, n_shards=shards) if shards else None
        router = ClusterRouter(n + 32, dim, cfg) if cfg else None
        ix = VectorIndex(n + 32, dim, router=router)
        for i in range(n):
            ix.add(i, embs[i])
        return ix

    ids_b, sims_b = build(0).search(q, k, 0.0)
    assert ids_b == [0, 1, 2, 3]
    assert all(s == np.float32(0.5) for s in sims_b)
    for shards in (1, 2, 8):       # 8 > n_clusters: empty shards legal
        ix = build(shards)
        rt = ix.router
        assert rt.trained
        ids, sims = ix.search(q, k, 0.0)
        assert ids == ids_b
        assert np.array_equal(sims, sims_b)
        if shards > 1:
            # the winning tie group really straddles a shard boundary
            owners = rt.shard_of[rt.assign[ids]]
            assert len(set(owners.tolist())) >= 2
