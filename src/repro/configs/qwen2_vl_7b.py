"""qwen2-vl-7b [vlm] — arXiv:2409.12191.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. M-RoPE with
sections (16, 24, 24); dynamic-resolution vision frontend is a STUB —
input_specs() supplies precomputed patch embeddings + a frontend mask
(backbone-only per the assignment).

TP note: 28 query heads padded to 32 for the 16-way model axis
(see DESIGN.md §6); kv=4 heads are replicated under TP16.
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig

NAME = "qwen2-vl-7b"
PAPER_N_HEADS = 28


@register(NAME)
def config() -> ModelConfig:
    attn = AttnConfig(
        n_heads=32,  # padded from 28 for TP16 divisibility
        n_kv_heads=4,
        head_dim=128,
        rope_kind="mrope",
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        qkv_bias=True,
    )
    return ModelConfig(
        name=NAME,
        family="vlm",
        d_model=3584,
        vocab_size=152064,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=18944),),
        n_repeat=28,
        tie_embeddings=False,
        frontend="vision",
    )
