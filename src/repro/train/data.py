"""Synthetic-but-learnable LM data pipeline.

Sequences are sampled from a fixed random bigram Markov chain over the
vocabulary, so a model that learns anything drives loss below the unigram
entropy — giving the train examples/tests a real convergence signal
without any external dataset. Deterministic, shardable, restart-exact
(the stream is indexed by step, so checkpoint replay sees identical data).
"""
from __future__ import annotations

import numpy as np


class BigramStream:
    def __init__(self, vocab: int, *, branch: int = 8, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        # sparse-ish bigram: each token transitions to `branch` successors
        succ = rng.integers(0, vocab, size=(vocab, branch))
        self.succ = succ.astype(np.int32)
        self.branch = branch

    def batch(self, step: int, batch: int, seq: int):
        """Deterministic (tokens, labels) for a given step index."""
        rng = np.random.default_rng(hash(("bigram", step)) & 0x7FFFFFFF)
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        choices = rng.integers(0, self.branch, size=(batch, seq))
        for t in range(seq):
            toks[:, t + 1] = self.succ[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @property
    def bigram_entropy(self) -> float:
        return float(np.log(self.branch))

    @property
    def unigram_entropy(self) -> float:
        return float(np.log(self.vocab))
