import numpy as np
import pytest


@pytest.fixture(scope="session")
def world():
    from repro.data.world import SemanticWorld

    return SemanticWorld(n_intents=200, dim=64, seed=0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
