"""End-to-end behaviour tests for the paper's system: the serving engine
reproduces the qualitative claims (semantic hits >> exact hits, judge
protects accuracy, rate-limit relief, co-location near-parity)."""
import pytest

from repro.launch.serve import run_once


@pytest.fixture(scope="module")
def results():
    out = {}
    for mode in ("vanilla", "exact", "cortex", "cortex-nojudge"):
        out[mode] = run_once(
            workload="zipf", mode=mode, n_requests=500, cache_ratio=0.5,
            n_intents=600, concurrency=8, seed=0,
        )
    return out


def test_cortex_hit_rate_dominates_exact(results):
    assert results["cortex"]["hit_rate"] > 0.55
    assert results["cortex"]["hit_rate"] > 2 * results["exact"]["hit_rate"]


def test_cortex_throughput_dominates(results):
    assert (
        results["cortex"]["throughput_rps"]
        > 1.5 * results["exact"]["throughput_rps"]
    )
    assert (
        results["cortex"]["throughput_rps"]
        > 2.0 * results["vanilla"]["throughput_rps"]
    )


def test_api_calls_slashed(results):
    assert results["cortex"]["api_calls"] < 0.5 * results["vanilla"]["api_calls"]
    assert results["cortex"]["retry_ratio"] < results["vanilla"]["retry_ratio"]


def test_judge_protects_accuracy(results):
    """Naive ANN caching loses EM; the full pipeline stays near vanilla
    (paper Fig 13)."""
    assert results["cortex"]["em"] >= results["vanilla"]["em"] - 0.03
    assert results["cortex-nojudge"]["em"] < results["cortex"]["em"]
    assert results["cortex"]["info_accuracy"] > 0.97


def test_cost_efficiency(results):
    assert (
        results["cortex"]["thpt_per_dollar"]
        > 2 * results["vanilla"]["thpt_per_dollar"]
    )


def test_rate_limit_ablation():
    """Table 4: removing the rate limit helps vanilla more than cortex —
    cortex's advantage under limits is larger."""
    lim = {
        m: run_once(workload="zipf", mode=m, n_requests=300, cache_ratio=0.5,
                    concurrency=8, qpm=100.0, seed=1)
        for m in ("vanilla", "cortex")
    }
    nolim = {
        m: run_once(workload="zipf", mode=m, n_requests=300, cache_ratio=0.5,
                    concurrency=8, qpm=None, seed=1)
        for m in ("vanilla", "cortex")
    }
    gain_lim = lim["cortex"]["throughput_rps"] / lim["vanilla"]["throughput_rps"]
    gain_nolim = (
        nolim["cortex"]["throughput_rps"] / nolim["vanilla"]["throughput_rps"]
    )
    assert gain_lim > gain_nolim > 1.0


def test_colocation_near_parity():
    """Table 7: co-located retains most of dedicated-2-chip throughput at
    half the hardware. Prefetch is off so the co/ded comparison isolates
    the serving architecture: speculative fetches fire at slightly
    different virtual times in the two configurations, and that api-cost
    jitter (a few calls) is larger than the GPU-cost saving the
    assertion measures."""
    co = run_once(workload="zipf", mode="cortex", n_requests=400,
                  cache_ratio=0.6, concurrency=12, colocated=True,
                  prefetch=False, seed=2)
    ded = run_once(workload="zipf", mode="cortex", n_requests=400,
                   cache_ratio=0.6, concurrency=12, colocated=False,
                   prefetch=False, seed=2)
    assert co["throughput_rps"] > 0.8 * ded["throughput_rps"]
    assert co["thpt_per_dollar"] > ded["thpt_per_dollar"]


def test_recalibration_runs_and_is_cheap():
    base = run_once(workload="zipf", mode="cortex", n_requests=400,
                    cache_ratio=0.5, concurrency=8, seed=3)
    recal = run_once(workload="zipf", mode="cortex", n_requests=400,
                     cache_ratio=0.5, concurrency=8,
                     recalibrate_every=30.0, seed=3)
    # bounded overhead (paper: ~2%; allow slack for simulation variance)
    assert recal["throughput_rps"] > 0.9 * base["throughput_rps"]


def test_swe_workload_gains():
    """Fig 9: coding workload sees moderate (but real) gains."""
    ex = run_once(workload="swe", mode="exact", n_requests=400,
                  cache_ratio=0.5, concurrency=8, seed=4)
    co = run_once(workload="swe", mode="cortex", n_requests=400,
                  cache_ratio=0.5, concurrency=8, seed=4)
    assert co["hit_rate"] > ex["hit_rate"]
    assert co["throughput_rps"] >= ex["throughput_rps"]
