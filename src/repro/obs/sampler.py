"""Time-series telemetry: interval sampling of the metrics registry
(DESIGN.md §16).

:class:`TimeSeriesSampler` rides the shared
:class:`~repro.serving.clock.VirtualClock`: every ``interval`` virtual
seconds it snapshots each engine's :class:`~repro.obs.metrics.
MetricsRegistry`, diffs against the previous snapshot, and appends one
sample row — per-window rates (hit-rate, rps, rows-scanned/s,
judge-calls/s), windowed latency percentiles over the requests that
*completed* in the window, live pressure gauges (judge backlog, stage-1
pending, in-flight requests, GPU lane occupancy, limiter headroom,
federation peek queue), and cumulative totals.

**Observational neutrality** — the strict contract everything here is
built around: a sampled run must be bit-identical in virtual time (and
therefore in summary) to an unsampled run.

* The sampler's tick events consume heap sequence numbers, but the seq
  counter is strictly monotonic, so the *relative* order of every other
  pair of events is unchanged — ties between engine events still break
  exactly as before.
* Tick callbacks only **read**: registry collectors, record lists,
  gauge state. The one read that looks mutating — token-bucket headroom
  — is taken through the pure :func:`limiter_headroom` below instead of
  ``TokenBucket.headroom`` (whose ``_refill`` rewrites float state along
  a different operation order than a single later refill would, which
  can flip a ``tokens >= 1.0`` comparison bit).
* The engine / federation run loops terminate on ``done``, not on heap
  exhaustion, so a self-rescheduling sampler can neither extend nor
  hang a run; at most one un-fired tick is left pending.

**Exact reconciliation** — the first snapshot is taken at ``start()``
and :meth:`finalize` emits a final partial-window sample at the run's
last virtual instant, so the integer window deltas telescope: for every
counter, ``sum(window deltas) == final total - start total`` exactly
(integer arithmetic, no float accumulation). The ``obs_timeseries``
benchmark gates on this.

Under federation the "global" topology shares one cache across engines,
so each engine's registry reports the SAME cache counters; fleet
aggregates therefore count cache-derived namespaces (``cache.``,
``scan.``, ``tier.``, ``pipeline.``) once per *distinct cache object*
(the first engine holding it is the owner), while per-engine namespaces
(``remote.``, ``engine.``, ``gauge.``, ``exact.``) sum over every
engine. Gauge aggregation sums counts; ``*_headroom`` gauges take the
fleet ``min`` (the most-constrained region is the pressure signal).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.obs.metrics import percentile

# cache-derived namespaces: counted once per distinct cache object in
# fleet aggregates (the federation "global" topology shares one cache)
_CACHE_NAMESPACES = ("cache.", "scan.", "tier.", "pipeline.")


def limiter_headroom(remote, now: float) -> float:
    """Pure-read token-bucket headroom in [0, 1] — semantically
    ``TokenBucket.headroom(now)`` but WITHOUT the ``_refill`` mutation
    (splitting one refill into two is not float-bit-neutral), so the
    sampler can read it without perturbing the run."""
    lim = getattr(remote, "limiter", None)
    if lim is None:
        return 1.0
    tokens = lim.tokens
    if now > lim.t_last:
        tokens = min(lim.capacity, tokens + (now - lim.t_last) * lim.rate)
    return tokens / lim.capacity


def _d(cur: dict, prev: dict, key: str) -> float | int:
    """Delta of one numeric snapshot key (missing counts as 0)."""
    a = cur.get(key, 0)
    b = prev.get(key, 0)
    a = a if isinstance(a, (int, float)) and not isinstance(a, bool) else 0
    b = b if isinstance(b, (int, float)) and not isinstance(b, bool) else 0
    return a - b


def _lat_stats(lats: Sequence[float]) -> dict:
    """Windowed latency stats over the requests completed in a window;
    all-``None`` when the window completed nothing (an SLO skips
    no-data samples rather than treating them as 0)."""
    if not lats:
        return {"latency_p50": None, "latency_p99": None,
                "latency_max": None, "latency_mean": None}
    return {
        "latency_p50": percentile(lats, 50),
        "latency_p99": percentile(lats, 99),
        "latency_max": float(max(lats)),
        "latency_mean": float(np.mean(lats)),
    }


class TimeSeriesSampler:
    """Fixed-interval registry sampler on the shared virtual clock.

    Parameters
    ----------
    clock : VirtualClock shared by every engine being observed.
    interval : virtual seconds between samples (the window length).
    engines : engines to observe (one for a solo run; one per region
        under federation — they must all share ``clock``).
    federation : optional :class:`~repro.serving.federation.Federation`
        whose queue-depth gauges ride along in fleet samples.
    monitor : optional :class:`~repro.obs.slo.SLOMonitor`; every
        emitted sample is fed to it in order.
    """

    def __init__(self, clock, interval: float, engines,
                 federation=None, monitor=None):
        if interval <= 0:
            raise ValueError("sample interval must be > 0")
        self.clock = clock
        self.interval = float(interval)
        self.engines = list(engines)
        self.federation = federation
        self.monitor = monitor
        self.samples: list[dict] = []
        self._t0: Optional[float] = None
        self._prev_t: float = 0.0
        self._prev: list[dict] = []       # per-engine snapshots
        self._rec_idx: list[int] = []     # records consumed per engine
        self._k = 0                       # ticks scheduled so far
        self._finalized = False
        # fleet-aggregate owner mask: count cache-derived namespaces
        # once per distinct cache object (global topology shares one)
        seen: set[int] = set()
        self._cache_owner: list[bool] = []
        for e in self.engines:
            c = getattr(e, "cache", None)
            own = c is not None and id(c) not in seen
            if c is not None:
                seen.add(id(c))
            self._cache_owner.append(own)

    # ------------------------------------------------------------ clock

    def start(self) -> None:
        """Take the baseline snapshot at the current virtual instant and
        schedule the first tick. Call once, before the run loop."""
        if self._t0 is not None:
            raise RuntimeError("sampler already started")
        self._t0 = self.clock.now
        self._prev_t = self._t0
        self._prev = [e.metrics.snapshot() for e in self.engines]
        self._rec_idx = [len(e.records) for e in self.engines]
        # the start-of-run baseline the cumulative totals subtract (a
        # sampler attached mid-run still reconciles exactly)
        self._base = list(self._prev)
        self._base_recs = list(self._rec_idx)
        self._schedule()

    def _schedule(self) -> None:
        self._k += 1
        self.clock.push(self._t0 + self._k * self.interval, self._tick)

    def _tick(self, now=None) -> None:
        if self._finalized:
            return
        # the grid instant, computed with the same float expression the
        # push used — events fire in time order, so clock.now == label
        self._sample(self._t0 + self._k * self.interval)
        self._schedule()

    def finalize(self) -> None:
        """Emit one final partial-window sample at the run's last virtual
        instant (unless a grid tick already landed exactly there), so the
        window deltas telescope to the end-of-run totals exactly."""
        if self._finalized:
            return
        if self._t0 is None:
            raise RuntimeError("sampler never started")
        self._finalized = True
        t = self.clock.now
        if t > self._prev_t:
            self._sample(t)

    # ----------------------------------------------------------- sample

    def _engine_window(self, i: int, cur: dict, dur: float) -> dict:
        """Window block for ONE engine from its snapshot delta + the
        records completed since the previous sample."""
        prev = self._prev[i]
        e = self.engines[i]
        new_recs = e.records[self._rec_idx[i]:]
        hits = _d(cur, prev, "cache.hits") + _d(cur, prev, "exact.hits")
        lookups = (_d(cur, prev, "cache.lookups")
                   + _d(cur, prev, "exact.lookups"))
        api = _d(cur, prev, "remote.calls")
        rows = _d(cur, prev, "scan.total_rows")
        judge = _d(cur, prev, "cache.judge_calls")
        stale = _d(cur, prev, "engine.stale_hits")
        w = {
            "n_done": len(new_recs),
            "rps": len(new_recs) / dur,
            "hits": int(hits),
            "lookups": int(lookups),
            "hit_rate": (hits / lookups) if lookups else None,
            "api_calls": int(api),
            "api_cost": float(_d(cur, prev, "remote.total_cost")),
            "rows_scanned": int(rows),
            "rows_per_s": rows / dur,
            "judge_calls": int(judge),
            "judge_calls_per_s": judge / dur,
            "stale_hits": int(stale),
            "stale_rate": (stale / hits) if hits else None,
            "info_accuracy": (
                float(np.mean([r.info_correct for r in new_recs]))
                if new_recs else None
            ),
        }
        w.update(_lat_stats([r.latency for r in new_recs]))
        return w

    def _merge_windows(self, wins: list[dict], dur: float,
                       all_lats: list[float]) -> dict:
        """Fleet window: sum counts (cache-derived ones were already
        deduped per owner at snapshot time — see _engine_window's caller),
        re-derive ratios, pool latencies."""
        keys = ("n_done", "hits", "lookups", "api_calls", "rows_scanned",
                "judge_calls", "stale_hits")
        agg = {k: sum(w[k] for w in wins) for k in keys}
        agg["api_cost"] = float(sum(w["api_cost"] for w in wins))
        agg["rps"] = agg["n_done"] / dur
        agg["rows_per_s"] = agg["rows_scanned"] / dur
        agg["judge_calls_per_s"] = agg["judge_calls"] / dur
        agg["hit_rate"] = (agg["hits"] / agg["lookups"]
                           if agg["lookups"] else None)
        agg["stale_rate"] = (agg["stale_hits"] / agg["hits"]
                             if agg["hits"] else None)
        accs = [w["info_accuracy"] for w in wins
                if w["info_accuracy"] is not None]
        ns = [w["n_done"] for w in wins if w["info_accuracy"] is not None]
        agg["info_accuracy"] = (
            float(sum(a * n for a, n in zip(accs, ns)) / sum(ns))
            if ns and sum(ns) else None
        )
        agg.update(_lat_stats(all_lats))
        return agg

    def _gauges(self, snaps: list[dict]) -> dict:
        """Fleet gauges from the engines' ``gauge.`` namespaces (counts
        sum; ``*_headroom`` takes the fleet min) + federation depths."""
        out: dict[str, float | int] = {}
        for snap in snaps:
            for k, v in snap.items():
                if not k.startswith("gauge."):
                    continue
                name = k[len("gauge."):]
                if name.endswith("_headroom"):
                    out[name] = min(out.get(name, v), v)
                else:
                    out[name] = out.get(name, 0) + v
        if self.federation is not None:
            for k, v in self.federation.gauges().items():
                out[f"fed_{k}"] = v
        return out

    def _sample(self, t: float) -> None:
        dur = t - self._prev_t
        snaps = [e.metrics.snapshot() for e in self.engines]
        # per-engine windows; cache-derived counters zeroed on non-owner
        # engines so the fleet sums count each distinct cache once
        wins = []
        per_region_lats: list[list[float]] = []
        for i, cur in enumerate(snaps):
            if not self._cache_owner[i] and \
                    getattr(self.engines[i], "cache", None) is not None:
                cur_dedup = {
                    k: (self._prev[i].get(k, v)
                        if k.startswith(_CACHE_NAMESPACES) else v)
                    for k, v in cur.items()
                }
            else:
                cur_dedup = cur
            wins.append(self._engine_window(i, cur_dedup, dur))
            per_region_lats.append([
                r.latency
                for r in self.engines[i].records[self._rec_idx[i]:]
            ])
        all_lats = [x for ls in per_region_lats for x in ls]
        row = {
            "t": float(t),
            "dur": float(dur),
            "window": self._merge_windows(wins, dur, all_lats),
            "gauges": self._gauges(snaps),
            "cum": self._cum(snaps),
        }
        if len(self.engines) > 1:
            regions = {}
            for i, e in enumerate(self.engines):
                g = {k[len("gauge."):]: v for k, v in snaps[i].items()
                     if k.startswith("gauge.")}
                blk = {
                    "n_done": wins[i]["n_done"],
                    "api_calls": wins[i]["api_calls"],
                    "gauges": g,
                }
                blk.update(_lat_stats(per_region_lats[i]))
                regions[str(getattr(e, "region_id", i))] = blk
            row["regions"] = regions
        self.samples.append(row)
        # advance window state
        self._prev = snaps
        self._prev_t = t
        self._rec_idx = [len(e.records) for e in self.engines]
        if self.monitor is not None:
            self.monitor.observe(row)

    def _cum(self, snaps: list[dict]) -> dict:
        """Cumulative integer totals since ``start()`` — what the window
        deltas must telescope to (the reconciliation gate)."""
        def total(key_cache: str, key_exact: str | None = None) -> int:
            tot = 0
            for i, snap in enumerate(snaps):
                if key_cache.startswith(_CACHE_NAMESPACES) \
                        and not self._cache_owner[i] \
                        and getattr(self.engines[i], "cache", None) \
                        is not None:
                    continue
                v = snap.get(key_cache, 0) - self._base[i].get(key_cache, 0)
                if key_exact is not None:
                    v += (snap.get(key_exact, 0)
                          - self._base[i].get(key_exact, 0))
                tot += int(v)
            return tot

        return {
            "n_done": int(sum(
                len(e.records) - b
                for e, b in zip(self.engines, self._base_recs)
            )),
            "hits": total("cache.hits", "exact.hits"),
            "lookups": total("cache.lookups", "exact.lookups"),
            "api_calls": total("remote.calls"),
            "rows_scanned": total("scan.total_rows"),
            "judge_calls": total("cache.judge_calls"),
            "stale_hits": total("engine.stale_hits"),
        }
