"""jit-able step builders shared by dryrun.py, train.py and serve.py."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models.lm import LM
from repro.nn import runtime
from repro.nn.config import ModelConfig
from repro.nn.sharding import ShardCtx
from repro.train.optim import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh],
                    opt_cfg: AdamWConfig, remat: str = "dots",
                    microbatches: int = 1, accum_dtype=jnp.float32,
                    shard_cfg=None):
    """Train step with optional gradient accumulation over microbatches
    (lax.scan; activation memory scales 1/μ, FSDP all-gathers scale ×μ —
    the classic memory/collective trade recorded per cell in §Roofline)."""
    lm = LM(cfg)
    ctx = ShardCtx(mesh, shard_cfg)

    def loss_fn(p, mb):
        loss, aux = lm.loss_and_aux(ctx, p, mb, remat=remat)
        return loss

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split_mb(key, t):
                if key == "positions" and t.ndim == 3:  # (3, B, S) M-RoPE
                    r = t.reshape(
                        t.shape[0], microbatches,
                        t.shape[1] // microbatches, t.shape[2],
                    )
                    return jnp.moveaxis(r, 1, 0)  # (μ, 3, B/μ, S)
                return t.reshape(
                    microbatches, t.shape[0] // microbatches, *t.shape[1:]
                )

            mb_batch = {k: split_mb(k, v) for k, v in batch.items()}

            def body(carry, mb):
                g_acc, l_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            (grads, loss), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mb_batch,
                unroll=runtime.unroll_for(microbatches),
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        new_params, new_state, metrics = adamw_update(
            opt_cfg, params, grads, opt_state
        )
        return new_params, new_state, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh]):
    lm = LM(cfg)
    ctx = ShardCtx(mesh)

    def prefill_step(params, batch):
        return lm.prefill(ctx, params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh]):
    lm = LM(cfg)
    ctx = ShardCtx(mesh)

    def serve_step(params, tokens, caches, pos):
        logits, new_caches = lm.decode(ctx, params, tokens, caches, pos)
        return logits, new_caches

    return serve_step
