"""Critical-path decomposition + latency attribution (DESIGN.md §15).

:func:`check_conservation` proves the conservation law for a finished
run: every completed request's request-scoped spans — sorted by start
time — tile ``[rec.arrival, rec.t_done]`` with NO gap and NO overlap,
every boundary compared with exact float ``==``. Because the segments
tile the interval exactly, their summed duration telescopes:
``sum(t1_i - t0_i) = t_last - t_first = rec.t_done - rec.arrival``,
which is *bit-for-bit* the expression the engine used to compute
``rec.latency`` — so the spans sum exactly (``==``, not ``≈``) to the
recorded latency. (Summing the float durations naively would NOT
telescope exactly — float addition is not associative — which is why
the law is stated, and checked, as exact tiling.)

:func:`attribution` then answers *where the time went*: per-segment
p50/p99 (shared :func:`~repro.obs.metrics.percentile`) split by request
class — pure cache hits (``remote_calls == 0``), federated
(``peer_transfers > 0``), and origin misses — the trace-derived
replacement for the engine's hand-rolled ``hitpath_*`` means.
"""
from __future__ import annotations

from typing import Iterable, Mapping

from repro.obs.metrics import percentile
from repro.obs.trace import T0, T1, Tracer


def _records_by_key(records) -> dict[tuple[int, int], object]:
    """Normalize records to ``{(region, rid): rec}``. Accepts a plain
    list (solo engine ⇒ region 0) or a ``{region: [recs]}`` mapping
    (federation — per-region workloads reuse rid ranges, so rid alone
    is not a key)."""
    if isinstance(records, Mapping):
        return {
            (int(region), r.rid): r
            for region, recs in records.items() for r in recs
        }
    return {(0, r.rid): r for r in records}


def check_conservation(tracer: Tracer, records) -> list[str]:
    """Return a list of violations (empty ⇒ the law holds).

    Checked per completed request, all comparisons exact float ``==``:

    1. the request has spans at all;
    2. the first span starts at ``rec.arrival``;
    3. each span ends exactly where the next begins (zero-duration
       markers tile trivially);
    4. the last span ends at ``rec.t_done``;
    5. the telescoped total ``t_last - t_first`` equals ``rec.latency``.
    """
    by_req = tracer.request_spans()
    violations: list[str] = []
    for key, rec in _records_by_key(records).items():
        spans = by_req.get(key)
        tag = f"region {key[0]} rid {key[1]}"
        if not spans:
            violations.append(f"{tag}: no spans recorded")
            continue
        spans = sorted(spans, key=lambda s: (s[T0], s[T1]))
        if spans[0][T0] != rec.arrival:
            violations.append(
                f"{tag}: first span {spans[0][1]} starts at "
                f"{spans[0][T0]!r} != arrival {rec.arrival!r}"
            )
        for a, b in zip(spans, spans[1:]):
            if a[T1] != b[T0]:
                kind = "gap" if a[T1] < b[T0] else "overlap"
                violations.append(
                    f"{tag}: {kind} between {a[1]} (ends {a[T1]!r}) and "
                    f"{b[1]} (starts {b[T0]!r})"
                )
        if spans[-1][T1] != rec.t_done:
            violations.append(
                f"{tag}: last span {spans[-1][1]} ends at "
                f"{spans[-1][T1]!r} != t_done {rec.t_done!r}"
            )
        if spans[-1][T1] - spans[0][T0] != rec.latency:
            violations.append(
                f"{tag}: telescoped span total "
                f"{spans[-1][T1] - spans[0][T0]!r} != latency "
                f"{rec.latency!r}"
            )
    return violations


def _req_class(rec) -> str:
    if rec.remote_calls == 0:
        return "hit"
    if rec.peer_transfers > 0:
        return "federated"
    return "miss"


def attribution(tracer: Tracer, records) -> dict:
    """Queueing-delay attribution: per request class, per span name,
    the count / total seconds / p50 / p99 of **per-request time in that
    segment** (a request's multiple rounds of, say, ``judge_queue_wait``
    are summed before the quantile — the unit of the paper's Fig 11 is
    the request, not the span)."""
    by_req = tracer.request_spans()
    recs = _records_by_key(records)
    # class -> name -> list of per-request summed durations
    acc: dict[str, dict[str, list[float]]] = {}
    lat: dict[str, list[float]] = {}
    for key, rec in recs.items():
        cls = _req_class(rec)
        lat.setdefault(cls, []).append(rec.latency)
        per_name: dict[str, float] = {}
        for s in by_req.get(key, ()):
            per_name[s[1]] = per_name.get(s[1], 0.0) + (s[T1] - s[T0])
        slot = acc.setdefault(cls, {})
        for name, d in per_name.items():
            slot.setdefault(name, []).append(d)
    out: dict[str, dict] = {}
    for cls in sorted(acc):
        segs = {}
        for name in sorted(acc[cls]):
            ds = acc[cls][name]
            segs[name] = {
                "n": len(ds),
                "total_s": float(sum(ds)),
                "p50": percentile(ds, 50),
                "p99": percentile(ds, 99),
            }
        out[cls] = {
            "n_requests": len(lat[cls]),
            "latency_p50": percentile(lat[cls], 50),
            "latency_p99": percentile(lat[cls], 99),
            "segments": segs,
        }
    return out


def critical_path(tracer: Tracer, records) -> dict:
    """Per-request-class critical-path aggregates (DESIGN.md §16).

    The conservation law makes the critical path trivial to extract:
    each request's spans *tile* ``[arrival, t_done]``, so every span IS
    on the critical path — the per-class question is not *which* spans
    matter but *where a millisecond of improvement lands*. For each
    class and segment name this reports:

    * ``n_requests`` / ``occurrences`` — requests containing the
      segment, and total span count (a request can pass a segment
      several times across rounds);
    * ``total_s`` and ``frac`` — summed seconds and share of the
      class's total latency;
    * ``leverage`` — occurrences / class requests: shaving 1 ms off
      every pass through this segment cuts the class's *mean* latency
      by ``leverage`` ms. The per-class ``ranked`` list orders segment
      names by ``total_s`` (descending, name-tiebroken) — the answer to
      "optimize what first".
    """
    by_req = tracer.request_spans()
    recs = _records_by_key(records)
    # class -> name -> [occurrences, total_s, n_requests]
    acc: dict[str, dict[str, list]] = {}
    cls_lat: dict[str, float] = {}
    cls_n: dict[str, int] = {}
    for key, rec in recs.items():
        cls = _req_class(rec)
        cls_lat[cls] = cls_lat.get(cls, 0.0) + rec.latency
        cls_n[cls] = cls_n.get(cls, 0) + 1
        seen: set[str] = set()
        slot = acc.setdefault(cls, {})
        for s in by_req.get(key, ()):
            cell = slot.setdefault(s[1], [0, 0.0, 0])
            cell[0] += 1
            cell[1] += s[T1] - s[T0]
            if s[1] not in seen:
                seen.add(s[1])
                cell[2] += 1
    out: dict[str, dict] = {}
    for cls in sorted(acc):
        total = cls_lat[cls]
        n_req = cls_n[cls]
        segs = {}
        for name in sorted(acc[cls]):
            occ, tot_s, nr = acc[cls][name]
            segs[name] = {
                "n_requests": nr,
                "occurrences": occ,
                "total_s": float(tot_s),
                "frac": float(tot_s / total) if total else 0.0,
                "leverage": float(occ / n_req),
            }
        ranked = sorted(segs, key=lambda n: (-segs[n]["total_s"], n))
        out[cls] = {
            "n_requests": n_req,
            "total_latency_s": float(total),
            "segments": segs,
            "ranked": ranked,
        }
    return out


def flamegraph_folded(tracer: Tracer, records) -> list[str]:
    """Span-duration aggregates as folded-stack lines —
    ``class;segment <microseconds>`` — the input format of the standard
    flamegraph toolchain (one frame deep: the conservation law makes
    request span trees linear, so class;segment is the whole stack).
    Lines are sorted, weights are integer µs: deterministic output."""
    report = critical_path(tracer, records)
    lines = []
    for cls, blk in report.items():
        for name, seg in blk["segments"].items():
            lines.append(f"{cls};{name} {int(round(seg['total_s'] * 1e6))}")
    return sorted(lines)


def format_critical_path(report: Mapping) -> str:
    """Human-readable critical-path table (one block per class, segments
    in ranked order)."""
    lines = []
    for cls, blk in report.items():
        lines.append(
            f"[{cls}] n={blk['n_requests']} "
            f"total={blk['total_latency_s']:.3f}s"
        )
        lines.append(f"  {'segment':<18}{'occ':>6}{'total_s':>10}"
                     f"{'frac':>7}{'lev':>6}")
        for name in blk["ranked"]:
            seg = blk["segments"][name]
            lines.append(
                f"  {name:<18}{seg['occurrences']:>6}"
                f"{seg['total_s']:>10.3f}{seg['frac']:>7.1%}"
                f"{seg['leverage']:>6.2f}"
            )
    return "\n".join(lines)


def format_attribution(report: Mapping) -> str:
    """Human-readable attribution table (one block per request class)."""
    lines = []
    for cls, blk in report.items():
        lines.append(
            f"[{cls}] n={blk['n_requests']} "
            f"latency p50={blk['latency_p50']:.4f}s "
            f"p99={blk['latency_p99']:.4f}s"
        )
        lines.append(f"  {'segment':<18}{'n':>6}{'total_s':>10}"
                     f"{'p50':>9}{'p99':>9}")
        for name, seg in blk["segments"].items():
            lines.append(
                f"  {name:<18}{seg['n']:>6}{seg['total_s']:>10.3f}"
                f"{seg['p50']:>9.4f}{seg['p99']:>9.4f}"
            )
    return "\n".join(lines)
