"""JudgePipeline — the one stage-2 seam (DESIGN.md §14).

Every layer that judges — the serving engine's micro-batched dispatcher,
``CortexCache``/``TieredCache`` batched lookups (including warm-promotion
validation), and federation's peek/lease validation — routes through one
:class:`JudgePipeline`, which owns three things:

* **Adaptive admission** (:class:`AdmissionBand`): a confidence band
  around τ_sim. Stage-1 candidates whose similarity clears the band's
  upper edge are trusted without paying judge latency (bypass hit); the
  stage-1 gate drops to the band's lower edge so borderline candidates
  that used to be silent misses get judged instead; anything below the
  lower edge goes straight to origin. Only the uncertain band pays the
  judge. ``width == 0`` collapses to each seam's legacy policy — the
  engine judges every candidate, federation peeks stay ANN-only — so the
  band machinery is event-neutral when disabled.
* **Model-derived cost**: the judge job's token-equivalent cost on the
  GPU lanes derives from the judge model config's prefill FLOPs
  (``launch/roofline.model_flops``) normalized by one agent-model token,
  instead of a hard-coded constant. Changing the judge's ``d_model``
  changes the measured judge latency.
* **Calibration shim** (generalizing ``HybridJudge``): decision
  semantics come from a ground-truth-faithful scorer (``OracleJudge``)
  while the compute — both the virtual-time cost above and, when
  ``compute`` is set, real tiny-LM ``score_pairs`` work — is
  model-faithful. Benchmarks stay comparable; the co-location scheduler
  sees the real footprint.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.obs.trace import BACKGROUND, NULL_TRACER
from repro.serving.gpu import judge_batch_tokens


def default_judge_cfg(d_model: int = 128, vocab: int = 512,
                      n_repeat: int = 2):
    """The reproduction's stage-2 judge model: the tiny qwen3-family
    cross-encoder ``ModelJudge`` instantiates (prefill-only, single
    score token)."""
    from repro.configs import get_config, shrink

    return shrink(get_config("qwen3-0.6b"), d_model=d_model, vocab=vocab,
                  n_repeat=n_repeat)


def agent_reference_cfg():
    """The reproduction's co-located *agent* model: same shrink family as
    the judge but at the source model's native width (d_model=1024).
    One prefill token of this config is the GPU lanes' token-equivalent
    unit, so judge cost is expressed in the same currency as
    ``think_tokens``/``answer_tokens``."""
    from repro.configs import get_config, shrink

    return shrink(get_config("qwen3-0.6b"), d_model=1024, vocab=512,
                  n_repeat=2)


def judge_token_cost(judge_cfg=None, max_len: int = 128,
                     agent_cfg=None) -> float:
    """Token-equivalent cost of ONE judge prefill, derived from model
    configs: judge prefill FLOPs over ``max_len`` tokens divided by the
    per-token prefill FLOPs of the agent reference model. The default
    judge config (d_model=128) costs 16.0 token-eq; d_model=256 costs
    32.0 — the co-location scheduler prices the actual model."""
    from repro.launch.roofline import model_flops

    judge_cfg = judge_cfg if judge_cfg is not None else default_judge_cfg()
    agent_cfg = agent_cfg if agent_cfg is not None else agent_reference_cfg()
    return (model_flops(judge_cfg, "prefill", max_len)
            / model_flops(agent_cfg, "prefill", 1))


@dataclasses.dataclass
class AdmissionBand:
    """Confidence band of total ``width`` centered on τ_sim.

    ``classify`` edges are pinned (tests/test_judge_pipeline.py):
    ``sim >= hi`` is *trust* (upper edge INCLUSIVE — a candidate exactly
    at the edge bypasses), ``lo <= sim < hi`` is *uncertain* (lower edge
    INCLUSIVE — a candidate exactly at the stage-1 gate is judged, never
    silently dropped), ``sim < lo`` is *reject*. ``adaptive`` arms the
    engine's recalibration tick to re-derive the width from the stage-1
    similarity precision curve alongside τ_lsm."""

    width: float = 0.0
    adaptive: bool = False

    def lo(self, tau_sim: float) -> float:
        return tau_sim - self.width / 2.0

    def hi(self, tau_sim: float) -> float:
        return tau_sim + self.width / 2.0

    def classify(self, sim: float, tau_sim: float) -> str:
        if sim >= self.hi(tau_sim):
            return "trust"
        if sim >= self.lo(tau_sim):
            return "uncertain"
        return "reject"


@dataclasses.dataclass
class PipelineStats:
    judged_pairs: int = 0       # (query, key) pairs actually scored
    judge_batches: int = 0      # score_pairs calls (micro-batches)
    bypass_hits: int = 0        # band trust: hit served without a judge
    band_judged: int = 0        # engine entries that paid judge latency
    lease_validations: int = 0  # federation in-band leases judged
    lease_rejections: int = 0   # ... of which the judge rejected


class JudgePipeline:
    """One dispatch seam for stage-2 validation.

    ``decisions`` supplies the scores that drive hit/miss semantics
    (``OracleJudge`` in behavioural runs, ``ModelJudge`` end to end when
    semantics-faithfulness is not required). ``compute``, when set, is a
    ``ModelJudge`` whose ``score_pairs`` is *paid* (real tiny-LM prefill
    through the Pallas flash-attention stack) and discarded — the
    calibration shim. ``base_tokens`` is the virtual-time cost of one
    unbatched judge job; by default it derives from ``judge_cfg`` via
    :func:`judge_token_cost` (which is also how ``compute``'s config
    prices itself when given).
    """

    def __init__(
        self,
        decisions,
        *,
        compute=None,
        judge_cfg=None,
        max_len: int = 128,
        band: Optional[AdmissionBand] = None,
        base_tokens: Optional[float] = None,
    ):
        self.decisions = decisions
        self.compute = compute
        if judge_cfg is None:
            judge_cfg = (compute.cfg if compute is not None
                         else getattr(decisions, "cfg", None))
        self.judge_cfg = (judge_cfg if judge_cfg is not None
                          else default_judge_cfg())
        self.max_len = (compute.max_len if compute is not None else max_len)
        self.band = band
        self.base_tokens = (
            base_tokens if base_tokens is not None
            else judge_token_cost(self.judge_cfg, self.max_len)
        )
        self.stats = PipelineStats()
        self._tracer = NULL_TRACER
        self._clock = None
        self._region = 0

    def bind_tracer(self, tracer, clock, region: int = 0) -> None:
        """Arm §15 tracing: holder-side lease validations emit a
        background marker stamped with this pipeline's region. Purely
        observational — no virtual-time effect."""
        self._tracer = tracer
        self._clock = clock
        self._region = region

    # ------------------------------------------------------------ scoring

    def score_pairs(self, queries: Sequence[str],
                    cached_keys: Sequence[str]) -> np.ndarray:
        """THE scoring seam: one call per micro-batch. Pays the real
        model compute when the shim is armed, returns the decision
        scorer's values."""
        self.stats.judge_batches += 1
        self.stats.judged_pairs += len(queries)
        if self.compute is not None:
            self.compute.score_pairs(queries, cached_keys)
        return self.decisions.score_pairs(queries, cached_keys)

    def staticity(self, query: str) -> int:
        return self.decisions.staticity(query)

    # ---------------------------------------------------------- admission

    def stage1_gate(self, tau_sim: float) -> float:
        """Similarity gate stage 1 should apply: the band's lower edge
        when a band is armed (borderline candidates surface so the judge
        can recover them), τ_sim otherwise."""
        if self.band is not None and self.band.width > 0:
            return self.band.lo(tau_sim)
        return tau_sim

    def admit(self, sims, tau_sim: float) -> str:
        """Engine-side admission for one candidate block (sims are the
        surviving stage-1 similarities, descending). Returns ``"miss"``
        (no candidates), ``"bypass"`` (best candidate clears the band's
        upper edge — serve it without judging), or ``"judge"``. With no
        band (or width 0) every non-empty block is judged — the legacy
        judge-everything engine, event for event."""
        if not len(sims):
            return "miss"
        if self.band is None or self.band.width <= 0:
            return "judge"
        if self.band.classify(float(sims[0]), tau_sim) == "trust":
            self.stats.bypass_hits += 1
            return "bypass"
        self.stats.band_judged += 1
        return "judge"

    def validate_lease(self, query: str, key: str, sim: float,
                       tau_sim: float, tau_lsm: float) -> bool:
        """Federation peek/lease validation. A probe site has no judge
        lane, so the band IS the policy: trust leases ship ANN-only (as
        every lease did before the band existed — width 0 keeps that
        legacy exactly), in-band leases pay one judge score and must
        clear τ_lsm, below-band candidates never surface (the stage-1
        gate). Cost note: peer-side judge time is folded into the probe
        RTT, matching the half-RTT granularity of the peek protocol."""
        if self.band is None or self.band.width <= 0:
            return True
        if self.band.classify(sim, tau_sim) != "uncertain":
            return True
        self.stats.lease_validations += 1
        if self._tracer.enabled and self._clock is not None:
            self._tracer.marker(BACKGROUND, "lease_validate",
                                self._clock.now, self._region)
        score = float(self.score_pairs([query], [key])[0])
        if score >= tau_lsm:
            return True
        self.stats.lease_rejections += 1
        return False

    # ------------------------------------------------------------- timing

    def batch_tokens(self, m: int, marginal: float = 0.5) -> float:
        """Virtual-time cost of a judge micro-batch of ``m`` requests:
        the co-location formula (``serving/gpu.judge_batch_tokens``)
        over the model-derived base cost."""
        return judge_batch_tokens(self.base_tokens, m, marginal)


def as_pipeline(judge) -> JudgePipeline:
    """Wrap a raw judge object in a default pipeline (no band, cost
    derived from the default judge config); a JudgePipeline passes
    through unchanged. The seam every ``Seri`` construction funnels
    through."""
    if isinstance(judge, JudgePipeline):
        return judge
    return JudgePipeline(judge)
