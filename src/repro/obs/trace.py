"""Request-lifecycle tracing in virtual time (DESIGN.md §15).

A :class:`Tracer` records *spans*: half-open ``[t0, t1)`` segments of
virtual time attributed to one request (``rid >= 0``) or to background
work (``rid == BACKGROUND``). Spans are emitted by the serving layers at
the instants they already know exactly — the engine's stage-1 flush, the
judge dispatcher, the federation router's response handler, the
freshness manager's refetch — so tracing never pushes clock events, never
draws randomness, and a traced run is bit-identical in virtual time to an
untraced one.

Span taxonomy (request-scoped unless noted):

  ``agent_think`` / ``agent_answer``  accelerator lane time for a think
                                      round / the final answer
  ``stage1_queue_wait``   tool-call arrival -> its stage-1 pass opening
                          (host busy-wait serialization, §12)
  ``stage1_scan``         the pass itself: fixed host cost + RTT to a
                          non-local cache + per-row scan streaming
  ``warm_consult``        extra WARM-tier access latency (§10)
  ``band_bypass``         zero-duration marker: hit served without judge
                          latency (admission-band trust, §14)
  ``judge_queue_wait``    stage-1 resolve -> judge micro-batch submit
                          (the backlog + admission-guardrail wait)
  ``judge_compute``       micro-batch submit -> completion on the judge
                          lane (lane queueing + processor sharing)
  ``peek_rtt``            federation broadcast -> winning response (or
                          the last NAK) (§9)
  ``lease_transfer``      winning response -> transferred value arrival
  ``origin_fetch``        origin WAN fetch incl. rate-limiter wait
  ``refresh``             background: revalidation fetch in flight (§11)
  ``invalidation_drop``   background marker: entry dropped by a
                          change-feed notice (§11)
  ``lease_validate``      background marker: holder-side judge score on
                          an in-band federation lease (§14)

**Conservation law**: for every completed request, its request-scoped
spans — sorted by start time — tile ``[rec.arrival, rec.t_done]``
exactly: the first span starts at the arrival instant, every span ends
where the next begins (float ``==``, no tolerance), and the last ends at
completion. The telescoped sum of the segments is therefore *exactly*
``rec.latency``. :func:`repro.obs.analyze.check_conservation` verifies
this per request; a gap or overlap names the offending boundary.

The disabled path is a zero-allocation no-op: :data:`NULL_TRACER` is a
singleton whose ``span`` is an empty method, and every instrumentation
site either calls it directly (cold paths) or guards a loop with
``tracer.enabled`` (the per-batch hot paths), so an untraced engine does
no per-span work at all.
"""
from __future__ import annotations

from typing import Optional

# rid used for spans that belong to no request (refresh-ahead fetches,
# invalidation drops, holder-side lease validation)
BACKGROUND = -1

# tuple field offsets of one span record (plain tuples: the enabled-path
# cost is one append, nothing else)
RID, NAME, T0, T1, REGION, TAG = range(6)


class Tracer:
    """Span sink shared by every layer of one run (one per engine, or one
    per federation — spans carry the region id either way)."""

    enabled = True
    __slots__ = ("spans",)

    def __init__(self):
        # (rid, name, t0, t1, region, tag) in emission order — which is
        # deterministic (clock event order), making the exported JSONL
        # byte-identical across same-seed runs
        self.spans: list[tuple] = []

    def span(self, rid: int, name: str, t0: float, t1: float,
             region: int = 0, tag: Optional[str] = None) -> None:
        self.spans.append((rid, name, t0, t1, region, tag))

    def marker(self, rid: int, name: str, t: float, region: int = 0,
               tag: Optional[str] = None) -> None:
        """Zero-duration span: an event worth seeing on the timeline that
        consumes no virtual time (band bypass, invalidation drop)."""
        self.spans.append((rid, name, t, t, region, tag))

    def request_spans(self) -> dict[tuple[int, int], list[tuple]]:
        """Request-scoped spans grouped by ``(region, rid)`` — the pair is
        the unique request key under federation, where per-region
        workloads reuse rid ranges."""
        out: dict[tuple[int, int], list[tuple]] = {}
        for s in self.spans:
            if s[RID] >= 0:
                out.setdefault((s[REGION], s[RID]), []).append(s)
        return out


class NullTracer:
    """Disabled tracer: every method is a no-op, no state, no allocation.
    The singleton :data:`NULL_TRACER` is the default everywhere a tracer
    can be threaded."""

    enabled = False
    __slots__ = ()

    def span(self, rid, name, t0, t1, region=0, tag=None) -> None:
        return None

    def marker(self, rid, name, t, region=0, tag=None) -> None:
        return None


NULL_TRACER = NullTracer()
