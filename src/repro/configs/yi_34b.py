"""yi-34b [dense] — arXiv:2403.04652. Llama-style GQA.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

TP note: 56 query heads do not divide the 16-way model axis; we pad to 64
heads (the standard Megatron head-padding tradeoff, ~14% attention-FLOP
waste, visible in the MODEL_FLOPS/HLO_FLOPS ratio — see DESIGN.md §6).
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig

NAME = "yi-34b"
PAPER_N_HEADS = 56  # faithful head count (used for MODEL_FLOPS accounting)


@register(NAME)
def config() -> ModelConfig:
    attn = AttnConfig(
        n_heads=64,  # padded from 56 for TP16 divisibility
        n_kv_heads=8,
        head_dim=128,
        rope_theta=5_000_000.0,
    )
    return ModelConfig(
        name=NAME,
        family="dense",
        d_model=7168,
        vocab_size=64000,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=20480),),
        n_repeat=60,
        tie_embeddings=False,
    )
