"""Optimizer, compression, checkpoint and supervisor tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import latest_step, restore, save
from repro.train.compression import (
    ef_compress_tree, ef_init, int8_dequantize, int8_quantize,
    wire_bytes_dense, wire_bytes_int8,
)
from repro.train.optim import AdamWConfig, adamw_update, init_state, lr_at
from repro.train.supervisor import FaultInjector, Supervisor


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, schedule="const")
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = init_state(cfg, params)
    target = jnp.array([1.0, 1.0, 1.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, m = adamw_update(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, 0)) == 0.0
    assert abs(float(lr_at(cfg, 10)) - 1.0) < 1e-6
    assert float(lr_at(cfg, 100)) <= 1.0
    assert float(lr_at(cfg, 100)) >= cfg.min_lr_frac - 1e-6


def test_grad_clip():
    from repro.train.optim import clip_by_global_norm

    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5


def test_int8_roundtrip_bound(rng):
    x = jnp.asarray(rng.standard_normal((1000,)) * 3.0, jnp.float32)
    z = int8_quantize(x, block=128)
    y = int8_dequantize(z)
    err = np.abs(np.asarray(x - y))
    scales = np.repeat(np.asarray(z.scale), 128)[: x.size]
    assert (err <= scales * 0.5 + 1e-7).all()
    assert wire_bytes_int8({"x": x}) < wire_bytes_dense({"x": x}) / 3


def test_error_feedback_converges():
    """Top-k EF gradient descent still reaches the optimum (quadratic)."""
    w = jnp.array([4.0, -2.0, 1.5, 8.0])
    target = jnp.zeros(4)
    res = ef_init({"w": w})
    for _ in range(300):
        g = {"w": 2 * (w - target)}
        _, res, dense = ef_compress_tree(g, res, frac=0.25)
        w = w - 0.05 * dense["w"]
    np.testing.assert_allclose(np.asarray(w), 0.0, atol=1e-2)


def test_checkpoint_roundtrip_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
                "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
        th = save(d, 7, tree, extra={"next_step": 7}, async_write=True)
        th.join()
        assert latest_step(d) == 7
        assert not any(x.endswith(".tmp") for x in os.listdir(d))
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
        )
        out, extra = restore(d, 7, like)
        assert extra["next_step"] == 7
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))


def test_checkpoint_retention():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save(d, s, {"x": jnp.zeros(2)}, async_write=False, keep_last=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2 and latest_step(d) == 5


def test_supervisor_restart_exactness():
    """The loss sequence with an injected failure + restart equals the
    uninterrupted sequence (restart-idempotent training)."""

    def make_run(fail_at):
        with tempfile.TemporaryDirectory() as d:
            sup = Supervisor(d, save_every=5,
                             injector=FaultInjector(fail_at))

            def init():
                return {"w": jnp.array(10.0)}

            def step_fn(state, step):
                w = state["w"] * 0.9
                return {"w": w}, {"loss": float(w)}

            res = sup.run(init_state=init, step_fn=step_fn, n_steps=20)
            return res

    clean = make_run(set())
    faulty = make_run({12})
    assert faulty.restarts == 1
    assert clean.losses[-1] == pytest.approx(faulty.losses[-1])
