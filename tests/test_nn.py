"""nn-layer unit tests: flash == naive sdpa (fwd+grad), prefill→decode
consistency, rope/sharding properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, shrink
from repro.models.lm import LM
from repro.nn.attention import _causal_mask, _sdpa
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig
from repro.nn.flash import sdpa_flash
from repro.nn.param import init_tree
from repro.nn.sharding import ShardCtx, ShardingConfig, resolve_pspec

CTX = ShardCtx(None)


@pytest.mark.parametrize(
    "s,h,kvh,dh,causal,window,chunk",
    [
        (256, 4, 2, 32, True, None, 64),
        (512, 8, 8, 16, True, 100, 128),
        (128, 4, 1, 64, False, None, 32),
    ],
)
def test_flash_matches_naive(s, h, kvh, dh, causal, window, chunk, rng):
    b = 2
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, dh)), jnp.float32)
    scale = 1 / np.sqrt(dh)
    if causal:
        mask = _causal_mask(s, s, 0, window)[None]
    else:
        mask = jnp.ones((1, s, s), bool)
    o_ref = _sdpa(CTX, q, k, v, mask, scale)
    o = sdpa_flash(q, k, v, scale, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)
    # gradients
    f_ref = lambda q, k, v: jnp.sum(jnp.sin(_sdpa(CTX, q, k, v, mask, scale)))
    f = lambda q, k, v: jnp.sum(jnp.sin(
        sdpa_flash(q, k, v, scale, causal=causal, window=window, chunk=chunk)
    ))
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-4)


def test_prefill_decode_consistency(rng):
    """decode(t | prefill(0..t-1) cache) == full forward at position t."""
    attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16)
    cfg = ModelConfig(
        "t", "dense", 64, 97,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=128),), n_repeat=2,
        param_dtype="float32", compute_dtype="float32",
    )
    lm = LM(cfg)
    params = init_tree(jax.random.PRNGKey(0), lm.param_specs())
    S = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0, 97)

    # full forward logits at position S-? : loss path gives (B,S,V)
    x = lm._embed(CTX, params, toks)
    pos = lm._positions(toks)
    h, _, _ = lm._run_stack(CTX, params, x, pos)
    full_logits = lm._logits(CTX, params, h)  # (1, S+1, V)

    # prefill on first S tokens, then decode token S
    _, caches = lm.prefill(CTX, params, {"tokens": toks[:, :S]})
    # pad prefill caches to S+1 slots
    def pad(c):
        if c.ndim >= 2 and c.shape[-2 if False else 1] == S:
            widths = [(0, 0)] * c.ndim
            widths[1] = (0, 1)
            return jnp.pad(c, widths)
        return c
    # caches: blocks stacked trees with k/v (n_repeat, B, S, kv, dh)
    caches = jax.tree.map(
        lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, 1)] + [(0, 0)] * (c.ndim - 3))
        if c.ndim >= 3 and c.shape[2] == S else c,
        caches,
    )
    lg, _ = lm.decode(CTX, params, toks[:, S:S + 1], caches, jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(lg[0, 0]), np.asarray(full_logits[0, S]), atol=2e-4
    )


def test_sliding_window_ring_decode_matches_full(rng):
    """Ring-buffer sliding-window decode == full attention with window mask."""
    attn = AttnConfig(n_heads=2, n_kv_heads=2, head_dim=16, window=8)
    cfg = ModelConfig(
        "t", "dense", 32, 61,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=64),), n_repeat=1,
        param_dtype="float32", compute_dtype="float32",
    )
    lm = LM(cfg)
    params = init_tree(jax.random.PRNGKey(0), lm.param_specs())
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0, 61)
    x = lm._embed(CTX, params, toks)
    h, _, _ = lm._run_stack(CTX, params, x, lm._positions(toks))
    full_logits = lm._logits(CTX, params, h)

    # replay decode step-by-step through the ring cache
    caches = init_tree(jax.random.PRNGKey(2), lm.cache_specs(1, S + 1))
    caches = jax.tree.map(jnp.zeros_like, caches)
    for t in range(S + 1):
        lg, caches = lm.decode(
            CTX, params, toks[:, t:t + 1], caches, jnp.int32(t)
        )
    np.testing.assert_allclose(
        np.asarray(lg[0, 0]), np.asarray(full_logits[0, S]), atol=3e-4
    )


@given(
    dim=st.integers(1, 4096),
    data=st.integers(1, 16),
    model=st.integers(1, 16),
)
@settings(max_examples=60, deadline=None)
def test_resolve_pspec_divisibility(dim, data, model):
    """Best-effort sharding never assigns an axis that doesn't divide."""
    import numpy as _np
    from jax.sharding import Mesh

    devs = _np.array(jax.devices()[:1] * (1)).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    # fake the sizes by monkeypatching shape lookup via a stub mesh object
    class StubMesh:
        shape = {"data": data, "model": model}
        axis_names = ("data", "model")

    ps = resolve_pspec(StubMesh(), ("fsdp", "model"), (dim, dim))
    prod = 1
    for entry, d in zip(tuple(ps) + (None,) * 2, (dim, dim)):
        names = (
            () if entry is None
            else (entry,) if isinstance(entry, str) else entry
        )
        sz = 1
        for n in names:
            sz *= StubMesh.shape[n]
        assert d % sz == 0
