"""CortexCache — the cache abstraction layered on Seri (paper §4.3).

Turns probabilistic similarity into deterministic cache semantics:

* semantic-aware HIT — only after the full two-stage pipeline validates a
  candidate; a hit increments the SE's frequency.
* admission — every remote fetch result is inserted as a new SE with
  judge-estimated staticity → TTL; prefetched items enter with freq=0.
* LCFU eviction (Algorithm 2) — TTL purge first, then evict lowest
  value-score until under capacity.
* capacity is byte-based (cache_ratio × workload footprint in the
  benchmarks, matching the paper's "cache size ratio" axis).

Runtime layout (DESIGN.md §8): SE metadata lives in ``SEStore`` parallel
arrays row-aligned with the ``VectorIndex``, so the TTL purge is a boolean
mask, LCFU scoring is one vectorized expression, and victim selection uses
``argpartition`` instead of a full sort. ``lookup``/``insert`` are
one-element wrappers over ``lookup_batch``/``insert_batch`` internals, so
the scalar and batched paths share semantics by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.se_store import SEStore, SEStoreMapping
from repro.core.semantic_element import SemanticElement, ttl_from_staticity
from repro.core.seri import Seri, SeriResult, VectorIndex
from repro.obs.metrics import ScanMetrics


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    ttl_evictions: int = 0
    invalidations: int = 0    # dropped by change-feed notice (freshness)
    judge_calls: int = 0
    prefetch_inserts: int = 0
    prefetch_hits: int = 0
    bytes_stored: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class CortexCache:
    def __init__(
        self,
        seri: Seri,
        *,
        capacity_bytes: int,
        max_ttl: float = 3600.0,
        min_ttl: float = 30.0,
        eviction: str = "lcfu",  # lcfu | lru | lfu (paper Table 6 ablation)
    ):
        self.seri = seri
        self.capacity_bytes = capacity_bytes
        self.max_ttl = max_ttl
        self.min_ttl = min_ttl
        self.eviction = eviction
        self.soa = SEStore(seri.index.capacity)
        self.store = SEStoreMapping(self.soa)  # dict-like se_id -> SE view
        self.usage = 0
        self.stats = CacheStats()
        # stage-1 scan accounting (DESIGN.md §12/§15). Deliberately NOT
        # in CacheStats: scan volume is batch-granularity dependent (a
        # scalar replay scans the index once per QUERY, a batched run
        # once per PASS), and CacheStats holds only quantities the
        # scalar and batched paths must agree on — same reasoning that
        # keeps warm_lookups in TierStats. First-class home:
        # obs.metrics.ScanMetrics (caveats documented there); the legacy
        # attribute names remain as read-only properties below.
        self.scan = ScanMetrics()
        self._next_id = 0
        # freshness seam: the tiered cache fires this when a warm entry
        # re-enters HOT, so the FreshnessManager can re-arm its
        # refresh-ahead timer (the timer dies while an entry sits warm)
        self.on_promote = None

    @property
    def rows(self) -> dict[int, int]:
        """se_id -> index row (row-aligned SoA: the store's own map)."""
        return self.soa.id2row

    # legacy scan-counter names (pre-§15), now backed by ScanMetrics.
    # ``last_scan_rows`` is the most recent pass (both tiers), consumed
    # synchronously by the engine for the scan-proportional latency term;
    # ``rows_scanned`` is the running total; the *_shard variants are the
    # §13 max-over-shards companions (equal whenever stage1_shards == 1).

    @property
    def last_scan_rows(self) -> int:
        return self.scan.last_rows

    @property
    def rows_scanned(self) -> int:
        return self.scan.total_rows

    @property
    def last_scan_shard_rows(self) -> int:
        return self.scan.last_max_shard_rows

    @property
    def rows_scanned_max_shard(self) -> int:
        return self.scan.total_max_shard_rows

    @property
    def stage1_shards(self) -> int:
        """Mesh shards the stage-1 index is partitioned over (DESIGN.md
        §13); 1 = unsharded. Both tiers share the shard count (the warm
        router is built from the same ClusterConfig)."""
        rt = self.seri.index.router
        return rt.n_shards if rt is not None else 1

    # ------------------------------------------------------------ lookup

    def account_hit(self, se: SemanticElement, now: float) -> None:
        """Shared hit bookkeeping — EVERY validated-hit path (full lookup,
        staged finalize, the engine's ANN-only ablation) must route through
        here so freq/last_access/hits/prefetch_hits stay comparable across
        modes."""
        se.freq += 1
        se.last_access = now
        self.stats.hits += 1
        if se.prefetched and se.freq == 1:
            self.stats.prefetch_hits += 1

    def lookup(self, query: str, q_emb: np.ndarray, now: float) -> SeriResult:
        return self.lookup_batch([query], q_emb[None], now)[0]

    def _stage1_blocks(self, q_embs: np.ndarray, now: float):
        """Stage 1 for a query block. Returns ``(blocks, flags)``:
        per-query ``(cands, sims)`` with sims ALIGNED to the surviving
        (unexpired) candidates, plus a per-query slow-tier-consult flag
        (always False here). The single stage-1 seam — the tiered cache
        overrides this to consult its warm tier, and every lookup flavor
        below goes through it."""
        # gate at the admission band's lower edge when a band is armed
        # (DESIGN.md §14): borderline candidates surface so the judge
        # can recover them; τ_sim exactly otherwise
        found = self.seri.index.search_batch(
            np.asarray(q_embs), self.seri.top_k, self.seri.stage1_gate
        )
        self.scan.note_pass(self.seri.index.last_scanned,
                            self.seri.index.last_scanned_max_shard)
        out = []
        for se_ids, sims in found:
            # revalidating rows are KNOWN stale (change-feed notice,
            # refetch in flight) — a miss now is a correct answer later
            keep = [
                j for j, i in enumerate(se_ids)
                if i in self.store and not self.store[i].expired(now)
                and not self.store[i].revalidating
            ]
            out.append(([self.store[se_ids[j]] for j in keep],
                        np.asarray(sims[keep], np.float32)))
        return out, [False] * len(out)

    def _judge_blocks(self, queries: Sequence[str], blocks,
                      now: float) -> list[SeriResult]:
        """Stage 2 over pre-fetched stage-1 blocks: candidates of every
        query validated in ONE ``score_pairs`` call (pair order = query
        order, candidate order — exactly the order sequential scalar
        calls would use, so per-pair-seeded judges draw identical
        scores), then per-query ``finalize`` applies hit bookkeeping in
        query order. Admission-band bypass (DESIGN.md §14) is applied
        per block BEFORE flattening: a block whose best similarity
        clears the band's upper edge serves its top candidate without
        judging (``judge_calls=0``; ``best_score`` then reports the
        stage-1 similarity, not a judge score). With no band armed every
        non-empty block is judged — identical to the legacy path."""
        pipe = self.seri.pipeline
        results: list[Optional[SeriResult]] = [None] * len(queries)
        flat_q: list[str] = []
        flat_key: list[str] = []
        judged: list[int] = []
        for i, (query, (cands, sims)) in enumerate(zip(queries, blocks)):
            if not cands:
                self.stats.misses += 1
                results[i] = SeriResult(False, None, 0, 0, 0.0, sims)
                continue
            if pipe.admit(sims, self.seri.tau_sim) == "bypass":
                se = self._rebind(cands[0], now)
                if se is not None:
                    self.account_hit(se, now)
                    results[i] = SeriResult(True, se, len(cands), 0,
                                            float(sims[0]), sims)
                    continue
                # top candidate vanished between stages — judge the rest
            flat_q.extend([query] * len(cands))
            flat_key.extend(c.key for c in cands)
            judged.append(i)
        flat_scores = (
            pipe.score_pairs(flat_q, flat_key) if flat_q
            else np.zeros(0, np.float32)
        )
        off = 0
        for i in judged:
            cands, sims = blocks[i]
            m = len(cands)
            results[i] = self.finalize(queries[i], cands,
                                       flat_scores[off:off + m], now,
                                       sims=sims)
            off += m
        return results

    def lookup_batch(self, queries: Sequence[str], q_embs: np.ndarray,
                     now: float) -> list[SeriResult]:
        """Batched full lookup: stage 1 for the whole block in one masked
        matmul / ``ann_topk`` launch, stage 2 in one judge call. Hit
        bookkeeping is applied in query order, so the hit/miss sequence is
        identical to sequential scalar lookups from the same state."""
        self.stats.lookups += len(queries)
        blocks, _ = self._stage1_blocks(q_embs, now)
        return self._judge_blocks(queries, blocks, now)

    # ---------------------------------------------------- staged lookup
    # The serving engine needs the two Seri stages split so the judge can
    # run as an async (deferrable) accelerator job (paper §4.4): stage1 =
    # ANN candidates; finalize = apply judge scores -> deterministic hit.

    def stage1(self, query: str, q_emb: np.ndarray, now: float):
        return self.stage1_batch([query], q_emb[None], now)[0]

    def stage1_batch(self, queries: Sequence[str], q_embs: np.ndarray,
                     now: float) -> list[list[SemanticElement]]:
        """ANN candidates for a query block (engine micro-batching)."""
        blocks, _ = self.stage1_batch_flagged(queries, q_embs, now)
        return [cands for cands, _ in blocks]

    def stage1_batch_flagged(self, queries: Sequence[str],
                             q_embs: np.ndarray, now: float):
        """``stage1_batch`` plus per-query slow-tier-consult flags (all
        False for the single-tier cache). Returns ``(blocks, flags)``
        with blocks = per-query ``(cands, sims)`` — the engine needs the
        aligned similarities for admission-band classification. The
        engine reads the flags for per-tier latency accounting — the
        consult policy is the cache's, and the engine must never
        re-derive it."""
        self.stats.lookups += len(queries)
        return self._stage1_blocks(q_embs, now)

    def _rebind(self, se, now: float):
        """Return the live HOT-tier view for a judge-validated winner, or
        None if it vanished between stage 1 and judge completion — or
        went revalidating meanwhile (serving it would serve known-stale
        knowledge). The tiered subclass overrides this to promote
        warm-tier winners."""
        if se.se_id not in self.store:
            return None
        live = self.store[se.se_id]
        return None if live.revalidating else live

    def finalize(self, query: str, cands, scores, now: float,
                 sims: Optional[np.ndarray] = None) -> SeriResult:
        self.stats.judge_calls += len(cands)
        if sims is None:
            sims = np.zeros(0, np.float32)
        # full-sort audit (ISSUE 5): the COMPLETE descending order is
        # semantically required here — the loop walks past winners whose
        # rows vanished between stage 1 and judge completion — and
        # len(scores) ≤ top_k (≤ 4 by default), so argpartition has
        # nothing to win. Hot-path top-k selections use
        # ``seri.topk_desc``/``topk_desc_stable`` instead.
        order = np.argsort(-np.asarray(scores))
        best = float(scores[order[0]]) if len(cands) else 0.0
        for j in order:
            if scores[j] >= self.seri.tau_lsm:
                se = self._rebind(cands[j], now)
                if se is None:  # evicted meanwhile
                    continue
                self.account_hit(se, now)
                return SeriResult(True, se, len(cands), len(cands), best,
                                  sims)
        self.stats.misses += 1
        return SeriResult(False, None, len(cands), len(cands), best, sims)

    def miss_no_candidates(self) -> None:
        self.stats.misses += 1

    # ------------------------------------------------------------ admit

    def insert(
        self,
        query: str,
        q_emb: np.ndarray,
        value: Any,
        *,
        now: float,
        cost: float,
        latency: float,
        size: int,
        staticity: Optional[int] = None,
        prefetched: bool = False,
        intent: Optional[int] = None,
        ttl: Optional[float] = None,
        origin: Optional[int] = None,
        version: int = 0,
        fetched_at: Optional[float] = None,
    ) -> SemanticElement:
        # `is None`, not truthiness: staticity 0 is a legitimate caller
        # override and must not trigger a judge re-estimate
        if staticity is None:
            staticity = self.seri.pipeline.staticity(query)
        if ttl is None:
            # explicit ttl: federated transfers admit with the SOURCE
            # entry's remaining lifetime so a copy never outlives its origin
            ttl = ttl_from_staticity(staticity, self.max_ttl, self.min_ttl)
        self._make_room(size, now)
        if self.seri.index.full:
            self._evict_n(1, now)
        se_id = self._next_id
        self._next_id += 1
        row = self.seri.index.add(se_id, q_emb)
        se = self.soa.add(
            row, se_id,
            key=query,
            value=value,
            staticity=staticity,
            cost=cost,
            latency=latency,
            size=size,
            created_at=now,
            expires_at=now + ttl,
            # the triggering miss counts as an access; only speculative
            # prefetches enter cold (paper §4.3: "prefetched items enter
            # with zero frequency")
            freq=0 if prefetched else 1,
            last_access=now,
            prefetched=prefetched,
            intent=intent,
            origin=origin,
            version=version,
            fetched_at=fetched_at,
        )
        self.usage += size
        self.stats.insertions += 1
        if prefetched:
            self.stats.prefetch_inserts += 1
        self.stats.bytes_stored = self.usage
        return se

    def insert_batch(self, items: Sequence[dict], *,
                     now: float) -> list[SemanticElement]:
        """Admit a block of fetch results. Staticity estimation is batched
        through the judge up front; the admissions themselves apply in
        order (each may trigger eviction that the next must observe), so
        the eviction sequence matches sequential ``insert`` calls."""
        staticities = [
            it["staticity"] if it.get("staticity") is not None
            else self.seri.pipeline.staticity(it["query"])
            for it in items
        ]
        out = []
        for it, st in zip(items, staticities):
            kw = dict(it)
            q = kw.pop("query")
            emb = kw.pop("q_emb")
            value = kw.pop("value")
            kw["staticity"] = st
            out.append(self.insert(q, emb, value, now=now, **kw))
        return out

    def insert_block(self, queries: Sequence[str], q_embs: np.ndarray,
                     values: Sequence[Any], *, now: float, cost: float,
                     latency: float, size: int, staticity: int,
                     ttl: float) -> np.ndarray:
        """Bulk admission for large prefills (the million-entry scaling
        sweeps): one index ``add_batch`` + one SoA ``add_block`` instead
        of n scalar ``insert`` calls. No judge, no eviction — every
        entry shares the scalar economics and the CALLER guarantees
        capacity (index rows checked here; byte budget is the caller's).
        Returns the assigned se_ids."""
        n = len(queries)
        if self.seri.index.capacity - len(self.seri.index) < n:
            raise RuntimeError("insert_block needs free index capacity")
        ids = np.arange(self._next_id, self._next_id + n, dtype=np.int64)
        self._next_id += n
        rows = self.seri.index.add_batch(ids, q_embs)
        self.soa.add_block(
            rows, ids, keys=queries, values=values, staticity=staticity,
            cost=cost, latency=latency, size=size, created_at=now,
            expires_at=now + ttl,
        )
        self.usage += size * n
        self.stats.insertions += n
        self.stats.bytes_stored = self.usage
        return ids

    def peek_semantic_scored(self, query: str, q_emb: np.ndarray,
                             now: float):
        """Best live stage-1 match WITHOUT any bookkeeping (no stats, no
        freq bump, no judge), as ``(se, sim)`` — or None. The gate is
        ``seri.stage1_gate``, so an armed admission band also widens the
        peek (in-band peers become lease candidates the pipeline can
        validate); with no band this is the τ_sim gate exactly."""
        se_ids, sims = self.seri.index.search(
            q_emb, self.seri.top_k, self.seri.stage1_gate
        )
        for i, sim in zip(se_ids, sims):  # similarity-descending
            if i in self.store:
                se = self.store[i]
                if not se.expired(now) and not se.revalidating:
                    return se, float(sim)
        return None

    def peek_semantic(self, query: str, q_emb: np.ndarray,
                      now: float) -> Optional[SemanticElement]:
        """Scored peek minus the similarity. Used by the prefetcher's
        presence check. NOTE: this trusts the ANN gate alone — callers
        that ship the value somewhere (federation leases) go through
        ``peek_lease`` so a stage-1 false positive (e.g. a confusable
        pair above τ_sim) can be caught by the judge pipeline instead of
        propagating as an info_accuracy loss."""
        hit = self.peek_semantic_scored(query, q_emb, now)
        return hit[0] if hit is not None else None

    def peek_lease(self, query: str, q_emb: np.ndarray,
                   now: float) -> Optional[SemanticElement]:
        """Federation's peek/lease validation through the one judge
        seam (DESIGN.md §14): ANN peek, then
        ``JudgePipeline.validate_lease`` decides whether the candidate
        ships — trust-band leases stay ANN-only (every lease, when no
        band is armed — the legacy protocol exactly), in-band leases pay
        one judge score at the HOLDER and must clear τ_lsm."""
        hit = self.peek_semantic_scored(query, q_emb, now)
        if hit is None:
            return None
        se, sim = hit
        if not self.seri.pipeline.validate_lease(
            query, se.key, sim, self.seri.tau_sim, self.seri.tau_lsm
        ):
            return None
        return se

    def contains_semantic(self, query: str, q_emb: np.ndarray,
                          now: float) -> bool:
        """Peek (no stats, no freq bump) — used by the prefetcher."""
        return self.peek_semantic(query, q_emb, now) is not None

    # --------------------------------------------------------- freshness
    # Mechanism only — the *policy* (drop vs revalidate, who refreshes a
    # federated copy) lives in core/freshness.py:FreshnessManager.

    def ses_for_intent(self, intent) -> list:
        """Live SE views whose admission-time intent equals ``intent``,
        in se_id (insertion) order — the invalidation fan-out set,
        O(matching) via the store's intent index. The tiered subclass
        appends its warm-tier views."""
        ids = self.soa.by_intent.get(intent)
        return [self.store[i] for i in sorted(ids)] if ids else []

    def has_intent(self, intent) -> bool:
        """Any live entry for this intent? O(1) — the change feed's
        keep-watching predicate."""
        return intent in self.soa.by_intent

    def invalidate_se(self, se_id: int, now: float) -> bool:
        """Drop one entry because its origin knowledge changed. Counted
        as ``invalidations`` — NOT an eviction (it did not lose a
        capacity contest) and NOT a TTL lapse. Never demotes: a
        known-stale value is not worth keeping in any tier."""
        row = self.soa.id2row.get(se_id)
        if row is None:
            return False
        self._drop_rows(np.asarray([row]))
        self.stats.invalidations += 1
        return True

    def refresh_entry(self, se_id: int, *, value: Any, version: int,
                      now: float,
                      ttl: Optional[float] = None
                      ) -> Optional[SemanticElement]:
        """Revalidate an entry IN PLACE: new value + version, fetch
        timestamp bumped, expiry renewed (staticity-derived TTL unless
        given). The row, se_id, embedding, and hit statistics all
        survive — live ``SemanticElement`` views across the refresh keep
        working, which is what lets refresh-ahead renew an entry while a
        judge micro-batch still holds views on it. Size is unchanged by
        construction (a refresh re-fetches the same intent's value)."""
        row = self.soa.id2row.get(se_id)
        if row is None:
            return None
        if ttl is None:
            ttl = ttl_from_staticity(
                int(self.soa.staticity[row]), self.max_ttl, self.min_ttl
            )
        self.soa.value[row] = value
        self.soa.version[row] = version
        self.soa.fetched_at[row] = now
        self.soa.freq_at_fetch[row] = self.soa.freq[row]
        self.soa.expires_at[row] = now + ttl
        self.soa.revalidating[row] = False
        return self.store[se_id]

    # ------------------------------------------------------------ evict

    def _remove(self, se_id: int, *, ttl: bool) -> None:
        row = self.soa.id2row[se_id]
        self._remove_rows(np.asarray([row]), ttl=ttl)

    def _drop_rows(self, rows: np.ndarray) -> None:
        """Free hot rows (index + SoA + usage) WITHOUT eviction stats —
        the shared tail of eviction, TTL purge, and tier demotion."""
        freed = int(self.soa.size[rows].sum())
        self.seri.index.remove_rows(rows)
        for r in rows:
            self.soa.remove_row(int(r))
        self.usage -= freed
        self.stats.bytes_stored = self.usage

    def _remove_rows(self, rows: np.ndarray, *, ttl: bool) -> None:
        """Batched removal: index rows + SoA fields in one pass."""
        n = len(rows)
        if not n:
            return
        self._drop_rows(rows)
        if ttl:
            self.stats.ttl_evictions += n
        else:
            self.stats.evictions += n

    def purge_expired(self, now: float) -> int:
        """TTL purge as one boolean mask over the SoA arrays."""
        dead = self.soa.expired_rows(now)
        self._remove_rows(dead, ttl=True)
        return len(dead)

    def _retire_victims(self, victims: np.ndarray, now: float) -> None:
        """Victim sink: base cache evicts outright; the tiered cache
        overrides this to demote into its warm tier instead."""
        self._remove_rows(victims, ttl=False)

    def _make_room(self, incoming: int, now: float) -> None:
        if self.usage + incoming <= self.capacity_bytes:
            return
        self.purge_expired(now)  # TTL purge first (Algorithm 2 line 6)
        need = self.usage + incoming - self.capacity_bytes
        if need <= 0:
            return
        victims = self.soa.victim_rows(now, self.eviction, need_bytes=need)
        self._retire_victims(victims, now)

    def _evict_n(self, n: int, now: float) -> None:
        victims = self.soa.victim_rows(now, self.eviction, n=n)
        self._retire_victims(victims, now)

    # ------------------------------------------------------------ misc

    def __len__(self) -> int:
        return len(self.store)


def make_cache(
    *,
    capacity_bytes: int,
    dim: int,
    judge,
    index_capacity: int = 8192,
    tau_sim: float = 0.9,
    tau_lsm: float = 0.9,
    top_k: int = 4,
    eviction: str = "lcfu",
    max_ttl: float = 3600.0,
    backend: str = "numpy",
    cluster=None,
) -> CortexCache:
    """``cluster`` (a ``core.clustering.ClusterConfig``) switches stage 1
    to the clustered IVF routing (DESIGN.md §12); None = brute force."""
    router = None
    if cluster is not None:
        from repro.core.clustering import ClusterRouter

        router = ClusterRouter(index_capacity, dim, cluster)
    index = VectorIndex(index_capacity, dim, backend=backend,
                        router=router)
    seri = Seri(index, judge, tau_sim=tau_sim, tau_lsm=tau_lsm, top_k=top_k)
    return CortexCache(
        seri, capacity_bytes=capacity_bytes, max_ttl=max_ttl,
        eviction=eviction,
    )
