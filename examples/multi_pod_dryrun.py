"""Lower+compile one (arch × shape) cell on the 512-chip multi-pod
production mesh and print its memory/cost/roofline analysis.

Run:  PYTHONPATH=src python examples/multi_pod_dryrun.py [arch] [shape]
"""
import subprocess
import sys

arch = sys.argv[1] if len(sys.argv) > 1 else "granite-3-8b"
shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"

# dryrun must own process start (XLA_FLAGS before jax import)
subprocess.run(
    [sys.executable, "-m", "repro.launch.dryrun",
     "--arch", arch, "--shape", shape, "--multi-pod"],
    check=True,
)
