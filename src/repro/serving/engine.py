"""Virtual-time discrete-event serving engine.

Reproduces the paper's end-to-end pipeline (Fig 4/6): agent think rounds on
the accelerator, tool calls intercepted by the data client, two-stage cache
lookups with the judge as a *deferrable* accelerator job (timeout ⇒ treated
as a miss — the paper's degradation-not-blocking property), remote fetches
through the rate-limited WAN service, LCFU admission/eviction, Markov
prefetching, and periodic threshold recalibration.

Concurrent requests are *micro-batched* (DESIGN.md §8): stage-1 lookups
that land within one host-path window are flushed together through
``CortexCache.stage1_batch`` (one masked matmul over the whole query
block), and the judge dispatcher drains its backlog in micro-batches —
one accelerator job and ONE ``score_pairs`` call per batch, with the
shared prompt prefill amortized across co-batched requests (§4.4).

Modes: "vanilla" (no cache), "exact" (exact-match KV cache),
"cortex" (full), "cortex-nojudge" (ANN-only ablation, Fig 13).

Events live on a :class:`~repro.serving.clock.VirtualClock`. A solo
engine owns a private clock; under federation (DESIGN.md §9) every
per-region engine shares ONE clock, and an optional ``router`` redirects
cache misses through the cross-region peek/transfer path before the
origin WAN fetch.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.cache import CortexCache
from repro.core.prefetch import MarkovPrefetcher
from repro.core.semantic_element import ttl_from_staticity
from repro.core.recalibrate import EvalRecord, recalibrate
from repro.data.workloads import Request
from repro.data.world import SemanticWorld
from repro.obs.metrics import (STALE_AGE_EDGES, FixedHistogram,
                               MetricsRegistry, percentile)
from repro.obs.sampler import limiter_headroom
from repro.obs.trace import NULL_TRACER
from repro.serving.clock import VirtualClock
from repro.serving.gpu import GPU, GPUConfig, judge_batch_tokens
from repro.serving.remote import RemoteDataService


@dataclasses.dataclass
class EngineConfig:
    think_tokens: float = 160.0
    answer_tokens: float = 160.0
    judge_tokens: Optional[float] = None  # prefill-only classification
                                        # job cost; None (default) =
                                        # derive from the judge model
                                        # config's prefill FLOPs via the
                                        # cache's JudgePipeline
                                        # (DESIGN.md §14). A float pins
                                        # the legacy hand-set cost.
    t_cache_cpu: float = 0.02           # embed + ANN fixed cost (Fig 11)
    t_cache_per_row: float = 0.0        # stage-1 cost PER ROW SCANNED:
                                        # the full pass costs
                                        # t_cache_cpu + per_row · rows,
                                        # so index size (and the IVF
                                        # router's sublinear scan,
                                        # DESIGN.md §12) shows up in
                                        # end-to-end latency. 0 = legacy
                                        # flat-cost model.
    t_shard_merge: float = 0.0          # cross-shard top-k merge cost
                                        # per stage-1 pass (§13): added
                                        # to the scan term only when the
                                        # cache's stage-1 index is
                                        # partitioned (stage1_shards>1)
    judge_timeout: float = 0.25         # deferred validation ⇒ miss
    judge_batch_max: int = 8            # judge micro-batch size cap (§4.4)
    judge_batch_marginal: float = 0.5   # marginal prefill cost per co-batched req
    cache_access_latency: float = 0.0   # RTT to a non-local (global) cache
    t_cache_warm: float = 0.01          # extra stage-1 latency when the
                                        # WARM tier is consulted (§10)
    closed_loop: Optional[int] = None   # concurrency, or None = open loop
    prefetch: bool = True
    prefetch_confidence: float = 0.55
    prefetch_min_headroom: float = 0.2
    recalibrate_every: Optional[float] = None  # seconds; None = off
    recal_samples: int = 16             # ground-truth fetches per tick
    recal_smooth: float = 0.5           # EMA weight on the new tau estimate
    p_target: float = 0.99
    em_p_base: float = 0.79             # EM | correct info (per dataset)
    em_p_wrong: float = 0.10            # EM | wrong cached info
    gpu_cost_per_hour: float = 1.49     # Table 5
    warmup_frac: float = 0.0            # exclude first fraction from stats
    stale_age_reservoir: Optional[int] = None  # bound the stale-age
                                        # histogram's raw-sample list to
                                        # a seeded reservoir of this size
                                        # (long burst runs, §16); None =
                                        # raw retention, the
                                        # stale_age_mean bit-parity mode
    seed: int = 0


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    t_done: float = 0.0
    latency: float = 0.0
    agent_time: float = 0.0
    cache_time: float = 0.0
    remote_time: float = 0.0
    rounds: int = 0
    cache_hits: int = 0
    remote_calls: int = 0
    peer_transfers: int = 0   # remote calls served by a sibling region
    info_correct: bool = True
    em_correct: bool = False


@dataclasses.dataclass
class _ReqState:
    req: Request
    rec: RequestRecord
    round: int = 0
    round_t0: float = 0.0
    info_values: list = dataclasses.field(default_factory=list)


class ExactCache:
    """Exact-key baseline (Agent_exact): byte-identical query match, LRU.

    Freshness parity with the semantic cache: inserts that carry a
    staticity class age through the same ``ttl_from_staticity`` curve, so
    the exact and semantic baselines expire comparably instead of the
    exact cache serving every entry for the full ``max_ttl``."""

    def __init__(self, capacity_bytes: int, max_ttl: float = 3600.0,
                 min_ttl: float = 30.0):
        self.capacity = capacity_bytes
        self.max_ttl = max_ttl
        self.min_ttl = min_ttl
        self.d: dict[str, tuple[Any, float, int]] = {}  # val, expires, size
        # LRU order; deque so the evict-side popleft is O(1) (the
        # recency-bump ``remove`` stays O(n) either way)
        self.order: collections.deque[str] = collections.deque()
        self.usage = 0
        self.hits = 0
        self.lookups = 0

    def lookup(self, query: str, now: float):
        self.lookups += 1
        ent = self.d.get(query)
        if ent is None:
            return None
        if now >= ent[1]:
            # expired: reclaim the bytes NOW — leaving the entry resident
            # kept its size counted in `usage` forever, silently shrinking
            # effective capacity with every TTL lapse
            self.usage -= self.d.pop(query)[2]
            self.order.remove(query)
            return None
        self.hits += 1
        self.order.remove(query)
        self.order.append(query)
        return ent[0]

    def insert(self, query: str, value, size: int, now: float,
               staticity: int | None = None):
        if query in self.d:
            # refresh value + TTL in place (a stale entry would otherwise
            # never be replaced and the key would permanently miss)
            self.usage -= self.d.pop(query)[2]
            self.order.remove(query)
        while self.usage + size > self.capacity and self.order:
            victim = self.order.popleft()
            self.usage -= self.d.pop(victim)[2]
        ttl = self.max_ttl if staticity is None else ttl_from_staticity(
            staticity, self.max_ttl, self.min_ttl
        )
        self.d[query] = (value, now + ttl, size)
        self.order.append(query)
        self.usage += size

    @property
    def hit_rate(self):
        return self.hits / self.lookups if self.lookups else 0.0


class Engine:
    def __init__(
        self,
        *,
        world: SemanticWorld,
        requests: list[Request],
        mode: str = "cortex",
        cache: Optional[CortexCache] = None,
        exact: Optional[ExactCache] = None,
        remote: Optional[RemoteDataService] = None,
        gpu: Optional[GPU] = None,
        cfg: Optional[EngineConfig] = None,
        clock: Optional[VirtualClock] = None,
        router=None,
        region_id: int = 0,
        freshness=None,
        tracer=None,
        overload=None,
        faults=None,
    ):
        self.world = world
        self.requests = requests
        self.mode = mode
        self.cache = cache
        self.exact = exact
        self.remote = remote or RemoteDataService()
        self.gpu = gpu or GPU(GPUConfig())
        self.cfg = cfg or EngineConfig()
        self.clock = clock or VirtualClock()
        # Federation seam: when set, cache misses route through the
        # cross-region peek/transfer path instead of going straight to the
        # origin service (serving/federation.py).
        self.router = router
        self.region_id = region_id
        # Freshness seam (core/freshness.py): when set, admissions arm
        # change-feed watches + refresh-ahead timers, and cache hits are
        # checked against the world's CURRENT knowledge version.
        self.freshness = freshness
        # Observability seam (DESIGN.md §15): span tracing + the unified
        # metrics registry. The tracer only *records* virtual instants
        # the event flow already computes — it never pushes clock events
        # — so a traced run is bit-identical in virtual time to an
        # untraced one, and NULL_TRACER makes the disabled path free.
        self.trace = tracer if tracer is not None else NULL_TRACER
        # Robustness seam (DESIGN.md §17): an armed OverloadController
        # actuates shed-to-nojudge / background-pause / serve-stale
        # policies off the §16 telemetry; an armed FaultSchedule injects
        # deterministic failure windows (judge slowdown is read here,
        # brownouts live in RemoteDataService, outages in Federation).
        # Both default to None and every consult is None-gated, so
        # fault-free runs stay bit-identical.
        self.overload = overload
        self.faults = faults
        self.stale_hits = 0
        self.stale_age_hist = FixedHistogram(
            STALE_AGE_EDGES, max_samples=self.cfg.stale_age_reservoir,
            seed=self.cfg.seed,
        )
        self.rng = np.random.default_rng(self.cfg.seed)
        self.prefetcher = MarkovPrefetcher(
            confidence=self.cfg.prefetch_confidence
        )
        self.records: list[RequestRecord] = []
        self.eval_log: list[EvalRecord] = []
        self.recal_history: list[tuple[float, float]] = []
        self.recal_cost = 0.0
        self._pending = collections.deque(requests)
        self._active = 0
        self._judge_backlog: collections.deque[dict] = collections.deque()
        self._stage1_pending: list[tuple] = []
        self._stage1_open: Optional[float] = None  # current pass open time
        # instant the host finishes streaming the current pass's scanned
        # rows (scan-proportional latency model, DESIGN.md §12); a new
        # pass cannot open before it
        self._stage1_busy_until = 0.0
        self._done = 0
        self._warm_cut = int(len(requests) * self.cfg.warmup_frac)
        self._warm_snap = None
        self.metrics = MetricsRegistry()
        self._register_metrics()
        if self.trace.enabled:
            # bind the background-span emitters (holder-side lease
            # validation, refresh fetches, invalidation drops) — only
            # when tracing, so untraced construction is untouched
            if self.cache is not None:
                self.cache.seri.pipeline.bind_tracer(
                    self.trace, self.clock, self.region_id
                )
            if self.freshness is not None:
                self.freshness.bind_tracer(self.trace, self.region_id)

    @property
    def stale_ages(self) -> list[float]:
        """Legacy name: raw stale-age samples, now held by the §15
        FixedHistogram (which needs the raw values for a bit-identical
        mean)."""
        return self.stale_age_hist.values

    def _register_metrics(self) -> None:
        """Populate the MetricsRegistry (DESIGN.md §15) with *pull*
        collectors over the existing counter objects. Pull-based means
        every increment site keeps its exact legacy code path — the
        registry observes state at ``snapshot()`` time — which is what
        lets ``summary()`` be rebuilt on top of the registry while
        staying byte-identical. Collectors for absent components return
        ``{}``, so the snapshot's key set reflects the engine's actual
        configuration."""
        reg = self.metrics
        reg.register("engine", lambda: {
            "stale_hits": self.stale_hits,
            "stale_age_mean": self.stale_age_hist.mean,
            "stale_age_hist": self.stale_age_hist.to_dict(),
            "recal_cost": self.recal_cost,
        })
        reg.register("remote", lambda: {
            "calls": self.remote.calls,
            "attempts": self.remote.attempts,
            "retries": self.remote.retries,
            "failed": getattr(self.remote, "failed", 0),
            "total_cost": self.remote.total_cost,
            "throttled_wait": getattr(self.remote, "throttled_wait", 0.0),
        })

        def overload_ns():
            # §17 actuation counters; read dynamically so a controller
            # attached after construction is still observed
            if self.overload is None:
                return {}
            return self.overload.metrics()

        reg.register("overload", overload_ns)
        reg.register("gpu", lambda: {
            "n_chips": self.gpu.n_chips,
            "agent_lane_tokens": float(self.gpu.agent.busy_tokens),
            "judge_lane_tokens": float(self.gpu.judge.busy_tokens),
        })

        def gauge_ns():
            # live pressure gauges (DESIGN.md §16): instantaneous state
            # the cumulative counters can't see — sampled by the
            # TimeSeriesSampler, never projected into summary(), and
            # every read is pure (limiter headroom via the non-mutating
            # peek, NOT TokenBucket.headroom)
            from repro.obs.sampler import limiter_headroom

            g = {
                "inflight": self._active,
                "judge_backlog": len(self._judge_backlog),
                "stage1_pending": len(self._stage1_pending),
                "limiter_headroom": limiter_headroom(
                    self.remote, self.clock.now
                ),
            }
            g.update(self.gpu.occupancy())
            return g

        reg.register("gauge", gauge_ns)

        def cache_ns():
            if self.cache is None:
                return {}
            d = dataclasses.asdict(self.cache.stats)
            d["items"] = len(self.cache)
            return d

        def scan_ns():
            # ScanMetrics fields (batch-granularity caveats documented
            # on the dataclass): total_rows / total_max_shard_rows feed
            # the summary's rows_scanned / rows_scanned_max_shard
            if self.cache is None:
                return {}
            return dataclasses.asdict(self.cache.scan)

        def pipeline_ns():
            if self.cache is None:
                return {}
            pipe = self.cache.seri.pipeline
            d = dataclasses.asdict(pipe.stats)
            d["band_width"] = (float(pipe.band.width)
                               if pipe.band is not None else 0.0)
            d["base_tokens"] = float(pipe.base_tokens)
            return d

        def shard_ns():
            if self.cache is None:
                return {}
            shards = getattr(self.cache, "stage1_shards", 1)
            if shards <= 1:
                return {}
            rt = self.cache.seri.index.router
            reb, mig, chunks = (rt.rebalances, rt.migrated_rows,
                                rt.migration_chunks)
            wix = getattr(self.cache, "warm", None)
            if wix is not None and wix.index.router is not None:
                wrt = wix.index.router
                reb += wrt.rebalances
                mig += wrt.migrated_rows
                chunks += wrt.migration_chunks
            return {"shards": shards, "rebalances": reb,
                    "migrated_rows": mig, "migration_chunks": chunks}

        def tier_ns():
            ts = getattr(self.cache, "tier_stats", None)
            if ts is None:
                return {}
            d = dataclasses.asdict(ts)
            d["warm_items"] = len(self.cache.warm)
            d["warm_bytes"] = self.cache.warm.usage
            return d

        def freshness_ns():
            if self.freshness is None:
                return {}
            return dataclasses.asdict(self.freshness.stats)

        def exact_ns():
            if self.exact is None:
                return {}
            return {"hits": self.exact.hits,
                    "lookups": self.exact.lookups}

        reg.register("cache", cache_ns)
        reg.register("scan", scan_ns)
        reg.register("pipeline", pipeline_ns)
        reg.register("shard", shard_ns)
        reg.register("tier", tier_ns)
        reg.register("freshness", freshness_ns)
        reg.register("exact", exact_ns)

    # ------------------------------------------------------------ events

    @property
    def _now(self) -> float:
        return self.clock.now

    @property
    def done(self) -> bool:
        return self._done >= len(self.requests)

    def _push(self, t: float, fn, *args):
        self.clock.push(t, fn, *args)

    def _push_lane_event(self, lane):
        nxt = lane.next_completion()
        if nxt is not None:
            ver = lane.version
            self._push(nxt, self._lane_tick, lane, ver)

    def _lane_tick(self, lane, ver):
        if ver != lane.version:
            return  # stale
        done = lane.complete_due(self._now)
        for job in done:
            job.callback(self._now)
        self._push_lane_event(lane)
        self._dispatch_judges()
        if self.gpu.rebalance(self._now):
            self._push_lane_event(self.gpu.agent)

    def _submit(self, lane, tokens, cb):
        lane.submit(self._now, tokens, cb)
        if self.gpu.rebalance(self._now):
            self._push_lane_event(self.gpu.agent)
        self._push_lane_event(lane)

    # ------------------------------------------------------------ fsm

    def _start_request(self, req: Request):
        rec = RequestRecord(rid=req.rid, arrival=req.arrival)
        st = _ReqState(req=req, rec=rec)
        self._active += 1
        self._begin_round(st)

    def _begin_round(self, st: _ReqState):
        st.round_t0 = self._now
        t0 = self._now

        def think_done(now):
            st.rec.agent_time += now - t0
            self.trace.span(st.rec.rid, "agent_think", t0, now,
                            self.region_id)
            self._tool_call(st)

        self._submit(self.gpu.agent, self.cfg.think_tokens, think_done)

    def _tool_call(self, st: _ReqState):
        q = st.req.query_for_round(st.round)
        if self.mode == "vanilla" or (
            self.mode == "exact" and self.exact is None
        ):
            self._go_remote(st)
            return
        if self.mode == "exact":
            val = self.exact.lookup(q, self._now)
            if val is not None:
                self._observe(st, val, from_cache=True)
            else:
                self._go_remote(st)
            return
        # cortex / cortex-nojudge: embed+ANN on host, then judge on chip.
        # The host runs one batched stage-1 pass at a time: requests
        # arriving at the pass's open instant ride it; later arrivals
        # queue for the next pass (which opens when this one flushes), so
        # every request pays at least one full t_cache_cpu and the batch
        # contents are frozen when the pass starts.
        self._stage1_pending.append((st, q, self._now))
        if self._stage1_open is None:
            # the host may still be streaming the previous pass's scan
            # (scan-proportional model): the new pass opens when it ends
            open_at = max(self._now, self._stage1_busy_until)
            self._stage1_open = open_at
            self._push(open_at + self._stage1_latency(), self._stage1_flush)

    def _stage1_latency(self) -> float:
        """Host embed+ANN time, plus the network RTT when the cache is a
        shared global one homed in another region (federation's
        single-global-cache baseline, DESIGN.md §9)."""
        return self.cfg.t_cache_cpu + self.cfg.cache_access_latency

    def _stage1_flush(self, now=None):
        open_t = self._stage1_open
        batch = [e for e in self._stage1_pending if e[2] <= open_t]
        self._stage1_pending = [
            e for e in self._stage1_pending if e[2] > open_t
        ]
        self._stage1_open = None
        if not batch:
            if self._stage1_pending:  # next pass opens as this one retires
                self._stage1_open = self._now
                self._push(self._now + self._stage1_latency(),
                           self._stage1_flush)
            return
        now = self._now
        queries = [q for _, q, _ in batch]
        q_embs = np.stack([self.world.embed(q) for q in queries])
        # every warm CONSULT pays the tier's extra access latency before
        # proceeding (§10 per-tier stage-1 cost) — including consults
        # that came back empty. The cache reports the consult fact per
        # query; the engine must not re-derive that policy.
        blocks, consults = self.cache.stage1_batch_flagged(
            queries, q_embs, now
        )
        # scan-proportional stage-1 cost (§12): the flush instant covers
        # the FIXED host cost (embed + routing); streaming the scanned
        # rows takes per_row · rows_scanned longer, during which the
        # host is busy (next pass waits) and this batch's resolutions
        # are deferred. per_row = 0 reproduces the legacy flat model
        # exactly — same events, same order. Under a sharded stage 1
        # (§13) the shards stream in parallel, so the pass charges the
        # BUSIEST shard's rows plus one cross-shard merge term; at
        # stage1_shards == 1 the expression reduces to the §12 model
        # verbatim (last_scan_shard_rows == last_scan_rows, no merge).
        shards = getattr(self.cache, "stage1_shards", 1)
        t_scan = self.cfg.t_cache_per_row * self.cache.last_scan_shard_rows
        if shards > 1:
            t_scan += self.cfg.t_shard_merge
        self._stage1_busy_until = now + t_scan
        if self.trace.enabled:
            # stage1_queue_wait = tool-call arrival -> pass opening;
            # stage1_scan = the pass itself (fixed host cost + optional
            # RTT + scan streaming). scan_end is the exact instant the
            # deferred _scan_resolve fires (same float expression).
            scan_end = now + t_scan
            for bst, _, t_arr in batch:
                self.trace.span(bst.rec.rid, "stage1_queue_wait", t_arr,
                                open_t, self.region_id)
                self.trace.span(bst.rec.rid, "stage1_scan", open_t,
                                scan_end, self.region_id)
        if self._stage1_pending:  # next pass opens as the scan retires
            self._stage1_open = now + t_scan
            self._push(self._stage1_open + self._stage1_latency(),
                       self._stage1_flush)
        entries = list(zip(batch, blocks, consults))
        if t_scan > 0:
            self._push(
                now + t_scan,
                lambda now2, e=entries: self._scan_resolve(e, now2, True),
            )
        else:
            self._scan_resolve(entries, now, False)

    def _scan_resolve(self, entries, now: float, revalidate: bool):
        """Resolve a stage-1 pass once its scan time has elapsed.
        ``revalidate`` is set when the pass was deferred (t_scan > 0):
        clock events in the scan window may have evicted/expired/
        promoted candidates, so their views are re-examined first."""
        deferred = []
        for (st, q, t0), (cands, sims), warm in entries:
            if revalidate:
                cands, sims = self._revive(cands, sims, now)
            if warm:
                deferred.append((st, q, t0, cands, sims))
                continue
            self._stage1_resolve(st, q, t0, cands, sims, now)
        if deferred:
            if self.trace.enabled:
                for dst, _, _, _, _ in deferred:
                    self.trace.span(dst.rec.rid, "warm_consult", now,
                                    now + self.cfg.t_cache_warm,
                                    self.region_id)
            self._push(
                now + self.cfg.t_cache_warm,
                lambda now2, d=deferred: self._warm_resolve(d, now2),
            )
        # one dispatch for the whole flush: requests that arrived in the
        # same stage-1 window ride the same judge micro-batch (dispatching
        # inside _judge_request would submit solo batches whenever the
        # judge lane has free slots)
        self._dispatch_judges()

    def _revive(self, cands, sims, now: float):
        """Re-examine candidate views after a deferral window: rebind
        views whose entry promoted meanwhile, drop evicted/expired/
        revalidating ones. Sims stay ALIGNED with the surviving views
        (the admission band classifies on them)."""
        live = []
        keep = []
        for j, c in enumerate(cands):
            if not c.valid and c.se_id in self.cache.store:
                c = self.cache.store[c.se_id]  # promoted meanwhile
            if c.valid and not c.expired(now) and \
                    not getattr(c, "revalidating", False):
                live.append(c)
                keep.append(j)
        return live, np.asarray(sims)[keep].astype(np.float32)

    def _stage1_resolve(self, st: _ReqState, q: str, t0: float, cands,
                        sims, now: float):
        st.rec.cache_time += now - t0
        if not cands:
            # under an armed band a sub-lo best match never surfaced
            # here — the straight-to-origin shortcut IS this path
            self.cache.miss_no_candidates()
            self._go_remote(st)
            return
        if self.mode == "cortex-nojudge":
            # ANN-only ablation: accept nearest candidate blindly —
            # but through the SHARED hit accounting, so prefetch_hits
            # and freq bookkeeping stay comparable with full cortex.
            # Snapshot key/value FIRST: accounting a warm winner
            # promotes it, which retires the warm row behind the view.
            se = cands[0]
            key, value = se.key, se.value
            self._note_stale(se, now)
            self.cache.account_hit(se, now)
            st.rec.cache_hits += 1
            self._after_validated(st, key)
            self._observe(st, value, from_cache=True)
            return
        # adaptive admission (DESIGN.md §14): a best-similarity above the
        # band's trust edge is served without judge latency — through the
        # same shared hit accounting as the nojudge ablation. With no
        # band armed, admit() is a constant "judge" and this is the
        # legacy judge-everything engine, event for event. Under
        # overload (§17) a judge-classified request may be SHED to the
        # same trust path: the band effectively widens toward trust
        # while the latency SLO is breached or the backlog is capped.
        verdict = self.cache.seri.pipeline.admit(
            sims, self.cache.seri.tau_sim
        )
        shed = (verdict == "judge" and self.overload is not None
                and self.overload.shed_judge(
                    now, len(self._judge_backlog),
                    best_sim=float(sims[0]),
                    tau=self.cache.seri.tau_sim))
        if verdict == "bypass" or shed:
            self.trace.marker(st.rec.rid,
                              "shed_nojudge" if shed else "band_bypass",
                              now, self.region_id)
            se = cands[0]
            key, value = se.key, se.value
            self._note_stale(se, now)
            self.cache.account_hit(se, now)
            st.rec.cache_hits += 1
            self._after_validated(st, key)
            self._observe(st, value, from_cache=True)
            return
        self._judge_request(st, q, cands, sims)

    def _warm_resolve(self, deferred, now: float):
        """Warm-consulting requests resume after t_cache_warm; their
        judge jobs dispatch as one micro-batch of their own. Candidates
        are re-examined: clock events between the flush and this wakeup
        may have promoted a warm view (rebind to the live hot row — it
        is still a perfectly good candidate), evicted it, or expired it."""
        for st, q, t0, cands, sims in deferred:
            live, live_sims = self._revive(cands, sims, now)
            self._stage1_resolve(st, q, t0, live, live_sims, now)
        self._dispatch_judges()

    def _judge_request(self, st: _ReqState, q: str, cands, sims):
        # done/timed_out live on the ENTRY, not the request: a request has
        # one judge job per round, and a stale timed-out entry from an
        # earlier round must never be revived by a later round's flags.
        # snapshot keys/values now: candidates may be evicted (and their
        # SoA rows reused) while the judge job waits on the accelerator.
        # sims ride along so eval-log records carry the stage-1 cosine
        # the band recalibration sweeps.
        entry = dict(
            st=st, q=q, cands=cands, t0=self._now,
            keys=[c.key for c in cands], values=[c.value for c in cands],
            sims=[float(s) for s in sims],
            done=False, timed_out=False, t_dispatch=None,
        )
        self._judge_backlog.append(entry)
        self._push(self._now + self.cfg.judge_timeout,
                   self._judge_timeout, entry)
        # no dispatch here — the caller (_stage1_flush) dispatches once
        # for the whole window so co-arrived requests share a micro-batch

    def _judge_timeout(self, entry):
        if entry["done"]:
            return
        entry["timed_out"] = True
        self.cache.stats.misses += 1
        if self.trace.enabled:
            # close the judge spans at the timeout instant: the request
            # proceeds as a miss NOW; the (abandoned) batch result is
            # attributed to nothing when it lands later
            rid = entry["st"].rec.rid
            td = entry["t_dispatch"]
            if td is None:
                self.trace.span(rid, "judge_queue_wait", entry["t0"],
                                self._now, self.region_id, "timeout")
            else:
                self.trace.span(rid, "judge_queue_wait", entry["t0"],
                                td, self.region_id)
                self.trace.span(rid, "judge_compute", td, self._now,
                                self.region_id, "timeout")
        self._go_remote(entry["st"])  # deferred validation = miss (§4.4)

    def _dispatch_judges(self):
        """Drain the backlog in micro-batches: one accelerator job and one
        ``score_pairs`` call per batch of up to judge_batch_max requests,
        with the shared prompt prefill amortized (paper §4.4)."""
        while self._judge_backlog and self.gpu.judge_admission_ok() and \
                self.gpu.judge.n_waiting == 0:
            batch = []
            while self._judge_backlog and \
                    len(batch) < self.cfg.judge_batch_max:
                e = self._judge_backlog.popleft()
                if e["timed_out"]:
                    continue  # already proceeded as a miss
                batch.append(e)
            if not batch:
                return
            for e in batch:
                e["t_dispatch"] = self._now
            # cost of the micro-batch: model-config-derived via the
            # pipeline unless the config pins a legacy hand-set base
            if self.cfg.judge_tokens is None:
                tokens = self.cache.seri.pipeline.batch_tokens(
                    len(batch), self.cfg.judge_batch_marginal
                )
            else:
                tokens = judge_batch_tokens(
                    self.cfg.judge_tokens, len(batch),
                    self.cfg.judge_batch_marginal,
                )
            if self.faults is not None:
                # judge-device slowdown (§17): the micro-batch costs
                # mult× the tokens while the fault window is active
                tokens *= self.faults.judge_mult(self.region_id,
                                                 self._now)
            self._submit(
                self.gpu.judge, tokens,
                lambda now, b=batch: self._judge_batch_done(b, now),
            )

    def _judge_batch_done(self, batch, now):
        live = [e for e in batch if not e["timed_out"]]
        for e in live:
            e["done"] = True
        if not live:
            return
        # one flattened judge call for the whole micro-batch
        flat_q, flat_k = [], []
        for e in live:
            flat_q.extend([e["q"]] * len(e["cands"]))
            flat_k.extend(e["keys"])
        scores = self.cache.seri.pipeline.score_pairs(flat_q, flat_k)
        off = 0
        for e in live:
            m = len(e["cands"])
            sc = scores[off:off + m]
            off += m
            st = e["st"]
            st.rec.cache_time += now - e["t0"]
            if self.trace.enabled:
                self.trace.span(st.rec.rid, "judge_queue_wait", e["t0"],
                                e["t_dispatch"], self.region_id)
                self.trace.span(st.rec.rid, "judge_compute",
                                e["t_dispatch"], now, self.region_id)
            for key, val, s, sim in zip(e["keys"], e["values"], sc,
                                        e["sims"]):
                self.eval_log.append(
                    EvalRecord(e["q"], key, val, float(s), sim=sim)
                )
            res = self.cache.finalize(e["q"], e["cands"], sc, now)
            if res.hit:
                self._note_stale(res.se, now)
                st.rec.cache_hits += 1
                self._after_validated(st, res.se.key)
                self._observe(st, res.se.value, from_cache=True)
            else:
                self._go_remote(st)

    def _note_stale(self, se, now: float) -> None:
        """Freshness accounting for a cache-served value: compare the
        SE's fetch-time knowledge version against the world's CURRENT
        version of its intent. Exactly 0 stale hits on a static world
        (every version is 0), so the static suites double as a
        regression guard on this path."""
        if se is None:
            return
        intent = se.intent
        cur = (
            self.world.intent_version(int(intent), now)
            if intent is not None
            else self.world.version_at(se.key, now)
        )
        if se.version < cur:
            self.stale_hits += 1
            self.stale_age_hist.add(now - se.fetched_at)

    def _go_remote(self, st: _ReqState):
        q = st.req.query_for_round(st.round)
        st.rec.remote_calls += 1
        t0 = self._now
        if self.router is not None:
            # federation: peek sibling regions before the origin WAN fetch
            self.router.route(self, st, q, t0)
            return
        out = self.remote.fetch(
            self._now,
            latency_mult=self.world.latency_mult(q),
            cost_mult=self.world.cost_mult(q),
        )
        if out.failed:
            self.fetch_failed(st, q, t0, out)
            return
        self.trace.span(st.rec.rid, "origin_fetch", t0, out.finish,
                        self.region_id)
        self._push(
            out.finish,
            lambda now: self.remote_done(st, q, t0, now, value=None,
                                         cost=out.cost),
        )

    def fetch_failed(self, st: _ReqState, q: str, t0: float, out,
                     t_start: Optional[float] = None):
        """Answer through a degraded path after a terminal fetch failure
        (origin brownout + retries exhausted, DESIGN.md §17). The request
        must never hang: serve a known-stale but present cache entry if
        the controller allows it, else re-enter ``_go_remote`` at the
        failure horizon (``out.finish`` — the virtual instant the last
        backoff expired) and try again; brownout windows are finite, so
        the retry chain terminates.

        ``t_start`` is where the failed attempt's span opens (the last
        NAK's arrival on the federated path; ``t0`` otherwise)."""
        span_t0 = t0 if t_start is None else t_start
        self.trace.span(st.rec.rid, "origin_fetch", span_t0, out.finish,
                        self.region_id, "failed")
        ov = self.overload
        if (ov is not None and ov.serve_stale_ok()
                and self.cache is not None):
            se = self._stale_candidate(q)
            if se is not None:
                ov.stats.stale_served += 1
                self.trace.marker(st.rec.rid, "stale_serve", out.finish,
                                  self.region_id)
                # snapshot now: the entry can be evicted (its SoA row
                # reused) before the serve instant arrives
                value = se.value
                self._note_stale(se, self._now)

                def serve(now):
                    st.rec.remote_time += now - t0
                    self._observe(st, value, from_cache=True)

                self._push(out.finish, serve)
                return
        if ov is not None:
            ov.stats.failed_retries += 1
        self._push(out.finish, lambda now: self._go_remote(st))

    def _stale_candidate(self, q: str):
        """A present (possibly expired/stale) entry for the query's own
        intent — §17 serve-stale: better a known-stale answer than an
        error while the origin browns out."""
        ses = self.cache.ses_for_intent(self.world.intent_of(q))
        for se in ses:
            if se.valid and not getattr(se, "revalidating", False):
                return se
        return None

    def remote_done(self, st: _ReqState, q: str, t0: float, now: float, *,
                    value=None, cost: float = 0.0,
                    ttl: Optional[float] = None,
                    staticity: Optional[int] = None,
                    origin: Optional[int] = None,
                    size: Optional[int] = None,
                    version: Optional[int] = None,
                    fetched_at: Optional[float] = None,
                    src_intent: Optional[int] = None):
        """Complete one remote resolution (origin fetch or federated peer
        transfer): admit into the local cache and resume the request.

        ``value=None`` means "fetched from the origin" (ground truth from
        the world AS OF ``now``, stamped with the origin's current
        knowledge version); a peer transfer passes the sibling's cached
        value with ITS version/fetch-time, which — like any cache hit —
        may be stale or semantically wrong, and flows into accuracy and
        staleness accounting the same way."""
        st.rec.remote_time += now - t0
        peer = value is not None
        if not peer:
            value = self.world.fetch(q, now)
            version = self.world.version_at(q, now)
            fetched_at = now
        else:
            st.rec.peer_transfers += 1
        if size is None:
            size = self.world.value_size(q)
        if self.mode in ("cortex", "cortex-nojudge") and self.cache is not None:
            q_emb = self.world.embed(q)
            # a cross-intent peer lease (ANN-only peek) must be tracked
            # under the SOURCE entry's intent: the value's staleness and
            # invalidation follow the intent the knowledge belongs to
            se = self.cache.insert(
                q, q_emb, value, now=now, cost=cost,
                latency=now - t0, size=size,
                intent=(src_intent if src_intent is not None
                        else self.world.intent_of(q)),
                ttl=ttl, staticity=staticity, origin=origin,
                version=0 if version is None else version,
                fetched_at=fetched_at,
            )
            if self.freshness is not None:
                self.freshness.on_insert(se)
            if peer:
                # the transferred value is served to THIS request too —
                # staleness exposure counts like a local cache hit
                self._note_stale(se, now)
            self._after_validated(st, q)
        elif self.mode == "exact" and self.exact is not None:
            self.exact.insert(q, value, size, now,
                              staticity=self.world.staticity(q))
        self._observe(st, value, from_cache=False)

    def _after_validated(self, st: _ReqState, key: str):
        """Feed the prefetcher with the validated intent stream."""
        if not self.cfg.prefetch or self.mode != "cortex":
            return
        intent = self.world.intent_of(key)
        # keyed by session so interleaved concurrent requests don't
        # cross-contaminate the learned transition table
        self.prefetcher.observe(intent, key=st.req.session)
        pred = self.prefetcher.predict(intent)
        if pred is None:
            return
        pq = self.world.query(int(pred.state), 0)
        pq_emb = self.world.embed(pq)
        if self.cache.contains_semantic(pq, pq_emb, self._now):
            return
        # pure-read headroom (the same helper the §16 sampler uses), so
        # the on-path gate and the telemetry see one value and the read
        # never mutates limiter state
        headroom = limiter_headroom(self.remote, self._now)
        if headroom < self.cfg.prefetch_min_headroom:
            return
        if self.overload is not None and \
                not self.overload.allow_prefetch(headroom, self._now):
            # §17: prefetch paused under limiter-headroom / SLO pressure
            return
        out = self.remote.fetch(
            self._now,
            latency_mult=self.world.latency_mult(pq),
            cost_mult=self.world.cost_mult(pq),
        )
        if out.failed:
            return  # §17 brownout: drop the speculative fetch, no retry
        t0 = self._now

        def prefetched(now):
            se = self.cache.insert(
                pq, pq_emb, self.world.fetch(pq, now), now=now,
                cost=out.cost,
                latency=now - t0, size=self.world.value_size(pq),
                prefetched=True, intent=int(pred.state),
                version=self.world.version_at(pq, now), fetched_at=now,
            )
            if self.freshness is not None:
                self.freshness.on_insert(se)

        self._push(out.finish, prefetched)

    def _observe(self, st: _ReqState, value, *, from_cache: bool):
        q_round = st.req.query_for_round(st.round)
        correct = self.world.equivalent(
            value, self.world.answer_at(q_round, self._now)
        )
        st.info_values.append(correct)
        st.round += 1
        st.rec.rounds += 1
        if st.round < st.req.n_rounds:
            self._begin_round(st)
        else:
            t0 = self._now

            def answered(now):
                st.rec.agent_time += now - t0
                self.trace.span(st.rec.rid, "agent_answer", t0, now,
                                self.region_id)
                self._complete(st)

            self._submit(self.gpu.agent, self.cfg.answer_tokens, answered)

    def _complete(self, st: _ReqState):
        rec = st.rec
        rec.t_done = self._now
        # closed-loop arrivals are re-stamped at dispatch, so this single
        # expression is correct for both loop disciplines
        rec.latency = self._now - rec.arrival
        rec.info_correct = all(st.info_values)
        p = self.cfg.em_p_base if rec.info_correct else self.cfg.em_p_wrong
        rec.em_correct = bool(self.rng.random() < p)
        self.records.append(rec)
        self._active -= 1
        self._done += 1
        if self._done == self._warm_cut and self._warm_snap is None:
            # warm-up boundary: one registry snapshot (§15) — summary()
            # subtracts it via MetricsRegistry.delta for the
            # steady-state fields
            self._warm_snap = {
                "n_records": len(self.records),
                "t": self._now,
                "metrics": self.metrics.snapshot(),
            }
        if self.cfg.closed_loop is not None:
            self._dispatch_closed_loop()

    # --------------------------------------------------------- recal

    def _recal_tick(self):
        if self.eval_log:
            n = min(self.cfg.recal_samples, len(self.eval_log))

            def fetch_gt(q):
                self.recal_cost += self.remote.cost_per_call
                self.remote.calls += 1
                self.remote.total_cost += self.remote.cost_per_call
                return self.world.fetch(q, self._now)

            res = recalibrate(
                self.eval_log[-512:], fetch_gt, self.world.equivalent,
                p_target=self.cfg.p_target, sample_size=n,
                rng=self.rng,
            )
            # hysteresis: one noisy sample window must not swing the
            # serving threshold — blend toward the new estimate
            a = self.cfg.recal_smooth
            tau = (1.0 - a) * self.cache.seri.tau_lsm + a * res.tau
            self.cache.seri.tau_lsm = tau
            self.recal_history.append((self._now, tau))
            # admission-band recalibration (DESIGN.md §14): the same
            # labeled sample yields the smallest stage-1 similarity
            # whose precision meets the target — the trust edge. The
            # band's width re-centers on 2·(edge − τ_sim) under the
            # same EMA hysteresis as τ_lsm.
            band = self.cache.seri.pipeline.band
            if band is not None and band.adaptive and \
                    res.sim_tau is not None:
                w_target = 2.0 * max(
                    0.0, res.sim_tau - self.cache.seri.tau_sim
                )
                band.width = (1.0 - a) * band.width + a * w_target
        self._push(self._now + self.cfg.recalibrate_every, lambda now=None: self._recal_tick())

    # --------------------------------------------------------- run

    def _dispatch_closed_loop(self):
        n = self.cfg.closed_loop
        while self._pending and self._active < n:
            req = self._pending.popleft()
            req = dataclasses.replace(req, arrival=self._now)
            self._start_request(req)

    def prepare(self) -> None:
        """Schedule arrivals (and the recal timer) without running the
        loop — the federation runner prepares every region's engine, then
        drives their SHARED clock itself."""
        if self.cfg.closed_loop is not None:
            self._dispatch_closed_loop()
        else:
            for req in self._pending:
                self._push(req.arrival, lambda now=None, r=req: self._start_request(r))
            self._pending.clear()
        if self.cfg.recalibrate_every and self.mode == "cortex":
            self._push(self.cfg.recalibrate_every, lambda now=None: self._recal_tick())

    def run(self) -> dict:
        self.prepare()
        while self.clock.pending and not self.done:
            self.clock.step()
        return self.summary()

    # --------------------------------------------------------- metrics

    def summary(self) -> dict:
        snap = self._warm_snap
        recs = self.records[snap["n_records"]:] if snap else self.records
        if not recs:
            return {}
        # one registry snapshot is THE source for every counter-derived
        # field below (DESIGN.md §15) — the legacy keys are projections
        # of "namespace.key" entries, byte-identical by construction
        # because the collectors read the same counters the old code
        # read directly. Steady-state fields subtract the warm-up
        # snapshot through the registry's delta.
        m = self.metrics.snapshot()
        d = MetricsRegistry.delta(m, snap["metrics"] if snap else {})
        t_end = max(r.t_done for r in recs)
        t_start = snap["t"] if snap else min(r.arrival for r in recs)
        makespan = max(t_end - t_start, 1e-9)
        lat = np.array([r.latency for r in recs])
        gpu_hours = makespan / 3600 * m["gpu.n_chips"]
        out = {
            "mode": self.mode,
            "n": len(recs),
            "throughput_rps": len(recs) / makespan,
            "latency_mean": float(lat.mean()),
            "latency_p50": percentile(lat, 50),
            "latency_p99": percentile(lat, 99),
            "agent_time_mean": float(np.mean([r.agent_time for r in recs])),
            "cache_time_mean": float(np.mean([r.cache_time for r in recs])),
            "remote_time_mean": float(np.mean([r.remote_time for r in recs])),
            "remote_calls_per_req": float(
                np.mean([r.remote_calls for r in recs])
            ),
            "peer_transfers": int(sum(r.peer_transfers for r in recs)),
            "api_calls": d["remote.calls"],
            "api_attempts": d["remote.attempts"],
            "retry_ratio": (d["remote.retries"] / d["remote.attempts"]
                            if d["remote.attempts"] else 0.0),
            "api_cost": d["remote.total_cost"],
            "gpu_cost": gpu_hours * self.cfg.gpu_cost_per_hour,
            "em": float(np.mean([r.em_correct for r in recs])),
            "info_accuracy": float(np.mean([r.info_correct for r in recs])),
            "makespan": makespan,
        }
        # hit-path breakdown (all rounds served from cache): the paper's
        # Fig 11 steady-state per-request latency decomposition
        hit_recs = [r for r in recs if r.remote_calls == 0]
        if hit_recs:
            out["hitpath_latency"] = float(
                np.mean([r.latency for r in hit_recs])
            )
            out["hitpath_agent"] = float(
                np.mean([r.agent_time for r in hit_recs])
            )
            out["hitpath_cache"] = float(
                np.mean([r.cache_time for r in hit_recs])
            )
        if self.mode in ("cortex", "cortex-nojudge") and self.cache is not None:
            if snap:
                lk = d["cache.lookups"]
                out["hit_rate_steady"] = (
                    d["cache.hits"] / lk if lk else 0.0
                )
            out.update(
                hit_rate=(m["cache.hits"] / m["cache.lookups"]
                          if m["cache.lookups"] else 0.0),
                evictions=m["cache.evictions"],
                ttl_evictions=m["cache.ttl_evictions"],
                invalidations=m["cache.invalidations"],
                prefetch_inserts=m["cache.prefetch_inserts"],
                prefetch_hits=m["cache.prefetch_hits"],
                judge_calls=m["cache.judge_calls"],
                cache_items=m["cache.items"],
                # stage-1 scan volume (DESIGN.md §12): total rows the
                # stage-1 passes touched and the per-lookup average —
                # the sublinearity of the clustered index read straight
                # off the summary
                rows_scanned=m["scan.total_rows"],
                rows_per_lookup=(
                    m["scan.total_rows"] / m["cache.lookups"]
                    if m["cache.lookups"] else 0.0
                ),
                # judge economics (DESIGN.md §14): the per-job token
                # cost actually charged (model-config-derived unless the
                # config pinned a legacy constant) and the judge lane's
                # processed token-equivalents — changing the judge's
                # d_model moves BOTH, which is the "no constant left on
                # the path" property the colocation sweep gates on.
                judge_tokens_base=float(
                    self.cfg.judge_tokens
                    if self.cfg.judge_tokens is not None
                    else m["pipeline.base_tokens"]
                ),
                judge_lane_tokens=m["gpu.judge_lane_tokens"],
            )
            if m["pipeline.band_width"] > 0:
                # admission band (§14). Keyed OFF at width 0 so the
                # width-0 engine's summary stays byte-identical to the
                # band-free engine (the sweep's bit-identity gate).
                out.update(
                    band_width=m["pipeline.band_width"],
                    band_bypass_hits=m["pipeline.bypass_hits"],
                    band_judged=m["pipeline.band_judged"],
                    lease_validations=m["pipeline.lease_validations"],
                    lease_rejections=m["pipeline.lease_rejections"],
                )
            if "shard.shards" in m:
                # mesh-sharded stage 1 (§13). The shard collector
                # returns {} when unsharded, so pre-§13 summaries (and
                # the bit-identity gates that compare them) are
                # byte-identical.
                out.update(
                    stage1_shards=m["shard.shards"],
                    rows_scanned_max_shard=m["scan.total_max_shard_rows"],
                    shard_rebalances=m["shard.rebalances"],
                    shard_migrated_rows=m["shard.migrated_rows"],
                    shard_migration_chunks=m["shard.migration_chunks"],
                )
            # freshness accounting (DESIGN.md §11): every cache-served
            # value is version-checked, so these are exact, not sampled.
            # stale_hit_rate is per SERVED value (local hits + federated
            # peer transfers — a transferred value reaches the requester
            # just like a hit), the histogram buckets the age of the
            # stale values at serve time (now - fetched_at, seconds).
            # Denominator from THIS engine's records, not cache.stats —
            # the federation "global" topology shares one cache across
            # engines, and stale_hits is per engine.
            served = sum(
                r.cache_hits + r.peer_transfers for r in self.records
            )
            out["stale_hits"] = m["engine.stale_hits"]
            out["stale_hit_rate"] = (
                m["engine.stale_hits"] / served if served else 0.0
            )
            out["stale_age_hist"] = m["engine.stale_age_hist"]
            out["stale_age_mean"] = m["engine.stale_age_mean"]
            if self.freshness is not None:
                out.update(
                    refreshes=m["freshness.refreshes"],
                    refresh_cost=m["freshness.refresh_cost"],
                    refresh_skipped=m["freshness.refresh_skipped"],
                    feed_notices=m["freshness.notices"],
                    stale_found=m["freshness.stale_found"],
                )
            if "tier.demotions" in m:  # tiered storage (DESIGN.md §10)
                out.update(
                    demotions=m["tier.demotions"],
                    promotions=m["tier.promotions"],
                    warm_lookups=m["tier.warm_lookups"],
                    warm_hits=m["tier.warm_hits"],
                    warm_evictions=m["tier.warm_evictions"],
                    warm_items=m["tier.warm_items"],
                    warm_bytes=m["tier.warm_bytes"],
                )
        elif self.mode == "exact" and self.exact is not None:
            out.update(hit_rate=(m["exact.hits"] / m["exact.lookups"]
                                 if m["exact.lookups"] else 0.0))
        else:
            out.update(hit_rate=0.0)
        if self.faults is not None:
            # fault injection armed (§17): brownout outcome accounting.
            # Keyed off when fault-free so pre-§17 summaries stay
            # byte-identical (the neutrality gate).
            out["fetch_failed"] = d["remote.failed"]
            out["throttled_wait"] = d["remote.throttled_wait"]
        if self.overload is not None:
            # §17 actuation counters (same conditional-key contract)
            out["overload"] = {k: m[f"overload.{k}"]
                               for k in self.overload.metrics()}
        out["cost_total"] = out["api_cost"] + out["gpu_cost"]
        out["thpt_per_dollar"] = out["throughput_rps"] / max(
            out["cost_total"], 1e-9
        )
        return out
