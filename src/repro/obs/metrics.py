"""Unified metrics registry + shared statistics helpers (DESIGN.md §15).

Three things live here:

* :func:`percentile` — THE percentile used repo-wide (engine summary,
  federation summary, benchmark figures, trace attribution). One pinned
  interpolation method, so a quantile in a BENCH gate and the same
  quantile in a trace report can never drift apart.
* :class:`FixedHistogram` — a fixed-bucket histogram that *keeps its raw
  values*. The legacy summary computed ``np.mean(list)`` over raw
  samples; numpy's pairwise summation is not bit-equal to a running
  ``sum/count``, so a histogram that only kept bucket counts could not
  reproduce the legacy ``stale_age_mean`` byte-for-byte.
* :class:`MetricsRegistry` — a pull-based registry: components register
  *collector* callables under a namespace, ``snapshot()`` flattens them
  into one ``"ns.key" -> value`` dict, and ``delta()`` subtracts two
  snapshots. Pull-based means the existing increment sites
  (``CacheStats``, ``PipelineStats``, ``TierStats``, remote counters…)
  keep their exact code paths — the registry observes them, so every
  legacy number stays bit-identical while ``summary()`` is rebuilt on
  top of ``snapshot()``.

:class:`ScanMetrics` gives the stage-1 scan-volume counters (previously
ad-hoc ``CortexCache`` instance attributes, deliberately outside
``CacheStats`` per PR 5/6) a first-class home with the batch-granularity
caveat documented where the numbers are defined.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

# stale-age bucket edges (seconds) — the §11 staleness histogram
STALE_AGE_EDGES = (30.0, 60.0, 120.0, 300.0, 600.0, 1800.0)


def percentile(values: Sequence[float] | np.ndarray, q: float) -> float:
    """Repo-wide percentile: linear interpolation, pinned explicitly.

    ``np.percentile``'s default *is* linear today, but the repo's
    bit-identity gates compare quantiles computed in three different
    modules — pinning the method here makes that contract explicit and
    survives a numpy default change.
    """
    return float(np.percentile(np.asarray(values), q, method="linear"))


class FixedHistogram:
    """Fixed-bucket histogram over ``[0, e0), [e0, e1), …, [e_last, inf)``.

    Two retention modes:

    * **raw (default, ``max_samples=None``)** — keeps every sample:
      bucket counts are derived on demand, and ``mean`` is
      ``np.mean(values)`` — bit-identical to the pre-registry summary
      code that held a bare ``list[float]``. Sample volume on the
      stale-age path is small (one float per *stale* serve), so raw
      retention is the right default and the ``stale_age_mean``
      bit-parity contract is untouched.
    * **bounded reservoir (``max_samples=N``)** — for long burst runs:
      ``values`` holds a deterministic (seeded) Algorithm-R reservoir of
      at most N samples, while bucket counts and the mean come from
      exact incremental counters (``count`` / running sum) — the
      histogram and mean stay exact at any volume; only the raw-sample
      *list* is bounded. The reservoir RNG is private and only consumed
      in this mode, so default-mode behavior is untouched.
    """

    __slots__ = ("edges", "values", "max_samples", "count",
                 "_counts", "_sum", "_rng")

    def __init__(self, edges: Sequence[float] = STALE_AGE_EDGES, *,
                 max_samples: int | None = None, seed: int = 0):
        self.edges = tuple(float(e) for e in edges)
        self.values: list[float] = []
        if max_samples is not None and max_samples < 1:
            raise ValueError("max_samples must be >= 1")
        self.max_samples = max_samples
        self.count = 0
        # exact incremental bucket counts (len(edges)+1 buckets) + sum:
        # only consulted in reservoir mode, maintained in both
        self._counts = [0] * (len(self.edges) + 1)
        self._sum = 0.0
        self._rng = (np.random.default_rng(seed)
                     if max_samples is not None else None)

    def _bucket(self, v: float) -> int:
        for i, hi in enumerate(self.edges):
            if v < hi:
                return i
        return len(self.edges)

    def add(self, v: float) -> None:
        self._counts[self._bucket(v)] += 1
        self._sum += v
        self.count += 1
        if self.max_samples is None:
            self.values.append(v)
        elif len(self.values) < self.max_samples:
            self.values.append(v)
        else:
            # Algorithm R: the i-th sample (1-based) replaces a resident
            # with probability N/i — seeded, so deterministic
            j = int(self._rng.integers(0, self.count))
            if j < self.max_samples:
                self.values[j] = v

    def __len__(self) -> int:
        """Total samples *added* (== ``len(values)`` in raw mode; may
        exceed it in reservoir mode)."""
        return self.count

    def to_dict(self) -> dict[str, int]:
        """Bucket counts under the legacy summary keys: ``"0-30"``,
        ``"30-60"``, …, ``"1800+"`` (``%g``-formatted edges). Exact in
        BOTH modes — reservoir mode reads the incremental counters."""
        if self.max_samples is None:
            hist: dict[str, int] = {}
            lo = 0.0
            for hi in self.edges:
                hist[f"{lo:g}-{hi:g}"] = sum(
                    1 for a in self.values if lo <= a < hi
                )
                lo = hi
            hist[f"{lo:g}+"] = sum(1 for a in self.values if a >= lo)
            return hist
        hist = {}
        lo = 0.0
        for i, hi in enumerate(self.edges):
            hist[f"{lo:g}-{hi:g}"] = self._counts[i]
            lo = hi
        hist[f"{lo:g}+"] = self._counts[len(self.edges)]
        return hist

    @property
    def mean(self) -> float:
        """Raw mode: ``np.mean(values)`` (the bit-parity contract).
        Reservoir mode: exact running ``sum/count`` over EVERY sample —
        not an estimate from the reservoir."""
        if self.max_samples is None:
            return float(np.mean(self.values)) if self.values else 0.0
        return self._sum / self.count if self.count else 0.0


@dataclasses.dataclass
class ScanMetrics:
    """Stage-1 scan-volume counters (DESIGN.md §12/§13).

    **Batch-granularity caveat**: stage 1 runs as *batched passes* — one
    masked matmul over every query that co-arrived in the host window —
    so ``last_rows`` is rows touched by the last PASS, not the last
    query, and ``total_rows`` advances once per pass. Dividing
    ``total_rows`` by per-query ``lookups`` (as ``rows_per_lookup``
    does) is therefore an *amortized* per-query figure: co-batched
    queries share one scan. ``last_max_shard_rows`` is the busiest
    shard's slice of the last pass — the quantity the §13 latency model
    charges (shards stream in parallel); at one shard it equals
    ``last_rows``.
    """

    last_rows: int = 0            # rows scanned by the last stage-1 pass
    total_rows: int = 0           # cumulative rows over all passes
    last_max_shard_rows: int = 0  # busiest shard's rows, last pass (§13)
    total_max_shard_rows: int = 0 # cumulative max-shard rows

    def note_pass(self, rows: int, max_shard_rows: int | None = None) -> None:
        """Record one stage-1 pass. ``max_shard_rows`` defaults to
        ``rows`` (the unsharded index IS one shard)."""
        m = rows if max_shard_rows is None else max_shard_rows
        self.last_rows = int(rows)
        self.total_rows += int(rows)
        self.last_max_shard_rows = int(m)
        self.total_max_shard_rows += int(m)

    def add_warm_pass(self, rows: int, max_shard_rows: int | None = None) -> None:
        """Fold a warm-tier consult into the CURRENT pass (§10): the hot
        and warm scans of one flush count as one pass's volume."""
        m = rows if max_shard_rows is None else max_shard_rows
        self.last_rows += int(rows)
        self.total_rows += int(rows)
        self.last_max_shard_rows += int(m)
        self.total_max_shard_rows += int(m)


class MetricsRegistry:
    """Pull-based metrics registry.

    Components ``register(namespace, collector)`` where ``collector`` is
    a zero-arg callable returning a flat ``{key: number-or-hist-dict}``
    mapping. ``snapshot()`` invokes every collector and flattens to
    ``"namespace.key"`` — a point-in-time copy safe to stash (the
    engine's warm-up snapshot) or diff (:meth:`delta`).
    """

    def __init__(self):
        self._collectors: list[tuple[str, Callable[[], Mapping]]] = []

    def register(self, namespace: str, collector: Callable[[], Mapping]) -> None:
        """Idempotent per namespace: re-registering REPLACES the prior
        collector in place (keeping its snapshot position), so engines
        rebuilt inside a sweep loop sharing one registry can't silently
        double-collect — the last registration wins."""
        for i, (ns, _) in enumerate(self._collectors):
            if ns == namespace:
                self._collectors[i] = (namespace, collector)
                return
        self._collectors.append((namespace, collector))

    def unregister(self, namespace: str) -> bool:
        """Drop a namespace's collector; returns whether it existed."""
        for i, (ns, _) in enumerate(self._collectors):
            if ns == namespace:
                del self._collectors[i]
                return True
        return False

    def namespaces(self) -> list[str]:
        return [ns for ns, _ in self._collectors]

    def snapshot(self) -> dict[str, float | int | dict]:
        out: dict[str, float | int | dict] = {}
        for ns, collect in self._collectors:
            for k, v in collect().items():
                out[f"{ns}.{k}"] = v
        return out

    @staticmethod
    def delta(cur: Mapping, base: Mapping) -> dict:
        """``cur - base`` for every numeric key in ``cur`` (missing base
        keys count as 0; non-numeric values pass through from ``cur``)."""
        out = {}
        for k, v in cur.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                out[k] = v
            else:
                b = base.get(k, 0)
                b = b if isinstance(b, (int, float)) and \
                    not isinstance(b, bool) else 0
                out[k] = v - b
        return out
