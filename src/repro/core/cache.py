"""CortexCache — the cache abstraction layered on Seri (paper §4.3).

Turns probabilistic similarity into deterministic cache semantics:

* semantic-aware HIT — only after the full two-stage pipeline validates a
  candidate; a hit increments the SE's frequency.
* admission — every remote fetch result is inserted as a new SE with
  judge-estimated staticity → TTL; prefetched items enter with freq=0.
* LCFU eviction (Algorithm 2) — TTL purge first, then evict lowest
  value-score until under capacity.
* capacity is byte-based (cache_ratio × workload footprint in the
  benchmarks, matching the paper's "cache size ratio" axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.core.semantic_element import SemanticElement, ttl_from_staticity
from repro.core.seri import Seri, SeriResult, VectorIndex


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    ttl_evictions: int = 0
    judge_calls: int = 0
    prefetch_inserts: int = 0
    prefetch_hits: int = 0
    bytes_stored: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class CortexCache:
    def __init__(
        self,
        seri: Seri,
        *,
        capacity_bytes: int,
        max_ttl: float = 3600.0,
        min_ttl: float = 30.0,
        eviction: str = "lcfu",  # lcfu | lru | lfu (paper Table 6 ablation)
    ):
        self.seri = seri
        self.capacity_bytes = capacity_bytes
        self.max_ttl = max_ttl
        self.min_ttl = min_ttl
        self.eviction = eviction
        self.store: dict[int, SemanticElement] = {}
        self.rows: dict[int, int] = {}  # se_id -> index row
        self.usage = 0
        self.stats = CacheStats()
        self._next_id = 0

    # ------------------------------------------------------------ lookup

    def lookup(self, query: str, q_emb: np.ndarray, now: float) -> SeriResult:
        self.stats.lookups += 1
        res = self.seri.retrieve(query, q_emb, self.store, now)
        self.stats.judge_calls += res.judge_calls
        if res.hit:
            se = res.se
            se.freq += 1
            se.last_access = now
            self.stats.hits += 1
            if se.prefetched and se.freq == 1:
                self.stats.prefetch_hits += 1
        else:
            self.stats.misses += 1
        return res

    # ---------------------------------------------------- staged lookup
    # The serving engine needs the two Seri stages split so the judge can
    # run as an async (deferrable) accelerator job (paper §4.4): stage1 =
    # ANN candidates; finalize = apply judge scores -> deterministic hit.

    def stage1(self, query: str, q_emb: np.ndarray, now: float):
        self.stats.lookups += 1
        se_ids, sims = self.seri.index.search(
            q_emb, self.seri.top_k, self.seri.tau_sim
        )
        cands = [
            self.store[i] for i in se_ids
            if i in self.store and not self.store[i].expired(now)
        ]
        return cands

    def finalize(self, query: str, cands, scores, now: float) -> SeriResult:
        self.stats.judge_calls += len(cands)
        order = np.argsort(-np.asarray(scores))
        best = float(scores[order[0]]) if len(cands) else 0.0
        for j in order:
            if scores[j] >= self.seri.tau_lsm:
                se = cands[j]
                if se.se_id not in self.store:  # evicted meanwhile
                    continue
                se.freq += 1
                se.last_access = now
                self.stats.hits += 1
                if se.prefetched and se.freq == 1:
                    self.stats.prefetch_hits += 1
                return SeriResult(True, se, len(cands), len(cands), best,
                                  np.zeros(0, np.float32))
        self.stats.misses += 1
        return SeriResult(False, None, len(cands), len(cands), best,
                          np.zeros(0, np.float32))

    def miss_no_candidates(self) -> None:
        self.stats.misses += 1

    # ------------------------------------------------------------ admit

    def insert(
        self,
        query: str,
        q_emb: np.ndarray,
        value: Any,
        *,
        now: float,
        cost: float,
        latency: float,
        size: int,
        staticity: Optional[int] = None,
        prefetched: bool = False,
        intent: Optional[int] = None,
    ) -> SemanticElement:
        staticity = staticity or self.seri.judge.staticity(query)
        ttl = ttl_from_staticity(staticity, self.max_ttl, self.min_ttl)
        se = SemanticElement(
            se_id=self._next_id,
            key=query,
            value=value,
            embedding=q_emb,
            staticity=staticity,
            cost=cost,
            latency=latency,
            size=size,
            created_at=now,
            expires_at=now + ttl,
            # the triggering miss counts as an access; only speculative
            # prefetches enter cold (paper §4.3: "prefetched items enter
            # with zero frequency")
            freq=0 if prefetched else 1,
            last_access=now,
            prefetched=prefetched,
            intent=intent,
        )
        self._next_id += 1
        self._make_room(size, now)
        if self.seri.index.full:
            self._evict_n(1, now)
        row = self.seri.index.add(se.se_id, q_emb)
        self.store[se.se_id] = se
        self.rows[se.se_id] = row
        self.usage += size
        self.stats.insertions += 1
        if prefetched:
            self.stats.prefetch_inserts += 1
        self.stats.bytes_stored = self.usage
        return se

    def contains_semantic(self, query: str, q_emb: np.ndarray,
                          now: float) -> bool:
        """Peek (no stats, no freq bump) — used by the prefetcher."""
        se_ids, _ = self.seri.index.search(
            q_emb, self.seri.top_k, self.seri.tau_sim
        )
        return any(
            i in self.store and not self.store[i].expired(now) for i in se_ids
        )

    # ------------------------------------------------------------ evict

    def _remove(self, se_id: int, *, ttl: bool) -> None:
        se = self.store.pop(se_id)
        row = self.rows.pop(se_id)
        self.seri.index.remove(row)
        self.usage -= se.size
        if ttl:
            self.stats.ttl_evictions += 1
        else:
            self.stats.evictions += 1
        self.stats.bytes_stored = self.usage

    def purge_expired(self, now: float) -> int:
        dead = [i for i, se in self.store.items() if se.expired(now)]
        for i in dead:
            self._remove(i, ttl=True)
        return len(dead)

    def _victim_order(self, now: float):
        if self.eviction == "lru":
            key = lambda se: se.last_access
        elif self.eviction == "lfu":
            key = lambda se: (se.freq, se.last_access)
        else:  # lcfu (Algorithm 2)
            key = lambda se: se.lcfu_score(now)
        return sorted(self.store.values(), key=key)

    def _make_room(self, incoming: int, now: float) -> None:
        if self.usage + incoming <= self.capacity_bytes:
            return
        self.purge_expired(now)  # TTL purge first (Algorithm 2 line 6)
        if self.usage + incoming <= self.capacity_bytes:
            return
        for se in self._victim_order(now):
            if self.usage + incoming <= self.capacity_bytes:
                break
            self._remove(se.se_id, ttl=False)

    def _evict_n(self, n: int, now: float) -> None:
        for se in self._victim_order(now)[:n]:
            self._remove(se.se_id, ttl=False)

    # ------------------------------------------------------------ misc

    def __len__(self) -> int:
        return len(self.store)


def make_cache(
    *,
    capacity_bytes: int,
    dim: int,
    judge,
    index_capacity: int = 8192,
    tau_sim: float = 0.9,
    tau_lsm: float = 0.9,
    top_k: int = 4,
    eviction: str = "lcfu",
    max_ttl: float = 3600.0,
    backend: str = "numpy",
) -> CortexCache:
    index = VectorIndex(index_capacity, dim, backend=backend)
    seri = Seri(index, judge, tau_sim=tau_sim, tau_lsm=tau_lsm, top_k=top_k)
    return CortexCache(
        seri, capacity_bytes=capacity_bytes, max_ttl=max_ttl,
        eviction=eviction,
    )
