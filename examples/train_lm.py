"""Train a reduced granite-family LM for a few hundred steps on synthetic
bigram data, with mid-run fault injection + checkpoint/restart — shows the
training substrate end to end (optimizer, remat, supervisor, data).

CPU runtime: ~2-4 minutes. On an accelerator host drop --smoke and raise
--steps / dims toward the 100M-parameter scale.

Run:  PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import tempfile

from repro.launch.train import main

ckpt = tempfile.mkdtemp(prefix="repro_train_")
try:
    res = main([
        "--arch", "granite-3-8b", "--smoke",
        "--steps", "200", "--batch", "16", "--seq", "64",
        "--d-model", "128", "--vocab", "256", "--n-repeat", "2",
        "--lr", "3e-3", "--ckpt-dir", ckpt,
        "--save-every", "50", "--fail-at", "120",
    ])
    assert res.restarts == 1, "fault injection should have fired once"
    assert res.losses[-1] < res.losses[0], "loss should decrease"
    print("train example OK (restarted once, loss decreased)")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
