"""Observability subsystem (DESIGN.md §15): request-lifecycle tracing,
unified metrics registry, trace export, and latency attribution.

Everything here rides the deterministic :class:`~repro.serving.clock.
VirtualClock`, so traces are bit-reproducible: same seed, same bytes.
"""
from repro.obs.analyze import (attribution, check_conservation,
                               format_attribution)
from repro.obs.export import export_trace, write_chrome_trace, write_jsonl
from repro.obs.metrics import (STALE_AGE_EDGES, FixedHistogram,
                               MetricsRegistry, ScanMetrics, percentile)
from repro.obs.trace import BACKGROUND, NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "BACKGROUND",
    "MetricsRegistry", "FixedHistogram", "ScanMetrics", "percentile",
    "STALE_AGE_EDGES",
    "export_trace", "write_jsonl", "write_chrome_trace",
    "check_conservation", "attribution", "format_attribution",
]
