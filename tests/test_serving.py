"""Serving-runtime unit + property tests: PS lanes, rate limiter, priority
guardrail, and the remote service retry path."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.gpu import GPU, GPUConfig, PSLane
from repro.serving.remote import RemoteDataService, TokenBucket


def test_pslane_single_job_rate():
    lane = PSLane(capacity=1000.0, v1=100.0, slots=8)
    done = []
    lane.submit(0.0, 200.0, lambda now: done.append(now))
    # single job limited by v1: 200 tokens / 100 tok/s = 2s
    t = lane.next_completion()
    assert abs(t - 2.0) < 1e-9
    for j in lane.complete_due(t):
        j.callback(t)
    assert done == [2.0]


def test_pslane_processor_sharing():
    lane = PSLane(capacity=100.0, v1=100.0, slots=8)
    # two equal jobs share capacity: each runs at 50 tok/s
    lane.submit(0.0, 100.0, lambda now: None)
    lane.submit(0.0, 100.0, lambda now: None)
    assert abs(lane.next_completion() - 2.0) < 1e-9


@given(st.lists(st.tuples(st.floats(0.0, 5.0), st.floats(10.0, 200.0)),
                min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_pslane_work_conservation(jobs):
    """Total tokens processed equals total tokens submitted."""
    lane = PSLane(capacity=123.0, v1=77.0, slots=4)
    t = 0.0
    total = 0.0
    for dt, tok in jobs:
        t += dt
        lane.advance(t)
        lane.submit(t, tok, lambda now: None)
        total += tok
    # drain
    guard = 0
    while lane.active or lane.queue:
        nxt = lane.next_completion()
        lane.complete_due(nxt)
        guard += 1
        assert guard < 1000
    assert lane.busy_tokens == pytest.approx(total, rel=1e-6)


def test_token_bucket_rate():
    tb = TokenBucket(qpm=60.0, burst=1.0)  # 1/s, burst 1
    assert tb.try_acquire(0.0)
    assert not tb.try_acquire(0.01)
    assert tb.try_acquire(1.05)


def test_token_bucket_out_of_order_acquires_monotonic():
    """Regression: interleaved fetches resolve future retry instants, so a
    later-issued acquire can arrive with an EARLIER timestamp. The refill
    must clamp to monotonic time — a negative dt used to subtract tokens
    and drag t_last backwards."""
    tb = TokenBucket(qpm=600.0, burst=10.0)  # 10/s
    times = [5.0, 2.0, 8.0, 1.0, 0.5, 8.0, 3.0, 20.0, 4.0]
    prev = tb.tokens
    for t in times:
        ok = tb.try_acquire(t)
        # time alone never decreases the count; only a granted token does
        assert tb.tokens >= prev - (1.0 if ok else 0.0) - 1e-12
        assert tb.tokens >= 0.0
        prev = tb.tokens
    # t_last never moved backwards
    assert tb.t_last == 20.0


def test_token_bucket_backdated_refill_no_double_credit():
    tb = TokenBucket(qpm=60.0, burst=2.0)  # 1/s
    assert tb.try_acquire(0.0)
    assert tb.try_acquire(0.0)
    assert not tb.try_acquire(0.0)          # drained
    assert tb.try_acquire(1.5)              # 1.5 tokens refilled, take 1
    # a stale-timestamped acquire must not mint extra tokens (t_last is
    # already 1.5; refilling "from 0.2" again would double-credit)
    assert not tb.try_acquire(0.2)
    assert tb.tokens == pytest.approx(0.5)


def test_exact_cache_expired_lookup_reclaims_usage():
    """Regression: expired entries stayed resident, so their bytes were
    counted in `usage` forever and silently shrank effective capacity."""
    from repro.serving.engine import ExactCache

    c = ExactCache(capacity_bytes=1000, max_ttl=10.0)
    c.insert("a", "va", 300, now=0.0)
    c.insert("b", "vb", 400, now=0.0)
    assert c.usage == 700
    assert c.lookup("a", now=5.0) == "va"   # still live
    # TTL passes: the miss must delete the entries and reclaim bytes
    assert c.lookup("a", now=15.0) is None
    assert c.usage == 400
    assert "a" not in c.d and "a" not in c.order
    assert c.lookup("b", now=15.0) is None
    assert c.usage == 0
    assert list(c.order) == []  # order is a deque since ISSUE 5
    # reclaimed capacity is usable again without evicting anything
    c.insert("c", "vc", 900, now=16.0)
    assert c.usage == 900


def test_remote_retry_counts():
    svc = RemoteDataService(qpm=60.0, seed=0)
    t = 0.0
    retries = 0
    for i in range(20):
        out = svc.fetch(t)
        retries += out.retries
        t += 0.05  # offered load 20/s >> 1/s limit
    assert svc.retry_ratio > 0.3
    assert svc.calls == 20
    assert svc.total_cost == pytest.approx(20 * svc.cost_per_call)


def test_priority_guardrail():
    gpu = GPU(GPUConfig(agent_slots=2, colocated=True))
    # saturate agent lane beyond slots -> judge admission blocked
    for _ in range(3):
        gpu.agent.submit(0.0, 100.0, lambda now: None)
    assert gpu.agent.n_waiting == 1
    assert not gpu.judge_admission_ok()
    # dedicated mode never blocks
    gpu2 = GPU(GPUConfig(agent_slots=2, colocated=False))
    for _ in range(3):
        gpu2.agent.submit(0.0, 100.0, lambda now: None)
    assert gpu2.judge_admission_ok()


def test_no_rate_limit_service():
    svc = RemoteDataService(qpm=None, seed=0)
    out = svc.fetch(0.0)
    assert out.retries == 0
    assert 0.3 <= out.finish <= 0.5
