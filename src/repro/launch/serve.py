"""Serving driver: run the Cortex engine on a chosen workload and mode.

  PYTHONPATH=src python -m repro.launch.serve --workload zipf --mode cortex
  PYTHONPATH=src python -m repro.launch.serve --workload swe \
      --mode cortex --cache-ratio 0.6 --concurrency 8
"""
from __future__ import annotations

import argparse
import json

from repro.core.cache import make_cache
from repro.core.clustering import ClusterConfig
from repro.core.freshness import ChangeFeed, FreshnessConfig, FreshnessManager
from repro.core.judge import OracleJudge
from repro.core.tiers import make_tiered_cache
from repro.data.workloads import (churn_workload, longtail_workload,
                                  swe_workload, trend_workload,
                                  zipf_workload)
from repro.data.world import MutableWorld, SemanticWorld
from repro.serving.clock import VirtualClock
from repro.serving.engine import Engine, EngineConfig, ExactCache
from repro.serving.gpu import GPU, GPUConfig
from repro.serving.remote import RemoteDataService


def build_workload(world, name: str, n: int, seed: int, zipf_s: float = 0.99,
                   tail_len: int | None = None,
                   trend_duration: float | None = None):
    if name == "zipf":
        return zipf_workload(world, n, seed=seed, zipf_s=zipf_s)
    if name == "trend":
        # trend_duration compresses the same request count into a
        # shorter virtual window — the §16 burst-QPS knob (default
        # 600 s; 60 s is a 10× elevated-QPS flash crowd)
        if trend_duration is not None:
            return trend_workload(world, n, seed=seed,
                                  duration=trend_duration)
        return trend_workload(world, n, seed=seed)
    if name == "swe":
        return swe_workload(world, max(n // 5, 1), seed=seed)
    if name == "longtail":
        return longtail_workload(world, n, seed=seed, tail_len=tail_len)
    if name == "churn":
        return churn_workload(world, n, seed=seed, zipf_s=zipf_s)
    raise ValueError(name)


def run_once(
    *,
    workload: str = "zipf",
    mode: str = "cortex",
    n_requests: int = 800,
    cache_ratio: float = 0.4,
    n_intents: int = 1000,
    dim: int = 128,
    eviction: str = "lcfu",
    concurrency: int | None = None,
    qpm: float | None = 100.0,
    colocated: bool = True,
    gpu_capacity: float | None = None,
    judge_acc: float = 0.98,
    judge_band: float | None = None,
    judge_adaptive_band: bool = False,
    judge_compute: str = "oracle",
    judge_d_model: int = 128,
    judge_max_len: int = 128,
    recalibrate_every: float | None = None,
    prefetch: bool = True,
    max_ttl: float = 3600.0,
    zipf_s: float = 0.99,
    em_p_base: float = 0.79,
    judge_timeout: float = 0.25,
    warmup_frac: float = 0.0,
    warm_frac: float | None = None,
    warm_value_ratio: float = 0.4,
    warm_access_latency: float = 0.01,
    tail_len: int | None = None,
    churn_period: float | None = None,
    churn_max_period: float | None = None,
    churn_frac: float = 1.0,
    invalidation: bool = False,
    refresh_ahead: bool = False,
    feed_delay: float = 0.15,
    refresh_min_freq: int = 1,
    cluster: bool = False,
    n_clusters: int = 64,
    nprobe: int | None = 8,
    t_cache_per_row: float = 0.0,
    shards: int = 1,
    t_shard_merge: float = 0.0,
    trace: str | None = None,
    sample_interval: float | None = None,
    slo: list | None = None,
    timeseries: str | None = None,
    trend_duration: float | None = None,
    stale_age_reservoir: int | None = None,
    faults: list | None = None,
    overload: str | None = None,
    seed: int = 0,
) -> dict:
    # churn_period switches the ground truth to a MutableWorld whose
    # low-staticity intents update every churn_period seconds (DESIGN.md
    # §11); None keeps the immutable world, and stale_hits stays 0.
    if churn_period is not None:
        world = MutableWorld(
            n_intents=n_intents, dim=dim, seed=seed,
            churn_min_period=churn_period,
            churn_max_period=churn_max_period or churn_period * 8.0,
            churn_frac=churn_frac,
        )
    else:
        world = SemanticWorld(n_intents=n_intents, dim=dim, seed=seed)
    reqs = build_workload(world, workload, n_requests, seed + 1,
                          zipf_s=zipf_s, tail_len=tail_len,
                          trend_duration=trend_duration)
    cap = int(cache_ratio * world._sizes.sum())
    cache = exact = None
    if mode in ("cortex", "cortex-nojudge"):
        from repro.core.judge_pipeline import (AdmissionBand, JudgePipeline,
                                               default_judge_cfg)

        oracle = OracleJudge(world, accuracy=judge_acc, seed=seed + 2)
        jcfg = default_judge_cfg(d_model=judge_d_model)
        model = None
        if judge_compute == "model":
            # pay real tiny-LM prefill per judge micro-batch (the
            # calibration shim: oracle decisions, model compute)
            from repro.core.judge import ModelJudge

            model = ModelJudge(cfg=jcfg, max_len=judge_max_len,
                               seed=seed + 6)
        band = None
        if judge_band is not None:
            band = AdmissionBand(width=judge_band,
                                 adaptive=judge_adaptive_band)
        # the ONE judge seam (DESIGN.md §14): admission band + model-
        # derived token cost + optional real compute. judge_band=None
        # (and oracle compute) is today's engine, event for event.
        judge = JudgePipeline(oracle, compute=model, judge_cfg=jcfg,
                              max_len=judge_max_len, band=band)
        # clustered (IVF) stage-1 routing, DESIGN.md §12; nprobe=None
        # probes every cluster (the brute-force-parity mode). shards>1
        # (the §13 mesh partition) requires the router, so it implies
        # --cluster on its own.
        ccfg = ClusterConfig(
            n_clusters=n_clusters, nprobe=nprobe, seed=seed + 5,
            n_shards=max(1, shards),
        ) if (cluster or shards > 1) else None
        if warm_frac:
            # tiered storage at EQUAL total bytes: the warm slice comes
            # OUT of the same budget, it is never additional capacity
            warm_bytes = int(cap * warm_frac)
            # the warm tier's extra access latency is an engine-side
            # virtual-time cost: EngineConfig.t_cache_warm (below)
            cache = make_tiered_cache(
                hot_bytes=cap - warm_bytes, warm_bytes=warm_bytes,
                dim=dim, judge=judge, eviction=eviction, max_ttl=max_ttl,
                warm_value_ratio=warm_value_ratio, cluster=ccfg,
            )
        else:
            cache = make_cache(
                capacity_bytes=cap, dim=dim, judge=judge, eviction=eviction,
                max_ttl=max_ttl, cluster=ccfg,
            )
    elif mode == "exact":
        exact = ExactCache(cap, max_ttl=max_ttl)
    clock = VirtualClock()
    # §17 fault injection: parse --faults specs into a FaultSchedule
    # (brownouts live in the remote service, judge slowdown in the
    # engine); None = today's fault-free run, byte-identical
    fault_sched = None
    if faults:
        from repro.serving.faults import FaultSchedule

        fault_sched = (faults if hasattr(faults, "region_down")
                       else FaultSchedule.parse(faults))
    remote = RemoteDataService(qpm=qpm, seed=seed + 3, faults=fault_sched)
    freshness = None
    if cache is not None and (invalidation or refresh_ahead):
        feed = ChangeFeed(world, clock) if invalidation else None
        freshness = FreshnessManager(
            cache=cache, remote=remote, world=world, clock=clock,
            cfg=FreshnessConfig(
                invalidation=invalidation, refresh_ahead=refresh_ahead,
                feed_delay=feed_delay, refresh_min_freq=refresh_min_freq,
            ),
            feed=feed,
        )
    tracer = None
    if trace is not None:
        from repro.obs.trace import Tracer

        tracer = Tracer()
    # §16 monitor is created BEFORE the engine so the §17 overload
    # controller can read its breach state; the sampler that feeds it
    # starts right after construction (ordering only — no behavior
    # change for telemetry-only runs)
    sampler = monitor = None
    if slo and sample_interval is None:
        raise ValueError("slo requires sample_interval")
    if timeseries is not None and sample_interval is None:
        raise ValueError("timeseries requires sample_interval")
    if sample_interval is not None and slo:
        from repro.obs.slo import SLOMonitor

        monitor = SLOMonitor(slo, tracer=tracer)
    ctrl = None
    if overload is not None:
        if overload not in ("on", "off"):
            raise ValueError(f"overload must be 'on'/'off', got {overload!r}")
        from repro.serving.overload import (OverloadConfig,
                                            OverloadController)

        ctrl = OverloadController(
            OverloadConfig(enabled=(overload == "on")),
            monitor=monitor, tracer=tracer,
        )
        if freshness is not None:
            freshness.overload = ctrl
    eng = Engine(
        world=world,
        requests=reqs,
        mode=mode,
        cache=cache,
        exact=exact,
        remote=remote,
        gpu=GPU(GPUConfig(colocated=colocated)
                if gpu_capacity is None else
                GPUConfig(capacity=gpu_capacity, colocated=colocated)),
        cfg=EngineConfig(
            closed_loop=concurrency,
            prefetch=prefetch,
            recalibrate_every=recalibrate_every,
            em_p_base=em_p_base,
            judge_timeout=judge_timeout,
            warmup_frac=warmup_frac,
            t_cache_warm=warm_access_latency,
            t_cache_per_row=t_cache_per_row,
            t_shard_merge=t_shard_merge,
            stale_age_reservoir=stale_age_reservoir,
            seed=seed + 4,
        ),
        clock=clock,
        freshness=freshness,
        tracer=tracer,
        overload=ctrl,
        faults=fault_sched,
    )
    # §16 continuous telemetry: interval sampling of the registry +
    # optional SLO monitoring (monitor built above). Strictly
    # observational — with these off the engine sees the exact same
    # event stream (gated byte-identical).
    if sample_interval is not None:
        from repro.obs.sampler import TimeSeriesSampler

        sampler = TimeSeriesSampler(clock, sample_interval, [eng],
                                    monitor=monitor)
        sampler.start()
    out = eng.run()
    if sampler is not None:
        sampler.finalize()
        # telemetry-enabled runs get extra keys ONLY — with
        # sample_interval=None the summary is byte-identical
        out["timeseries_samples"] = len(sampler.samples)
        if monitor is not None:
            out["slo_breaches"] = monitor.breaches
            out["slo_recoveries"] = monitor.recoveries
        if timeseries is not None:
            from repro.obs.export import export_timeseries

            paths = export_timeseries(sampler, monitor, timeseries)
            out["timeseries_path"] = paths["timeseries"]
            if "alerts" in paths:
                out["alerts_path"] = paths["alerts"]
    if tracer is not None:
        from repro.obs.analyze import check_conservation
        from repro.obs.export import export_trace

        paths = export_trace(tracer, trace)
        violations = check_conservation(tracer, eng.records)
        # traced runs get extra keys ONLY — with trace=None the summary
        # is byte-identical to the untraced engine's
        out["trace_jsonl"] = paths["jsonl"]
        out["trace_chrome"] = paths["chrome"]
        out["trace_spans"] = len(tracer.spans)
        out["trace_conservation_violations"] = len(violations)
        if violations:
            raise AssertionError(
                "span conservation violated:\n" + "\n".join(violations[:20])
            )
    return out


def run_federated(
    *,
    n_regions: int = 3,
    topology: str = "peered",
    n_requests: int = 300,
    n_intents: int = 300,
    dim: int = 64,
    overlap: float = 0.5,
    rtt: float = 0.08,
    faults: list | None = None,
    peek_timeout: float | None = None,
    overload: str | None = None,
    sample_interval: float | None = None,
    slo: list | None = None,
    trace: str | None = None,
    seed: int = 0,
) -> dict:
    """Multi-region driver (--regions > 1): region-skewed request
    streams through a FederationRunner, with the §17 robustness knobs
    (--faults / --peek-timeout / --overload) on the federation path.
    Returns the runner's {aggregate, regions} summary."""
    from repro.data.workloads import region_workloads
    from repro.serving.federation import FederationRunner

    world = SemanticWorld(n_intents=n_intents, dim=dim, seed=seed)
    streams = region_workloads(
        world, max(n_requests // n_regions, 1), n_regions,
        overlap=overlap, seed=seed + 1,
    )
    tracer = None
    if trace is not None:
        from repro.obs.trace import Tracer

        tracer = Tracer()
    runner = FederationRunner(
        world=world, region_requests=streams, topology=topology,
        rtt=rtt, faults=faults or None, peek_timeout=peek_timeout,
        overload=overload, tracer=tracer,
        sample_interval=sample_interval, slos=slo, seed=seed,
    )
    out = runner.run()
    if tracer is not None:
        from repro.obs.export import export_trace

        paths = export_trace(tracer, trace)
        out["aggregate"]["trace_jsonl"] = paths["jsonl"]
        out["aggregate"]["trace_spans"] = len(tracer.spans)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="zipf",
                    choices=["zipf", "trend", "swe", "longtail", "churn"])
    ap.add_argument("--churn-period", type=float, default=None,
                    help="mutable world: class-1 intents update every this"
                         " many seconds (DESIGN.md §11)")
    ap.add_argument("--invalidation", action="store_true",
                    help="subscribe the cache to the origin change feed")
    ap.add_argument("--refresh-ahead", action="store_true",
                    help="revalidate hot entries instead of dropping them")
    ap.add_argument("--warm-frac", type=float, default=None,
                    help="split this fraction of the byte budget into an "
                         "int8/zlib warm tier (DESIGN.md §10)")
    ap.add_argument("--cluster", action="store_true",
                    help="clustered (IVF) stage-1 routing (DESIGN.md §12)")
    ap.add_argument("--n-clusters", type=int, default=64)
    ap.add_argument("--nprobe", type=int, default=8,
                    help="clusters probed per query; 0 = all (the "
                         "brute-force-parity mode)")
    ap.add_argument("--t-cache-per-row", type=float, default=0.0,
                    help="stage-1 latency per row scanned (the scan-"
                         "proportional model; 0 = legacy flat cost)")
    ap.add_argument("--shards", type=int, default=1,
                    help="mesh-shard the stage-1 index across this many "
                         "cluster-ownership shards (DESIGN.md §13; "
                         "implies --cluster)")
    ap.add_argument("--t-shard-merge", type=float, default=0.0,
                    help="cross-shard top-k merge cost per stage-1 pass "
                         "(only charged when --shards > 1)")
    ap.add_argument("--mode", default="cortex",
                    choices=["vanilla", "exact", "cortex", "cortex-nojudge"])
    ap.add_argument("--n-requests", type=int, default=800)
    ap.add_argument("--cache-ratio", type=float, default=0.4)
    ap.add_argument("--eviction", default="lcfu",
                    choices=["lcfu", "lru", "lfu"])
    ap.add_argument("--concurrency", type=int, default=None)
    ap.add_argument("--qpm", type=float, default=100.0)
    ap.add_argument("--no-rate-limit", action="store_true")
    ap.add_argument("--dedicated-judge", action="store_true")
    ap.add_argument("--gpu-capacity", type=float, default=None,
                    help="per-chip token-eq/s budget (default 3000); with "
                         "--dedicated-judge, 1500 matches the colocated "
                         "single-chip budget (the Fig 6 comparison)")
    ap.add_argument("--judge-band", type=float, default=None,
                    help="adaptive-admission band width around tau_sim "
                         "(DESIGN.md §14): best-sim >= tau_sim+w/2 "
                         "bypasses the judge, < tau_sim-w/2 goes straight "
                         "to origin; None/0 = judge everything (legacy)")
    ap.add_argument("--judge-adaptive-band", action="store_true",
                    help="recalibrate the band width alongside tau_lsm "
                         "(needs --recalibrate-every)")
    ap.add_argument("--judge-compute", default="oracle",
                    choices=["oracle", "model"],
                    help="'model' pays real tiny-LM prefill per judge "
                         "micro-batch (decisions stay oracle-faithful)")
    ap.add_argument("--judge-d-model", type=int, default=128,
                    help="judge model width; sets the FLOPs-derived "
                         "judge token cost (16.0 token-eq at 128)")
    ap.add_argument("--judge-max-len", type=int, default=128,
                    help="judge prefill length in tokens")
    ap.add_argument("--no-prefetch", action="store_true")
    ap.add_argument("--recalibrate-every", type=float, default=None)
    ap.add_argument("--trace", default=None, metavar="PREFIX",
                    help="record a request-lifecycle trace (DESIGN.md "
                         "§15): writes PREFIX.jsonl + PREFIX.chrome.json "
                         "(Perfetto-loadable) and verifies the span "
                         "conservation law")
    ap.add_argument("--sample-interval", type=float, default=None,
                    metavar="SECONDS",
                    help="continuous telemetry (DESIGN.md §16): sample "
                         "the metrics registry every this many VIRTUAL "
                         "seconds (windowed rates, latency percentiles, "
                         "pressure gauges); strictly observational")
    ap.add_argument("--slo", action="append", default=None, metavar="SPEC",
                    help="declarative SLO (repeatable; needs "
                         "--sample-interval): "
                         "name:metric:op:bound[:breach_after[:recover_"
                         "after]], e.g. p99:window.latency_p99:<=:3.0:2:2"
                         " — breach/recovery alerts with hysteresis")
    ap.add_argument("--timeseries", default=None, metavar="PREFIX",
                    help="write PREFIX.timeseries.jsonl (+ PREFIX.alerts"
                         ".jsonl when --slo is set); needs "
                         "--sample-interval")
    ap.add_argument("--trend-duration", type=float, default=None,
                    help="trend workload: compress the same requests "
                         "into this many virtual seconds (default 600; "
                         "60 = 10x elevated QPS — the §16 burst knob)")
    ap.add_argument("--stale-age-reservoir", type=int, default=None,
                    help="bound the stale-age histogram's raw samples "
                         "to a seeded reservoir of this size (long "
                         "burst runs; default keeps every sample)")
    ap.add_argument("--faults", action="append", default=None,
                    metavar="SPEC",
                    help="inject a deterministic fault window (DESIGN.md "
                         "§17; repeatable): kind:start:end[:k=v,...], "
                         "kinds region_outage / wan_degrade / "
                         "origin_brownout / judge_slowdown, e.g. "
                         "origin_brownout:20:80:error_rate=0.6")
    ap.add_argument("--overload", default=None, choices=["on", "off"],
                    help="arm the §17 OverloadController ('off' = armed "
                         "but every policy disabled — the neutrality "
                         "probe); policies: shed-to-nojudge above the "
                         "latency SLO / backlog cap, prefetch+refresh "
                         "pause under headroom pressure, serve-stale on "
                         "origin failure")
    ap.add_argument("--peek-timeout", type=float, default=None,
                    help="federation peek deadline in seconds (§17, "
                         "needs --regions > 1): a silent peer counts as "
                         "a NAK, with a per-peer circuit breaker")
    ap.add_argument("--regions", type=int, default=1,
                    help="run a multi-region federation of this many "
                         "regions (region-skewed streams) instead of "
                         "the solo engine")
    ap.add_argument("--topology", default="peered",
                    choices=["local", "peered", "global"],
                    help="federation topology for --regions > 1")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.regions > 1:
        s = run_federated(
            n_regions=args.regions,
            topology=args.topology,
            n_requests=args.n_requests,
            faults=args.faults,
            peek_timeout=args.peek_timeout,
            overload=args.overload,
            sample_interval=args.sample_interval,
            slo=args.slo,
            trace=args.trace,
            seed=args.seed,
        )
        print(json.dumps(s, indent=2, default=float))
        return s

    s = run_once(
        workload=args.workload,
        mode=args.mode,
        n_requests=args.n_requests,
        cache_ratio=args.cache_ratio,
        eviction=args.eviction,
        concurrency=args.concurrency,
        qpm=None if args.no_rate_limit else args.qpm,
        colocated=not args.dedicated_judge,
        gpu_capacity=args.gpu_capacity,
        judge_band=args.judge_band,
        judge_adaptive_band=args.judge_adaptive_band,
        judge_compute=args.judge_compute,
        judge_d_model=args.judge_d_model,
        judge_max_len=args.judge_max_len,
        recalibrate_every=args.recalibrate_every,
        prefetch=not args.no_prefetch,
        warm_frac=args.warm_frac,
        churn_period=args.churn_period,
        invalidation=args.invalidation,
        refresh_ahead=args.refresh_ahead,
        cluster=args.cluster,
        n_clusters=args.n_clusters,
        nprobe=args.nprobe or None,
        t_cache_per_row=args.t_cache_per_row,
        shards=args.shards,
        t_shard_merge=args.t_shard_merge,
        trace=args.trace,
        sample_interval=args.sample_interval,
        slo=args.slo,
        timeseries=args.timeseries,
        trend_duration=args.trend_duration,
        stale_age_reservoir=args.stale_age_reservoir,
        faults=args.faults,
        overload=args.overload,
        seed=args.seed,
    )
    print(json.dumps(s, indent=2, default=float))
    return s


if __name__ == "__main__":
    main()
