"""Render the dry-run JSONL records into the EXPERIMENTS.md roofline
tables.

  PYTHONPATH=src python -m repro.launch.report dryrun_results.jsonl
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def load(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(json.loads(line))
    # keep the LAST record per (arch, shape, mesh) — reruns override
    dedup = {}
    for r in out:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def _ms(x) -> str:
    return f"{x*1e3:.1f}"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | HBM/dev | fits | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "OK":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK "
                f"| {r['hbm_per_device']/2**30:.2f} GiB "
                f"| {'yes' if r['fits_hbm'] else 'NO'} "
                f"| {r.get('t_compile_s','')}s |"
            )
        else:
            reason = r.get("reason") or r.get("error", "")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {r['status']} | — | — | {reason[:60]} |"
            )
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | t_comp ms | t_mem ms | t_coll ms | bottleneck "
        "| MODEL_FLOPS/HLO | roofline-frac (MFU) | move-the-needle |",
        "|---|---|---|---|---|---|---|---|---|"[:-4],
    ]
    hints = {
        "compute": "reduce recompute (remat policy) / causal block-skip",
        "memory": "larger flash tiles; fuse norms; bf16 masters",
        "collective": "Megatron-SP (AR→RS+AG); FSDP-only plan for small "
        "dense; overlap grads",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            continue
        if r["status"] != "OK":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"{r['status']}: {r.get('reason', r.get('error',''))[:48]} "
                f"| — | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {_ms(r['t_compute'])} | {_ms(r['t_memory'])} "
            f"| {_ms(r['t_collective'])} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['mfu']:.3f} "
            f"| {hints[r['bottleneck']]} |"
        )
    return "\n".join(lines)


def collective_detail(recs: list[dict]) -> str:
    lines = ["| arch | shape | wire GiB/dev | by op |", "|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16" or r["status"] != "OK":
            continue
        ops = ", ".join(
            f"{k}:{v/2**30:.2f}" for k, v in sorted(
                r.get("coll_by_op", {}).items(), key=lambda kv: -kv[1]
            )
        )
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['wire_bytes_per_device']/2**30:.2f} | {ops} |"
        )
    return "\n".join(lines)


def summarize(recs):
    n_ok = sum(1 for r in recs if r["status"] == "OK")
    n_skip = sum(1 for r in recs if r["status"] == "SKIP")
    n_fail = sum(1 for r in recs if r["status"] == "FAIL")
    by_mesh = defaultdict(lambda: [0, 0, 0])
    for r in recs:
        i = {"OK": 0, "SKIP": 1, "FAIL": 2}[r["status"]]
        by_mesh[r["mesh"]][i] += 1
    return n_ok, n_skip, n_fail, dict(by_mesh)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl"
    recs = load(path)
    n_ok, n_skip, n_fail, by_mesh = summarize(recs)
    print(f"### Dry-run status: {n_ok} OK / {n_skip} SKIP / {n_fail} FAIL "
          f"{by_mesh}\n")
    print("#### Cell table (both meshes)\n")
    print(dryrun_table(recs))
    print("\n#### Roofline (single-pod 16x16, per step per device)\n")
    print(roofline_table(recs))
    print("\n#### Collective breakdown (single-pod)\n")
    print(collective_detail(recs))


if __name__ == "__main__":
    main()
