"""Seri — the Semantic Retrieval Index (paper §4.2).

Stage 1 (coarse): exact cosine top-k over the SE embedding matrix with the
τ_sim gate. On TPU this runs as the Pallas ``ann_topk`` kernel (brute-force
MXU matmul — the TPU-idiomatic replacement for Faiss graph traversal, see
DESIGN.md §3); on CPU the numpy path is bit-identical.

Stage 2 (fine): the semantic judge validates each candidate's *result*
against the new query; the first candidate with S_lsm ≥ τ_lsm is a
semantic-aware cache hit.

Both stages are batched (DESIGN.md §8): ``search_batch`` pushes a whole
(B, D) query block through one masked matmul (or one ``ann_topk`` launch,
which always had the B dimension), and ``CortexCache._judge_blocks``
scores the candidates of *all* queries in a single ``judge.score_pairs``
call. The scalar entry points are one-query wrappers over the batched
path, so scalar and batched execution are the same code and produce
identical results.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.semantic_element import SemanticElement


class RowIndex:
    """Fixed-capacity free-list row allocator — the management half
    shared by the fp32 hot index below and the int8 warm index
    (``core/tiers.py::QuantIndex``): active mask, row→se_id mapping, row
    alloc/free. Subclasses own the storage arrays and zero them in
    ``_clear_rows``, so the two tiers' row lifecycles cannot drift.

    ``row_se`` is an int64 array (-1 = free) so batched search paths
    resolve row→se_id with one fancy-indexed gather instead of a
    per-candidate Python loop. An optional
    :class:`~repro.core.clustering.ClusterRouter` observes the row
    lifecycle (``note_add``/``note_remove``) to keep its cluster
    buckets free-list-consistent (DESIGN.md §12)."""

    def __init__(self, capacity: int, dim: int, router=None):
        self.capacity = capacity
        self.dim = dim
        self.active = np.zeros(capacity, bool)
        self.row_se = np.full(capacity, -1, np.int64)
        self.router = router
        # rows touched by the most recent search_batch call (active rows
        # for brute force; centroids + gathered members for the routed
        # scan) — the engine's scan-proportional latency term
        self.last_scanned = 0
        # the busiest shard's share of last_scanned (DESIGN.md §13):
        # shards scan in parallel, so the engine's critical path is the
        # max-over-shards term, not the total. Equal to last_scanned
        # for brute force and unsharded routing.
        self.last_scanned_max_shard = 0
        # backends set these; the base dispatch only tests for presence
        self._kernel_fn = None
        self._ivf_kernel_fn = None
        self._ivf_sharded_fn = None
        self._free = list(range(capacity - 1, -1, -1))

    def __len__(self) -> int:
        return int(self.active.sum())

    @property
    def full(self) -> bool:
        return not self._free

    def _alloc(self, se_id: int) -> int:
        if not self._free:
            raise RuntimeError("index full — evict first")
        row = self._free.pop()
        self.active[row] = True
        self.row_se[row] = se_id
        return row

    def _clear_rows(self, ra: np.ndarray) -> None:
        raise NotImplementedError

    def _routed_dispatch(self, q: np.ndarray, kernel_scan, routed_scan,
                         brute_scan):
        """The one stage-1 dispatch both index flavors share (the same
        anti-drift rationale as ``topk_desc``): Pallas routed scan when
        the backend has one and clusters exist, numpy routed scan when
        the router is trained, brute force otherwise. Returns
        ``(rows, scores, routed)`` — ``routed`` tells the caller to
        apply the kernel NEG-slot row filter."""
        ready = self.router is not None and self.router.ready
        if ready and self._ivf_kernel_fn is not None and \
                np.any(self.router.counts > 0):
            return (*kernel_scan(), True)
        if ready:
            info = self.router.route(q)
            if info is not None:
                return (*routed_scan(info), True)
        self.last_scanned = len(self)
        self.last_scanned_max_shard = self.last_scanned
        return (*brute_scan(), False)

    def remove_rows(self, rows) -> None:
        """Batched removal: one fancy-indexed store per field."""
        rows = [r for r in rows if self.active[r]]
        if not rows:
            return
        ra = np.asarray(rows)
        self.active[ra] = False
        self._clear_rows(ra)
        self.row_se[ra] = -1
        if self.router is not None:
            self.router.note_remove(ra)
        for r in rows:
            self._free.append(r)


def topk_desc(s: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-row top-k, similarity-descending, over a (B, N) score matrix
    (mutates ``s``): negate in place, ``argpartition``, then a
    boundary-tie-exact stable sort — the one selection idiom both the
    fp32 and int8 (core/tiers.py) indexes use, so their tie-break
    semantics cannot drift. Returns (rows (B, k), vals (B, k)).

    Ties break by ascending COLUMN index — an exact rule, not
    argpartition luck: the candidate set is expanded to every value
    tying the k-th (the ``topk_desc_stable`` idiom) so the result is
    independent of the matrix layout. That is what makes the clustered
    index's nprobe=all mode bit-identical to brute force (DESIGN.md
    §12): the routed union scores the same values at different column
    positions, and a layout-dependent tie pick (exact-duplicate
    embeddings — judge false-negative re-inserts — tying at the
    boundary) would diverge. Ascending-column also matches the Pallas
    kernels' tie order (per-tile argmax + lax.top_k both prefer the
    lowest index)."""
    b, m = s.shape
    k_eff = min(k, m)
    np.negative(s, out=s)                             # sort ascending
    part = np.argpartition(s, k_eff - 1, axis=1)[:, :k_eff]
    psc = np.take_along_axis(s, part, axis=1)
    rows = np.empty((b, k_eff), part.dtype)
    vals = np.empty((b, k_eff), s.dtype)
    for i in range(b):
        thr = psc[i].max()
        sel = np.flatnonzero(s[i] <= thr)   # superset incl. boundary ties
        order = sel[np.argsort(s[i, sel], kind="stable")][:k_eff]
        rows[i] = order
        vals[i] = -s[i, order]
    return rows, vals


def topk_desc_stable(v: np.ndarray, k: int) -> np.ndarray:
    """Indices of the ``k`` largest values of 1-D ``v``, descending,
    ties broken by ascending position — EXACTLY
    ``np.argsort(-v, kind="stable")[:k]``, but O(n + t·log t) via
    ``argpartition`` with the boundary-tie expansion trick the SoA
    victim selector uses (``se_store._smallest_in_order``): the
    partition's candidate set is widened to every value tying the k-th,
    so a tie group split by the partition boundary cannot change which
    elements survive. The per-candidate rescore selections
    (``core/tiers.py``) use this instead of a full sort."""
    m = v.shape[0]
    k = min(k, m)
    if k <= 0:
        return np.zeros(0, np.intp)
    if k >= m:
        return np.argsort(-v, kind="stable")
    neg = -v
    part = np.argpartition(neg, k - 1)[:k]
    thr = neg[part].max()
    sel = np.flatnonzero(neg <= thr)       # superset incl. boundary ties
    return sel[np.argsort(neg[sel], kind="stable")][:k]


def sharded_topk_merge(s: np.ndarray, owners: np.ndarray, n_shards: int,
                       k: int) -> tuple[np.ndarray, np.ndarray]:
    """Shard-parallel top-k over a (B, G) score matrix whose columns
    are partitioned by ``owners`` (column → shard), merged to one
    global (rows, vals) — bit-identical to ``topk_desc(s, k)``.

    Each shard runs :func:`topk_desc` over its own column slice, then
    the ≤ S·k finalists merge under the same total order topk_desc
    uses: value descending, GLOBAL column ascending (``lexsort`` keys).
    Every global winner is by definition inside its own shard's top-k
    under that order, so the shard union always contains the global
    top-k and the merge reproduces it exactly — including duplicate
    scores straddling a shard boundary. This is the host-path model of
    the shard_map + cross-shard ``lax.top_k`` kernel (DESIGN.md §13);
    unlike :func:`topk_desc` it does NOT mutate ``s`` (the per-shard
    column gathers are copies).
    """
    b, m = s.shape
    k_eff = min(k, m)
    ccols, cvals = [], []
    for sh in range(n_shards):
        cols = np.flatnonzero(owners == sh)
        if not len(cols):
            continue
        lr, lv = topk_desc(s[:, cols], k)    # fancy-index copy of s
        ccols.append(cols[lr])
        cvals.append(lv)
    cc = np.concatenate(ccols, axis=1)
    cv = np.concatenate(cvals, axis=1)
    rows = np.empty((b, k_eff), np.intp)
    vals = np.empty((b, k_eff), s.dtype)
    for i in range(b):
        order = np.lexsort((cc[i], -cv[i]))[:k_eff]
        rows[i] = cc[i][order]
        vals[i] = cv[i][order]
    return rows, vals


class VectorIndex(RowIndex):
    """Fixed-capacity embedding store with free-list row management.

    With a :class:`~repro.core.clustering.ClusterRouter` attached,
    stage 1 runs as a clustered (IVF-style) routed scan — centroids
    first, then only the selected clusters' member rows — instead of
    the full-matrix brute force (DESIGN.md §12). Until the router has
    trained (or without one) the brute path runs unchanged."""

    def __init__(self, capacity: int, dim: int, backend: str = "numpy",
                 router=None):
        super().__init__(capacity, dim, router=router)
        self.backend = backend
        self.emb = np.zeros((capacity, dim), np.float32)
        if backend == "kernel":
            from repro.kernels.ops import (
                ann_topk_ivf_jit, ann_topk_ivf_sharded_jit, ann_topk_jit)

            self._kernel_fn = ann_topk_jit
            self._ivf_kernel_fn = ann_topk_ivf_jit
            self._ivf_sharded_fn = ann_topk_ivf_sharded_jit

    def add(self, se_id: int, embedding: np.ndarray) -> int:
        row = self._alloc(se_id)
        self.emb[row] = embedding
        if self.router is not None:
            self.router.note_add(row, self.emb[row], self)
        return row

    def add_batch(self, se_ids, embeddings) -> np.ndarray:
        """Bulk add for large prefills (the million-entry sweeps): one
        vectorized alloc+store per block instead of n scalar calls.

        Stays on the scalar :meth:`add` path until the router trains —
        the first refresh must trigger at the same index size as a
        sequential loop would hit — then switches to bulk allocation +
        ``note_add_batch`` (which itself splits at the router's exact
        refresh points). Returns the allocated rows, ascending.
        """
        embs = np.asarray(embeddings, np.float32)
        ids = np.asarray(se_ids, np.int64)
        n = len(ids)
        if len(self._free) < n:
            raise RuntimeError("index full — evict first")
        rows = np.empty(n, np.int64)
        i = 0
        while i < n and self.router is not None \
                and not self.router.trained:
            rows[i] = self.add(int(ids[i]), embs[i])
            i += 1
        rt = self.router
        while i < n:
            # allocate only up to the router's next refresh boundary: a
            # refresh sees exactly the rows a sequential loop would have
            # active (bulk-allocating ahead would leak not-yet-noted
            # rows into the training sample and re-bucketing pass)
            take = n - i
            if rt is not None:
                take = min(take, max(1, rt.cfg.refresh_every - rt._muts))
            ra = np.array([self._free.pop() for _ in range(take)],
                          np.int64)
            self.active[ra] = True
            self.row_se[ra] = ids[i:i + take]
            self.emb[ra] = embs[i:i + take]
            rows[i:i + take] = ra
            if rt is not None:
                rt.note_add_batch(ra, self.emb[ra], self)
            i += take
        return rows

    def _clear_rows(self, ra: np.ndarray) -> None:
        self.emb[ra] = 0.0

    def route_embs(self, rows: np.ndarray) -> np.ndarray:
        """Unit-norm fp32 rows for centroid training/assignment."""
        return self.emb[rows]

    # ----------------------------------------------------------- search

    def search(self, q: np.ndarray, k: int, tau_sim: float):
        """Top-k rows with cosine ≥ tau_sim. q: (dim,) unit-norm.
        Returns (se_ids, sims) sorted by similarity desc."""
        return self.search_batch(q[None], k, tau_sim)[0]

    def _search_routed(self, q: np.ndarray, k: int, routed):
        """Scan only the routed clusters' member rows. The gathered
        union is in ascending row order and the not-allowed mask uses
        the same -1.0 sentinel as the brute path's inactive mask, so at
        nprobe=all the scored matrix is exactly the brute matrix
        restricted to active rows — same values, same tie order."""
        g_rows, allowed, self.last_scanned = routed
        rt = self.router
        s = np.where(allowed, q @ self.emb[g_rows].T, -1.0)
        if rt.n_shards > 1:
            # shard-parallel selection over the SAME score matrix: each
            # shard top-k's its owned member columns, finalists merge
            # under topk_desc's (value desc, row asc) order — so the
            # result is bit-identical to the unsharded path and the
            # float-reduction tolerance across shard counts is zero
            owners = rt.shard_of[rt.assign[g_rows]]
            n_cent = self.last_scanned - len(g_rows)
            self.last_scanned_max_shard = n_cent + int(
                np.bincount(owners, minlength=rt.n_shards).max())
            lrows, sims = sharded_topk_merge(s, owners, rt.n_shards, k)
        else:
            self.last_scanned_max_shard = self.last_scanned
            lrows, sims = topk_desc(s, k)                      # (B, k)
        return g_rows[lrows], sims

    def _search_routed_kernel(self, q: np.ndarray, k: int):
        """Routed scan on the Pallas backend: routing (centroid scores +
        top-nprobe) runs INSIDE the jit wrapper, so no host-side
        route()/gather happens at all — rows-scanned accounting derives
        from the kernel's own cluster selection."""
        rt = self.router
        if rt.n_shards > 1 and self._ivf_sharded_fn is not None:
            return self._search_routed_kernel_sharded(q, k)
        layout, bucket_rows, bucket_valid = rt.kernel_buckets(self)
        nprobe = rt.cfg.n_clusters if rt.cfg.nprobe is None \
            else min(rt.cfg.nprobe, rt.cfg.n_clusters)
        live = rt.counts > 0
        sims, rows, sel, en = self._ivf_kernel_fn(
            rt.centroids, live.astype(np.int32), layout,
            bucket_rows, bucket_valid, q, nprobe, k,
        )
        probed = np.unique(np.asarray(sel)[np.asarray(en) > 0])
        self.last_scanned = int(live.sum() + rt.counts[probed].sum())
        self.last_scanned_max_shard = self.last_scanned
        return np.asarray(rows), np.asarray(sims)

    def _search_routed_kernel_sharded(self, q: np.ndarray, k: int):
        """Shard-parallel Pallas routed scan (DESIGN.md §13): routing
        stays global (centroid top-nprobe inside the jit wrapper); each
        mesh shard scans only its owned probes under ``shard_map`` and
        the S·nprobe·k finalists merge with one cross-shard
        ``lax.top_k``. Scan accounting splits the probed members by
        owner so the engine can charge max-over-shards."""
        rt = self.router
        layout, shard_rows, shard_valid, bounds = \
            rt.kernel_shard_buckets(self)
        nprobe = rt.cfg.n_clusters if rt.cfg.nprobe is None \
            else min(rt.cfg.nprobe, rt.cfg.n_clusters)
        live = rt.counts > 0
        sims, rows, sel, en = self._ivf_sharded_fn(
            rt.centroids, live.astype(np.int32), layout,
            shard_rows, shard_valid, bounds, q, nprobe, k,
        )
        probed = np.unique(np.asarray(sel)[np.asarray(en) > 0])
        n_cent = int(live.sum())
        per_shard = np.bincount(
            rt.shard_of[probed], weights=rt.counts[probed],
            minlength=rt.n_shards)
        self.last_scanned = n_cent + int(rt.counts[probed].sum())
        self.last_scanned_max_shard = n_cent + int(per_shard.max())
        return np.asarray(rows), np.asarray(sims)

    def _search_brute(self, q: np.ndarray, k: int):
        if self._kernel_fn is not None:
            sims, rows = self._kernel_fn(self.emb, self.active, q, k)
            return np.asarray(rows), np.asarray(sims)
        # (B, N) row-major so the per-query partition/sort runs over
        # contiguous lanes (axis=0 on (N, B) is strided and ~3× slower
        # at large N·B)
        s = np.where(self.active[None, :], q @ self.emb.T, -1.0)
        rows, sims = topk_desc(s, k)                           # (B, k)
        return rows, sims

    def search_batch(self, q: np.ndarray, k: int, tau_sim: float):
        """Batched stage-1: q (B, dim) -> list of B (se_ids, sims) pairs.

        One masked matmul over the whole query block (brute) or over the
        routed cluster union (IVF); per-column top-k via ``argpartition``
        along axis 0. Each column's result is identical to the
        single-query path (numpy partitions/sorts each 1-D lane
        independently), so batching never changes retrieval semantics.
        """
        b = q.shape[0]
        if len(self) == 0:
            self.last_scanned = 0
            self.last_scanned_max_shard = 0
            empty = ([], np.zeros(0, np.float32))
            return [empty] * b
        q = np.asarray(q, np.float32)
        rows, sims, routed = self._routed_dispatch(
            q,
            lambda: self._search_routed_kernel(q, k),
            lambda info: self._search_routed(q, k, info),
            lambda: self._search_brute(q, k),
        )
        out = []
        for i in range(b):
            keep = sims[i] >= tau_sim
            if routed:
                keep &= rows[i] >= 0   # kernel NEG slots carry row -1
            r = rows[i][keep]
            # row→se_id as ONE int64 gather (no per-candidate Python loop)
            out.append((self.row_se[r].tolist(),
                        sims[i][keep].astype(np.float32)))
        return out


@dataclasses.dataclass
class SeriResult:
    hit: bool
    se: Optional[SemanticElement]
    n_candidates: int
    judge_calls: int
    best_score: float
    # stage-1 similarities ALIGNED with the surviving candidate list:
    # sims[j] is the cosine of the j-th candidate the judge scored
    # (expired stage-1 matches are dropped from both)
    sims: np.ndarray


class Seri:
    """Two-stage retrieval configuration over a SE store.

    Holds the stage-1 index, the judge, and the thresholds. The
    retrieval pipeline itself lives in ``CortexCache._stage1_blocks`` /
    ``_judge_blocks`` (one implementation for the scalar, batched, and
    engine-staged paths — and the seam the tiered cache overrides);
    keeping a second copy here is how sims/candidate misalignment bugs
    happen twice."""

    def __init__(self, index: VectorIndex, judge, *, tau_sim: float = 0.9,
                 tau_lsm: float = 0.9, top_k: int = 4):
        from repro.core.judge_pipeline import as_pipeline

        self.index = index
        # every stage-2 interaction goes through ONE JudgePipeline
        # (DESIGN.md §14); a raw judge object is wrapped in a default
        # pipeline (no admission band, FLOPs-derived token cost)
        self.pipeline = as_pipeline(judge)
        self.tau_sim = tau_sim
        self.tau_lsm = tau_lsm
        self.top_k = top_k

    @property
    def judge(self):
        """Back-compat: the decision scorer behind the pipeline."""
        return self.pipeline.decisions

    @property
    def stage1_gate(self) -> float:
        """Similarity gate stage 1 applies: the admission band's lower
        edge when armed, τ_sim otherwise."""
        return self.pipeline.stage1_gate(self.tau_sim)
