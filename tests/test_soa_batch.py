"""SoA cache runtime (DESIGN.md §8): batched-path equivalence and
vectorized-eviction parity tests.

These are plain randomized tests (no hypothesis dependency) so they run in
the minimal container: fixed-seed workloads, exact equality assertions.
"""
import numpy as np
import pytest

from repro.core.cache import make_cache
from repro.core.judge import OracleJudge
from repro.core.se_store import SEStore
from repro.core.seri import VectorIndex
from repro.data.world import SemanticWorld
from repro.serving.engine import ExactCache

WORLD = SemanticWorld(n_intents=120, dim=48, seed=7)


def _fresh(seed=3, capacity=15_000, max_ttl=400.0, eviction="lcfu"):
    judge = OracleJudge(WORLD, accuracy=0.98, seed=seed)
    return make_cache(
        capacity_bytes=capacity, dim=WORLD.dim, judge=judge,
        index_capacity=256, max_ttl=max_ttl, eviction=eviction,
    )


def _run_workload(batched: bool, *, seed: int, eviction: str = "lcfu"):
    """Drive one cache through a randomized stream, batched or scalar.

    Scalar reference semantics for a block: all lookups first (in order),
    then all miss-inserts (in order) — which is exactly what
    lookup_batch/insert_batch promise to reproduce.
    """
    cache = _fresh(seed=seed, eviction=eviction)
    rng = np.random.default_rng(seed)
    now, hit_seq = 0.0, []
    for _ in range(50):
        now += float(rng.random() * 30)
        bs = int(rng.integers(1, 9))
        qs = [WORLD.query(int(rng.integers(0, 120)), int(rng.integers(0, 30)))
              for _ in range(bs)]
        embs = np.stack([WORLD.embed(q) for q in qs])
        if batched:
            results = cache.lookup_batch(qs, embs, now)
        else:
            results = [cache.lookup(q, e, now) for q, e in zip(qs, embs)]
        hit_seq.extend(r.hit for r in results)
        misses = [(q, e) for (q, e), r in zip(zip(qs, embs), results)
                  if not r.hit]
        if batched:
            cache.insert_batch(
                [dict(query=q, q_emb=e, value=WORLD.fetch(q), cost=0.005,
                      latency=0.4, size=WORLD.value_size(q))
                 for q, e in misses],
                now=now,
            )
        else:
            for q, e in misses:
                cache.insert(q, e, WORLD.fetch(q), now=now, cost=0.005,
                             latency=0.4, size=WORLD.value_size(q))
    return hit_seq, cache


@pytest.mark.parametrize("eviction", ["lcfu", "lru", "lfu"])
@pytest.mark.parametrize("seed", [3, 11, 29])
def test_batched_path_equivalent_to_scalar(seed, eviction):
    """lookup_batch/insert_batch reproduce the scalar hit/miss/eviction
    sequence exactly (same judge rng consumption, same victims)."""
    seq_a, cache_a = _run_workload(False, seed=seed, eviction=eviction)
    seq_b, cache_b = _run_workload(True, seed=seed, eviction=eviction)
    assert seq_a == seq_b
    assert cache_a.stats == cache_b.stats
    assert sorted(cache_a.store) == sorted(cache_b.store)
    assert cache_a.usage == cache_b.usage
    # per-SE metadata identical too
    for se_id in cache_a.store:
        a, b = cache_a.store[se_id], cache_b.store[se_id]
        assert (a.key, a.freq, a.last_access, a.expires_at, a.size) == \
               (b.key, b.freq, b.last_access, b.expires_at, b.size)


def test_store_invariants_under_batched_ops():
    _, cache = _run_workload(True, seed=5)
    assert cache.usage <= cache.capacity_bytes
    assert cache.usage == sum(se.size for se in cache.store.values())
    assert len(cache.store) == len(cache.rows)
    assert len(cache.seri.index) == len(cache.store)
    # SoA aggregate view agrees with the per-item views
    assert cache.soa.usage == cache.usage
    assert len(cache.soa) == len(cache.store)


def test_stage1_batch_matches_scalar():
    cache = _fresh(seed=9)
    rng = np.random.default_rng(9)
    now = 0.0
    for i in range(40):
        q = WORLD.query(int(rng.integers(0, 120)), 0)
        cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=now, cost=0.01,
                     latency=0.2, size=WORLD.value_size(q))
        now += 1.0
    qs = [WORLD.query(int(rng.integers(0, 120)), int(rng.integers(0, 30)))
          for _ in range(16)]
    embs = np.stack([WORLD.embed(q) for q in qs])
    batched = cache.stage1_batch(qs, embs, now)
    scalar = [cache.stage1(q, e, now) for q, e in zip(qs, embs)]
    assert [[c.se_id for c in cs] for cs in batched] == \
           [[c.se_id for c in cs] for cs in scalar]


def test_numpy_stage1_matches_pallas_kernel_rowwise():
    """The vectorized numpy stage-1 and the Pallas ``ann_topk`` kernel
    return the same rows in the same order for a whole query block."""
    rng = np.random.default_rng(0)
    n, d, b, k = 300, 32, 16, 4
    emb = rng.standard_normal((n, d)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    idx_np = VectorIndex(512, d, backend="numpy")
    idx_kr = VectorIndex(512, d, backend="kernel")
    for i in range(n):
        idx_np.add(i, emb[i])
        idx_kr.add(i, emb[i])
    # queries near stored points so candidates clear tau_sim
    pick = rng.integers(0, n, b)
    q = emb[pick] + 0.05 * rng.standard_normal((b, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    res_np = idx_np.search_batch(q, k, tau_sim=0.5)
    res_kr = idx_kr.search_batch(q, k, tau_sim=0.5)
    assert any(ids for ids, _ in res_np)
    for (ids_n, sims_n), (ids_k, sims_k) in zip(res_np, res_kr):
        assert ids_n == ids_k
        np.testing.assert_allclose(sims_n, sims_k, atol=2e-5)
    # scalar search is literally the B=1 batched path
    one = idx_np.search(q[0], k, tau_sim=0.5)
    assert one[0] == res_np[0][0]


@pytest.mark.parametrize("policy", ["lcfu", "lru", "lfu"])
def test_vectorized_victim_order_matches_reference(policy):
    """argpartition victim selection == the legacy full stable sort,
    including tie groups (freq=0 items all score 0 under LCFU)."""
    rng = np.random.default_rng(1)
    store = SEStore(128)
    now = 1000.0
    for i in range(100):
        store.add(
            i, i, key=f"k{i}", value=None,
            staticity=int(rng.integers(1, 11)),
            cost=float(rng.choice([0.0, 0.005, 0.5])),
            latency=float(rng.choice([0.05, 0.4, 2.0])),
            size=int(rng.choice([50, 100, 100, 200])),
            created_at=0.0,
            expires_at=float(rng.choice([500.0, 2000.0, 3000.0])),
            freq=int(rng.choice([0, 0, 1, 2, 7])),
            last_access=float(rng.integers(0, 5) * 100),
            prefetched=False, intent=None,
        )
    rows = np.flatnonzero(store.active)

    def ref_key(r):
        if policy == "lru":
            return (store.last_access[r], store.se_id[r])
        if policy == "lfu":
            return (store.freq[r], store.last_access[r], store.se_id[r])
        return (store.lcfu_scores(np.asarray([r]), now)[0], store.se_id[r])

    ref_order = sorted(rows, key=ref_key)
    for n in (1, 5, 33, 100):
        got = store.victim_rows(now, policy, n=n)
        assert list(got) == [int(r) for r in ref_order[:n]], (policy, n)
    # byte-targeted selection: prefix of the same order, minimal length
    need = int(store.size[rows].sum() * 0.3)
    got = store.victim_rows(now, policy, need_bytes=need)
    freed = np.cumsum(store.size[list(got)])
    assert freed[-1] >= need
    assert list(got) == [int(r) for r in ref_order[:len(got)]]
    assert len(got) == 1 or freed[-2] < need  # no over-eviction


def test_ttl_purge_is_masked_and_exact():
    cache = _fresh(seed=2, max_ttl=100.0)
    now = 0.0
    for i in range(30):
        q = WORLD.query(i, 0)
        cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=now, cost=0.005,
                     latency=0.4, size=100)
    expired_ref = {se.se_id for se in cache.store.values()
                   if se.expired(5000.0)}
    n = cache.purge_expired(5000.0)
    assert n == len(expired_ref)
    assert all(se_id not in cache.store for se_id in expired_ref)
    assert cache.stats.ttl_evictions == n


def test_retrieve_sims_aligned_with_surviving_candidates():
    """When stage 1 returns candidates and some expire, ``sims[j]`` must
    stay the similarity of ``cands[j]`` — the expired candidate's sim is
    dropped with it (previously sims kept stage-1 order and misaligned)."""
    cache = _fresh(seed=13, max_ttl=3600.0)
    now = 0.0
    q_short = WORLD.query(0, 0)
    q_long = WORLD.query(0, 1)
    cache.insert(q_short, WORLD.embed(q_short), WORLD.fetch(q_short),
                 now=now, cost=0.01, latency=0.4, size=100, ttl=50.0)
    cache.insert(q_long, WORLD.embed(q_long), WORLD.fetch(q_long),
                 now=now, cost=0.01, latency=0.4, size=100, ttl=5000.0)
    probe = WORLD.query(0, 2)
    p_emb = WORLD.embed(probe)
    # both paraphrases are live: two candidates, two sims
    res = cache.lookup(probe, p_emb, 10.0)
    assert res.n_candidates == 2 and len(res.sims) == 2
    # after the short-TTL entry expires: ONE candidate — and the one sim
    # returned must be the survivor's own cosine, not the stage-1 best
    res = cache.lookup(probe, p_emb, 100.0)
    assert res.n_candidates == 1
    assert len(res.sims) == res.n_candidates
    np.testing.assert_allclose(
        res.sims[0], float(p_emb @ WORLD.embed(q_long)), rtol=1e-5
    )


def test_sestore_add_rejects_active_row():
    """Clobbering a live row corrupted id2row (the displaced SE's entry
    kept pointing at a row describing a different element)."""
    store = SEStore(4)
    kw = dict(key="k", value="v", staticity=5, cost=0.01, latency=0.1,
              size=10, created_at=0.0, expires_at=100.0, freq=1,
              last_access=0.0, prefetched=False, intent=None)
    store.add(2, 7, **kw)
    with pytest.raises(ValueError, match="already holds live SE 7"):
        store.add(2, 8, **kw)
    # the original mapping is intact and a freed row is reusable again
    assert store.id2row == {7: 2}
    store.remove_row(2)
    store.add(2, 8, **kw)
    assert store.id2row == {8: 2}


def test_exact_cache_refreshes_stale_entry():
    """Reinserting a key must refresh value and TTL — an expired entry
    previously stuck forever and the key could never hit again."""
    c = ExactCache(capacity_bytes=10_000, max_ttl=10.0)
    c.insert("q", "v1", 100, now=0.0)
    assert c.lookup("q", 5.0) == "v1"
    assert c.lookup("q", 50.0) is None          # expired
    c.insert("q", "v2", 120, now=50.0)          # re-fetched: must refresh
    assert c.lookup("q", 55.0) == "v2"
    assert c.usage == 120
    assert list(c.d) == ["q"]


def test_view_is_live_and_guarded():
    cache = _fresh(seed=4)
    q = WORLD.query(0, 0)
    se = cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=0.0, cost=0.01,
                      latency=0.1, size=100)
    se.freq += 3
    assert cache.store[se.se_id].freq == 4  # view writes hit the arrays
    assert se.valid
    cache._remove(se.se_id, ttl=False)
    assert not se.valid
    assert se.se_id not in cache.store
