"""Shared helpers for the per-figure/table benchmarks."""
from __future__ import annotations

from repro.launch.serve import run_once

# The paper's four search benchmarks, as synthetic-world profiles: the
# skew/locality and the no-cache EM baseline differ per dataset (Fig 7/13;
# EM baselines follow published Search-R1-7B numbers).
DATASETS = {
    "zilliz": dict(zipf_s=1.10, em_p_base=0.80, seed=11),
    "hotpotqa": dict(zipf_s=0.99, em_p_base=0.62, seed=12),
    "musique": dict(zipf_s=0.99, em_p_base=0.35, seed=13),
    "2wiki": dict(zipf_s=0.99, em_p_base=0.52, seed=14),
    "strategyqa": dict(zipf_s=0.99, em_p_base=0.79, seed=15),
}


# rows emitted by the current benchmark, captured for --json output
# (benchmarks/run.py clears this before each benchmark and snapshots it
# after, so regression gates that SystemExit still leave their rows)
ROWS: list[dict] = []


def emit(name: str, us_per_call: float, *, seed=None, shards=None,
         nprobe=None, judge_model=None, band=None, **derived):
    """One benchmark row. ``seed`` lands as a first-class field in the
    --json BENCH_*.json rows (alongside the git_sha and device count
    benchmarks/run.py stamps at write time) so cross-PR trajectory
    diffs can tell a code change from a seed change; None = not
    seed-parameterized. ``shards``/``nprobe`` are likewise first-class
    (None = not shard/probe-parameterized): the mesh-sharded stage-1
    rows (DESIGN.md §13) must be groupable by shard/mesh config without
    parsing the free-form derived dict. ``judge_model``/``band`` do the
    same for the judge-colocation frontier rows (§14): the throughput-
    vs-judge-accuracy frontier must be reconstructable from the
    artifacts alone — judge_model names the stage-2 cost/compute config
    (e.g. "oracle+flops:d128"), band is the admission-band width."""
    first = {k: v for k, v in (("shards", shards), ("nprobe", nprobe),
                               ("judge_model", judge_model),
                               ("band", band))
             if v is not None}
    kv = " ".join(f"{k}={v}" for k, v in {**first, **derived}.items())
    print(f"{name},{us_per_call:.1f},{kv}")
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "seed": seed, "shards": shards, "nprobe": nprobe,
                 "judge_model": judge_model, "band": band,
                 "derived": derived})


def run_ds(dataset: str, mode: str, **kw):
    prof = DATASETS[dataset]
    import repro.serving.engine as eng_mod

    base = dict(
        workload="zipf", mode=mode, n_requests=500, n_intents=800,
        concurrency=8, seed=prof["seed"],
    )
    base.update(kw)
    s = run_once(**base)
    return s


def fmt(s: dict) -> dict:
    return dict(
        thpt=round(s["throughput_rps"], 3),
        hit=round(s.get("hit_rate", 0.0), 3),
        lat_ms=round(s["latency_mean"] * 1e3, 1),
        p99_ms=round(s["latency_p99"] * 1e3, 1),
        api=s["api_calls"],
        em=round(s["em"], 3),
    )
