"""Cross-region cache federation (DESIGN.md §9).

Cortex is a *cross-region* architecture: the agent cluster and the data
source sit in different regions, and the cache's whole purpose is to keep
knowledge near the requester. This module adds the missing topology
dimension — several agent regions, each with its own local
:class:`~repro.core.cache.CortexCache` and origin
:class:`~repro.serving.remote.RemoteDataService` (region-specific WAN
latency / cost / QPM), joined by a :class:`Federation` router.

On a local cache miss the router broadcasts a *semantic peek* to every
sibling region: a probe flies one half-RTT, runs a stage-1
(``peek_semantic``) search against the sibling's cache at the virtual
instant it arrives, and the response carries a lease (value, absolute
expiry, staticity) back. The nearest positive response wins — responses
arrive in RTT order on the shared clock, so "first positive response"
IS "nearest holder" — and a transfer admits the value into the local
cache with

  * **provenance** — ``se.origin`` records the source region;
  * **adjusted TTL** — the copy expires at the SOURCE entry's absolute
    expiry, so federation never extends a value's lifetime;
  * **transfer economics** — admission cost is the (cheap) inter-region
    transfer cost, not the origin call price, so LCFU correctly treats
    federated copies as cheap to re-obtain.

Only when every sibling NAKs (or the lease would expire in flight) does
the request fall back to its region's origin WAN fetch, paying its own
rate limiter. Three topologies are benchmarked (``--only federation``):
per-region caches without peering ("local"), the full federation
("peered"), and one shared global cache homed in region 0 that remote
regions reach at inter-region RTT ("global").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.cache import CortexCache, make_cache
from repro.core.judge import OracleJudge
from repro.data.workloads import Request
from repro.data.world import SemanticWorld
from repro.obs.metrics import percentile
from repro.obs.trace import BACKGROUND
from repro.serving.clock import VirtualClock
from repro.serving.engine import Engine, EngineConfig
from repro.serving.gpu import GPU, GPUConfig
from repro.serving.remote import RemoteDataService


@dataclasses.dataclass
class RegionConfig:
    """One agent region: its WAN link to the origin data service and the
    sizing of its local cache slice."""

    name: str = "region"
    wan_lat_lo: float = 0.3     # origin WAN latency band (paper §2.2)
    wan_lat_hi: float = 0.5
    wan_cost: float = 0.005     # $ per origin call
    qpm: Optional[float] = 100.0  # origin rate limit (per-region bucket)
    cache_ratio: float = 0.4    # capacity as fraction of world footprint


@dataclasses.dataclass
class Region:
    """Region bundle the router sees: local cache + origin service."""

    rid: int
    cfg: RegionConfig
    cache: CortexCache
    remote: RemoteDataService
    gpu: GPU
    engine: Optional[Engine] = None
    freshness: Optional[object] = None  # FreshnessManager (DESIGN.md §11)


@dataclasses.dataclass
class FederationStats:
    peeks: int = 0            # miss broadcasts issued
    probes: int = 0           # per-peer probe messages
    peer_hits: int = 0        # broadcasts resolved by a sibling transfer
    peer_misses: int = 0      # broadcasts that fell back to origin
    transfers: int = 0
    transfer_bytes: int = 0
    transfer_cost: float = 0.0
    expired_leases: int = 0   # positive peeks whose lease died in flight
    origin_fetches: int = 0
    warm_leases: int = 0      # positive peeks served from a WARM tier
    # robustness (DESIGN.md §17)
    peek_timeouts: int = 0    # probes resolved by the deadline, not a response
    breaker_skips: int = 0    # probes suppressed by an open circuit
    breaker_opens: int = 0    # circuit transitions closed/half-open -> open
    breaker_closes: int = 0   # circuit transitions half-open -> closed


@dataclasses.dataclass
class _Lease:
    """Snapshot a positive peek response carries home (the source pins
    the entry for the transfer, so eviction races are not modelled).
    ``version``/``fetched_at`` ride along so staleness accounting (and
    the provenance-based invalidation rule, DESIGN.md §11) follows the
    copy: a transferred value is exactly as fresh as its source."""

    value: Any
    expires_at: float
    staticity: int
    size: int
    version: int = 0
    fetched_at: float = 0.0
    # the SOURCE entry's intent: an ANN-only peek can lease across
    # intents (confusable pairs), and the copy's version/invalidation
    # must track the intent the VALUE belongs to, not the local query's
    intent: Optional[int] = None


class Federation:
    """Router over a set of regions sharing one virtual clock.

    ``rtt`` is a scalar (uniform mesh) or an (n, n) matrix of inter-region
    round-trip times. Transfers take one response half-RTT plus
    ``size / bandwidth`` serialization, and cost ``transfer_cost`` —
    an order of magnitude under the origin call price (egress, not API).
    """

    def __init__(
        self,
        regions: list[Region],
        clock: VirtualClock,
        *,
        rtt: float | np.ndarray = 0.08,
        transfer_cost: float = 5e-4,
        bandwidth: float = 50e6,   # bytes/s on inter-region links
        peering: bool = True,
        peek_timeout: Optional[float] = None,  # NAK a silent peer after
                                               # this deadline (§17)
        faults=None,               # FaultSchedule (DESIGN.md §17)
        breaker_k: int = 3,        # consecutive timeouts that open a circuit
        breaker_cooldown: float = 5.0,  # open -> half-open probe interval
    ):
        self.regions = regions
        self.clock = clock
        n = len(regions)
        r = np.asarray(rtt, dtype=np.float64)
        if r.ndim == 0:
            r = np.full((n, n), float(r))
            np.fill_diagonal(r, 0.0)
        if r.shape != (n, n):
            raise ValueError(f"rtt matrix must be ({n}, {n})")
        self.rtt_matrix = r
        self.transfer_cost = transfer_cost
        self.bandwidth = bandwidth
        self.peering = peering
        self.peek_timeout = peek_timeout
        self.faults = faults
        self.breaker_k = breaker_k
        self.breaker_cooldown = breaker_cooldown
        self.stats = FederationStats()
        # live queue depth (§16 gauges): broadcasts currently undecided,
        # per requesting region — incremented at route(), decremented
        # exactly once per broadcast (first positive claim OR last NAK,
        # where a peek timeout counts as that peer's NAK — §17)
        self._inflight_peeks = [0] * n
        # per-directed-link circuit breakers, lazily created, keyed
        # (src_rid, dst_rid): each region learns its own view of which
        # peers are dark from its own peek timeouts (DESIGN.md §17)
        self._breaker: dict = {}

    def rtt(self, a: int, b: int) -> float:
        return float(self.rtt_matrix[a, b])

    def gauges(self) -> dict:
        """Pressure gauges for the telemetry sampler (DESIGN.md §16):
        total and per-region in-flight peek broadcasts. Pure reads."""
        out = {"inflight_peeks": sum(self._inflight_peeks)}
        for rid, n in enumerate(self._inflight_peeks):
            out[f"inflight_peeks_r{rid}"] = n
        return out

    # ------------------------------------------------------------ routing

    def route(self, engine: Engine, st, q: str, t0: float) -> None:
        """Resolve a local miss: broadcast peek -> nearest-holder transfer
        -> origin fallback. Every hop is a clock event, so sibling caches
        are observed at the exact virtual instant the probe arrives."""
        region = self.regions[engine.region_id]
        peers = [p for p in self.regions if p.rid != region.rid]
        if not self.peering or not peers:
            self._origin(engine, st, q, t0)
            return
        if self.peek_timeout is not None:
            # circuit breakers (§17) only operate when timeouts can trip
            # them; without a deadline this filter is the identity
            peers = [p for p in peers
                     if self._breaker_admits(engine, region.rid, p.rid)]
            if not peers:
                # every peer's circuit is open: skip the peek entirely
                self._origin(engine, st, q, t0)
                return
        self.stats.peeks += 1
        self._inflight_peeks[region.rid] += 1
        q_emb = engine.world.embed(q)
        # one shared decision cell per broadcast: first positive response
        # claims it; the last NAK triggers the origin fallback. "resolved"
        # holds peers that already answered OR timed out, so a late
        # response after its timeout NAK cannot double-resolve (§17)
        state = {"decided": False, "pending": len(peers),
                 "src": region.rid, "resolved": set()}
        for peer in peers:
            rtt = self.rtt(region.rid, peer.rid)
            if self.faults is not None:
                rtt *= self.faults.link_mult(region.rid, peer.rid, t0)
            self.stats.probes += 1
            self.clock.push(
                t0 + rtt / 2.0, self._probe,
                engine, st, q, q_emb, t0, peer, rtt, state,
            )
            if self.peek_timeout is not None:
                self.clock.push(
                    t0 + self.peek_timeout, self._peek_timeout,
                    engine, st, q, t0, peer, state,
                )

    # ------------------------------------------------- circuit breaker

    def _br(self, src: int, dst: int) -> dict:
        key = (src, dst)
        br = self._breaker.get(key)
        if br is None:
            br = {"state": "closed", "consec": 0, "opened_at": 0.0}
            self._breaker[key] = br
        return br

    def _breaker_admits(self, engine, src: int, dst: int) -> bool:
        """May src probe dst right now? Open circuits are skipped until
        the cooldown elapses; then ONE half-open probe rides the next
        broadcast and its outcome closes or re-opens the circuit."""
        br = self._breaker.get((src, dst))
        if br is None or br["state"] == "closed":
            return True
        if br["state"] == "open":
            if self.clock.now - br["opened_at"] >= self.breaker_cooldown:
                br["state"] = "half_open"
                engine.trace.marker(BACKGROUND, "circuit_half_open",
                                    self.clock.now, src, f"r{src}->r{dst}")
                return True
            self.stats.breaker_skips += 1
            return False
        # half_open: one probe is already in flight — don't pile on
        self.stats.breaker_skips += 1
        return False

    def _peek_timeout(self, engine, st, q, t0, peer, state) -> None:
        """The deadline fired before ``peer`` answered: treat it as that
        peer's NAK, exactly once (a response that already arrived makes
        this a no-op; a response arriving later finds itself resolved)."""
        if state["decided"] or peer.rid in state["resolved"]:
            return
        state["resolved"].add(peer.rid)
        self.stats.peek_timeouts += 1
        br = self._br(state["src"], peer.rid)
        br["consec"] += 1
        if (br["state"] == "half_open"
                or (br["state"] == "closed"
                    and br["consec"] >= self.breaker_k)):
            br["state"] = "open"
            br["opened_at"] = self.clock.now
            self.stats.breaker_opens += 1
            engine.trace.marker(
                BACKGROUND, "circuit_open", self.clock.now,
                state["src"], f"r{state['src']}->r{peer.rid}",
            )
        state["pending"] -= 1
        if state["pending"] == 0:
            # the broadcast ends on the last timeout, same contract as
            # the last NAK: decrement in-flight exactly once, fall back
            self._inflight_peeks[state["src"]] -= 1
            if engine.trace.enabled:
                engine.trace.span(st.rec.rid, "peek_rtt", t0,
                                  self.clock.now, engine.region_id,
                                  "timeout")
            self.stats.peer_misses += 1
            self._origin(engine, st, q, t0)

    def _probe(self, engine, st, q, q_emb, t0, peer, rtt, state) -> None:
        """Probe arrives at the sibling: stage-1 peek against its cache
        as of NOW, validated through the peer's judge pipeline
        (``peek_lease``, DESIGN.md §14): with no admission band armed
        the peek stays ANN-only — the legacy protocol exactly — while an
        armed band judges in-band candidates at the holder before they
        ship (peer-side judge time folds into the probe's half-RTT)."""
        if (self.faults is not None
                and self.faults.region_down(peer.rid, self.clock.now)):
            # the peer is dark (§17): the probe lands on a region that
            # answers nothing — no response event is ever pushed, and
            # only an armed peek_timeout resolves this probe
            return
        lease = None
        if not state["decided"]:  # decided = probe logically cancelled
            # a tiered peer consults BOTH tiers: warm entries are
            # leasable too (the lease carries the decompressed value and
            # the ORIGINAL size — the transfer ships a full value)
            se = peer.cache.peek_lease(q, q_emb, self.clock.now)
            if se is not None:
                if getattr(se, "tier", "hot") == "warm":
                    self.stats.warm_leases += 1
                lease = _Lease(
                    value=se.value,
                    expires_at=float(se.expires_at),
                    staticity=int(se.staticity),
                    size=int(se.size),
                    version=int(se.version),
                    fetched_at=float(se.fetched_at),
                    intent=se.intent,
                )
        self.clock.push(
            t0 + rtt, self._response,
            engine, st, q, t0, peer, rtt, lease, state,
        )

    def _response(self, engine, st, q, t0, peer, rtt, lease, state) -> None:
        if state["decided"] or peer.rid in state["resolved"]:
            # broadcast already claimed, or this peer's timeout already
            # NAKed it — a late response must not double-resolve (§17)
            return
        state["resolved"].add(peer.rid)
        br = self._breaker.get((state["src"], peer.rid))
        if br is not None:
            if br["state"] == "half_open":
                br["state"] = "closed"
                self.stats.breaker_closes += 1
                engine.trace.marker(
                    BACKGROUND, "circuit_close", self.clock.now,
                    state["src"], f"r{state['src']}->r{peer.rid}",
                )
            br["consec"] = 0
        now = self.clock.now
        state["pending"] -= 1
        if lease is not None:
            t_arrive = now + rtt / 2.0 + lease.size / self.bandwidth
            if lease.expires_at > t_arrive:
                state["decided"] = True
                self._inflight_peeks[state["src"]] -= 1
                # §15 spans: broadcast -> winning response, then the
                # response half-RTT + serialization until the value
                # lands (t_arrive is the exact remote_done instant)
                if engine.trace.enabled:
                    engine.trace.span(st.rec.rid, "peek_rtt", t0, now,
                                      engine.region_id)
                    engine.trace.span(st.rec.rid, "lease_transfer", now,
                                      t_arrive, engine.region_id)
                self.stats.peer_hits += 1
                self.stats.transfers += 1
                self.stats.transfer_bytes += lease.size
                self.stats.transfer_cost += self.transfer_cost
                ttl = lease.expires_at - t_arrive
                self.clock.push(
                    t_arrive,
                    lambda now2: engine.remote_done(
                        st, q, t0, now2,
                        value=lease.value, cost=self.transfer_cost,
                        ttl=ttl, staticity=lease.staticity,
                        origin=peer.rid,
                        # admit the bytes actually moved: an ANN match
                        # across intents can have a different payload
                        # size than the local query's own value
                        size=lease.size,
                        version=lease.version,
                        fetched_at=lease.fetched_at,
                        src_intent=lease.intent,
                    ),
                )
                return
            self.stats.expired_leases += 1
        if state["pending"] == 0:
            # every sibling NAKed (or leased too close to expiry): the
            # peek ends with the LAST response; origin fetch starts here
            self._inflight_peeks[state["src"]] -= 1
            if engine.trace.enabled:
                engine.trace.span(st.rec.rid, "peek_rtt", t0, now,
                                  engine.region_id, "miss")
            self.stats.peer_misses += 1
            self._origin(engine, st, q, t0)

    def _origin(self, engine, st, q, t0) -> None:
        """Fall back to the region's own origin WAN fetch (its own rate
        limiter, its own latency band)."""
        self.stats.origin_fetches += 1
        out = engine.remote.fetch(
            self.clock.now,
            latency_mult=engine.world.latency_mult(q),
            cost_mult=engine.world.cost_mult(q),
        )
        if out.failed:
            # origin brownout exhausted the retry budget (§17): hand the
            # request to the engine's degraded-answer path
            engine.fetch_failed(st, q, t0, out, t_start=self.clock.now)
            return
        # starts at NOW (== t0 on the no-peering path, the last NAK's
        # arrival after a failed peek), ends when the fetch lands
        if engine.trace.enabled:
            engine.trace.span(st.rec.rid, "origin_fetch", self.clock.now,
                              out.finish, engine.region_id)
        self.clock.push(
            out.finish,
            lambda now2: engine.remote_done(st, q, t0, now2, value=None,
                                            cost=out.cost),
        )


class FederationRunner:
    """Build + run one multi-region experiment on a shared virtual clock.

    ``topology``:
      * ``"local"``  — per-region caches, no peering (each region alone);
      * ``"peered"`` — per-region caches + the Federation router;
      * ``"global"`` — ONE shared cache homed in region 0, remote regions
        pay ``rtt(r, 0)`` on every stage-1 access. Total cache bytes
        match the other topologies (n × per-region slice), so the sweep
        isolates *placement*, not capacity.

    Every stochastic component is seeded per region, so two runs with the
    same arguments produce identical summaries — and because all regions
    share one clock (seq-tie-broken heap), the interleaving itself is
    deterministic regardless of region count.
    """

    def __init__(
        self,
        *,
        world: SemanticWorld,
        region_requests: list[list[Request]],
        topology: str = "peered",
        region_cfgs: Optional[list[RegionConfig]] = None,
        rtt: float | np.ndarray = 0.08,
        transfer_cost: float = 5e-4,
        bandwidth: float = 50e6,
        judge_acc: float = 0.98,
        judge_band: Optional[float] = None,  # admission-band width; also
                                             # arms judge-validated
                                             # peer leases (§14)
        engine_cfg: Optional[EngineConfig] = None,
        gpu_cfg: Optional[GPUConfig] = None,
        warm_frac: Optional[float] = None,
        cluster=None,  # ClusterConfig -> IVF stage-1 routing (§12)
        freshness=None,  # FreshnessConfig -> per-region managers (§11)
        tracer=None,  # one obs.Tracer shared by every region (§15)
        sample_interval: Optional[float] = None,  # §16 telemetry: sample
                                                  # the fleet every this
                                                  # many virtual seconds
        slos=None,  # SLO objects / spec strings for the §16 monitor
                    # (requires sample_interval)
        faults=None,  # FaultSchedule or spec strings (DESIGN.md §17)
        peek_timeout: Optional[float] = None,  # §17 peek deadline
        breaker_k: int = 3,
        breaker_cooldown: float = 5.0,
        overload: Optional[str] = None,  # None | "on" | "off" — arm a §17
                                         # OverloadController per region
        overload_cfg=None,  # OverloadConfig template (overrides on/off)
        seed: int = 0,
    ):
        if topology not in ("local", "peered", "global"):
            raise ValueError(topology)
        n = len(region_requests)
        if region_cfgs is None:
            region_cfgs = [RegionConfig(name=f"r{i}") for i in range(n)]
        if len(region_cfgs) != n:
            raise ValueError("one RegionConfig per request stream")
        self.world = world
        self.topology = topology
        self.clock = VirtualClock()
        footprint = int(world._sizes.sum())
        base_cfg = engine_cfg or EngineConfig()
        if faults is not None and not hasattr(faults, "region_down"):
            from repro.serving.faults import FaultSchedule

            faults = FaultSchedule.parse(faults)
        self.faults = faults

        # §16 monitor first (engines' §17 controllers read its breach
        # state); the sampler that FEEDS it is created after the engines
        self.monitor = None
        self.sampler = None
        if slos and sample_interval is None:
            raise ValueError("slos require sample_interval")
        if sample_interval is not None and slos:
            from repro.obs.slo import SLOMonitor

            self.monitor = SLOMonitor(slos, tracer=tracer)

        # per-region router seeds: each region's cache clusters its OWN
        # rows (peek_semantic then routes peer probes through the same
        # sublinear scan, so federation peeks stay cheap at scale)
        self._next_region = 0

        def region_cluster():
            if cluster is None:
                return None
            ccfg = dataclasses.replace(
                cluster, seed=cluster.seed + 10 * self._next_region
            )
            self._next_region += 1
            return ccfg

        def wrap_judge(judge):
            # one JudgePipeline per cache (DESIGN.md §14): an armed band
            # gives every region adaptive admission locally AND
            # judge-validated in-band leases on the peek path
            if judge_band is None:
                return judge
            from repro.core.judge_pipeline import (AdmissionBand,
                                                   JudgePipeline)

            return JudgePipeline(judge,
                                 band=AdmissionBand(width=judge_band))

        def build_cache(capacity: int, judge) -> CortexCache:
            # warm_frac splits each region's byte budget into a tiered
            # hot+warm pair at EQUAL total bytes (DESIGN.md §10) — peers
            # can then lease each other's warm entries via peek_semantic
            if warm_frac:
                from repro.core.tiers import make_tiered_cache

                warm_bytes = int(capacity * warm_frac)
                return make_tiered_cache(
                    hot_bytes=capacity - warm_bytes, warm_bytes=warm_bytes,
                    dim=world.dim, judge=judge, cluster=region_cluster(),
                )
            return make_cache(
                capacity_bytes=capacity, dim=world.dim, judge=judge,
                cluster=region_cluster(),
            )

        # one origin change feed shared by every region; each region
        # subscribes with ITS one-way WAN delay (half the mean fetch
        # RTT), so the eventual-consistency window is per-region —
        # exactly the asymmetry the provenance rule exists for
        self.feed = None
        if freshness is not None:
            from repro.core.freshness import ChangeFeed

            self.feed = ChangeFeed(world, self.clock)

        self.regions: list[Region] = []
        shared_cache = None
        shared_mgr = None
        if topology == "global":
            judge = wrap_judge(
                OracleJudge(world, accuracy=judge_acc, seed=seed + 7)
            )
            shared_cache = build_cache(
                sum(int(rc.cache_ratio * footprint) for rc in region_cfgs),
                judge,
            )
        for rid, rc in enumerate(region_cfgs):
            if shared_cache is not None:
                cache = shared_cache
            else:
                judge = wrap_judge(OracleJudge(
                    world, accuracy=judge_acc, seed=seed + 101 * (rid + 1)
                ))
                cache = build_cache(
                    int(rc.cache_ratio * footprint), judge,
                )
            remote = RemoteDataService(
                lat_lo=rc.wan_lat_lo, lat_hi=rc.wan_lat_hi,
                cost_per_call=rc.wan_cost, qpm=rc.qpm,
                seed=seed + 13 * (rid + 1),
                faults=faults, region=rid,
            )
            gpu = GPU(gpu_cfg or GPUConfig())
            mgr = None
            if freshness is not None:
                if shared_cache is not None and shared_mgr is not None:
                    mgr = shared_mgr  # one manager for the one cache
                else:
                    from repro.core.freshness import FreshnessManager

                    mgr = FreshnessManager(
                        cache=cache, remote=remote, world=world,
                        clock=self.clock,
                        cfg=dataclasses.replace(
                            freshness,
                            feed_delay=0.25 * (rc.wan_lat_lo + rc.wan_lat_hi),
                        ),
                        feed=self.feed,
                    )
                    if shared_cache is not None:
                        shared_mgr = mgr
            self.regions.append(
                Region(rid, rc, cache, remote, gpu, freshness=mgr)
            )

        self.federation = Federation(
            self.regions, self.clock, rtt=rtt,
            transfer_cost=transfer_cost, bandwidth=bandwidth,
            peering=(topology == "peered"),
            peek_timeout=peek_timeout, faults=faults,
            breaker_k=breaker_k, breaker_cooldown=breaker_cooldown,
        )
        self.overload = overload
        for region, reqs in zip(self.regions, region_requests):
            cfg = dataclasses.replace(
                base_cfg,
                seed=seed + 29 * (region.rid + 1),
                cache_access_latency=(
                    self.federation.rtt(region.rid, 0)
                    if topology == "global" else 0.0
                ),
            )
            ctrl = None
            if overload is not None:
                from repro.serving.overload import (OverloadConfig,
                                                    OverloadController)

                cfg_o = (dataclasses.replace(overload_cfg)
                         if overload_cfg is not None
                         else OverloadConfig())
                cfg_o.enabled = (overload == "on")
                ctrl = OverloadController(
                    cfg_o, monitor=self.monitor, tracer=tracer,
                    region=region.rid,
                )
                if region.freshness is not None:
                    region.freshness.overload = ctrl
            region.engine = Engine(
                world=world,
                requests=reqs,
                mode="cortex",
                cache=region.cache,
                remote=region.remote,
                gpu=region.gpu,
                cfg=cfg,
                clock=self.clock,
                router=(self.federation if topology == "peered" else None),
                region_id=region.rid,
                freshness=region.freshness,
                tracer=tracer,
                overload=ctrl,
                faults=faults,
            )

        # §16 continuous telemetry: ONE sampler over the whole fleet
        # (shared clock), with the federation's queue-depth gauges and
        # an optional SLO monitor (created above, before the engines,
        # so §17 controllers can hold it) riding the sample stream.
        # Strictly observational — summaries stay byte-identical (gated).
        if sample_interval is not None:
            from repro.obs.sampler import TimeSeriesSampler

            self.sampler = TimeSeriesSampler(
                self.clock, sample_interval, self.engines,
                federation=self.federation, monitor=self.monitor,
            )

    @property
    def engines(self) -> list[Engine]:
        return [r.engine for r in self.regions]

    def records_by_region(self) -> dict[int, list]:
        """Completed records keyed by region id — the shape
        ``obs.analyze`` wants, since per-region workloads reuse rid
        ranges (the unique request key is ``(region, rid)``)."""
        return {r.rid: r.engine.records for r in self.regions}

    def run(self) -> dict:
        for e in self.engines:
            e.prepare()
        if self.sampler is not None:
            self.sampler.start()
        while self.clock.pending and not all(e.done for e in self.engines):
            self.clock.step()
        if self.sampler is not None:
            self.sampler.finalize()
        return self.summary()

    # ----------------------------------------------------------- metrics

    def _caches(self) -> list[CortexCache]:
        """Distinct cache objects (the global topology shares one)."""
        return list({id(r.cache): r.cache for r in self.regions}.values())

    def _managers(self) -> list:
        """Distinct freshness managers (global topology shares one)."""
        return list({
            id(r.freshness): r.freshness for r in self.regions
            if r.freshness is not None
        }.values())

    def summary(self) -> dict:
        per_region = {
            r.cfg.name: r.engine.summary() for r in self.regions
        }
        recs = [rec for e in self.engines for rec in e.records]
        lat = np.array([r.latency for r in recs])
        fs = self.federation.stats
        agg = {
            "topology": self.topology,
            "n": len(recs),
            "latency_mean": float(lat.mean()),
            "latency_p50": percentile(lat, 50),
            "latency_p99": percentile(lat, 99),
            "remote_time_mean": float(
                np.mean([r.remote_time for r in recs])
            ),
            "cache_time_mean": float(
                np.mean([r.cache_time for r in recs])
            ),
            "cache_hits": int(sum(r.cache_hits for r in recs)),
            "hit_rate": _ratio(
                sum(c.stats.hits for c in self._caches()),
                sum(c.stats.lookups for c in self._caches()),
            ),
            "peer_transfers": int(sum(r.peer_transfers for r in recs)),
            "api_calls": sum(r.remote.calls for r in self.regions),
            "api_cost": float(
                sum(r.remote.total_cost for r in self.regions)
                + fs.transfer_cost
            ),
            "retry_ratio": _ratio(
                sum(r.remote.retries for r in self.regions),
                sum(r.remote.attempts for r in self.regions),
            ),
            "info_accuracy": float(
                np.mean([r.info_correct for r in recs])
            ),
            "peeks": fs.peeks,
            "peer_hit_rate": _ratio(fs.peer_hits, fs.peeks),
            "transfer_bytes": fs.transfer_bytes,
            "expired_leases": fs.expired_leases,
            "warm_leases": fs.warm_leases,
            # freshness (DESIGN.md §11): fleet-wide staleness exposure
            "stale_hits": int(sum(e.stale_hits for e in self.engines)),
            "stale_rate": _ratio(
                sum(e.stale_hits for e in self.engines),
                sum(r.cache_hits + r.peer_transfers for r in recs),
            ),
            "invalidations": int(
                sum(c.stats.invalidations for c in self._caches())
            ),
            "refreshes": int(sum(
                m.stats.refreshes for m in self._managers()
            )),
        }
        # per-region tail attribution through records_by_region() (§16):
        # the fleet p99 above hides WHICH region is slow — this names it,
        # via the same shared percentile the engine summaries use
        agg["latency_p99_by_region"] = {
            self.regions[rid].cfg.name: percentile(
                [rec.latency for rec in rrecs], 99
            )
            for rid, rrecs in self.records_by_region().items() if rrecs
        }
        shards = max(
            (getattr(c, "stage1_shards", 1) for c in self._caches()),
            default=1,
        )
        if shards > 1:
            # mesh-sharded stage 1 (DESIGN.md §13) — keyed off when
            # unsharded so pre-§13 aggregate summaries stay identical
            agg["stage1_shards"] = shards
        if self.sampler is not None:
            # telemetry-enabled runs get extra keys ONLY (the §16
            # neutrality gate strips these before byte-comparison)
            agg["timeseries_samples"] = len(self.sampler.samples)
            if self.monitor is not None:
                agg["slo_breaches"] = self.monitor.breaches
                agg["slo_recoveries"] = self.monitor.recoveries
        fed = self.federation
        if fed.peek_timeout is not None or fed.faults is not None:
            # §17 robustness keys, gated so fault-free pre-§17 summaries
            # stay byte-identical; hung_peeks MUST be 0 after run()
            agg["peek_timeouts"] = fs.peek_timeouts
            agg["breaker_skips"] = fs.breaker_skips
            agg["breaker_opens"] = fs.breaker_opens
            agg["breaker_closes"] = fs.breaker_closes
            agg["hung_peeks"] = int(sum(fed._inflight_peeks))
            agg["fetch_failed"] = int(
                sum(r.remote.failed for r in self.regions))
        if self.overload is not None:
            from repro.serving.overload import OverloadStats

            tot = OverloadStats()
            for e in self.engines:
                for k, v in e.overload.metrics().items():
                    setattr(tot, k, getattr(tot, k) + v)
            agg["overload"] = dataclasses.asdict(tot)
        return {"aggregate": agg, "regions": per_region}


def _ratio(a, b) -> float:
    return a / b if b else 0.0
