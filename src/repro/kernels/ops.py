"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU the launchers pass interpret=False for the Mosaic lowering. The
pure-jnp oracles live in kernels.ref; tests sweep shapes/dtypes and
assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ann_topk import ann_topk
from repro.kernels.ann_topk_ivf import NEG, ann_topk_ivf, ann_topk_ivf_quant
from repro.kernels.ann_topk_quant import ann_topk_quant
from repro.kernels.ann_topk_sharded import (ann_topk_ivf_quant_sharded,
                                            ann_topk_ivf_sharded)
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd

__all__ = ["ann_topk", "ann_topk_quant", "ann_topk_ivf",
           "ann_topk_ivf_quant", "ann_topk_ivf_sharded",
           "ann_topk_ivf_quant_sharded", "flash_attention_fwd",
           "decode_attention", "ann_topk_jit", "ann_topk_quant_jit",
           "ann_topk_ivf_jit", "ann_topk_ivf_quant_jit",
           "ann_topk_ivf_sharded_jit", "ann_topk_ivf_quant_sharded_jit"]


_B_ALIGN = 8  # fp32 sublane count: pad the query block to aligned shapes


def ann_topk_jit(emb, active, q, k: int = 4):
    """VectorIndex backend adapter: (D,) or (B, D) queries -> (sims, rows).

    The batched cache runtime sends variable-size query blocks (engine
    micro-batches, DESIGN.md §8); padding B up to a multiple of the fp32
    sublane count keeps the kernel's (B, D) block shape TPU-aligned and
    bounds jit retraces to one per padded size. Each query column is
    reduced independently inside the kernel, so the zero-padded rows are
    sliced off without affecting real results."""
    single = q.ndim == 1
    if single:
        q = q[None]
    b = q.shape[0]
    pad = (-b) % _B_ALIGN
    if pad:
        q = jnp.pad(jnp.asarray(q), ((0, pad), (0, 0)))
    vals, rows = ann_topk(
        jnp.asarray(emb), jnp.asarray(active), jnp.asarray(q), k
    )
    vals, rows = vals[:b], rows[:b]
    if single:
        return vals[0], rows[0]
    return vals, rows


def _route(centroids, live, q, nprobe: int):
    """Centroid scoring + top-``nprobe`` cluster selection — the routing
    half of the fused IVF scan, in the same jit scope as the
    ``pallas_call`` (it cannot live inside it: the scan grid's
    scalar-prefetch index maps need ``sel`` before the first step)."""
    cs = jnp.where(jnp.asarray(live) > 0,
                   jnp.asarray(q) @ jnp.asarray(centroids).T, NEG)
    svals, sel = jax.lax.top_k(cs, nprobe)
    return sel.astype(jnp.int32), (svals > NEG / 2).astype(jnp.int32)


def _merge_probes(vals, slots, sel, bucket_rows, k: int):
    """(B, nprobe, k) per-probe finalists -> (B, kk) global top-k.
    Disabled probes carry NEG vals and row -1; callers filter on
    ``vals > NEG / 2``."""
    rows = jnp.where(vals > NEG / 2,
                     jnp.asarray(bucket_rows)[sel[:, :, None], slots], -1)
    b, nprobe, kk_in = vals.shape
    flat_v = vals.reshape(b, nprobe * kk_in)
    flat_r = rows.reshape(b, nprobe * kk_in)
    kk = min(k, nprobe * kk_in)
    top_v, pos = jax.lax.top_k(flat_v, kk)
    top_r = jnp.take_along_axis(flat_r, pos, axis=1)
    return top_v, top_r


def ann_topk_ivf_jit(centroids, live, buckets, bucket_rows, bucket_valid,
                     q, nprobe: int, k: int = 4):
    """Clustered VectorIndex backend adapter: route the (B, D) query
    block against the centroids, scan only the selected buckets
    (scalar-prefetch Pallas kernel), merge per-probe finalists. Returns
    ``(vals (B, kk), rows (B, kk), sel, enabled)`` — rows are global
    index rows (-1 where masked); sel/enabled feed the host's
    rows-scanned accounting."""
    b = q.shape[0]
    pad = (-b) % _B_ALIGN
    q = jnp.asarray(q)
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
    sel, enabled = _route(centroids, live, q, nprobe)
    vals, slots = ann_topk_ivf(sel, enabled, q, jnp.asarray(buckets),
                               jnp.asarray(bucket_valid), k)
    top_v, top_r = _merge_probes(vals, slots, sel, bucket_rows, k)
    return top_v[:b], top_r[:b], sel[:b], enabled[:b]


def ann_topk_ivf_quant_jit(centroids, live, buckets_q, bucket_scale,
                           bucket_rows, bucket_valid, q, qq, q_scales,
                           nprobe: int, k: int = 16):
    """Clustered QuantIndex backend adapter (coarse phase only): routing
    runs on the fp32 query against the fp32 centroids; the bucket scan
    is fully quantized (int8 × int8, int32 accumulate), mirroring the
    brute ``ann_topk_quant`` coarse/rescore split."""
    b = qq.shape[0]
    pad = (-b) % _B_ALIGN
    q, qq, q_scales = jnp.asarray(q), jnp.asarray(qq), jnp.asarray(q_scales)
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        qq = jnp.pad(qq, ((0, pad), (0, 0)))
        q_scales = jnp.pad(q_scales, (0, pad))
    sel, enabled = _route(centroids, live, q, nprobe)
    vals, slots = ann_topk_ivf_quant(
        sel, enabled, qq, q_scales, jnp.asarray(buckets_q),
        jnp.asarray(bucket_scale), jnp.asarray(bucket_valid), k,
    )
    top_v, top_r = _merge_probes(vals, slots, sel, bucket_rows, k)
    return top_v[:b], top_r[:b], sel[:b], enabled[:b]


def _merge_shards(vals, rows, k: int):
    """(S, B, nprobe, k) shard stacks -> (B, kk) finalists via ONE
    cross-shard ``lax.top_k`` — the §13 merge step. Rows already carry
    GLOBAL index ids (-1 where masked), so no translation here. Exact-
    score ties across shards resolve in shard-major flat order — the
    same class of kernel-backend tie caveat as ``_merge_probes``'s
    between-bucket order (the numpy sharded path does not share it)."""
    b = vals.shape[1]
    v = jnp.moveaxis(jnp.asarray(vals), 0, 1).reshape(b, -1)
    r = jnp.moveaxis(jnp.asarray(rows), 0, 1).reshape(b, -1)
    kk = min(k, v.shape[1])
    top_v, pos = jax.lax.top_k(v, kk)
    top_r = jnp.take_along_axis(r, pos, axis=1)
    return top_v, top_r


def ann_topk_ivf_sharded_jit(centroids, live, shard_buckets, shard_rows,
                             shard_valid, bounds, q, nprobe: int,
                             k: int = 4):
    """Sharded clustered VectorIndex backend adapter (DESIGN.md §13):
    routing stays GLOBAL (same ``_route`` as the unsharded wrapper, so
    the probed cluster set is shard-count invariant); the scan fans out
    per shard (``kernels/ann_topk_sharded``) and the S·nprobe·k
    finalists merge with one cross-shard ``lax.top_k``. Returns
    ``(vals, rows, sel, enabled)`` like ``ann_topk_ivf_jit``."""
    b = q.shape[0]
    pad = (-b) % _B_ALIGN
    q = jnp.asarray(q)
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
    sel, enabled = _route(centroids, live, q, nprobe)
    vals, rows = ann_topk_ivf_sharded(sel, enabled, q, shard_buckets,
                                      shard_valid, shard_rows, bounds, k)
    top_v, top_r = _merge_shards(vals, rows, k)
    return top_v[:b], top_r[:b], sel[:b], enabled[:b]


def ann_topk_ivf_quant_sharded_jit(centroids, live, shard_bq, shard_scale,
                                   shard_rows, shard_valid, bounds, q, qq,
                                   q_scales, nprobe: int, k: int = 16):
    """Sharded clustered QuantIndex backend adapter (coarse phase only):
    fp32 global routing, int8 per-shard scans, one cross-shard merge —
    mirrors ``ann_topk_ivf_quant_jit`` exactly as
    ``ann_topk_ivf_sharded_jit`` mirrors ``ann_topk_ivf_jit``."""
    b = qq.shape[0]
    pad = (-b) % _B_ALIGN
    q, qq, q_scales = jnp.asarray(q), jnp.asarray(qq), jnp.asarray(q_scales)
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        qq = jnp.pad(qq, ((0, pad), (0, 0)))
        q_scales = jnp.pad(q_scales, (0, pad))
    sel, enabled = _route(centroids, live, q, nprobe)
    vals, rows = ann_topk_ivf_quant_sharded(
        sel, enabled, qq, q_scales, shard_bq, shard_scale, shard_valid,
        shard_rows, bounds, k,
    )
    top_v, top_r = _merge_shards(vals, rows, k)
    return top_v[:b], top_r[:b], sel[:b], enabled[:b]


def ann_topk_quant_jit(emb_q, scales, active, qq, q_scales, k: int = 16):
    """Warm-tier QuantIndex backend adapter (coarse phase only).

    Queries arrive already int8-quantized — the host quantizes them with
    the same routine the numpy path uses, so both backends score identical
    integers. B is padded to the sublane multiple like ``ann_topk_jit``;
    padded query lanes carry scale 0 (all-zero scores) and are sliced off.
    """
    b = qq.shape[0]
    pad = (-b) % _B_ALIGN
    if pad:
        qq = jnp.pad(jnp.asarray(qq), ((0, pad), (0, 0)))
        q_scales = jnp.pad(jnp.asarray(q_scales), (0, pad))
    vals, rows = ann_topk_quant(
        jnp.asarray(emb_q), jnp.asarray(scales), jnp.asarray(active),
        jnp.asarray(qq), jnp.asarray(q_scales), k,
    )
    return vals[:b], rows[:b]
