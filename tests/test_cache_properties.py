"""Hypothesis property tests on the Cortex cache invariants."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cache import make_cache
from repro.core.judge import OracleJudge
from repro.core.semantic_element import ttl_from_staticity
from repro.data.world import SemanticWorld

WORLD = SemanticWorld(n_intents=120, dim=48, seed=7)


def fresh_cache(capacity=20_000, eviction="lcfu", tau_lsm=0.9, acc=1.0,
                max_ttl=600.0):
    judge = OracleJudge(WORLD, accuracy=acc, seed=1)
    return make_cache(
        capacity_bytes=capacity, dim=WORLD.dim, judge=judge,
        eviction=eviction, max_ttl=max_ttl, index_capacity=256,
    )


ops = st.lists(
    st.tuples(
        st.integers(0, 119),       # intent
        st.integers(0, 30),        # paraphrase
        st.floats(0.0, 500.0),     # time offset
    ),
    min_size=1, max_size=60,
)


@given(ops)
@settings(max_examples=40, deadline=None)
def test_capacity_never_exceeded(seq):
    cache = fresh_cache()
    now = 0.0
    for intent, para, dt in seq:
        now += dt
        q = WORLD.query(intent, para)
        emb = WORLD.embed(q)
        res = cache.lookup(q, emb, now)
        if not res.hit:
            cache.insert(q, emb, WORLD.fetch(q), now=now, cost=0.005,
                         latency=0.4, size=WORLD.value_size(q))
        # invariants
        assert cache.usage <= cache.capacity_bytes
        assert cache.usage == sum(se.size for se in cache.store.values())
        assert len(cache.store) == len(cache.rows)
        assert len(cache.seri.index) == len(cache.store)


@given(ops)
@settings(max_examples=25, deadline=None)
def test_no_expired_item_ever_hits(seq):
    cache = fresh_cache(max_ttl=120.0)
    now = 0.0
    for intent, para, dt in seq:
        now += dt
        q = WORLD.query(intent, para)
        emb = WORLD.embed(q)
        res = cache.lookup(q, emb, now)
        if res.hit:
            assert not res.se.expired(now)
        else:
            cache.insert(q, emb, WORLD.fetch(q), now=now, cost=0.005,
                         latency=0.4, size=WORLD.value_size(q))


@given(ops)
@settings(max_examples=25, deadline=None)
def test_semantic_hits_are_correct_with_perfect_judge(seq):
    """With a perfect judge every hit serves the right intent's answer."""
    cache = fresh_cache(acc=1.0)
    now = 0.0
    for intent, para, dt in seq:
        now += dt
        q = WORLD.query(intent, para)
        emb = WORLD.embed(q)
        res = cache.lookup(q, emb, now)
        if res.hit:
            assert res.se.value == WORLD.answer(q)
        else:
            cache.insert(q, emb, WORLD.fetch(q), now=now, cost=0.005,
                         latency=0.4, size=WORLD.value_size(q))


def test_lcfu_evicts_lowest_score():
    cache = fresh_cache(capacity=5_000)
    now = 0.0
    inserted = []
    for i in range(30):
        q = WORLD.query(i, 0)
        emb = WORLD.embed(q)
        se = cache.insert(q, emb, WORLD.fetch(q), now=now, cost=0.005,
                          latency=0.4, size=WORLD.value_size(q))
        inserted.append(se)
        now += 1.0
        # every survivor must score >= every evicted item at eviction time
    surviving = set(cache.store)
    scores = {se.se_id: se.lcfu_score(now) for se in inserted}
    if surviving and len(surviving) < len(inserted):
        max_evicted = max(
            s for i, s in scores.items() if i not in surviving
        )
        # allow ties; freq growth can reorder later, so compare loosely:
        # at least one survivor must outscore the best evicted item
        assert any(
            scores[i] >= max_evicted for i in surviving
        )


def test_ttl_from_staticity_monotone():
    ttls = [ttl_from_staticity(s, 3600.0) for s in range(1, 11)]
    assert all(a <= b for a, b in zip(ttls, ttls[1:]))
    assert ttls[0] == 30.0
    assert abs(ttls[-1] - 3600.0) < 1e-6


def test_eviction_policies_differ():
    """LCFU keeps high-cost items that LRU would drop."""
    from repro.core.seri import Seri, VectorIndex
    from repro.core.cache import CortexCache

    for ev in ("lcfu", "lru", "lfu"):
        cache = fresh_cache(capacity=1_500, eviction=ev)
        now = 0.0
        for i in range(5):  # expensive, once-validated items
            q = WORLD.query(i, 0)
            cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=now,
                         cost=0.5, latency=2.0, size=100)
            # one confirmed semantic hit -> freq=1 (Algorithm 2: fresh
            # items score 0 regardless of cost — prefetch self-correction)
            q2 = WORLD.query(i, 1)
            assert cache.lookup(q2, WORLD.embed(q2), now).hit
            now += 1.0
        for i in range(5, 25):  # cheap one-shot items, each also hit once
            q = WORLD.query(i, 0)
            cache.insert(q, WORLD.embed(q), WORLD.fetch(q), now=now,
                         cost=1e-4, latency=0.05, size=100)
            q2 = WORLD.query(i, 1)
            cache.lookup(q2, WORLD.embed(q2), now)
            now += 1.0
        kept = {WORLD.intent_of(se.key) for se in cache.store.values()}
        if ev == "lcfu":
            # expensive early items survive under LCFU
            assert any(i < 5 for i in kept)
        if ev == "lru":
            # pure recency: the early expensive items are gone
            assert not any(i < 5 for i in kept)
