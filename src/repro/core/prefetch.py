"""Predictive prefetching — first-order Markov over confirmed hits (§4.3).

The model learns P(q_{i+1} | q_i) from the stream of *validated* queries
(intent-level transitions, so paraphrases of one topic share a state).
When the top transition probability exceeds the confidence threshold and
the predicted item is absent, the engine issues an async fetch; the new SE
enters with freq = 0, making unused speculation the first eviction victim
(self-correcting pollution control).
"""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict
from typing import Hashable, Optional


@dataclasses.dataclass
class Prediction:
    state: Hashable
    prob: float
    support: int


class MarkovPrefetcher:
    def __init__(self, *, confidence: float = 0.5, min_support: int = 3,
                 max_states: int = 100_000):
        self.confidence = confidence
        self.min_support = min_support
        self.max_states = max_states
        self.trans: dict[Hashable, Counter] = defaultdict(Counter)
        self.totals: Counter = Counter()
        self._prev: Optional[Hashable] = None

    def observe(self, state: Hashable) -> None:
        """Feed one validated (hit-or-fetched) query state."""
        if self._prev is not None and self._prev != state:
            if len(self.trans) < self.max_states or self._prev in self.trans:
                self.trans[self._prev][state] += 1
                self.totals[self._prev] += 1
        self._prev = state

    def reset_session(self) -> None:
        self._prev = None

    def predict(self, state: Hashable) -> Optional[Prediction]:
        total = self.totals.get(state, 0)
        if total < self.min_support:
            return None
        nxt, cnt = self.trans[state].most_common(1)[0]
        p = cnt / total
        if p >= self.confidence:
            return Prediction(nxt, p, cnt)
        return None
