"""Mamba (selective SSM) sequence mixer — Jamba's Mamba-1 style block.

Train/prefill uses a *time-chunked* scan: sequential ``lax.scan`` over chunks
of ``cfg.chunk`` steps, associative scan inside a chunk. The full hidden
state h (B, S, d_inner, d_state) is never materialised — only one chunk's
worth — which is what makes seq=4k..500k feasible (this mirrors the
hardware-aware recomputation insight of the Mamba CUDA kernel, re-expressed
for XLA/TPU).

Decode keeps {conv_state (B, d_conv-1, d_inner), ssm_state (B, d_inner, N)}
and performs an O(1)-in-sequence recurrent update — this is why Jamba/xLSTM
are the long_500k-eligible architectures.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.config import MambaConfig
from repro.nn.param import ParamSpec
from repro.nn.sharding import ShardCtx


def _dims(cfg: MambaConfig, d_model: int):
    d_inner = cfg.expand * d_model
    dt_rank = cfg.dt_rank or math.ceil(d_model / 16)
    return d_inner, dt_rank


def mamba_specs(cfg: MambaConfig, d_model: int, dtype) -> dict:
    d_inner, dt_rank = _dims(cfg, d_model)
    n = cfg.d_state
    return {
        "w_in": ParamSpec((d_model, 2 * d_inner), dtype, ("fsdp", "model")),
        "conv_w": ParamSpec((cfg.d_conv, d_inner), jnp.float32, (None, "model")),
        "conv_b": ParamSpec((d_inner,), jnp.float32, ("model",), init="zeros"),
        "w_x": ParamSpec((d_inner, dt_rank + 2 * n), dtype, ("model", None)),
        "w_dt": ParamSpec((dt_rank, d_inner), jnp.float32, ("fsdp", "model")),
        "b_dt": ParamSpec((d_inner,), jnp.float32, ("model",), init="ones"),
        # A stored as log(-A) for stability; init ~ log(1..N) per channel
        "a_log": ParamSpec((d_inner, n), jnp.float32, ("model", None), init="ones"),
        "d_skip": ParamSpec((d_inner,), jnp.float32, ("model",), init="ones"),
        "w_out": ParamSpec((d_inner, d_model), dtype, ("model", "fsdp")),
    }


def _ssm_chunk(carry_h, xs):
    """Associative scan inside one chunk.

    carry_h: (B, d_inner, N); xs = (decay (B,Q,d,N), inp (B,Q,d,N))
    h_t = decay_t * h_{t-1} + inp_t
    """
    decay, inp = xs

    def combine(a, b):
        da, ia = a
        db, ib = b
        return da * db, ia * db + ib

    dec_c, inp_c = jax.lax.associative_scan(combine, (decay, inp), axis=1)
    h = dec_c * carry_h[:, None] + inp_c  # (B, Q, d, N)
    return h[:, -1], h


def mamba_apply(
    ctx: ShardCtx,
    p,
    cfg: MambaConfig,
    x,
    cache: Optional[dict] = None,
):
    """x: (B, S, D). Returns (y, new_cache)."""
    d_model = x.shape[-1]
    d_inner, dt_rank = _dims(cfg, d_model)
    n = cfg.d_state
    b, s, _ = x.shape

    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xz = ctx.constrain(xz, "dp", None, "model")
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, S, d_inner) each

    # -------- causal depthwise conv
    if cache is None:
        pad = jnp.zeros((b, cfg.d_conv - 1, d_inner), xi.dtype)
        xc_in = jnp.concatenate([pad, xi], axis=1)
        new_conv_state = xc_in[:, -(cfg.d_conv - 1):, :] if cfg.d_conv > 1 else None
    else:
        xc_in = jnp.concatenate([cache["conv"].astype(xi.dtype), xi], axis=1)
        new_conv_state = xc_in[:, -(cfg.d_conv - 1):, :]
    # conv as sum of shifted slices (d_conv is tiny, e.g. 4)
    xc = sum(
        xc_in[:, i : i + s, :] * p["conv_w"][i].astype(xi.dtype)
        for i in range(cfg.d_conv)
    ) + p["conv_b"].astype(xi.dtype)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(xi.dtype)

    # -------- input-dependent SSM parameters
    proj = jnp.einsum("bsd,dr->bsr", xc, p["w_x"])
    dt_in, b_in, c_in = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_in.astype(jnp.float32), p["w_dt"])
    dt = jax.nn.softplus(dt + p["b_dt"])  # (B,S,d_inner) f32
    a = -jnp.exp(p["a_log"])  # (d_inner, N)
    b_in = b_in.astype(jnp.float32)
    c_in = c_in.astype(jnp.float32)

    if cache is None and s > 1:
        # chunked parallel scan
        q = min(cfg.chunk, s)
        n_chunks = max(1, s // q)
        rem = s - n_chunks * q
        assert rem == 0, f"seq {s} must be divisible by chunk {q}"
        xcf = xc.astype(jnp.float32)

        def chunk_body(h, idx):
            sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * q, q, axis=1)
            dt_c, b_c, c_c, x_c = sl(dt), sl(b_in), sl(c_in), sl(xcf)
            decay = jnp.exp(dt_c[..., None] * a)  # (B,Q,d,N)
            inp = (dt_c * x_c)[..., None] * b_c[:, :, None, :]  # (B,Q,d,N)
            h_last, h_all = _ssm_chunk(h, (decay, inp))
            y_c = jnp.einsum("bqdn,bqn->bqd", h_all, c_c)
            return h_last, y_c

        h0 = jnp.zeros((b, d_inner, n), jnp.float32)
        h_last, ys = jax.lax.scan(chunk_body, h0, jnp.arange(n_chunks))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d_inner)
        new_ssm_state = h_last
    else:
        # single-step (decode) or s==1 prefill
        h_prev = (
            cache["ssm"] if cache is not None
            else jnp.zeros((b, d_inner, n), jnp.float32)
        )
        decay = jnp.exp(dt[:, 0, :, None] * a)  # (B,d,N)
        inp = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * b_in[:, 0, None, :]
        h = decay * h_prev + inp
        y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0])[:, None, :]
        new_ssm_state = h

    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = ctx.constrain(y, "dp", None, "model")
    out = jnp.einsum("bsd,de->bse", y, p["w_out"])
    new_cache = {"conv": new_conv_state, "ssm": new_ssm_state}
    return ctx.constrain(out, "dp", None, None), new_cache


def mamba_cache_specs(cfg: MambaConfig, d_model: int, batch: int) -> dict:
    d_inner, _ = _dims(cfg, d_model)
    return {
        "conv": ParamSpec(
            (batch, cfg.d_conv - 1, d_inner), jnp.bfloat16,
            ("dp", None, "model"), init="zeros",
        ),
        "ssm": ParamSpec(
            (batch, d_inner, cfg.d_state), jnp.float32,
            ("dp", "model", None), init="zeros",
        ),
    }
