"""Production mesh definition.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips, axes
("data", "model"). Multi-pod: (2, 16, 16) = 512 chips, axes
("pod", "data", "model") — the "pod" axis is the slow inter-pod (DCN/ICI
cross-link) dimension and defaults to pure data parallelism.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # jax < 0.5: explicit axis types don't exist yet
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


# TPU v5e-class hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,            # bytes/s per chip
    "ici_bw": 50e9,             # bytes/s per link (~per chip, one direction)
    "hbm_bytes": 16 * 1024**3,  # 16 GiB per chip
}
