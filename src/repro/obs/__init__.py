"""Observability subsystem (DESIGN.md §15/§16): request-lifecycle
tracing, unified metrics registry, continuous time-series telemetry,
SLO monitoring, trace export, and latency attribution.

Everything here rides the deterministic :class:`~repro.serving.clock.
VirtualClock`, so traces, timeseries, and alerts are bit-reproducible:
same seed, same bytes.
"""
from repro.obs.analyze import (attribution, check_conservation,
                               critical_path, flamegraph_folded,
                               format_attribution, format_critical_path)
from repro.obs.export import (export_timeseries, export_trace,
                              write_alerts, write_chrome_trace,
                              write_jsonl, write_timeseries)
from repro.obs.metrics import (STALE_AGE_EDGES, FixedHistogram,
                               MetricsRegistry, ScanMetrics, percentile)
from repro.obs.sampler import TimeSeriesSampler, limiter_headroom
from repro.obs.slo import SLO, SLOMonitor
from repro.obs.trace import BACKGROUND, NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "BACKGROUND",
    "MetricsRegistry", "FixedHistogram", "ScanMetrics", "percentile",
    "STALE_AGE_EDGES",
    "TimeSeriesSampler", "limiter_headroom", "SLO", "SLOMonitor",
    "export_trace", "write_jsonl", "write_chrome_trace",
    "export_timeseries", "write_timeseries", "write_alerts",
    "check_conservation", "attribution", "format_attribution",
    "critical_path", "flamegraph_folded", "format_critical_path",
]
