"""Real co-located serving: a reduced agent LM decodes batched requests
through the continuous-batching runtime while the (real, tiny) semantic
judge cross-encoder executes between decode ticks under the paper's
priority rule — the concrete JAX realization of Cortex §4.4 (no
simulation; actual jit-compiled models on this host).

Run:  PYTHONPATH=src python examples/colocated_serving.py
"""
import time

import numpy as np

from repro.configs import get_config, shrink
from repro.core.judge import ModelJudge
from repro.serving.generator import ContinuousBatcher, GenRequest

agent_cfg = shrink(get_config("search-r1-7b"), d_model=128, vocab=512,
                   n_repeat=2)
judge = ModelJudge()
pairs = ([f"query {i}" for i in range(4)], [f"cached {i}" for i in range(4)])
judge_scores = []


def judge_batch():
    judge_scores.append(judge.score_pairs(*pairs).mean())


cb = ContinuousBatcher(agent_cfg, slots=4, max_len=96, judge=judge_batch)
rng = np.random.default_rng(0)
reqs = [
    GenRequest(i, rng.integers(1, 512, size=int(rng.integers(4, 12))),
               max_new=8)
    for i in range(10)
]
for r in reqs:
    cb.submit(r)

t0 = time.perf_counter()
ticks = cb.run()
dt = time.perf_counter() - t0
done = sum(r.done for r in reqs)
print(f"served {done}/{len(reqs)} requests in {ticks} ticks "
      f"({cb.decode_steps} batched decode steps) in {dt:.2f}s")
print(f"judge batches interleaved (priority rule): {cb.judge_batches_run}")
print(f"sample generation (req 0): {reqs[0].out_tokens}")
assert done == len(reqs) and cb.judge_batches_run > 0
print("CO-LOCATED SERVING OK")
