"""GPipe pipeline-parallel runner test (needs >1 device: subprocess with
forced host device count, same pattern as the dry-run)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.nn.pipeline import pipeline_apply

try:
    from jax.sharding import AxisType
    mesh = jax.make_mesh((4, 2), ("pod", "data"),
                         axis_types=(AxisType.Auto,) * 2)
except ImportError:  # jax < 0.5: explicit axis types don't exist yet
    mesh = jax.make_mesh((4, 2), ("pod", "data"))
S, M, mb, d = 4, 6, 2, 8
params = jnp.arange(1.0, S + 1)[:, None] * jnp.ones((S, d))
x = jnp.asarray(np.random.default_rng(0).standard_normal((M, mb, d)),
                jnp.float32)

def stage(p, x):
    return x + p[None, :]

out = jax.jit(lambda p, x: pipeline_apply(mesh, "pod", stage, p, x))(params, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(x + 10.0), atol=1e-6)
g = jax.grad(lambda p: jnp.sum(pipeline_apply(mesh, "pod", stage, p, x)**2))(
    params
)
assert np.isfinite(np.asarray(g)).all()
print("PIPELINE_TEST_PASS")
"""


@pytest.mark.timeout(300)
def test_pipeline_parallel_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=280,
    )
    assert "PIPELINE_TEST_PASS" in out.stdout, out.stderr[-2000:]
