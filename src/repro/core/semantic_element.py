"""Semantic Element (SE) — Cortex's core caching unit (paper §4.1, Fig 5).

An SE encapsulates one discrete agent↔tool interaction: the agent's query
(semantic key), the retrieved knowledge (value), the embedding fingerprint,
and the performance-aware metadata driving eviction/TTL decisions:

  * staticity  1–10  — expected validity duration class (judge-estimated):
                       10 = stable fact, 5 = moderate, 1 = ephemeral.
  * cost ($), latency (s) — what the remote fetch cost; retained items
                       with high fetch cost are worth more per byte.
  * freq       — confirmed semantic-hit count (only validated hits count).
  * size       — bytes of the cached value.

Since the SoA refactor (DESIGN.md §8) the per-SE state lives in the
``SEStore`` parallel arrays; ``SemanticElement`` is a thin *live view*
onto one store row. Attribute reads/writes go straight to the arrays, so
``se.freq += 1`` is visible to the vectorized eviction path and vice
versa. The class keeps the old dataclass surface (same field names,
``expired``/``ttl_remaining``/``lcfu_score``)."""
from __future__ import annotations

from typing import Any, Optional

import numpy as np


def _field(name, cast):
    def get(self):
        return cast(getattr(self._store, name)[self._row])

    def set_(self, v):
        getattr(self._store, name)[self._row] = v

    return property(get, set_)


class SemanticElement:
    __slots__ = ("_store", "_row", "se_id")

    # storage tier this view lives in; the warm tier's view class
    # (core/tiers.py::WarmElement) reports "warm" so retrieval/hit paths
    # can route promotions without isinstance checks across modules
    tier = "hot"

    def __init__(self, store, row: int):
        self._store = store
        self._row = int(row)
        self.se_id = int(store.se_id[row])

    # numeric metadata: live views into the SoA arrays
    freq = _field("freq", int)
    size = _field("size", int)
    staticity = _field("staticity", int)
    cost = _field("cost", float)
    latency = _field("latency", float)
    created_at = _field("created_at", float)
    expires_at = _field("expires_at", float)
    # freshness (core/freshness.py): origin knowledge version this value
    # was fetched at + when; a refresh bumps both in place. revalidating
    # = known stale, refetch in flight, not servable meanwhile
    version = _field("version", int)
    fetched_at = _field("fetched_at", float)
    freq_at_fetch = _field("freq_at_fetch", int)
    revalidating = _field("revalidating", bool)
    last_access = _field("last_access", float)
    prefetched = _field("prefetched", bool)

    @property
    def key(self) -> str:
        return self._store.key[self._row]

    @property
    def value(self) -> Any:
        return self._store.value[self._row]

    @property
    def intent(self) -> Optional[int]:
        return self._store.intent[self._row]

    @property
    def origin(self) -> Optional[int]:
        """Provenance: region id this value was transferred from, or None
        if this cache's own region fetched it from the origin service."""
        return self._store.origin[self._row]

    @property
    def row(self) -> int:
        return self._row

    @property
    def valid(self) -> bool:
        """False once this row was evicted (or reused by another SE)."""
        return int(self._store.se_id[self._row]) == self.se_id

    # ------------------------------------------------------------ logic

    def expired(self, now: float) -> bool:
        return now >= self.expires_at

    def ttl_remaining(self, now: float) -> float:
        return self.expires_at - now

    def lcfu_score(self, now: float) -> float:
        """Algorithm 2 CalScore: log-composite value per byte (delegates
        to the store's vectorized kernel so view and batch paths agree
        bit-for-bit)."""
        return float(
            self._store.lcfu_scores(np.asarray([self._row]), now)[0]
        )

    def __repr__(self) -> str:
        return (f"SemanticElement(se_id={self.se_id}, key={self.key!r}, "
                f"freq={self.freq}, size={self.size})")


def ttl_from_staticity(staticity: int, max_ttl: float,
                       min_ttl: float = 30.0) -> float:
    """Map the 1–10 staticity class to a TTL. Exponential in the class so
    ephemeral items (1–3) expire in minutes while stable facts (9–10) live
    at the user-defined ceiling (paper §4.1/§4.3 aging mechanism)."""
    frac = (max(1, min(10, staticity)) - 1) / 9.0
    return min_ttl * (max_ttl / min_ttl) ** frac
