"""Deterministic fault injection for the virtual-time serving stack
(DESIGN.md §17).

A :class:`FaultSchedule` is a set of half-open virtual-time windows
``[start, end)``, each describing one failure mode at one blast radius:

* ``region_outage`` — the target region goes dark: its cache stops
  answering semantic peeks (the probe lands and nothing comes back;
  only a federation ``peek_timeout`` resolves the broadcast).
* ``wan_degrade`` — region links touching the target region (or all
  links when no region is given) have their RTT multiplied by ``mult``.
* ``origin_brownout`` — the remote data service's origin is degraded:
  each attempt fails with probability ``error_rate`` and is spuriously
  throttled with probability ``throttle``; retries stay bounded by
  ``max_retries`` and then the fetch terminates with
  ``FetchOutcome.failed`` instead of waiting forever.
* ``judge_slowdown`` — the judge device runs ``mult``× slower (the
  stage-2 micro-batch token cost is scaled up).

The schedule itself is pure: every method is a read-only query of
``(kind, region, t)``, so an *armed but empty* schedule is byte-identical
to no schedule at all. The only randomness faults introduce (brownout
error/throttle draws) lives in a dedicated rng owned by
``RemoteDataService`` that is never touched outside an active brownout
window — the main request/latency streams are unperturbed.

CLI spec grammar (``--faults``, repeatable)::

    kind:start:end[:key=val[,key=val...]]

    region_outage:60:120:region=1
    wan_degrade:30:90:region=1,mult=4
    origin_brownout:20:80:error_rate=0.6,throttle=0.2
    judge_slowdown:10:50:mult=3
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

KINDS = ("region_outage", "wan_degrade", "origin_brownout",
         "judge_slowdown")


@dataclasses.dataclass(frozen=True)
class FaultWindow:
    """One failure window, active over virtual time ``[start, end)``.
    ``region=None`` means every region (or every link) is affected."""
    kind: str
    start: float
    end: float
    region: Optional[int] = None
    mult: float = 1.0          # wan_degrade / judge_slowdown multiplier
    error_rate: float = 0.0    # origin_brownout: P(attempt errors)
    throttle: float = 0.0      # origin_brownout: P(attempt 429s)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if not self.end > self.start:
            raise ValueError(f"empty fault window [{self.start}, {self.end})")

    def active(self, t: float) -> bool:
        return self.start <= t < self.end

    def hits(self, region: Optional[int], t: float) -> bool:
        return self.active(t) and (self.region is None or region is None
                                   or self.region == region)


class FaultSchedule:
    """Pure query interface over a list of :class:`FaultWindow`."""

    def __init__(self, windows: Iterable[FaultWindow] = ()):
        self.windows = list(windows)
        for w in self.windows:
            if not isinstance(w, FaultWindow):
                raise TypeError(f"not a FaultWindow: {w!r}")
        # per-kind buckets so on-path queries touch only relevant windows
        self._by_kind = {k: [w for w in self.windows if w.kind == k]
                         for k in KINDS}

    def region_down(self, rid: int, t: float) -> bool:
        """Is region ``rid`` dark (not answering peeks) at ``t``?"""
        return any(w.hits(rid, t) for w in self._by_kind["region_outage"])

    def link_mult(self, a: int, b: int, t: float) -> float:
        """RTT multiplier for the link a<->b at ``t`` (product of active
        degradation windows touching either endpoint)."""
        m = 1.0
        for w in self._by_kind["wan_degrade"]:
            if w.active(t) and (w.region is None
                                or w.region in (a, b)):
                m *= w.mult
        return m

    def brownout(self, region: Optional[int], t: float) -> Optional[FaultWindow]:
        """The active origin-brownout window for ``region`` at ``t``
        (None when the origin is healthy)."""
        for w in self._by_kind["origin_brownout"]:
            if w.hits(region, t):
                return w
        return None

    def judge_mult(self, region: Optional[int], t: float) -> float:
        """Judge-device slowdown multiplier for ``region`` at ``t``."""
        m = 1.0
        for w in self._by_kind["judge_slowdown"]:
            if w.hits(region, t):
                m *= w.mult
        return m

    # -- CLI spec parsing ------------------------------------------------

    @classmethod
    def parse(cls, specs: Iterable[str]) -> "FaultSchedule":
        """Parse ``kind:start:end[:k=v,...]`` spec strings (see module
        docstring for the grammar)."""
        wins = []
        for spec in specs:
            parts = spec.strip().split(":")
            if len(parts) < 3:
                raise ValueError(
                    f"fault spec {spec!r}: want kind:start:end[:k=v,...]")
            kind, start, end = parts[0], float(parts[1]), float(parts[2])
            kw: dict = {}
            if len(parts) > 3:
                for item in ":".join(parts[3:]).split(","):
                    if not item:
                        continue
                    k, _, v = item.partition("=")
                    k = k.strip()
                    if k == "region":
                        kw[k] = int(v)
                    elif k in ("mult", "error_rate", "throttle"):
                        kw[k] = float(v)
                    else:
                        raise ValueError(
                            f"fault spec {spec!r}: unknown key {k!r}")
            wins.append(FaultWindow(kind, start, end, **kw))
        return cls(wins)

    def __len__(self) -> int:
        return len(self.windows)

    def __repr__(self) -> str:
        return f"FaultSchedule({self.windows!r})"
