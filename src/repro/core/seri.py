"""Seri — the Semantic Retrieval Index (paper §4.2).

Stage 1 (coarse): exact cosine top-k over the SE embedding matrix with the
τ_sim gate. On TPU this runs as the Pallas ``ann_topk`` kernel (brute-force
MXU matmul — the TPU-idiomatic replacement for Faiss graph traversal, see
DESIGN.md §3); on CPU the numpy path is bit-identical.

Stage 2 (fine): the semantic judge validates each candidate's *result*
against the new query; the first candidate with S_lsm ≥ τ_lsm is a
semantic-aware cache hit.

Both stages are batched (DESIGN.md §8): ``search_batch`` pushes a whole
(B, D) query block through one masked matmul (or one ``ann_topk`` launch,
which always had the B dimension), and ``retrieve_batch`` scores the
candidates of *all* queries in a single ``judge.score_pairs`` call. The
scalar entry points are one-query wrappers over the batched path, so
scalar and batched execution are the same code and produce identical
results.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.semantic_element import SemanticElement


class VectorIndex:
    """Fixed-capacity embedding store with free-list row management."""

    def __init__(self, capacity: int, dim: int, backend: str = "numpy"):
        self.capacity = capacity
        self.dim = dim
        self.backend = backend
        self.emb = np.zeros((capacity, dim), np.float32)
        self.active = np.zeros(capacity, bool)
        self.row_se: list[Optional[int]] = [None] * capacity
        self._free = list(range(capacity - 1, -1, -1))
        self._kernel_fn = None
        if backend == "kernel":
            from repro.kernels.ops import ann_topk_jit

            self._kernel_fn = ann_topk_jit

    def __len__(self) -> int:
        return int(self.active.sum())

    @property
    def full(self) -> bool:
        return not self._free

    def add(self, se_id: int, embedding: np.ndarray) -> int:
        if not self._free:
            raise RuntimeError("index full — evict first")
        row = self._free.pop()
        self.emb[row] = embedding
        self.active[row] = True
        self.row_se[row] = se_id
        return row

    def remove(self, row: int) -> None:
        if not self.active[row]:
            return
        self.active[row] = False
        self.row_se[row] = None
        self.emb[row] = 0.0
        self._free.append(row)

    def remove_rows(self, rows) -> None:
        """Batched removal: one fancy-indexed store per field."""
        rows = [r for r in rows if self.active[r]]
        if not rows:
            return
        ra = np.asarray(rows)
        self.active[ra] = False
        self.emb[ra] = 0.0
        for r in rows:
            self.row_se[r] = None
            self._free.append(r)

    # ----------------------------------------------------------- search

    def search(self, q: np.ndarray, k: int, tau_sim: float):
        """Top-k rows with cosine ≥ tau_sim. q: (dim,) unit-norm.
        Returns (se_ids, sims) sorted by similarity desc."""
        return self.search_batch(q[None], k, tau_sim)[0]

    def search_batch(self, q: np.ndarray, k: int, tau_sim: float):
        """Batched stage-1: q (B, dim) -> list of B (se_ids, sims) pairs.

        One masked matmul over the whole query block; per-column top-k via
        ``argpartition`` along axis 0. Each column's result is identical to
        the single-query path (numpy partitions/sorts each 1-D lane
        independently), so batching never changes retrieval semantics.
        """
        b = q.shape[0]
        if len(self) == 0:
            empty = ([], np.zeros(0, np.float32))
            return [empty] * b
        if self._kernel_fn is not None:
            sims, rows = self._kernel_fn(self.emb, self.active, q, k)
            sims = np.asarray(sims)
            rows = np.asarray(rows)
        else:
            # (B, N) row-major so the per-query partition/sort below runs
            # over contiguous lanes (axis=0 on (N, B) is strided and ~3×
            # slower at large N·B)
            neg = np.where(self.active[None, :], q @ self.emb.T, -1.0)
            np.negative(neg, out=neg)                     # sort ascending
            k_eff = min(k, neg.shape[1])
            part = np.argpartition(neg, k_eff - 1, axis=1)[:, :k_eff]
            psc = np.take_along_axis(neg, part, axis=1)
            order = np.argsort(psc, axis=1, kind="stable")
            rows = np.take_along_axis(part, order, axis=1)     # (B, k)
            sims = -np.take_along_axis(psc, order, axis=1)
        out = []
        for i in range(b):
            keep = sims[i] >= tau_sim
            r = rows[i][keep]
            out.append(([self.row_se[j] for j in r],
                        sims[i][keep].astype(np.float32)))
        return out


@dataclasses.dataclass
class SeriResult:
    hit: bool
    se: Optional[SemanticElement]
    n_candidates: int
    judge_calls: int
    best_score: float
    sims: np.ndarray


class Seri:
    """Two-stage retrieval over a SE store."""

    def __init__(self, index: VectorIndex, judge, *, tau_sim: float = 0.9,
                 tau_lsm: float = 0.9, top_k: int = 4):
        self.index = index
        self.judge = judge
        self.tau_sim = tau_sim
        self.tau_lsm = tau_lsm
        self.top_k = top_k

    def retrieve(self, query: str, q_emb: np.ndarray, store,
                 now: float) -> SeriResult:
        return self.retrieve_batch([query], q_emb[None], store, now)[0]

    def retrieve_batch(self, queries: Sequence[str], q_embs: np.ndarray,
                       store, now: float) -> list[SeriResult]:
        """Full two-stage retrieval for a query block.

        Candidates of every query are validated in ONE ``score_pairs``
        call (the judge-prefill amortization the engine's micro-batching
        exploits, paper §4.4). Pair order is (query order, candidate
        order), i.e. exactly the order sequential scalar calls would use —
        judges that consume rng state per pair draw identical scores.
        """
        found = self.index.search_batch(
            np.asarray(q_embs), self.top_k, self.tau_sim
        )
        per_q = []
        flat_q: list[str] = []
        flat_key: list[str] = []
        for query, (se_ids, sims) in zip(queries, found):
            # drop expired candidates (freshness is part of validity, §4.1)
            cands = [
                store[i] for i in se_ids
                if i in store and not store[i].expired(now)
            ]
            per_q.append((cands, sims))
            flat_q.extend([query] * len(cands))
            flat_key.extend(c.key for c in cands)
        flat_scores = (
            self.judge.score_pairs(flat_q, flat_key) if flat_q
            else np.zeros(0, np.float32)
        )
        results = []
        off = 0
        for cands, sims in per_q:
            m = len(cands)
            scores = flat_scores[off:off + m]
            off += m
            if not m:
                results.append(SeriResult(False, None, 0, 0, 0.0, sims))
                continue
            order = np.argsort(-scores)
            best = float(scores[order[0]])
            res = None
            for j in order:
                if scores[j] >= self.tau_lsm:
                    res = SeriResult(True, cands[j], m, m, best, sims)
                    break
            results.append(res or SeriResult(False, None, m, m, best, sims))
        return results
