"""Mesh-aware sharding resolution.

Parameters carry *logical* axis names ("model", "fsdp", "expert", ...).
This module resolves them against a concrete mesh with divisibility checks:
an axis is only applied when the dimension divides the mesh axis size,
otherwise the dim falls back to replication (best-effort sharding). This is
what lets one config system serve a (16,16) single-pod mesh, a (2,16,16)
multi-pod mesh, and the 1-device CPU test mesh without per-arch edits.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.nn.param import ParamSpec

# Logical axis -> mesh axis-name tuple. "dp" covers pod+data (pure DP);
# "fsdp" shards parameters/optimizer state over the data axis (ZeRO-3 style);
# "expert"/"model" are tensor/expert parallel over the model axis.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "dp": ("pod", "data"),
    "data": ("data",),
    "fsdp": ("data",),
    "model": ("model",),
    "expert": ("model",),
    "seq": ("pod", "data"),  # long-context KV/sequence sharding (batch=1)
    # decode KV caches: batch shards over dp, sequence over the model axis
    # (kv heads < TP width, so the seq dim is the shardable one; attention
    # over the sharded cache becomes a flash-decoding-style distributed
    # softmax, with the partial max/sum reductions inserted by GSPMD).
    "kv_seq": ("model",),
}


# FSDP-only plan (no tensor parallelism): batch shards over every mesh
# axis, parameters ZeRO-3-shard over (data, model). The right plan for
# ≤13B dense models at 4k context — Megatron-TP's per-layer activation
# all-reduces dominate their collective term (§Perf iteration 4).
FSDP_ONLY_RULES: dict[str, tuple[str, ...]] = {
    "dp": ("pod", "data", "model"),
    "data": ("data",),
    "fsdp": ("data", "model"),
    "model": (),
    "expert": (),
    "seq": ("pod", "data"),
    "kv_seq": (),
}


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    rules: dict[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )
    # ZeRO-3/FSDP: additionally shard params over the data axis when the
    # logical spec asks for "fsdp".
    enable_fsdp: bool = True

    @staticmethod
    def fsdp_only() -> "ShardingConfig":
        return ShardingConfig(rules=dict(FSDP_ONLY_RULES))

    @staticmethod
    def fsdp_hybrid() -> "ShardingConfig":
        """No-TP plan with batch over data only (leaves room for grad
        accumulation): params ZeRO-3 over all chips, batch 16-way + mu."""
        rules = dict(FSDP_ONLY_RULES)
        rules["dp"] = ("pod", "data")
        return ShardingConfig(rules=rules)

    def mesh_axes(self, logical: Any) -> tuple[str, ...]:
        if logical is None:
            return ()
        if isinstance(logical, (tuple, list)):
            out: list[str] = []
            for item in logical:
                out.extend(self.mesh_axes(item))
            return tuple(out)
        if logical == "fsdp" and not self.enable_fsdp:
            return ()
        return self.rules.get(logical, ())


def shard_map_compat(fn, *, mesh, in_specs, out_specs, axis_names):
    """`jax.shard_map` when available (jax ≥ 0.5), else the experimental
    shard_map with replication checking off — `axis_names` only exists in
    the new API and the old rep checker rejects these fully-manual
    kernels anyway."""
    new = getattr(jax, "shard_map", None)
    if new is not None:
        return new(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   axis_names=axis_names)
    from jax.experimental.shard_map import shard_map as old

    return old(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def axis_size(mesh: Mesh, names: tuple[str, ...]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape.get(n, 1)
    return size


def resolve_pspec(
    mesh: Mesh, spec_axes: tuple[Any, ...], shape: tuple[int, ...],
    cfg: ShardingConfig | None = None,
) -> P:
    """Resolve logical axes to a PartitionSpec, dropping non-divisible axes."""
    cfg = cfg or ShardingConfig()
    entries: list[Any] = []
    used: set[str] = set()
    if not spec_axes:
        return P()
    for dim, logical in zip(shape, spec_axes):
        names = [
            n for n in cfg.mesh_axes(logical)
            if n in mesh.shape and n not in used
        ]
        # keep the largest prefix of axis names whose product divides the dim
        kept: list[str] = []
        prod = 1
        for n in names:
            if dim % (prod * mesh.shape[n]) == 0:
                kept.append(n)
                prod *= mesh.shape[n]
        used.update(kept)
        if not kept:
            entries.append(None)
        elif len(kept) == 1:
            entries.append(kept[0])
        else:
            entries.append(tuple(kept))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_pspec(mesh: Mesh, spec: ParamSpec, cfg: ShardingConfig | None = None) -> P:
    return resolve_pspec(mesh, spec.axes, spec.shape, cfg)


def named(mesh: Mesh, *entries) -> NamedSharding:
    return NamedSharding(mesh, P(*entries))


class ShardCtx:
    """Carries the mesh + rules through model apply functions.

    ``constrain(x, *logical_axes)`` applies a with_sharding_constraint with
    the same best-effort divisibility resolution used for params. On a
    1-device test mesh every constraint resolves to replication, so the same
    model code runs in unit tests and in the 512-chip dry-run.
    """

    def __init__(self, mesh: Mesh | None, cfg: ShardingConfig | None = None):
        self.mesh = mesh
        self.cfg = cfg or ShardingConfig()

    def pspec(self, logical_axes: tuple[Any, ...], shape: tuple[int, ...]) -> P:
        if self.mesh is None:
            return P()
        return resolve_pspec(self.mesh, logical_axes, shape, self.cfg)

    def constrain(self, x: jax.Array, *logical_axes: Any) -> jax.Array:
        if self.mesh is None:
            return x
        ps = self.pspec(tuple(logical_axes), x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, ps)
        )

    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return axis_size(self.mesh, self.cfg.mesh_axes("dp"))

    def tp_size(self) -> int:
        if self.mesh is None:
            return 1
        return axis_size(self.mesh, self.cfg.mesh_axes("model"))


def make_test_mesh() -> Mesh:
    """1-device mesh with the production axis names (for tests)."""
    dev = jax.devices()[:1]
    import numpy as np

    return Mesh(np.array(dev).reshape(1, 1), ("data", "model"))
