"""xlstm-350m [ssm] — arXiv:2405.04517 (xLSTM[7:1]).

24L d_model=1024 4H vocab=50304, d_ff=0 (no separate FFN; the mLSTM block
carries a 2x up-projection internally). Superblock = 7 mLSTM + 1 sLSTM,
repeated 3x. O(1) recurrent state -> long_500k eligible.
"""
from repro.configs.common import register
from repro.nn.config import LayerSpec, ModelConfig, XLSTMConfig

NAME = "xlstm-350m"


@register(NAME)
def config() -> ModelConfig:
    ml = LayerSpec(
        kind="mlstm",
        xlstm=XLSTMConfig(kind="mlstm", n_heads=4, proj_factor=2.0, chunk=128),
    )
    sl = LayerSpec(
        kind="slstm",
        xlstm=XLSTMConfig(kind="slstm", n_heads=4),
    )
    return ModelConfig(
        name=NAME,
        family="ssm",
        d_model=1024,
        vocab_size=50304,
        blocks=(ml,) * 7 + (sl,),
        n_repeat=3,  # 3 x 8 = 24 layers
        tie_embeddings=True,
        sub_quadratic=True,
    )
