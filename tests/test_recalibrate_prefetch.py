"""Algorithm 1 (threshold recalibration) + Markov prefetcher properties."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.prefetch import MarkovPrefetcher
from repro.core.recalibrate import (
    EvalRecord, find_threshold, precision_curve, recalibrate,
)


def test_precision_curve_prefix_semantics(rng):
    scores = rng.random(200)
    labels = rng.random(200) > 0.3
    curve = precision_curve(scores, labels)
    # each entry's precision equals precision of the prefix at that threshold
    for thr, prec, rec in curve[:: 20]:
        keep = scores >= thr
        assert abs(prec - labels[keep].mean()) < 1e-9


@given(st.floats(0.5, 0.99))
@settings(max_examples=20, deadline=None)
def test_find_threshold_achieves_target(p_target):
    rng = np.random.default_rng(3)
    # separable-ish scores
    n = 400
    labels = rng.random(n) < 0.6
    scores = np.where(labels, 1 - rng.beta(1, 19, n), rng.beta(1, 19, n))
    curve = precision_curve(scores, labels)
    tau = find_threshold(curve, p_target)
    keep = scores >= tau
    if keep.any():
        assert labels[keep].mean() >= p_target - 1e-9


def test_recalibrate_end_to_end(world, rng):
    # log with mixed correct/incorrect cached pairs
    log = []
    for i in range(300):
        intent = int(rng.integers(0, 100))
        wrong = rng.random() < 0.3
        c_intent = intent + 1 if wrong else intent
        q = world.query(intent, int(rng.integers(0, 20)))
        c = world.query(c_intent % 100, 0)
        score = (
            float(rng.beta(1, 19)) if wrong else float(1 - rng.beta(1, 19))
        )
        log.append(EvalRecord(q, c, world.answer(c), score))
    res = recalibrate(
        log, world.fetch, world.equivalent, p_target=0.95, sample_size=128,
        rng=rng,
    )
    assert res.precision >= 0.9  # sampled precision near target
    assert 0.0 < res.tau <= 1.0


def test_markov_prefetcher_learns_transitions():
    pf = MarkovPrefetcher(confidence=0.6, min_support=3)
    for _ in range(5):
        for s in ("a", "b", "c"):
            pf.observe(s)
        pf.reset_session()
    pred = pf.predict("a")
    assert pred is not None and pred.state == "b" and pred.prob == 1.0
    pred = pf.predict("c")  # c only followed by session reset
    assert pred is None


def test_markov_interleaved_sessions_match_sequential():
    """Regression: a single global predecessor chain cross-contaminated
    transitions when concurrent request streams interleaved. Keyed by
    session id, any interleaving must learn the same table."""
    streams = {
        "s1": ["a", "b", "c", "a", "b"],
        "s2": ["x", "y", "x", "y", "x"],
        "s3": ["b", "a", "b", "a", "b"],
    }

    def learn(order):
        pf = MarkovPrefetcher(confidence=0.0, min_support=1)
        for key, state in order:
            pf.observe(state, key=key)
        return dict(pf.trans), dict(pf.totals)

    sequential = [
        (k, s) for k in sorted(streams) for s in streams[k]
    ]
    # round-robin interleaving of the three sessions
    interleaved = [
        (k, streams[k][i])
        for i in range(5) for k in sorted(streams)
    ]
    assert learn(sequential) == learn(interleaved)
    # and the contaminated global-chain result differs (the old bug):
    pf_global = MarkovPrefetcher(confidence=0.0, min_support=1)
    for _, s in interleaved:
        pf_global.observe(s)  # no key -> one shared chain
    assert (dict(pf_global.trans), dict(pf_global.totals)) \
        != learn(sequential)


@given(st.lists(st.integers(0, 4), min_size=2, max_size=200))
@settings(max_examples=30, deadline=None)
def test_markov_probabilities_valid(seq):
    pf = MarkovPrefetcher(confidence=0.0, min_support=1)
    for s in seq:
        pf.observe(s)
    for s in set(seq):
        pred = pf.predict(s)
        if pred is not None:
            assert 0.0 < pred.prob <= 1.0
            assert pred.support <= pf.totals[s]
