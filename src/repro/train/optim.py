"""Optimizers (pure JAX, no optax): AdamW with mixed-precision state
options, cosine/linear LR schedules, global-norm clipping.

Optimizer state dtype is configurable because it dominates memory at the
400-700B scale: fp32 m/v needs 8 bytes/param; bf16 m + bf16 v needs 4;
the ZeRO-style "fsdp" sharding of both params and optimizer state over the
data axis is inherited from the ParamSpec axes (state mirrors param
sharding), so state memory scales down with the full device count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # float32 | bfloat16
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | const
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        decay = 1.0
    else:
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * t)
            )
        else:
            decay = 1.0 - (1 - cfg.min_lr_frac) * t
    return cfg.lr * warm * decay


def init_state(cfg: AdamWConfig, params):
    sdt = jnp.dtype(cfg.state_dtype)
    zeros_like = lambda p: jnp.zeros(p.shape, sdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
    }


def state_specs(cfg: AdamWConfig, param_specs):
    """ParamSpec tree for the optimizer state (mirrors param sharding)."""
    import dataclasses as dc

    from repro.nn.param import ParamSpec, tree_map_specs

    sdt = jnp.dtype(cfg.state_dtype)
    mk = lambda s: dc.replace(s, dtype=sdt, init="zeros")
    return {
        "step": ParamSpec((), jnp.int32, (), init="zeros"),
        "m": tree_map_specs(mk, param_specs),
        "v": tree_map_specs(mk, param_specs),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.zeros((), jnp.float32)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
