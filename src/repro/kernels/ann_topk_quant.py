"""Pallas TPU kernel for the WARM tier's quantized stage-1 (DESIGN.md §10).

The warm tier stores int8 symmetric per-row quantized embeddings (4× the
rows per HBM byte of the hot tier's fp32 matrix), so its coarse scan is an
int8×int8 matmul with int32 accumulation — the MXU runs these at 2–4× the
fp32 rate, and the slab streamed per grid step is a quarter the bytes.

Two-phase retrieval: this kernel performs the COARSE phase only — it
returns the per-query top-R candidates by *approximately* rescaled int8
scores (R = rescore_k, a small multiple of the final k). The host then
rescores those R finalists exactly: fp32 query · dequantized row, which
removes the query-quantization error from the final ordering and the
τ_sim gate (``core/tiers.py::QuantIndex.search_batch``).

Structure mirrors ``ann_topk.py``: the quantized matrix (N, D) streams
HBM→VMEM in (TILE_N, D) int8 slabs; the quantized query block (B, D) stays
resident; each grid step computes a (TILE_N, B) int32 score tile, rescales
to fp32 with the per-row and per-query scales, masks inactive rows, and
reduces to per-tile top-R on the VPU. The (ntiles · R) finalists merge in
one lax.top_k outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512
NEG = -3.0e38  # plain float: jnp scalars would be captured consts in pallas


def _annq_kernel(qq_ref, qs_ref, emb_ref, scale_ref, mask_ref, vals_ref,
                 idx_ref, *, k: int):
    """One grid step: int8 scores for a (tile_n, D) slab; per-tile top-k."""
    emb = emb_ref[...]                       # (tile_n, D) int8
    qq = qq_ref[...]                         # (B, D) int8
    s = jax.lax.dot_general(
        emb, qq,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )                                        # (tile_n, B) exact int32
    # rescale: float(i32) * row_scale, then * query_scale — the numpy
    # reference path multiplies in the same order, so both sides agree
    # bit-for-bit on the coarse scores
    s = s.astype(jnp.float32) * scale_ref[...][:, None]
    s = s * qs_ref[...][None, :]
    mask = mask_ref[...] > 0
    s = jnp.where(mask[:, None], s, NEG)
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    for j in range(k):
        v = jnp.max(s, axis=0)               # (B,)
        i = jnp.argmax(s, axis=0)            # (B,) row within tile
        vals_ref[0, j, :] = v
        idx_ref[0, j, :] = i.astype(jnp.int32)
        s = jnp.where(rows == i[None, :], NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "interpret", "tile_n"))
def ann_topk_quant(emb_q, scales, active, qq, q_scales, k: int = 16, *,
                   interpret: bool = True, tile_n: int = TILE_N):
    """emb_q (N, D) int8; scales (N,) f32; active (N,); qq (B, D) int8;
    q_scales (B,) f32 -> (vals (B,k), rows (B,k)) coarse candidates.

    ``vals`` are the approximate (fully-quantized) scores — callers must
    rescore in fp32 before applying a similarity gate. Rows that fall off
    the active set carry ``NEG`` values; filter on ``vals > NEG / 2``.

    interpret=True executes the kernel body on CPU (this container);
    on TPU pass interpret=False for the Mosaic lowering.
    """
    n, d = emb_q.shape
    b = qq.shape[0]
    pad = (-n) % tile_n
    if pad:
        emb_q = jnp.pad(emb_q, ((0, pad), (0, 0)))
        scales = jnp.pad(scales, (0, pad))
        active = jnp.pad(active.astype(jnp.int32), (0, pad))
    active = active.astype(jnp.int32)
    ntiles = (n + pad) // tile_n

    vals, idx = pl.pallas_call(
        functools.partial(_annq_kernel, k=k),
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((b, d), lambda t: (0, 0)),        # qq resident
            pl.BlockSpec((b,), lambda t: (0,)),            # q_scales resident
            pl.BlockSpec((tile_n, d), lambda t: (t, 0)),   # int8 emb slab
            pl.BlockSpec((tile_n,), lambda t: (t,)),       # row scales slab
            pl.BlockSpec((tile_n,), lambda t: (t,)),       # active slab
        ],
        out_specs=[
            pl.BlockSpec((1, k, b), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, k, b), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ntiles, k, b), jnp.float32),
            jax.ShapeDtypeStruct((ntiles, k, b), jnp.int32),
        ],
        interpret=interpret,
    )(qq, q_scales, emb_q, scales, active)

    # global row ids, then merge the ntiles*k finalists per query
    base = (jnp.arange(ntiles, dtype=jnp.int32) * tile_n)[:, None, None]
    gidx = idx + base                                  # (ntiles, k, b)
    flat_v = vals.reshape(ntiles * k, b).T             # (b, ntiles*k)
    flat_i = gidx.reshape(ntiles * k, b).T
    kk = min(k, ntiles * k)
    top_v, pos = jax.lax.top_k(flat_v, kk)
    top_i = jnp.take_along_axis(flat_i, pos, axis=1)
    return top_v, top_i
