"""Kernel micro-benchmarks: wall time of the Seri stage-1 components on
this host (calibrates the engine's t_cache_cpu constant) plus derived
TPU-roofline estimates for the Pallas kernels (compute/memory terms from
first principles — the kernels execute here in interpret mode, so wall
times are NOT TPU numbers and are labelled host_*)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.launch.mesh import HW


def _timeit(fn, n=20):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def kernel_ann():
    rng = np.random.default_rng(0)
    for n_items in (1024, 8192, 65536):
        d, b, k = 256, 8, 4
        emb = rng.standard_normal((n_items, d)).astype(np.float32)
        act = np.ones(n_items, bool)
        q = rng.standard_normal((b, d)).astype(np.float32)

        # host numpy path (what VectorIndex uses on CPU)
        def np_path():
            s = emb @ q.T
            idx = np.argpartition(-s, k, axis=0)[:k]
            return idx

        t_np = _timeit(np_path)

        # XLA path
        embj, qj = jnp.asarray(emb), jnp.asarray(q)

        @jax.jit
        def xla_path(e, qq):
            return jax.lax.top_k(jnp.einsum("nd,bd->bn", e, qq), k)

        xla_path(embj, qj)[0].block_until_ready()
        t_xla = _timeit(lambda: xla_path(embj, qj)[0].block_until_ready())

        # TPU roofline estimate for the Pallas kernel (not measured here):
        flops = 2 * n_items * d * b
        bytes_moved = (n_items * d + b * d) * 4 + n_items * 4
        t_tpu_compute = flops / HW["peak_flops_bf16"]
        t_tpu_memory = bytes_moved / HW["hbm_bw"]
        emit(
            f"kernel_ann/N{n_items}", t_np * 1e6,
            host_numpy_us=round(t_np * 1e6, 1),
            host_xla_us=round(t_xla * 1e6, 1),
            tpu_roofline_us=round(
                max(t_tpu_compute, t_tpu_memory) * 1e6, 2
            ),
            bound="memory" if t_tpu_memory > t_tpu_compute else "compute",
        )


def kernel_flash():
    rng = np.random.default_rng(1)
    b, s, kv, g, dh = 1, 1024, 2, 4, 64
    q = jnp.asarray(rng.standard_normal((b, s, kv, g, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    from repro.nn.flash import sdpa_flash

    f = jax.jit(lambda q_, k_, v_: sdpa_flash(
        q_.reshape(b, s, kv * g, dh), k_, v_, 0.125, chunk=256
    ))
    f(q, k, v).block_until_ready()
    t = _timeit(lambda: f(q, k, v).block_until_ready(), n=5)
    h = kv * g
    flops = 4 * b * h * s * s * dh / 2  # causal half
    t_tpu = flops / HW["peak_flops_bf16"]
    emit(
        f"kernel_flash/s{s}", t * 1e6,
        host_xla_us=round(t * 1e6, 1),
        tpu_compute_us=round(t_tpu * 1e6, 2),
    )


def cache_path_calibration():
    """Measured cost of one full cache-lookup host path (embed + ANN) and
    one judge-model forward — validates the engine's Fig 11 constants."""
    from repro.core.embedder import ModelEmbedder
    from repro.core.judge import ModelJudge

    emb = ModelEmbedder(dim=64)
    judge = ModelJudge()
    texts = [f"query number {i}" for i in range(8)]
    emb.embed_batch(texts)  # warm
    t_embed = _timeit(lambda: emb.embed_batch(texts), n=5)
    judge.score_pairs(texts, texts)
    t_judge = _timeit(lambda: judge.score_pairs(texts, texts), n=5)
    emit(
        "cache_path/calibration", (t_embed + t_judge) * 1e6,
        embed_batch8_ms=round(t_embed * 1e3, 2),
        judge_batch8_ms=round(t_judge * 1e3, 2),
        engine_constant_cache_s=0.02, engine_constant_judge_s=0.03,
    )
