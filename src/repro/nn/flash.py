"""Flash attention in pure JAX (chunked online-softmax, custom_vjp).

This is the memory-safe attention used by every train/prefill path: the
(Sq × Sk) score matrix is never materialised — only (chunk × chunk) tiles.
The backward pass is the explicit FlashAttention-2 recomputation (not AD
through the forward scans), so activation memory is O(S·Dh) and the HLO
FLOPs of both passes are exact, which the roofline extraction relies on.

The Pallas TPU kernel in repro.kernels.flash_attention implements the same
tiling for the MXU; this module doubles as its oracle.

Layout: q (B, Sq, KV, G, Dh) — G = query-group fan-out per KV head (GQA);
k, v (B, Sk, KV, Dh). Masking: causal with q_offset, optional sliding
window. All softmax math in f32.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.nn import runtime

NEG_INF = -1e30


def _mask(qi, kj, causal: bool, window):
    m = jnp.ones(jnp.broadcast_shapes(qi.shape, kj.shape), bool)
    if causal:
        m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    return m


@partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention(q, k, v, scale, causal=True, window=None, q_offset=0,
                    chunk=1024):
    out, _ = _flash_fwd(q, k, v, scale, causal, window, q_offset, chunk)
    return out


def _flash_fwd(q, k, v, scale, causal, window, q_offset, chunk):
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    cq = min(chunk, sq)
    ck = min(chunk, sk)
    assert sq % cq == 0 and sk % ck == 0, (sq, sk, chunk)
    nq, nk = sq // cq, sk // ck
    qf = jnp.moveaxis(q, 1, 3)  # (B,KV,G,Sq,Dh)

    def q_chunk_body(_, qi_idx):
        qc = jax.lax.dynamic_slice_in_dim(qf, qi_idx * cq, cq, axis=3)
        qpos = q_offset + qi_idx * cq + jnp.arange(cq)

        def k_chunk_body(carry, kj_idx):
            m_run, l_run, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, kj_idx * ck, ck, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, kj_idx * ck, ck, axis=1)
            kpos = kj_idx * ck + jnp.arange(ck)
            s = jnp.einsum(
                "bkgqd,bskd->bkgqs", qc, kc,
                preferred_element_type=jnp.float32,
            ) * scale
            msk = _mask(qpos[:, None], kpos[None, :], causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, cq, dh), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            k_chunk_body, (m0, l0, a0), jnp.arange(nk),
            unroll=runtime.unroll_for(nk),
        )
        l_safe = jnp.maximum(l_f, 1e-30)
        o = (acc / l_safe[..., None]).astype(q.dtype)
        lse = m_f + jnp.log(l_safe)
        return None, (o, lse)

    _, (o_chunks, lse_chunks) = jax.lax.scan(
        q_chunk_body, None, jnp.arange(nq), unroll=runtime.unroll_for(nq)
    )
    # o_chunks: (nq, B,KV,G,cq,Dh) -> (B,Sq,KV,G,Dh)
    o = jnp.moveaxis(o_chunks, 0, 3).reshape(b, kvh, g, sq, dh)
    o = jnp.moveaxis(o, 3, 1)
    lse = jnp.moveaxis(lse_chunks, 0, 3).reshape(b, kvh, g, sq)
    return o, (q, k, v, o, lse)


def _flash_bwd(scale, causal, window, q_offset, chunk, res, dout):
    q, k, v, o, lse = res
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    cq = min(chunk, sq)
    ck = min(chunk, sk)
    nq, nk = sq // cq, sk // ck
    qf = jnp.moveaxis(q, 1, 3)          # (B,KV,G,Sq,Dh)
    dof = jnp.moveaxis(dout, 1, 3)      # (B,KV,G,Sq,Dh)
    of = jnp.moveaxis(o, 1, 3)
    # D_i = rowsum(dO * O)
    delta = jnp.sum(
        dof.astype(jnp.float32) * of.astype(jnp.float32), axis=-1
    )  # (B,KV,G,Sq)

    def k_chunk_body(dq_acc, kj_idx):
        kc = jax.lax.dynamic_slice_in_dim(k, kj_idx * ck, ck, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, kj_idx * ck, ck, axis=1)
        kpos = kj_idx * ck + jnp.arange(ck)

        def q_chunk_body(carry, qi_idx):
            dk_acc, dv_acc = carry
            qc = jax.lax.dynamic_slice_in_dim(qf, qi_idx * cq, cq, axis=3)
            doc = jax.lax.dynamic_slice_in_dim(dof, qi_idx * cq, cq, axis=3)
            lse_c = jax.lax.dynamic_slice_in_dim(lse, qi_idx * cq, cq, axis=3)
            dlt_c = jax.lax.dynamic_slice_in_dim(delta, qi_idx * cq, cq, axis=3)
            qpos = q_offset + qi_idx * cq + jnp.arange(cq)
            s = jnp.einsum(
                "bkgqd,bskd->bkgqs", qc, kc,
                preferred_element_type=jnp.float32,
            ) * scale
            msk = _mask(qpos[:, None], kpos[None, :], causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_c[..., None])  # (B,KV,G,cq,ck)
            dp = jnp.einsum(
                "bkgqd,bskd->bkgqs", doc.astype(jnp.float32),
                vc.astype(jnp.float32),
            )
            ds = p * (dp - dlt_c[..., None]) * scale
            dv_new = dv_acc + jnp.einsum(
                "bkgqs,bkgqd->bskd", p, doc.astype(jnp.float32)
            )
            dk_new = dk_acc + jnp.einsum(
                "bkgqs,bkgqd->bskd", ds, qc.astype(jnp.float32)
            )
            dq_c = jnp.einsum(
                "bkgqs,bskd->bkgqd", ds.astype(qc.dtype), kc,
                preferred_element_type=jnp.float32,
            )
            return (dk_new, dv_new), dq_c

        dk0 = jnp.zeros((b, ck, kvh, dh), jnp.float32)
        dv0 = jnp.zeros((b, ck, kvh, dh), jnp.float32)
        (dk_c, dv_c), dq_chunks = jax.lax.scan(
            q_chunk_body, (dk0, dv0), jnp.arange(nq),
            unroll=runtime.unroll_for(nq),
        )
        # dq_chunks (nq,B,KV,G,cq,Dh) is ordered: fold into (B,KV,G,Sq,Dh)
        dq_inc = jnp.moveaxis(dq_chunks, 0, 3).reshape(b, kvh, g, sq, dh)
        return dq_acc + dq_inc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, kvh, g, sq, dh), jnp.float32)
    dq_f, (dk_chunks, dv_chunks) = jax.lax.scan(
        k_chunk_body, dq0, jnp.arange(nk), unroll=runtime.unroll_for(nk)
    )
    dq = jnp.moveaxis(dq_f, 3, 1).astype(q.dtype)  # (B,Sq,KV,G,Dh)
    dk = jnp.moveaxis(dk_chunks, 0, 1).reshape(b, sk, kvh, dh).astype(k.dtype)
    dv = jnp.moveaxis(dv_chunks, 0, 1).reshape(b, sk, kvh, dh).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(
    lambda q, k, v, scale, causal, window, q_offset, chunk: _flash_fwd(
        q, k, v, scale, causal, window, q_offset, chunk
    ),
    _flash_bwd,
)


def sdpa_flash(q, k, v, scale, causal=True, window=None, q_offset=0,
               chunk=1024):
    """(B,Sq,H,Dh) x (B,Sk,KVH,Dh) GQA wrapper around flash_attention."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, sq, kvh, h // kvh, dh)
    out = flash_attention(qg, k, v, scale, causal, window, q_offset, chunk)
    return out.reshape(b, sq, h, dh)
