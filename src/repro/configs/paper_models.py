"""The paper's own models (Cortex §6.1):

* search-r1-7b  — the search agent (Qwen2.5-7B backbone, post-trained).
* qwen3-8b-code — the coding agent.
* qwen3-0.6b    — the embedding model AND the lightweight semantic judge
                  (LSM); the judge runs prefill-only classification
                  (single-token output), which is what makes co-location
                  cheap (paper §4.4).
"""
from repro.configs.common import register
from repro.nn.config import AttnConfig, LayerSpec, ModelConfig


@register("search-r1-7b")
def search_r1_7b() -> ModelConfig:
    attn = AttnConfig(
        n_heads=32,  # padded from 28 for TP16 (Qwen2.5-7B has 28H)
        n_kv_heads=4, head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
    )
    return ModelConfig(
        name="search-r1-7b",
        family="dense",
        d_model=3584,
        vocab_size=152064,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=18944),),
        n_repeat=28,
        tie_embeddings=False,
    )


@register("qwen3-8b-code")
def qwen3_8b_code() -> ModelConfig:
    attn = AttnConfig(
        n_heads=32, n_kv_heads=8, head_dim=128, rope_theta=1_000_000.0
    )
    return ModelConfig(
        name="qwen3-8b-code",
        family="dense",
        d_model=4096,
        vocab_size=151936,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=12288),),
        n_repeat=36,
        tie_embeddings=False,
    )


@register("qwen3-0.6b")
def qwen3_0_6b() -> ModelConfig:
    attn = AttnConfig(
        n_heads=16, n_kv_heads=8, head_dim=128, rope_theta=1_000_000.0
    )
    return ModelConfig(
        name="qwen3-0.6b",
        family="dense",
        d_model=1024,
        vocab_size=151936,
        blocks=(LayerSpec(kind="attn", attn=attn, d_ff=3072),),
        n_repeat=28,
        tie_embeddings=True,
    )
