"""Per-assigned-architecture smoke tests: a REDUCED same-family config runs
one forward/train step + prefill + decode on CPU; output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (struct-only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, shrink
from repro.models.lm import LM
from repro.nn.param import init_tree, param_count
from repro.nn.sharding import ShardCtx, make_test_mesh

B, S = 2, 32


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision":
        batch["frontend_emb"] = jnp.ones((B, S, cfg.d_model), cfg.pdt)
        batch["frontend_mask"] = (
            jnp.zeros((B, S), bool).at[:, :4].set(True)
        )
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S)
        )
    if cfg.enc_dec:
        batch["enc_emb"] = jnp.ones((B, S, cfg.d_model), cfg.pdt)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke(arch):
    cfg = shrink(get_config(arch))
    lm = LM(cfg)
    ctx = ShardCtx(make_test_mesh())
    key = jax.random.PRNGKey(0)
    params = init_tree(key, lm.param_specs())
    assert param_count(lm.param_specs()) > 0
    batch = _batch(cfg, key)

    # ---- train step (loss + grads finite)
    def loss_fn(p):
        loss, _ = lm.loss_and_aux(ctx, p, batch)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gn = sum(
        float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
        for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grads"

    # ---- prefill (decoder-only) + shapes
    if not cfg.enc_dec:
        logits, caches = jax.jit(lambda p, b: lm.prefill(ctx, p, b))(
            params, batch
        )
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    # ---- decode one token against a fresh cache
    cs = lm.cache_specs(B, S, enc_len=S if cfg.enc_dec else 0)
    caches = init_tree(key, cs)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    lg, new_caches = jax.jit(
        lambda p, t, c: lm.decode(ctx, p, t, c, jnp.int32(S - 1))
    )(params, tok, caches)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), f"{arch}: decode NaN"
    # cache tree structure preserved
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)
