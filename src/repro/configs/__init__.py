"""Architecture registry: the 10 assigned architectures + the paper's own
models. ``get_config(name)`` / ``list_archs()`` are the public API;
``ASSIGNED`` lists the dry-run matrix rows."""
from repro.configs import (  # noqa: F401  (import for registration)
    deepseek_v2_236b,
    deepseek_v3_671b,
    gemma3_12b,
    granite_3_8b,
    jamba_1_5_large_398b,
    paper_models,
    qwen1_5_110b,
    qwen2_vl_7b,
    seamless_m4t_large_v2,
    xlstm_350m,
    yi_34b,
)
from repro.configs.common import get_config, input_specs, list_archs, shrink

ASSIGNED = [
    "jamba-1.5-large-398b",
    "gemma3-12b",
    "yi-34b",
    "granite-3-8b",
    "qwen1.5-110b",
    "qwen2-vl-7b",
    "seamless-m4t-large-v2",
    "deepseek-v2-236b",
    "deepseek-v3-671b",
    "xlstm-350m",
]

PAPER_MODELS = ["search-r1-7b", "qwen3-8b-code", "qwen3-0.6b"]

__all__ = [
    "ASSIGNED",
    "PAPER_MODELS",
    "get_config",
    "input_specs",
    "list_archs",
    "shrink",
]
