"""Overload actuation (DESIGN.md §17): policies that react to the §16
telemetry instead of just alerting on it.

The :class:`OverloadController` closes the loop between the sensing half
(``TimeSeriesSampler`` gauges + ``SLOMonitor`` breach/recovery state) and
the serving engine's degradation seams:

* **shed-to-nojudge** — while the watched latency SLO is breached, or
  while the judge backlog exceeds ``judge_backlog_cap``, requests the
  admission band classified as "judge" are served through the trust
  (nojudge) path instead: the band effectively widens toward trust
  under pressure, so the judge lane stops being the queueing bottleneck.
* **prefetch / refresh-ahead pause** — background origin traffic
  (Markov prefetch, freshness refresh-ahead) is paused while limiter
  headroom is below a floor or the SLO is breached, reserving API
  budget for on-path misses.
* **serve-stale-on-origin-failure** — when a fetch terminates with
  ``FetchOutcome.failed`` (origin brownout, DESIGN.md §17), a
  known-stale but present cache entry beats an error.

Every decision method is a pure function of controller config + monitor
state + the gauge values passed in: no rng, no clock mutation, no
side effects beyond its own counters and trace markers. With
``enabled=False`` (or no controller at all) every policy answers the
legacy way, so runs are bit-identical to a controller-free engine —
that is the §17 neutrality contract, mirrored from §15/§16.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.trace import BACKGROUND, NULL_TRACER


@dataclasses.dataclass
class OverloadConfig:
    """Policy knobs; each policy has an independent off-switch."""
    enabled: bool = True                 # master switch ("off" = armed but inert)
    shed_on_slo: bool = True             # shed-to-nojudge while SLO breached
    slo_name: Optional[str] = None       # watch one SLO (None = any breach)
    judge_backlog_cap: Optional[int] = 16  # shed above this backlog depth
    shed_margin: float = 0.02            # only shed candidates with
                                         # best-sim >= tau_sim + this —
                                         # the band widens toward trust,
                                         # it does not trust everything
                                         # (keeps the accuracy floor)
    pause_prefetch: bool = True          # pause Markov prefetch under pressure
    pause_refresh: bool = True           # pause refresh-ahead under pressure
    min_headroom: float = 0.35           # limiter-headroom floor for background work
    serve_stale_on_failure: bool = True  # stale-but-present beats an error


@dataclasses.dataclass
class OverloadStats:
    """Actuation counters, surfaced via the ``overload.*`` registry
    namespace and (when armed) ``summary()``."""
    shed_hits: int = 0        # judge-classified requests served via trust path
    slo_sheds: int = 0        # ... of which triggered by an SLO breach
    backlog_sheds: int = 0    # ... of which triggered by the backlog cap
    shed_flips: int = 0       # shedding-state transitions (on↔off)
    prefetch_paused: int = 0  # prefetch decisions suppressed
    refresh_paused: int = 0   # refresh-ahead fetches suppressed
    stale_served: int = 0     # failed fetches answered from a stale entry
    failed_retries: int = 0   # failed fetches rescheduled (no stale entry)


class OverloadController:
    """See module docstring. One controller per engine; under federation
    each region's controller shares the fleet :class:`SLOMonitor`."""

    def __init__(self, cfg: Optional[OverloadConfig] = None, *,
                 monitor=None, tracer=None, region: int = 0):
        self.cfg = cfg if cfg is not None else OverloadConfig()
        self.monitor = monitor
        self.trace = tracer if tracer is not None else NULL_TRACER
        self.region = region
        self.stats = OverloadStats()
        self._shedding = False

    # -- sensing ---------------------------------------------------------

    def slo_breached(self) -> bool:
        """Is the watched SLO (or any SLO) currently in breach? Pure
        read of the monitor's hysteresis state."""
        if self.monitor is None:
            return False
        active = self.monitor.active()
        if self.cfg.slo_name is not None:
            return self.cfg.slo_name in active
        return bool(active)

    # -- actuation decisions --------------------------------------------

    def shed_judge(self, now: float, backlog: int, *,
                   best_sim: float = 1.0, tau: float = 0.0) -> bool:
        """Should a request the admission band classified as "judge" be
        served through the trust path instead? Called on-path per
        judge-classified request. Only candidates whose best stage-1
        similarity clears ``tau + shed_margin`` are eligible — shedding
        widens the trust edge toward τ_sim, it never serves matches the
        threshold itself would reject."""
        if not self.cfg.enabled:
            return False
        if best_sim < tau + self.cfg.shed_margin:
            return False
        over_cap = (self.cfg.judge_backlog_cap is not None
                    and backlog >= self.cfg.judge_backlog_cap)
        breached = self.cfg.shed_on_slo and self.slo_breached()
        shed = over_cap or breached
        if shed != self._shedding:
            self._shedding = shed
            self.stats.shed_flips += 1
            self.trace.marker(BACKGROUND, "shed_on" if shed else "shed_off",
                              now, self.region)
        if shed:
            self.stats.shed_hits += 1
            if over_cap:
                self.stats.backlog_sheds += 1
            if breached:
                self.stats.slo_sheds += 1
        return shed

    def allow_prefetch(self, headroom: float, now: float) -> bool:
        """May the Markov prefetcher spend origin budget right now?"""
        if not self.cfg.enabled or not self.cfg.pause_prefetch:
            return True
        if headroom < self.cfg.min_headroom or self.slo_breached():
            self.stats.prefetch_paused += 1
            return False
        return True

    def allow_refresh(self, headroom: float, now: float) -> bool:
        """May refresh-ahead spend origin budget right now?"""
        if not self.cfg.enabled or not self.cfg.pause_refresh:
            return True
        if headroom < self.cfg.min_headroom or self.slo_breached():
            self.stats.refresh_paused += 1
            return False
        return True

    def serve_stale_ok(self) -> bool:
        return self.cfg.enabled and self.cfg.serve_stale_on_failure

    def metrics(self) -> dict:
        return dataclasses.asdict(self.stats)
