"""Kernel micro-benchmarks: wall time of the Seri stage-1 components on
this host (calibrates the engine's t_cache_cpu constant) plus derived
TPU-roofline estimates for the Pallas kernels (compute/memory terms from
first principles — the kernels execute here in interpret mode, so wall
times are NOT TPU numbers and are labelled host_*)."""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.launch.mesh import HW


def _timeit(fn, n=20):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def kernel_ann():
    rng = np.random.default_rng(0)
    for n_items in (1024, 8192, 65536):
        d, b, k = 256, 8, 4
        emb = rng.standard_normal((n_items, d)).astype(np.float32)
        act = np.ones(n_items, bool)
        q = rng.standard_normal((b, d)).astype(np.float32)

        # host numpy path (what VectorIndex uses on CPU)
        def np_path():
            s = emb @ q.T
            idx = np.argpartition(-s, k, axis=0)[:k]
            return idx

        t_np = _timeit(np_path)

        # XLA path
        embj, qj = jnp.asarray(emb), jnp.asarray(q)

        @jax.jit
        def xla_path(e, qq):
            return jax.lax.top_k(jnp.einsum("nd,bd->bn", e, qq), k)

        xla_path(embj, qj)[0].block_until_ready()
        t_xla = _timeit(lambda: xla_path(embj, qj)[0].block_until_ready())

        # TPU roofline estimate for the Pallas kernel (not measured here):
        flops = 2 * n_items * d * b
        bytes_moved = (n_items * d + b * d) * 4 + n_items * 4
        t_tpu_compute = flops / HW["peak_flops_bf16"]
        t_tpu_memory = bytes_moved / HW["hbm_bw"]
        emit(
            f"kernel_ann/N{n_items}", t_np * 1e6,
            host_numpy_us=round(t_np * 1e6, 1),
            host_xla_us=round(t_xla * 1e6, 1),
            tpu_roofline_us=round(
                max(t_tpu_compute, t_tpu_memory) * 1e6, 2
            ),
            bound="memory" if t_tpu_memory > t_tpu_compute else "compute",
        )


def kernel_flash():
    rng = np.random.default_rng(1)
    b, s, kv, g, dh = 1, 1024, 2, 4, 64
    q = jnp.asarray(rng.standard_normal((b, s, kv, g, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    from repro.nn.flash import sdpa_flash

    f = jax.jit(lambda q_, k_, v_: sdpa_flash(
        q_.reshape(b, s, kv * g, dh), k_, v_, 0.125, chunk=256
    ))
    f(q, k, v).block_until_ready()
    t = _timeit(lambda: f(q, k, v).block_until_ready(), n=5)
    h = kv * g
    flops = 4 * b * h * s * s * dh / 2  # causal half
    t_tpu = flops / HW["peak_flops_bf16"]
    emit(
        f"kernel_flash/s{s}", t * 1e6,
        host_xla_us=round(t * 1e6, 1),
        tpu_compute_us=round(t_tpu * 1e6, 2),
    )


class _PassJudge:
    """Constant judge: isolates the host lookup/eviction path so the
    batched-vs-scalar sweep measures the cache runtime, not the judge."""

    def score_pairs(self, queries, cached_keys):
        return np.ones(len(queries), np.float32)

    def staticity(self, query):
        return 5


def _soa_cache(n_items, dim, rng):
    from repro.core.cache import make_cache

    cap = 1 << (n_items - 1).bit_length()
    cache = make_cache(
        capacity_bytes=1 << 60, dim=dim, judge=_PassJudge(),
        index_capacity=cap, tau_sim=0.7, top_k=4,
    )
    emb = rng.standard_normal((n_items, dim)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    for i in range(n_items):
        cache.insert(f"q{i}", emb[i], i, now=0.0, cost=0.005, latency=0.4,
                     size=100, staticity=5)
    return cache, emb


def cache_batched():
    """Batched SoA runtime vs the legacy scalar path (ISSUE 1 tentpole):
    lookup (stage-1 + judge + bookkeeping) and LCFU victim selection,
    swept over cache size × batch size on the numpy backend.

    scalar  = per-query ``lookup`` calls + legacy full ``sorted`` with a
              per-item Python ``lcfu_score`` (what the dict-of-dataclasses
              core did);
    batched = one ``lookup_batch`` + vectorized argpartition victims.
    """
    rng = np.random.default_rng(7)
    dim, now = 64, 1.0
    for n_items in (1024, 4096, 16384):
        cache, emb = _soa_cache(n_items, dim, rng)
        n_evict = 32
        for batch in (16, 64):
            pick = rng.integers(0, n_items, batch)
            q = emb[pick] + 0.03 * rng.standard_normal(
                (batch, dim)).astype(np.float32)
            q /= np.linalg.norm(q, axis=1, keepdims=True)
            qs = [f"q{i}" for i in pick]

            def legacy_lcfu(se):
                # the removed dict-of-dataclasses scoring, verbatim
                # (math.log per item), so the baseline is not penalized
                # by the view's vectorized one-row delegation
                if se.size == 0 or se.expires_at - now <= 0:
                    return 0.0
                return (
                    math.log(se.freq + 1.0)
                    * math.log(se.cost * 1e3 + 1.0)
                    * math.log(se.latency + 1.0)
                    * math.log(se.staticity + 1.0)
                ) / se.size

            def scalar_path():
                for i in range(batch):
                    cache.lookup(qs[i], q[i], now)
                order = sorted(cache.store.values(), key=legacy_lcfu)
                return order[:n_evict]

            def batched_path():
                cache.lookup_batch(qs, q, now)
                return cache.soa.victim_rows(now, "lcfu", n=n_evict)

            # interleaved min-of-N: this host's wall clock jitters by up
            # to ~10× under time-sharing; the minimum is the only stable
            # estimate of the actual cost of either path
            scalar_path(), batched_path()  # warm
            t_scalar, t_batch = [], []
            for _ in range(10):
                t0 = time.perf_counter()
                scalar_path()
                t_scalar.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                batched_path()
                t_batch.append(time.perf_counter() - t0)
            t_scalar, t_batch = min(t_scalar), min(t_batch)
            emit(
                f"cache_batched/N{n_items}_B{batch}", t_batch * 1e6,
                scalar_us=round(t_scalar * 1e6, 1),
                batched_us=round(t_batch * 1e6, 1),
                speedup=round(t_scalar / t_batch, 2),
            )


def cache_path_calibration():
    """Measured cost of one full cache-lookup host path (embed + ANN) and
    one judge-model forward — validates the engine's Fig 11 constants."""
    from repro.core.embedder import ModelEmbedder
    from repro.core.judge import ModelJudge

    emb = ModelEmbedder(dim=64)
    judge = ModelJudge()
    texts = [f"query number {i}" for i in range(8)]
    emb.embed_batch(texts)  # warm
    t_embed = _timeit(lambda: emb.embed_batch(texts), n=5)
    judge.score_pairs(texts, texts)
    t_judge = _timeit(lambda: judge.score_pairs(texts, texts), n=5)
    emit(
        "cache_path/calibration", (t_embed + t_judge) * 1e6,
        embed_batch8_ms=round(t_embed * 1e3, 2),
        judge_batch8_ms=round(t_judge * 1e3, 2),
        engine_constant_cache_s=0.02, engine_constant_judge_s=0.03,
    )
