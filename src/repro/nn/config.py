"""Model/layer configuration dataclasses shared by nn layers and configs/."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    kind: str = "gqa"  # gqa | mla
    rope_kind: str = "rope"  # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    window: Optional[int] = None  # sliding-window size; None = full attention
    qkv_bias: bool = False
    # MLA (deepseek) dims
    q_lora_rank: Optional[int] = None
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    @property
    def q_out_dim(self) -> int:
        if self.kind == "mla":
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0  # defaults to d_ff_expert * n_shared when 0
    capacity_factor: float = 1.25
    router_scale: bool = True  # normalise top-k weights to sum to 1
    router_fn: str = "softmax"  # softmax | sigmoid (deepseek-v3)
    aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    chunk: int = 256  # time-chunk for the train-time scan


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    kind: str = "mlstm"  # mlstm | slstm
    n_heads: int = 4
    proj_factor: float = 2.0
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of a (super)block: sequence-mixer + channel-mixer."""

    kind: str  # attn | mamba | mlstm | slstm
    attn: Optional[AttnConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    d_ff: int = 0  # dense FFN width; 0 = no dense FFN
    moe: Optional[MoEConfig] = None  # if set, channel mixer is MoE
    ffn_act: str = "swiglu"  # swiglu | gelu
    cross_attn: bool = False  # decoder cross-attention (enc-dec models)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    vocab_size: int
    # decoder structure: prefix layers (unrolled) + superblock * n_repeat (scan)
    blocks: tuple[LayerSpec, ...] = ()
    n_repeat: int = 1
    prefix: tuple[LayerSpec, ...] = ()
    # encoder-decoder
    enc_dec: bool = False
    enc_blocks: tuple[LayerSpec, ...] = ()
    enc_repeat: int = 0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    mtp: bool = False  # deepseek-v3 multi-token prediction head
    frontend: Optional[str] = None  # vision | audio (stub embeddings)
    sub_quadratic: bool = False  # eligible for the long_500k shape
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def n_layers(self) -> int:
        n = len(self.prefix) + len(self.blocks) * self.n_repeat
        if self.enc_dec:
            n += len(self.enc_blocks) * self.enc_repeat
        return n

    def layer_iter(self):
        """Logical (decoder-side) layer sequence (prefix, then repeats)."""
        out = list(self.prefix)
        for _ in range(self.n_repeat):
            out.extend(self.blocks)
        return out


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (workload) input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def step(self) -> str:
        return "train_step" if self.kind == "train" else "serve_step"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
