"""Shared virtual clock for discrete-event simulation (DESIGN.md §9).

One heap of ``(time, seq, fn, args)`` events. A single engine owns a
private clock; a :class:`~repro.serving.federation.FederationRunner`
hands the SAME clock to every per-region engine, so all regions advance
through one globally-ordered event stream — peer peeks observe sibling
caches at the exact virtual instant the probe arrives, and replaying the
same seeds yields the same interleaving (the ``seq`` tie-break makes
simultaneous events deterministic regardless of region count).
"""
from __future__ import annotations

import heapq
import itertools


class VirtualClock:
    """Monotonic virtual time + the event heap that advances it."""

    def __init__(self):
        self.now = 0.0
        self._events: list = []
        self._seq = itertools.count()

    def push(self, t: float, fn, *args) -> None:
        heapq.heappush(self._events, (t, next(self._seq), fn, args))

    @property
    def pending(self) -> int:
        return len(self._events)

    def step(self) -> None:
        """Pop and fire the next event. Time never moves backwards: an
        event scheduled in the past (by a caller that pre-advanced its own
        local time, e.g. retry backoff) fires at the current instant."""
        t, _, fn, args = heapq.heappop(self._events)
        self.now = max(self.now, t)
        fn(*args) if args else fn(self.now)
