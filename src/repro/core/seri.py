"""Seri — the Semantic Retrieval Index (paper §4.2).

Stage 1 (coarse): exact cosine top-k over the SE embedding matrix with the
τ_sim gate. On TPU this runs as the Pallas ``ann_topk`` kernel (brute-force
MXU matmul — the TPU-idiomatic replacement for Faiss graph traversal, see
DESIGN.md §3); on CPU the numpy path is bit-identical.

Stage 2 (fine): the semantic judge validates each candidate's *result*
against the new query; the first candidate with S_lsm ≥ τ_lsm is a
semantic-aware cache hit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.semantic_element import SemanticElement


class VectorIndex:
    """Fixed-capacity embedding store with free-list row management."""

    def __init__(self, capacity: int, dim: int, backend: str = "numpy"):
        self.capacity = capacity
        self.dim = dim
        self.backend = backend
        self.emb = np.zeros((capacity, dim), np.float32)
        self.active = np.zeros(capacity, bool)
        self.row_se: list[Optional[int]] = [None] * capacity
        self._free = list(range(capacity - 1, -1, -1))
        self._kernel_fn = None
        if backend == "kernel":
            from repro.kernels.ops import ann_topk_jit

            self._kernel_fn = ann_topk_jit

    def __len__(self) -> int:
        return int(self.active.sum())

    @property
    def full(self) -> bool:
        return not self._free

    def add(self, se_id: int, embedding: np.ndarray) -> int:
        if not self._free:
            raise RuntimeError("index full — evict first")
        row = self._free.pop()
        self.emb[row] = embedding
        self.active[row] = True
        self.row_se[row] = se_id
        return row

    def remove(self, row: int) -> None:
        if not self.active[row]:
            return
        self.active[row] = False
        self.row_se[row] = None
        self.emb[row] = 0.0
        self._free.append(row)

    def search(self, q: np.ndarray, k: int, tau_sim: float):
        """Top-k rows with cosine ≥ tau_sim. q: (dim,) unit-norm.
        Returns (se_ids, sims) sorted by similarity desc."""
        if len(self) == 0:
            return [], np.zeros(0, np.float32)
        if self._kernel_fn is not None:
            sims, rows = self._kernel_fn(self.emb, self.active, q, k)
            sims = np.asarray(sims)
            rows = np.asarray(rows)
        else:
            scores = self.emb @ q
            scores = np.where(self.active, scores, -1.0)
            k_eff = min(k, len(scores))
            rows = np.argpartition(-scores, k_eff - 1)[:k_eff]
            rows = rows[np.argsort(-scores[rows])]
            sims = scores[rows]
        keep = sims >= tau_sim
        rows, sims = rows[keep], sims[keep]
        return [self.row_se[r] for r in rows], sims


@dataclasses.dataclass
class SeriResult:
    hit: bool
    se: Optional[SemanticElement]
    n_candidates: int
    judge_calls: int
    best_score: float
    sims: np.ndarray


class Seri:
    """Two-stage retrieval over a SE store."""

    def __init__(self, index: VectorIndex, judge, *, tau_sim: float = 0.9,
                 tau_lsm: float = 0.9, top_k: int = 4):
        self.index = index
        self.judge = judge
        self.tau_sim = tau_sim
        self.tau_lsm = tau_lsm
        self.top_k = top_k

    def retrieve(self, query: str, q_emb: np.ndarray,
                 store: dict[int, SemanticElement],
                 now: float) -> SeriResult:
        se_ids, sims = self.index.search(q_emb, self.top_k, self.tau_sim)
        # drop expired candidates (freshness is part of validity, §4.1)
        cands = [
            store[i] for i in se_ids
            if i in store and not store[i].expired(now)
        ]
        if not cands:
            return SeriResult(False, None, 0, 0, 0.0, sims)
        scores = self.judge.score_pairs(
            [query] * len(cands), [c.key for c in cands]
        )
        order = np.argsort(-scores)
        best = float(scores[order[0]])
        for j in order:
            if scores[j] >= self.tau_lsm:
                return SeriResult(
                    True, cands[j], len(cands), len(cands), best, sims
                )
        return SeriResult(False, None, len(cands), len(cands), best, sims)
