"""Predictive prefetching — first-order Markov over confirmed hits (§4.3).

The model learns P(q_{i+1} | q_i) from the stream of *validated* queries
(intent-level transitions, so paraphrases of one topic share a state).
When the top transition probability exceeds the confidence threshold and
the predicted item is absent, the engine issues an async fetch; the new SE
enters with freq = 0, making unused speculation the first eviction victim
(self-correcting pollution control).
"""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict
from typing import Hashable, Optional


@dataclasses.dataclass
class Prediction:
    state: Hashable
    prob: float
    support: int


class MarkovPrefetcher:
    def __init__(self, *, confidence: float = 0.5, min_support: int = 3,
                 max_states: int = 100_000, max_sessions: int = 10_000):
        self.confidence = confidence
        self.min_support = min_support
        self.max_states = max_states
        self.max_sessions = max_sessions
        self.trans: dict[Hashable, Counter] = defaultdict(Counter)
        self.totals: Counter = Counter()
        # predecessor per observation stream: concurrent requests
        # interleave their validated queries, and a single global chain
        # would record transitions between unrelated sessions. The engine
        # keys by the request's session id, so the learned table is the
        # same whether streams run sequentially or interleaved. LRU-
        # bounded at max_sessions: workloads mint fresh session ids
        # forever, and only recently-active chains can still extend.
        self._prev: dict[Hashable, Hashable] = {}

    def observe(self, state: Hashable, key: Hashable = None) -> None:
        """Feed one validated (hit-or-fetched) query state.

        ``key`` identifies the observation stream (session/request id);
        transitions are only learned between consecutive states of the
        SAME stream."""
        prev = self._prev.pop(key, None)
        if prev is not None and prev != state:
            if len(self.trans) < self.max_states or prev in self.trans:
                self.trans[prev][state] += 1
                self.totals[prev] += 1
        self._prev[key] = state  # pop+reinsert = move to LRU tail
        if len(self._prev) > self.max_sessions:
            self._prev.pop(next(iter(self._prev)))

    def reset_session(self, key: Hashable = None) -> None:
        self._prev.pop(key, None)

    def predict(self, state: Hashable) -> Optional[Prediction]:
        total = self.totals.get(state, 0)
        if total < self.min_support:
            return None
        nxt, cnt = self.trans[state].most_common(1)[0]
        p = cnt / total
        if p >= self.confidence:
            return Prediction(nxt, p, cnt)
        return None
