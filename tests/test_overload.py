"""Overload-control tests (DESIGN.md §17): the controller's decision
functions in isolation, the armed-but-off neutrality contract end to
end, shed-to-nojudge under a flash crowd, and the judge-timeout span
discipline under sustained backlog (each timed-out request resolves
exactly once, through exactly one span shape)."""
import json

import pytest

from repro.launch.serve import run_once
from repro.serving.overload import OverloadConfig, OverloadController


def _canon(s):
    return json.dumps(s, sort_keys=True, default=float)


class _FakeMonitor:
    """SLOMonitor stand-in: `active()` returns whatever the test set."""

    def __init__(self, names=()):
        self.names = set(names)

    def active(self):
        return set(self.names)


# ------------------------------------------------- decision functions


def test_shed_requires_pressure_and_similarity_margin():
    ctrl = OverloadController(
        OverloadConfig(judge_backlog_cap=4, shed_margin=0.02),
        monitor=_FakeMonitor())
    # no pressure: never shed
    assert not ctrl.shed_judge(0.0, backlog=0, best_sim=0.99, tau=0.8)
    # backlog pressure + similarity clear of tau+margin: shed
    assert ctrl.shed_judge(1.0, backlog=4, best_sim=0.83, tau=0.8)
    # backlog pressure but the candidate sits inside the margin: judge it
    assert not ctrl.shed_judge(2.0, backlog=4, best_sim=0.81, tau=0.8)
    assert ctrl.stats.shed_hits == 1
    assert ctrl.stats.backlog_sheds == 1
    assert ctrl.stats.slo_sheds == 0


def test_shed_on_slo_breach_and_flip_accounting():
    mon = _FakeMonitor()
    ctrl = OverloadController(OverloadConfig(judge_backlog_cap=None),
                              monitor=mon)
    assert not ctrl.shed_judge(0.0, backlog=0, best_sim=1.0, tau=0.0)
    mon.names = {"p99"}
    assert ctrl.shed_judge(1.0, backlog=0, best_sim=1.0, tau=0.0)
    assert ctrl.stats.slo_sheds == 1
    mon.names = set()
    assert not ctrl.shed_judge(2.0, backlog=0, best_sim=1.0, tau=0.0)
    assert ctrl.stats.shed_flips == 2       # on at t=1, off at t=2


def test_slo_name_filter_watches_one_slo():
    mon = _FakeMonitor({"other"})
    ctrl = OverloadController(OverloadConfig(slo_name="p99"), monitor=mon)
    assert not ctrl.slo_breached()
    mon.names = {"other", "p99"}
    assert ctrl.slo_breached()


def test_background_work_pauses_on_headroom_or_breach():
    mon = _FakeMonitor()
    ctrl = OverloadController(OverloadConfig(min_headroom=0.35),
                              monitor=mon)
    assert ctrl.allow_prefetch(0.5, 0.0)
    assert not ctrl.allow_prefetch(0.2, 1.0)      # headroom floor
    mon.names = {"p99"}
    assert not ctrl.allow_refresh(0.9, 2.0)       # SLO breach
    assert ctrl.stats.prefetch_paused == 1
    assert ctrl.stats.refresh_paused == 1


def test_every_policy_has_an_off_switch():
    mon = _FakeMonitor({"p99"})
    # master switch
    off = OverloadController(OverloadConfig(enabled=False), monitor=mon)
    assert not off.shed_judge(0.0, backlog=10 ** 6, best_sim=1.0, tau=0.0)
    assert off.allow_prefetch(0.0, 0.0) and off.allow_refresh(0.0, 0.0)
    assert not off.serve_stale_ok()
    assert not any(off.metrics().values())
    # per-policy switches with the master on
    ctrl = OverloadController(
        OverloadConfig(shed_on_slo=False, judge_backlog_cap=None,
                       pause_prefetch=False, pause_refresh=False,
                       serve_stale_on_failure=False),
        monitor=mon)
    assert not ctrl.shed_judge(0.0, backlog=10 ** 6, best_sim=1.0, tau=0.0)
    assert ctrl.allow_prefetch(0.0, 0.0) and ctrl.allow_refresh(0.0, 0.0)
    assert not ctrl.serve_stale_ok()


# --------------------------------------------------- end-to-end: off


def test_armed_off_run_is_byte_neutral():
    kw = dict(n_requests=120, n_intents=100, dim=64, concurrency=4, seed=3)
    plain = run_once(**kw)
    off = run_once(overload="off", **kw)
    assert not any(off["overload"].values())
    assert "overload" not in plain
    off.pop("overload")
    assert _canon(off) == _canon(plain)


def test_run_once_rejects_unknown_overload_mode():
    with pytest.raises(ValueError):
        run_once(n_requests=10, overload="sideways")


# ---------------------------------------------- end-to-end: flash crowd


def test_flash_crowd_sheds_and_recovers_latency():
    kw = dict(workload="trend", n_requests=200, n_intents=150, dim=64,
              qpm=400.0, trend_duration=8.0, seed=9,
              sample_interval=5.0, slo=["p99:window.latency_p99:<=:5.0"])
    off = run_once(overload="off", **kw)
    on = run_once(overload="on", **kw)
    assert on["overload"]["shed_hits"] > 0
    assert on["overload"]["backlog_sheds"] > 0
    assert on["latency_p99"] < off["latency_p99"]
    assert on["hit_rate"] >= off["hit_rate"]
    # sheds only widen the trust edge: quality survives
    assert on["info_accuracy"] >= 0.98


# ------------------------------- judge timeout under sustained backlog


def _judge_spans(path):
    rows = [json.loads(line) for line in open(path)]
    by_rid = {}
    for r in rows:
        by_rid.setdefault(r["rid"], []).append(r)
    return rows, by_rid


def test_judge_timeout_spans_under_sustained_backlog(tmp_path):
    """Flash crowd + tight judge deadline: most judge jobs time out
    while still QUEUED (one `judge_queue_wait` span tagged "timeout"),
    a few after DISPATCH (an untagged queue-wait ending exactly where a
    "timeout"-tagged `judge_compute` begins).  Every timed-out request
    must proceed as a miss at the timeout instant — and only once: the
    conservation checker inside run_once would flag any double-resolve
    as overlapping spans."""
    out = run_once(workload="trend", n_requests=200, n_intents=150,
                   dim=64, qpm=400.0, trend_duration=10.0,
                   judge_timeout=0.05, seed=9,
                   trace=str(tmp_path / "t"))
    assert out["trace_conservation_violations"] == 0
    rows, by_rid = _judge_spans(str(tmp_path / "t.jsonl"))

    queued = [r for r in rows if r["name"] == "judge_queue_wait"
              and r.get("tag") == "timeout"]
    computed = [r for r in rows if r["name"] == "judge_compute"
                and r.get("tag") == "timeout"]
    assert queued, "no queued-timeout spans — deadline never bit"
    assert computed, "no dispatched-timeout spans — backlog never " \
                     "reached the accelerator before the deadline"

    for span in computed:
        # shape 2: an untagged queue-wait hands off exactly at dispatch
        waits = [r for r in by_rid[span["rid"]]
                 if r["name"] == "judge_queue_wait"
                 and r.get("tag") is None and r["t1"] == span["t0"]]
        assert waits, f"rid {span['rid']}: dispatched timeout without " \
                      "its queue-wait span"

    for span in queued + computed:
        # the request proceeds as a miss AT the timeout instant — the
        # origin fetch span opens where the judge span closed
        follows = [r for r in by_rid[span["rid"]]
                   if r["name"] == "origin_fetch" and r["t0"] == span["t1"]]
        assert follows, f"rid {span['rid']}: timed out at {span['t1']} " \
                        "but no origin fetch starts there"

    for rid, spans in by_rid.items():
        tagged = [r for r in spans if r.get("tag") == "timeout"
                  and r["name"].startswith("judge_")]
        # a request judges once per round: its timeout-tagged judge
        # spans must never overlap (double-resolution)
        tagged.sort(key=lambda r: r["t0"])
        for a, b in zip(tagged, tagged[1:]):
            assert a["t1"] <= b["t0"]
