"""8-bit AdamW states (block-wise absmax int8 m/v) — the memory lever the
§Roofline table needs for the 400-700B trains: fp32 m+v cost 8 bytes/param
(deepseek-v3: 5.4 TB); int8+scales cost ~2.06 bytes/param.

State layout per tensor: {"q": int8 flat blocks, "scale": f32 per block}.
The update dequantises, applies the exact AdamW math in f32, and
re-quantises — equivalent to bnb-style 8-bit Adam (dynamic quantisation,
block=256).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.train.compression import Quantized, int8_dequantize, int8_quantize
from repro.train.optim import AdamWConfig, clip_by_global_norm, lr_at


def init_state8(params, block: int = 256):
    def zq(p):
        n = p.size
        nblk = -(-n // block)
        return Quantized(
            q=jnp.zeros((nblk, block), jnp.int8),
            scale=jnp.zeros((nblk,), jnp.float32),
            shape=tuple(p.shape),
        )

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zq, params),
        "v": jax.tree.map(zq, params),
        "block": block,
    }


def state8_bytes(params, block: int = 256) -> int:
    total = 0
    for p in jax.tree.leaves(params):
        nblk = -(-p.size // block)
        total += 2 * (nblk * block + nblk * 4)  # m and v
    return total


def adamw8_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics). Exact AdamW in f32 with
    int8 state storage."""
    block = state["block"]
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.zeros((), jnp.float32)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mq, vq):
        gf = g.astype(jnp.float32)
        m32 = int8_dequantize(mq) * b1 + gf * (1 - b1)
        v32 = int8_dequantize(vq) * b2 + jnp.square(gf) * (1 - b2)
        delta = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, int8_quantize(m32, block), int8_quantize(v32, block)

    flat_p, treedef = jax.tree.flatten(params)
    is_q = lambda x: isinstance(x, Quantized)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    mdef = jax.tree.structure(state["m"], is_leaf=is_q)
    new_state = {
        "step": step,
        "m": jax.tree.unflatten(mdef, [o[1] for o in out]),
        "v": jax.tree.unflatten(mdef, [o[2] for o in out]),
        "block": block,
    }
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        new_state,
        {"lr": lr, "grad_norm": gnorm},
    )
