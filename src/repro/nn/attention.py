"""Attention flavors: GQA (full / sliding-window / cross) and MLA (DeepSeek).

Cache layouts
-------------
* GQA:  {"k": (B, S_cache, Kv, Dh), "v": (B, S_cache, Kv, Dh)}
        sliding-window layers use a ring buffer of S_cache = window.
* MLA:  {"latent": (B, S_cache, kv_lora), "k_rope": (B, S_cache, qk_rope)}
        — the compressed per-token latent is all that is stored; decode uses
        the weight-absorbed formulation (score via latent, no K expansion).

All applies run on a 1-device test mesh and on the production mesh; sharding
constraints are best-effort (see nn.sharding).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.basic import apply_rope, rmsnorm, rmsnorm_specs
from repro.nn.config import AttnConfig
from repro.nn.flash import sdpa_flash
from repro.nn.param import ParamSpec
from repro.nn.sharding import ShardCtx

NEG_INF = -1e30

# cache-less (train/prefill) attention uses the chunked flash path once the
# sequence exceeds this; below it the plain masked softmax is cheaper.
FLASH_THRESHOLD = 512


def flash_chunk(sq: int) -> int:
    """Tile edge: 1k tiles at training lengths (bwd keeps 3 f32 tiles
    live), 2k at prefill lengths (fwd-only, bigger MXU tiles)."""
    return 1024 if sq <= 8192 else 2048


# =================================================================== GQA


def gqa_specs(cfg: AttnConfig, d_model: int, dtype) -> dict:
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = {
        "wq": ParamSpec((d_model, h * dh), dtype, ("fsdp", "model")),
        "wk": ParamSpec((d_model, kv * dh), dtype, ("fsdp", "model")),
        "wv": ParamSpec((d_model, kv * dh), dtype, ("fsdp", "model")),
        "wo": ParamSpec((h * dh, d_model), dtype, ("model", "fsdp")),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamSpec((h * dh,), jnp.float32, ("model",), init="zeros")
        out["bk"] = ParamSpec((kv * dh,), jnp.float32, ("model",), init="zeros")
        out["bv"] = ParamSpec((kv * dh,), jnp.float32, ("model",), init="zeros")
    return out


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _sdpa(ctx: ShardCtx, q, k, v, mask, scale):
    """q: (B,Sq,H,Dh), k/v: (B,Sk,Kv,Dh); GQA via head grouping."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, sq, kvh, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, dh)


def _causal_mask(sq: int, sk: int, q_offset, window: Optional[int]):
    """(sq, sk) boolean mask. q position i (global) = q_offset + i."""
    qi = q_offset + jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    return m


def gqa_apply(
    ctx: ShardCtx,
    p,
    cfg: AttnConfig,
    x,
    positions,
    cache: Optional[dict] = None,
    cache_pos=None,
    kv_override=None,
    attn_fn=None,
):
    """Returns (out, new_cache).

    * train / prefill:  cache is None -> full causal self-attention. When the
      caller wants a cache back, use ``gqa_prefill`` (returns k/v).
    * decode: cache given, x is (B, 1, D), cache_pos is the write index.
    * cross-attention: kv_override=(k, v) precomputed from the encoder.
    """
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, sq, _ = x.shape
    scale = 1.0 / math.sqrt(dh)

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    q = ctx.constrain(q, "dp", None, "model")
    q = _split_heads(q, h, dh)

    if kv_override is not None:
        k, v = kv_override
        if cfg.rope_kind != "none":
            q = apply_rope(cfg, q, positions)
        mask = jnp.ones((b, sq, k.shape[1]), bool)
        out = _sdpa(ctx, q, k, v, mask, scale)
        new_cache = cache
    else:
        k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
        v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
        if cfg.qkv_bias:
            k = k + p["bk"].astype(k.dtype)
            v = v + p["bv"].astype(v.dtype)
        k = _split_heads(k, kv, dh)
        v = _split_heads(v, kv, dh)
        if cfg.rope_kind != "none":
            q = apply_rope(cfg, q, positions)
            k = apply_rope(cfg, k, positions)

        if cache is None:
            if attn_fn is not None:
                out = attn_fn(q, k, v, cfg.window)
            elif sq > FLASH_THRESHOLD:
                out = sdpa_flash(
                    q, k, v, scale, causal=True, window=cfg.window,
                    chunk=min(flash_chunk(sq), sq),
                )
            else:
                mask = _causal_mask(sq, sq, 0, cfg.window)[None]
                out = _sdpa(ctx, q, k, v, mask, scale)
            new_cache = {"k": k, "v": v}
        else:
            # decode: write k/v into the cache at cache_pos (ring for window)
            s_cache = cache["k"].shape[1]
            write = (
                cache_pos % s_cache if cfg.window is not None else cache_pos
            )
            quant = cache["k"].dtype == jnp.int8
            if quant:
                # int8 KV (per-token-per-head absmax scales): halves the
                # decode memory-roofline term; the Pallas decode kernel
                # dequantises in VMEM (§Perf iteration 2)
                k8, ks = _kv_quantize(k)
                v8, vs = _kv_quantize(v)
                ck = _dyn_write(cache["k"], k8, write)
                cv = _dyn_write(cache["v"], v8, write)
                cks = _dyn_write(cache["k_scale"], ks, write)
                cvs = _dyn_write(cache["v_scale"], vs, write)
                kf = ck.astype(k.dtype) * cks.astype(k.dtype)[..., None]
                vf = cv.astype(v.dtype) * cvs.astype(v.dtype)[..., None]
                new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            else:
                ck = _dyn_write(cache["k"], k, write)
                cv = _dyn_write(cache["v"], v, write)
                kf, vf = ck, cv
                new_cache = {"k": ck, "v": cv}
            kj = jnp.arange(s_cache)
            if cfg.window is not None:
                valid = (kj <= (cache_pos % s_cache)) | (cache_pos >= s_cache)
            else:
                valid = kj <= cache_pos
            mask = jnp.broadcast_to(valid[None, None, :], (b, sq, s_cache))
            out = _sdpa(ctx, q, kf, vf, mask, scale)

    out = out.reshape(b, sq, h * dh)
    out = ctx.constrain(out, "dp", None, "model")
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return ctx.constrain(y, "dp", None, None), new_cache


def _dyn_write(buf, val, idx):
    """dynamic_update_slice along seq dim (axis=1) at per-batch-shared idx."""
    if buf.dtype == jnp.int8:
        val = jnp.clip(jnp.round(val), -127, 127).astype(jnp.int8) \
            if val.dtype != jnp.int8 else val
    return jax.lax.dynamic_update_slice_in_dim(
        buf, val.astype(buf.dtype), idx, 1
    )


def _kv_quantize(x):
    """x (B,S,KV,Dh) -> (int8 values, f16 absmax scales (B,S,KV))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def gqa_cache_specs(cfg: AttnConfig, batch: int, s_cache: int, dtype,
                    quant: bool = False) -> dict:
    if cfg.window is not None:
        s_cache = min(s_cache, cfg.window)
    shp = (batch, s_cache, cfg.n_kv_heads, cfg.head_dim)
    axes = ("dp", "seq" if batch == 1 else "kv_seq", None, None)
    if quant:
        sshp = shp[:-1]
        saxes = axes[:-1]
        return {
            "k": ParamSpec(shp, jnp.int8, axes, init="zeros"),
            "v": ParamSpec(shp, jnp.int8, axes, init="zeros"),
            "k_scale": ParamSpec(sshp, jnp.float16, saxes, init="zeros"),
            "v_scale": ParamSpec(sshp, jnp.float16, saxes, init="zeros"),
        }
    return {
        "k": ParamSpec(shp, dtype, axes, init="zeros"),
        "v": ParamSpec(shp, dtype, axes, init="zeros"),
    }


# =================================================================== MLA


def mla_specs(cfg: AttnConfig, d_model: int, dtype) -> dict:
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lq, lkv = cfg.q_lora_rank, cfg.kv_lora_rank
    out = {}
    if lq:
        out["wq_a"] = ParamSpec((d_model, lq), dtype, ("fsdp", None))
        out["q_norm"] = rmsnorm_specs(lq)
        out["wq_b"] = ParamSpec((lq, h * (dn + dr)), dtype, ("fsdp", "model"))
    else:
        out["wq"] = ParamSpec((d_model, h * (dn + dr)), dtype, ("fsdp", "model"))
    out["wkv_a"] = ParamSpec((d_model, lkv + dr), dtype, ("fsdp", None))
    out["kv_norm"] = rmsnorm_specs(lkv)
    # up-projections: per-head K (nope) and V from the latent
    out["w_uk"] = ParamSpec((h, dn, lkv), dtype, ("model", None, None))
    out["w_uv"] = ParamSpec((h, lkv, dv), dtype, ("model", None, None))
    out["wo"] = ParamSpec((h * dv, d_model), dtype, ("model", "fsdp"))
    return out


def _mla_q(ctx, p, cfg: AttnConfig, x, positions, eps):
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    b, s, _ = x.shape
    if cfg.q_lora_rank:
        qa = jnp.einsum("bsd,dl->bsl", x, p["wq_a"])
        qa = rmsnorm(p["q_norm"], qa, eps)
        q = jnp.einsum("bsl,lh->bsh", qa, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    q = ctx.constrain(q, "dp", None, "model").reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(cfg, q_rope, positions)
    return q_nope, q_rope


def mla_apply(
    ctx: ShardCtx,
    p,
    cfg: AttnConfig,
    x,
    positions,
    cache: Optional[dict] = None,
    cache_pos=None,
    eps: float = 1e-6,
):
    """MLA attention. Prefill/train expands K/V per head; decode uses the
    weight-absorbed latent formulation (no K/V expansion, cache = latent)."""
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lkv = cfg.kv_lora_rank
    b, sq, _ = x.shape
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope = _mla_q(ctx, p, cfg, x, positions, eps)

    kv = jnp.einsum("bsd,dl->bsl", x, p["wkv_a"])
    latent = rmsnorm(p["kv_norm"], kv[..., :lkv], eps)
    k_rope = apply_rope(
        cfg, kv[..., lkv:][:, :, None, :], positions
    )[:, :, 0, :]  # (B,S,dr) shared across heads

    if cache is None:
        # train/prefill: expand per-head keys/values from the latent
        k_nope = jnp.einsum("bsl,hdl->bshd", latent, p["w_uk"])
        v = jnp.einsum("bsl,hlv->bshv", latent, p["w_uv"])
        if sq > FLASH_THRESHOLD:
            # fold the shared rope-key into per-head keys; pad V with zeros
            # so flash's single (q·k, p·v) pipeline applies unchanged.
            q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(
                    k_rope[:, :, None, :], (b, sq, h, dr)
                )], axis=-1,
            )
            v_pad = jnp.concatenate(
                [v, jnp.zeros((b, sq, h, dn + dr - dv), v.dtype)], axis=-1
            ) if dn + dr > dv else v
            out = sdpa_flash(
                q_full, k_full, v_pad, scale, causal=True,
                chunk=min(flash_chunk(sq), sq),
            )[..., :dv]
        else:
            mask = _causal_mask(sq, sq, 0, None)[None]
            scores = (
                jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
                + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope)
            ).astype(jnp.float32) * scale
            scores = jnp.where(mask[:, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
            out = jnp.einsum("bhqs,bshv->bqhv", probs, v)
        new_cache = {"latent": latent, "k_rope": k_rope}
    else:
        cl = _dyn_write(cache["latent"], latent, cache_pos)
        cr = _dyn_write(cache["k_rope"], k_rope, cache_pos)
        s_cache = cl.shape[1]
        # absorbed: q' = q_nope @ w_uk -> score against the latent directly
        q_abs = jnp.einsum("bqhd,hdl->bqhl", q_nope, p["w_uk"])
        scores = (
            jnp.einsum("bqhl,bsl->bhqs", q_abs, cl)
            + jnp.einsum("bqhd,bsd->bhqs", q_rope, cr)
        ).astype(jnp.float32) * scale
        valid = jnp.arange(s_cache) <= cache_pos
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(cl.dtype)
        ctx_lat = jnp.einsum("bhqs,bsl->bqhl", probs, cl)
        out = jnp.einsum("bqhl,hlv->bqhv", ctx_lat, p["w_uv"])
        new_cache = {"latent": cl, "k_rope": cr}

    out = ctx.constrain(out.reshape(b, sq, h * dv), "dp", None, "model")
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return ctx.constrain(y, "dp", None, None), new_cache


def mla_cache_specs(cfg: AttnConfig, batch: int, s_cache: int, dtype) -> dict:
    axes = ("dp", "seq" if batch == 1 else "kv_seq", None)
    return {
        "latent": ParamSpec(
            (batch, s_cache, cfg.kv_lora_rank), dtype, axes, init="zeros"
        ),
        "k_rope": ParamSpec(
            (batch, s_cache, cfg.qk_rope_dim), dtype, axes, init="zeros"
        ),
    }


# ============================================================ cross-attn


def cross_kv_specs(cfg: AttnConfig, d_model: int, dtype) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "wk": ParamSpec((d_model, kv * dh), dtype, ("fsdp", "model")),
        "wv": ParamSpec((d_model, kv * dh), dtype, ("fsdp", "model")),
    }


def cross_kv(ctx: ShardCtx, p, cfg: AttnConfig, enc_out):
    k = _split_heads(
        jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]), cfg.n_kv_heads, cfg.head_dim
    )
    v = _split_heads(
        jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]), cfg.n_kv_heads, cfg.head_dim
    )
    return k, v
