"""Chunked-vocab fused cross-entropy (custom_vjp).

For big-vocab LMs (gemma3: 262k, qwen: 152k) materialising the (tokens ×
vocab) f32 logits costs gigabytes of activation memory per step. This op
fuses unembedding + log-softmax + NLL with an online logsumexp over vocab
chunks, so only a (tokens × chunk) tile is ever live; the backward pass
recomputes each chunk's logits and emits (softmax − onehot) gradients
chunk-wise (the standard production-framework "fused vocab loss").

Used on the FSDP-only (no-TP) parallelism plan and the 1-device test mesh;
the vocab-sharded Megatron path (models.lm._sharded_xent) covers TP runs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.nn import runtime


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def chunked_xent(x, table, labels, chunk: int = 16384, softcap: float = 0.0):
    loss, _ = _fwd(x, table, labels, chunk, softcap)
    return loss


def _logits_chunk(x, w_c, softcap):
    lg = jnp.einsum(
        "td,vd->tv", x, w_c, preferred_element_type=jnp.float32
    )
    if softcap:
        lg = jnp.tanh(lg / softcap) * softcap
    return lg


def _nchunks(v: int, chunk_req: int) -> int:
    """Smallest chunk count k ≥ v/chunk_req with v % k == 0 (chunks must
    tile the vocab exactly so backward dW rows stay disjoint)."""
    k = max(1, -(-v // chunk_req))
    while v % k:
        k += 1
    return k


def _fwd(x, table, labels, chunk, softcap):
    t, d = x.shape
    v = table.shape[0]
    nchunks = _nchunks(v, chunk)
    chunk = v // nchunks

    def body(carry, ci):
        m, l, picked = carry
        w_c = jax.lax.dynamic_slice_in_dim(
            table, ci * chunk, chunk, axis=0
        )
        lg = _logits_chunk(x, w_c, softcap)  # (T, C)
        vid = ci * chunk + jnp.arange(chunk)
        m_new = jnp.maximum(m, jnp.max(lg, axis=1))
        l = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(lg - m_new[:, None]), axis=1
        )
        loc = labels - ci * chunk
        ok = (loc >= 0) & (loc < chunk)
        got = jnp.take_along_axis(
            lg, jnp.clip(loc, 0, chunk - 1)[:, None], axis=1
        )[:, 0]
        picked = jnp.where(ok, got, picked)
        return (m_new, l, picked), None

    m0 = jnp.full((t,), -1e30, jnp.float32)
    l0 = jnp.zeros((t,), jnp.float32)
    p0 = jnp.zeros((t,), jnp.float32)
    (m, l, picked), _ = jax.lax.scan(
        body, (m0, l0, p0), jnp.arange(nchunks),
        unroll=runtime.unroll_for(nchunks),
    )
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    loss = jnp.mean(lse - picked)
    return loss, (x, table, labels, lse)


def _bwd(chunk, softcap, res, ct):
    x, table, labels, lse = res
    t, d = x.shape
    v = table.shape[0]
    nchunks = _nchunks(v, chunk)
    chunk = v // nchunks
    scale = ct / t

    def body(dx, ci):
        w_c = jax.lax.dynamic_slice_in_dim(table, ci * chunk, chunk, axis=0)
        lg = _logits_chunk(x, w_c, softcap)
        vid = ci * chunk + jnp.arange(chunk)
        p = jnp.exp(lg - lse[:, None])  # softmax chunk
        onehot = (labels[:, None] == vid[None, :]).astype(jnp.float32)
        g = (p - onehot) * scale  # (T, C) dL/dlogits
        if softcap:
            # d tanh(z/c)*c = sech^2 = 1 - (lg/c)^2 on the capped value
            g = g * (1.0 - (lg / softcap) ** 2)
        dx = dx + jnp.einsum("tv,vd->td", g.astype(w_c.dtype), w_c,
                             preferred_element_type=jnp.float32)
        dw_c = jnp.einsum("tv,td->vd", g.astype(x.dtype), x,
                          preferred_element_type=jnp.float32)
        return dx, dw_c

    dx0 = jnp.zeros((t, d), jnp.float32)
    dx, dw_chunks = jax.lax.scan(
        body, dx0, jnp.arange(nchunks), unroll=runtime.unroll_for(nchunks)
    )
    dw = dw_chunks.reshape(v, d)
    return dx.astype(x.dtype), dw.astype(table.dtype), None


chunked_xent.defvjp(
    lambda x, table, labels, chunk, softcap: _fwd(
        x, table, labels, chunk, softcap
    ),
    _bwd,
)
