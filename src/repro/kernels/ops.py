"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU the launchers pass interpret=False for the Mosaic lowering. The
pure-jnp oracles live in kernels.ref; tests sweep shapes/dtypes and
assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ann_topk import ann_topk
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd

__all__ = ["ann_topk", "flash_attention_fwd", "decode_attention",
           "ann_topk_jit"]


def ann_topk_jit(emb, active, q, k: int = 4):
    """VectorIndex backend adapter: single query (D,) -> (sims, rows)."""
    single = q.ndim == 1
    if single:
        q = q[None]
    vals, rows = ann_topk(
        jnp.asarray(emb), jnp.asarray(active), jnp.asarray(q), k
    )
    if single:
        return vals[0], rows[0]
    return vals, rows
