"""Mesh-sharded stage 1 — DESIGN.md §13.

Covers the ISSUE 6 checklist: ``sharded_topk_merge`` bit-parity with
``topk_desc`` (engineered boundary ties included), shard-count
invariance of search results / engine decisions / final cache contents
at 1, 2, and 8 shards (zero float tolerance on the host path — the
explicit gate the documented tolerance clause requires), centroid
seed-determinism regardless of shard count, rebalance/migration
bookkeeping invariants, scalar-vs-``add_batch`` bit-equivalence, the
Pallas sharded scan (fp32 + int8) against the numpy sharded path, and
the engine's max-over-shards latency model.
"""
import json
import math

import numpy as np
import pytest

from repro.core.clustering import _MIGRATE_CHUNK, ClusterConfig, ClusterRouter
from repro.core.seri import VectorIndex, sharded_topk_merge, topk_desc
from repro.core.tiers import QuantIndex


def _clustered_embs(n, dim, seed=0, paras=8):
    from repro.data.world import SemanticWorld

    n_int = max(n // paras, 1)
    world = SemanticWorld(n_intents=n_int, dim=dim, seed=seed)
    return world, np.stack([
        world.embed(world.query((i // paras) % n_int, i % paras))
        for i in range(n)
    ])


def _build(cls, n, dim, embs, cfg, backend="numpy"):
    router = ClusterRouter(n + 32, dim, cfg) if cfg else None
    ix = cls(n + 32, dim, backend=backend, router=router)
    for i in range(n):
        ix.add(i, embs[i])
    return ix


SHARD_COUNTS = (1, 2, 8)


def _cfg(shards, **kw):
    base = dict(n_clusters=16, nprobe=4, min_train=64, seed=3,
                n_shards=shards)
    base.update(kw)
    return ClusterConfig(**base)


# --------------------------------------------------- sharded_topk_merge

def test_sharded_topk_merge_matches_topk_desc(rng):
    """Random matrices + random owner partitions: the per-shard select +
    lexsort merge reproduces topk_desc exactly (rows AND vals)."""
    for trial in range(40):
        b = int(rng.integers(1, 6))
        m = int(rng.integers(1, 50))
        k = int(rng.integers(1, 12))
        s_cnt = int(rng.integers(1, 9))
        if trial % 2:
            # heavy ties: scores from a tiny alphabet, so tie groups
            # routinely straddle the owner partition
            s = rng.choice(
                np.array([-1.0, 0.25, 0.25, 0.7], np.float32),
                size=(b, m)).astype(np.float32)
        else:
            s = rng.standard_normal((b, m)).astype(np.float32)
        owners = rng.integers(0, s_cnt, m).astype(np.int64)
        want_r, want_v = topk_desc(s.copy(), k)
        got_r, got_v = sharded_topk_merge(s, owners, s_cnt, k)
        assert np.array_equal(want_r, got_r), (trial, s, owners)
        assert np.array_equal(want_v, got_v)


def test_sharded_topk_merge_boundary_tie_straddle():
    """A tie group split exactly across two shards: both members of the
    k-boundary tie resolve by ascending global column, not by shard."""
    s = np.array([[0.9, 0.5, 0.5, 0.5, 0.1, 0.5]], np.float32)
    owners = np.array([0, 0, 0, 1, 1, 1])   # ties at cols 1,2 | 3,5
    want_r, want_v = topk_desc(s.copy(), 4)
    got_r, got_v = sharded_topk_merge(s, owners, 2, 4)
    assert np.array_equal(want_r, got_r)
    assert np.array_equal(want_v, got_v)
    assert got_r[0].tolist() == [0, 1, 2, 3]
    # does not mutate its input (topk_desc does — negates in place)
    assert s[0, 0] == np.float32(0.9)


# ------------------------------------------------- index-level sharding

@pytest.mark.parametrize("cls", [VectorIndex, QuantIndex])
def test_index_shard_count_invariance(cls, rng):
    """Same rows, same queries, shards ∈ {1, 2, 8}: identical ids AND
    sims — the host sharded path selects over one global score matrix,
    so the cross-shard-count float tolerance is zero by construction
    (this is the explicit gate for the documented tolerance clause)."""
    n, dim, k = 600, 32, 4
    _, embs = _clustered_embs(n, dim, seed=1)
    q = embs[rng.integers(0, n, 16)] + 0.03 * rng.standard_normal(
        (16, dim)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    for nprobe in (4, None):
        outs = []
        for s_cnt in SHARD_COUNTS:
            ix = _build(cls, n, dim, embs, _cfg(s_cnt, nprobe=nprobe))
            assert ix.router.ready
            outs.append((s_cnt, ix.search_batch(q, k, 0.0),
                         ix.last_scanned, ix.last_scanned_max_shard))
        (_, base, scanned1, max1), *rest = outs
        assert max1 == scanned1          # S=1: max-over-shards == total
        for s_cnt, res, scanned, max_shard in rest:
            assert scanned == scanned1   # routing is shard-invariant
            assert max_shard <= scanned
            if s_cnt > 1 and nprobe is not None:
                assert max_shard < scanned
            for (i0, v0), (i1, v1) in zip(base, res):
                assert i0 == i1, (cls, nprobe, s_cnt)
                assert np.array_equal(v0, v1)


def test_nprobe_all_sharded_bit_identical_to_brute(rng):
    """nprobe=all at 8 shards (clusters < shards included) still equals
    the un-routed brute index bit-for-bit."""
    n, dim, k = 400, 32, 4
    _, embs = _clustered_embs(n, dim, seed=2)
    brute = _build(VectorIndex, n, dim, embs, None)
    ivf = _build(VectorIndex, n, dim, embs,
                 _cfg(8, n_clusters=4, nprobe=None))
    q = embs[rng.integers(0, n, 8)] + 0.03 * rng.standard_normal(
        (8, dim)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    for (ids_b, sims_b), (ids_a, sims_a) in zip(
        brute.search_batch(q, k, 0.5), ivf.search_batch(q, k, 0.5)
    ):
        assert ids_b == ids_a
        assert np.array_equal(sims_b, sims_a)


def test_centroid_seed_invariance_across_shard_counts():
    """Deterministic seeding audit: training (mini-batch draws, init,
    refresh cadence) must never read the shard layout — centroids AND
    assignments are bitwise identical for a given seed at any shard
    count."""
    n, dim = 500, 32
    _, embs = _clustered_embs(n, dim, seed=4)
    ref = None
    for s_cnt in SHARD_COUNTS:
        ix = _build(VectorIndex, n, dim, embs,
                    _cfg(s_cnt, refresh_every=128))
        rt = ix.router
        assert rt.refreshes >= 2
        if ref is None:
            ref = (rt.centroids.copy(), rt.assign.copy(), rt.refreshes)
        else:
            assert np.array_equal(ref[0], rt.centroids)
            assert np.array_equal(ref[1], rt.assign)
            assert ref[2] == rt.refreshes


def test_add_batch_bit_equivalent_to_sequential(rng):
    """Bulk prefill (``add_batch``) splits allocation at the router's
    refresh boundaries, so centroids, assignments, and searches are
    bitwise identical to n scalar adds."""
    n, dim, k = 700, 32, 4
    _, embs = _clustered_embs(n, dim, seed=5)
    cfg = dict(n_clusters=16, nprobe=4, min_train=64, seed=3,
               n_shards=8, refresh_every=128)
    seq = _build(VectorIndex, n, dim, embs, ClusterConfig(**cfg))
    blk = VectorIndex(n + 32, dim,
                      router=ClusterRouter(n + 32, dim,
                                           ClusterConfig(**cfg)))
    blk.add_batch(np.arange(n), embs)
    assert np.array_equal(seq.router.centroids, blk.router.centroids)
    assert np.array_equal(seq.router.assign, blk.router.assign)
    assert np.array_equal(seq.router.shard_bounds,
                          blk.router.shard_bounds)
    q = embs[rng.integers(0, n, 8)] + 0.03 * rng.standard_normal(
        (8, dim)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    for (i0, v0), (i1, v1) in zip(seq.search_batch(q, k, 0.0),
                                  blk.search_batch(q, k, 0.0)):
        assert i0 == i1
        assert np.array_equal(v0, v1)


# ------------------------------------------- rebalance / migration

def test_rebalance_migration_invariants(rng):
    """Churn across refreshes: the contiguous-cut invariants hold after
    every rebalance, and the migration counters stay consistent with
    the chunked-migration protocol."""
    n, dim = 400, 16
    _, embs = _clustered_embs(n, dim, seed=7)
    cfg = ClusterConfig(n_clusters=8, nprobe=3, min_train=32,
                        refresh_every=64, seed=8, n_shards=4)
    ix = VectorIndex(n, dim, router=ClusterRouter(n, dim, cfg))
    rt = ix.router
    live, nxt = [], 0
    for step in range(900):
        if live and (ix.full or rng.random() < 0.35):
            kill = rng.choice(len(live), size=min(2, len(live)),
                              replace=False)
            ix.remove_rows([live[i] for i in kill])
            live = [r for j, r in enumerate(live) if j not in set(kill)]
        else:
            live.append(ix.add(nxt, embs[nxt % n]))
            nxt += 1
        if rt.trained:
            b = rt.shard_bounds
            assert b[0] == 0 and b[-1] == cfg.n_clusters
            assert np.all(np.diff(b) >= 0)       # empty shards legal
            # shard_of is exactly the contiguous-range ownership map
            for sh in range(rt.n_shards):
                assert np.all(rt.shard_of[b[sh]:b[sh + 1]] == sh)
    assert rt.refreshes >= 2
    assert rt.rebalances >= 1
    assert rt.migrated_rows > 0
    # chunk accounting: every migrated cluster moves in ≤ 4096-row
    # chunks, so chunks ≥ ceil(total / chunk) and ≥ 1 per rebalance
    assert rt.migration_chunks >= math.ceil(
        rt.migrated_rows / _MIGRATE_CHUNK)
    assert rt.migration_chunks >= rt.rebalances
    # balanced contiguous cut: no shard exceeds an equal split by more
    # than one cluster's worth of rows
    mass = np.array([rt.counts[rt.shard_of == sh].sum()
                     for sh in range(rt.n_shards)])
    assert mass.sum() == rt.counts.sum()
    assert mass.max() <= mass.sum() / rt.n_shards + rt.counts.max()


# ---------------------------------------------------- kernel backends

@pytest.mark.parametrize("cls", [VectorIndex, QuantIndex])
def test_sharded_kernel_matches_numpy(cls, rng):
    """The shard-fanned Pallas scan (fp32 ivf / int8 quant, unrolled
    per-shard loop on a 1-device host; shard_map when a mesh is up)
    agrees with the numpy sharded path — ids, sims, and the
    max-over-shards scan accounting."""
    n, dim, k = 500, 32, 4
    _, embs = _clustered_embs(n, dim, seed=2)
    cfg = dict(n_clusters=16, nprobe=4, min_train=64, seed=3, n_shards=8)
    np_ix = _build(cls, n, dim, embs, ClusterConfig(**cfg))
    kr_ix = _build(cls, n, dim, embs, ClusterConfig(**cfg),
                   backend="kernel")
    q = embs[rng.integers(0, n, 8)] + 0.03 * rng.standard_normal(
        (8, dim)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    for (ids_n, sims_n), (ids_k, sims_k) in zip(
        np_ix.search_batch(q, k, 0.0), kr_ix.search_batch(q, k, 0.0)
    ):
        assert ids_n == ids_k
        np.testing.assert_allclose(sims_n, sims_k, atol=2e-6)
    assert kr_ix.last_scanned == np_ix.last_scanned
    assert kr_ix.last_scanned_max_shard == np_ix.last_scanned_max_shard
    assert kr_ix.last_scanned_max_shard < kr_ix.last_scanned


def test_sharded_kernel_mesh_path_matches_loop(rng):
    """shard_map over a real device mesh == the unrolled fallback. Skips
    unless ≥ 8 devices are visible (CI's benchmark leg runs it under
    XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    from repro.kernels.ann_topk_sharded import mesh_available

    if not mesh_available(8):
        pytest.skip("needs ≥ 8 jax devices for the shard mesh")
    n, dim, k = 400, 32, 4
    _, embs = _clustered_embs(n, dim, seed=6)
    cfg = ClusterConfig(n_clusters=16, nprobe=4, min_train=64, seed=3,
                        n_shards=8)
    np_ix = _build(VectorIndex, n, dim, embs, cfg)
    kr_ix = _build(VectorIndex, n, dim, embs, cfg, backend="kernel")
    q = embs[rng.integers(0, n, 8)].copy()
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    for (ids_n, sims_n), (ids_k, sims_k) in zip(
        np_ix.search_batch(q, k, 0.0), kr_ix.search_batch(q, k, 0.0)
    ):
        assert ids_n == ids_k
        np.testing.assert_allclose(sims_n, sims_k, atol=2e-6)


# ----------------------------------------------------- engine / cache

ENGINE_KW = dict(workload="zipf", mode="cortex", n_requests=600,
                 n_intents=300, dim=32, concurrency=4, seed=21,
                 cache_ratio=0.9, cluster=True, n_clusters=8, nprobe=4)


def _strip_shard_keys(s):
    return {k: v for k, v in s.items()
            if k not in ("rows_scanned", "rows_per_lookup",
                         "stage1_shards", "rows_scanned_max_shard",
                         "shard_rebalances", "shard_migrated_rows",
                         "shard_migration_chunks")}


def test_engine_shard_count_invariance():
    """Same seed + workload at 1, 2, and 8 shards: identical summaries
    modulo the scan-instrumentation fields, identical routing volume,
    and the shard fields only appear when shards > 1."""
    from repro.launch.serve import run_once

    runs = {s: run_once(shards=s, **ENGINE_KW) for s in SHARD_COUNTS}
    assert "stage1_shards" not in runs[1]
    assert runs[8]["stage1_shards"] == 8
    # the router trained mid-run: sharded scans really engaged
    assert runs[8]["rows_scanned_max_shard"] < runs[8]["rows_scanned"]
    base = json.dumps(_strip_shard_keys(runs[1]), sort_keys=True,
                      default=float)
    for s in SHARD_COUNTS[1:]:
        assert runs[s]["rows_scanned"] == runs[1]["rows_scanned"]
        assert json.dumps(_strip_shard_keys(runs[s]), sort_keys=True,
                          default=float) == base


def test_cache_contents_invariant_across_shard_counts():
    """Driving the cache directly (lookup/insert churn with evictions):
    hit decisions, the id→row map, and the stored embeddings are
    bitwise identical at 1, 2, and 8 shards — while the 8-shard router
    really rebalances and migrates ownership underneath."""
    from repro.core.cache import make_cache
    from repro.core.judge import OracleJudge
    from repro.data.world import SemanticWorld

    def drive(shards):
        world = SemanticWorld(n_intents=120, dim=32, seed=9)
        judge = OracleJudge(world, accuracy=1.0, seed=10)
        cfg = ClusterConfig(n_clusters=16, nprobe=4, min_train=32,
                            refresh_every=64, seed=11, n_shards=shards)
        cache = make_cache(capacity_bytes=80_000, dim=32, judge=judge,
                           index_capacity=512, cluster=cfg)
        rng = np.random.default_rng(12)
        decisions, now = [], 0.0
        for _ in range(500):
            # zipf skew keeps cluster masses uneven, so the balanced
            # cut actually moves across refreshes (rebalances > 0)
            iid = int(rng.zipf(1.3)) % 120
            q = world.query(iid, int(rng.integers(0, 4)))
            emb = world.embed(q)
            res = cache.lookup(q, emb, now)
            decisions.append(bool(res.hit))
            if not res.hit:
                cache.insert(q, emb, world.answer(q), now=now, cost=0.01,
                             latency=0.2, size=int(world.value_size(q)),
                             staticity=world.staticity(q))
            now += 0.25
        ix = cache.seri.index
        return (decisions, sorted(cache.soa.id2row.items()),
                ix.emb[ix.active].tobytes(), cache.stats.evictions,
                cache.seri.index.router)

    d1, c1, e1, ev1, _ = drive(1)
    assert ev1 > 0                       # eviction churn actually ran
    for s_cnt in SHARD_COUNTS[1:]:
        d, c, e, ev, rt = drive(s_cnt)
        assert d == d1
        assert c == c1
        assert e == e1
        assert ev == ev1
        assert rt.rebalances >= 1 and rt.migrated_rows > 0


def test_engine_max_over_shards_latency():
    """t_cache_per_row > 0 + shards: stage-1 time is charged on the
    max-over-shards row count plus t_shard_merge, so the sharded run's
    cache-path time drops below the unsharded routed run's.

    concurrency=1 pins the request order — at higher concurrency the
    latency model feeds back into the virtual-time interleaving and the
    two runs stop being the same trace — so the identical-rows_scanned
    assertion isolates exactly the scan-charging change."""
    from repro.launch.serve import run_once

    kw = dict(workload="zipf", mode="cortex", n_requests=800,
              n_intents=400, dim=32, concurrency=1, seed=21,
              cache_ratio=0.9, cluster=True, n_clusters=16, nprobe=4,
              t_cache_per_row=2e-5)
    flat = run_once(**kw)
    shard = run_once(shards=8, t_shard_merge=1e-4, **kw)
    assert shard["rows_scanned"] == flat["rows_scanned"]
    assert shard["hit_rate"] == flat["hit_rate"]
    assert shard["rows_scanned_max_shard"] < shard["rows_scanned"]
    assert shard["cache_time_mean"] < flat["cache_time_mean"]
    assert shard["latency_mean"] < flat["latency_mean"]
    # and it stays deterministic
    again = run_once(shards=8, t_shard_merge=1e-4, **kw)
    assert json.dumps(shard, sort_keys=True, default=float) == \
        json.dumps(again, sort_keys=True, default=float)
