"""Declarative parameter system.

Every module describes its parameters as a pytree of :class:`ParamSpec`.
From a spec tree we can derive, without ever allocating the real arrays:

* ``struct_tree``  -> ``jax.ShapeDtypeStruct`` tree (multi-pod dry-run inputs)
* ``pspec_tree``   -> ``PartitionSpec`` tree (pjit in_shardings)
* ``init_tree``    -> real arrays (smoke tests / examples, small configs only)

This is what lets us "hold" a 671B-parameter model on a CPU-only container:
the full configs are only ever lowered from structs, never materialised.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

DType = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""

    shape: tuple[int, ...]
    dtype: DType = jnp.bfloat16
    # Logical sharding axes, one entry per dim. Each entry is an axis-name
    # string ("model", "data", "expert", ...), a tuple of axis names, or None.
    # These are *logical* names resolved against the mesh by nn.sharding.
    axes: tuple[Any, ...] = ()
    init: str = "normal"  # normal | zeros | ones | scaled | uniform
    scale: float | None = None  # stddev override for "normal"/"scaled"

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank mismatch with shape {self.shape}"
            )

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def with_stacked(self, n: int) -> "ParamSpec":
        """Prepend a stacking (scan-over-layers) dimension."""
        return dataclasses.replace(
            self,
            shape=(n, *self.shape),
            axes=(None, *self.axes) if self.axes else (),
        )


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def param_count(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_spec):
        total += leaf.size
    return total


def param_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_spec):
        total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total


def struct_tree(tree, mesh: Mesh | None = None, resolve=None):
    """ShapeDtypeStruct tree, optionally with NamedSharding attached."""

    def mk(spec: ParamSpec):
        if mesh is None:
            return jax.ShapeDtypeStruct(spec.shape, spec.dtype)
        pspec = resolve(spec) if resolve is not None else P()
        return jax.ShapeDtypeStruct(
            spec.shape, spec.dtype, sharding=NamedSharding(mesh, pspec)
        )

    return tree_map_specs(mk, tree)


def _fan_in(shape: tuple[int, ...]) -> int:
    if len(shape) == 0:
        return 1
    if len(shape) == 1:
        return shape[0]
    # weight matrices are (in, out) by convention here; stacked dims excluded
    return shape[-2]


def init_leaf(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "uniform":
        lim = spec.scale or 0.01
        return jax.random.uniform(
            key, spec.shape, jnp.float32, minval=-lim, maxval=lim
        ).astype(spec.dtype)
    if spec.init in ("normal", "scaled"):
        std = spec.scale
        if std is None:
            std = 1.0 / math.sqrt(max(_fan_in(spec.shape), 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(
            spec.dtype
        )
    raise ValueError(f"unknown init {spec.init}")


def init_tree(key, tree):
    """Materialise real parameters from a spec tree (small configs only)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def stack_tree(tree, n: int):
    """Stack a per-layer spec tree n times for lax.scan consumption."""
    return tree_map_specs(lambda s: s.with_stacked(n), tree)
