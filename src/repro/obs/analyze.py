"""Critical-path decomposition + latency attribution (DESIGN.md §15).

:func:`check_conservation` proves the conservation law for a finished
run: every completed request's request-scoped spans — sorted by start
time — tile ``[rec.arrival, rec.t_done]`` with NO gap and NO overlap,
every boundary compared with exact float ``==``. Because the segments
tile the interval exactly, their summed duration telescopes:
``sum(t1_i - t0_i) = t_last - t_first = rec.t_done - rec.arrival``,
which is *bit-for-bit* the expression the engine used to compute
``rec.latency`` — so the spans sum exactly (``==``, not ``≈``) to the
recorded latency. (Summing the float durations naively would NOT
telescope exactly — float addition is not associative — which is why
the law is stated, and checked, as exact tiling.)

:func:`attribution` then answers *where the time went*: per-segment
p50/p99 (shared :func:`~repro.obs.metrics.percentile`) split by request
class — pure cache hits (``remote_calls == 0``), federated
(``peer_transfers > 0``), and origin misses — the trace-derived
replacement for the engine's hand-rolled ``hitpath_*`` means.
"""
from __future__ import annotations

from typing import Iterable, Mapping

from repro.obs.metrics import percentile
from repro.obs.trace import T0, T1, Tracer


def _records_by_key(records) -> dict[tuple[int, int], object]:
    """Normalize records to ``{(region, rid): rec}``. Accepts a plain
    list (solo engine ⇒ region 0) or a ``{region: [recs]}`` mapping
    (federation — per-region workloads reuse rid ranges, so rid alone
    is not a key)."""
    if isinstance(records, Mapping):
        return {
            (int(region), r.rid): r
            for region, recs in records.items() for r in recs
        }
    return {(0, r.rid): r for r in records}


def check_conservation(tracer: Tracer, records) -> list[str]:
    """Return a list of violations (empty ⇒ the law holds).

    Checked per completed request, all comparisons exact float ``==``:

    1. the request has spans at all;
    2. the first span starts at ``rec.arrival``;
    3. each span ends exactly where the next begins (zero-duration
       markers tile trivially);
    4. the last span ends at ``rec.t_done``;
    5. the telescoped total ``t_last - t_first`` equals ``rec.latency``.
    """
    by_req = tracer.request_spans()
    violations: list[str] = []
    for key, rec in _records_by_key(records).items():
        spans = by_req.get(key)
        tag = f"region {key[0]} rid {key[1]}"
        if not spans:
            violations.append(f"{tag}: no spans recorded")
            continue
        spans = sorted(spans, key=lambda s: (s[T0], s[T1]))
        if spans[0][T0] != rec.arrival:
            violations.append(
                f"{tag}: first span {spans[0][1]} starts at "
                f"{spans[0][T0]!r} != arrival {rec.arrival!r}"
            )
        for a, b in zip(spans, spans[1:]):
            if a[T1] != b[T0]:
                kind = "gap" if a[T1] < b[T0] else "overlap"
                violations.append(
                    f"{tag}: {kind} between {a[1]} (ends {a[T1]!r}) and "
                    f"{b[1]} (starts {b[T0]!r})"
                )
        if spans[-1][T1] != rec.t_done:
            violations.append(
                f"{tag}: last span {spans[-1][1]} ends at "
                f"{spans[-1][T1]!r} != t_done {rec.t_done!r}"
            )
        if spans[-1][T1] - spans[0][T0] != rec.latency:
            violations.append(
                f"{tag}: telescoped span total "
                f"{spans[-1][T1] - spans[0][T0]!r} != latency "
                f"{rec.latency!r}"
            )
    return violations


def _req_class(rec) -> str:
    if rec.remote_calls == 0:
        return "hit"
    if rec.peer_transfers > 0:
        return "federated"
    return "miss"


def attribution(tracer: Tracer, records) -> dict:
    """Queueing-delay attribution: per request class, per span name,
    the count / total seconds / p50 / p99 of **per-request time in that
    segment** (a request's multiple rounds of, say, ``judge_queue_wait``
    are summed before the quantile — the unit of the paper's Fig 11 is
    the request, not the span)."""
    by_req = tracer.request_spans()
    recs = _records_by_key(records)
    # class -> name -> list of per-request summed durations
    acc: dict[str, dict[str, list[float]]] = {}
    lat: dict[str, list[float]] = {}
    for key, rec in recs.items():
        cls = _req_class(rec)
        lat.setdefault(cls, []).append(rec.latency)
        per_name: dict[str, float] = {}
        for s in by_req.get(key, ()):
            per_name[s[1]] = per_name.get(s[1], 0.0) + (s[T1] - s[T0])
        slot = acc.setdefault(cls, {})
        for name, d in per_name.items():
            slot.setdefault(name, []).append(d)
    out: dict[str, dict] = {}
    for cls in sorted(acc):
        segs = {}
        for name in sorted(acc[cls]):
            ds = acc[cls][name]
            segs[name] = {
                "n": len(ds),
                "total_s": float(sum(ds)),
                "p50": percentile(ds, 50),
                "p99": percentile(ds, 99),
            }
        out[cls] = {
            "n_requests": len(lat[cls]),
            "latency_p50": percentile(lat[cls], 50),
            "latency_p99": percentile(lat[cls], 99),
            "segments": segs,
        }
    return out


def format_attribution(report: Mapping) -> str:
    """Human-readable attribution table (one block per request class)."""
    lines = []
    for cls, blk in report.items():
        lines.append(
            f"[{cls}] n={blk['n_requests']} "
            f"latency p50={blk['latency_p50']:.4f}s "
            f"p99={blk['latency_p99']:.4f}s"
        )
        lines.append(f"  {'segment':<18}{'n':>6}{'total_s':>10}"
                     f"{'p50':>9}{'p99':>9}")
        for name, seg in blk["segments"].items():
            lines.append(
                f"  {name:<18}{seg['n']:>6}{seg['total_s']:>10.3f}"
                f"{seg['p50']:>9.4f}{seg['p99']:>9.4f}"
            )
    return "\n".join(lines)
