"""Knowledge freshness subsystem (DESIGN.md §11).

The cache's correctness story has three legs. Two already exist — the
semantic judge (is this *the same question*?) and staticity-derived TTLs
(how long is the answer *expected* to hold?). This module adds the third:
what happens when the world actually changes under a cached value.

Three cooperating pieces, mechanism split from policy:

* :class:`ChangeFeed` — the ORIGIN side. It walks a
  :class:`~repro.data.world.MutableWorld`'s deterministic update schedule
  and broadcasts one notice per (intent, update) to every subscriber,
  each delayed by that subscriber's one-way WAN latency. Intents are
  watched lazily (first cache admission starts the per-intent timer), so
  the event count is bounded by *cached* knowledge, not world size. The
  per-subscriber delay IS the eventual-consistency window: between the
  origin update and notice arrival a region may serve the stale value,
  exactly like a real invalidation bus.

* :class:`FreshnessManager` — one per region/cache. It applies policy on
  two triggers:

  - **change-feed notice** — every cached entry for the updated intent
    (both tiers) is stale. Provenance decides who revalidates:
    federated copies (``se.origin`` set) and warm/cold entries are
    DROPPED — the region that originally fetched the value refreshes its
    own copy, siblings re-lease later (one origin refetch per datum
    fleet-wide instead of one per replica). A hot, locally-fetched entry
    with enough validated hits is REFRESHED in place instead of dropped.
  - **refresh-ahead timer** — hot entries are revalidated shortly before
    TTL expiry instead of being purged, so a popular entry's lifetime is
    a sequence of cheap renewals rather than a miss storm at every TTL
    boundary. Entries that stopped earning hits simply expire.

  Refreshes go through the region's own rate-limited
  :class:`~repro.serving.remote.RemoteDataService` (they cost real
  money and tokens — ``refresh_cost`` is reported) and are skipped when
  limiter headroom is low, so revalidation never starves demand traffic.

* **Versioned SEs** — ``SEStore`` rows carry ``version`` (origin
  knowledge version at fetch) and ``fetched_at``; a refresh bumps both
  in place, preserving row/se_id/freq so live views survive. The engine
  compares a hit's version against the world's current one to count
  ``stale_hits`` and the staleness-age histogram.

Everything runs on the shared :class:`~repro.serving.clock.VirtualClock`,
so multi-region invalidation interleavings are deterministic and
same-seed runs are bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.cache import CortexCache
from repro.obs.trace import BACKGROUND, NULL_TRACER


@dataclasses.dataclass
class FreshnessConfig:
    invalidation: bool = True      # subscribe to the origin change feed
    refresh_ahead: bool = True     # revalidate instead of drop/expire
    refresh_margin: float = 0.15   # fraction of TTL left when refresh fires
    # validated hits SINCE THE LAST (re)fetch required to be worth a
    # renewal — lifetime freq would renew dead entries forever
    refresh_min_freq: int = 1
    refresh_min_headroom: float = 0.25  # skip refresh under limiter pressure
    feed_delay: float = 0.15       # one-way origin->region notice latency


@dataclasses.dataclass
class FreshnessStats:
    notices: int = 0           # change-feed notices received
    stale_found: int = 0       # cached entries a notice found outdated
    invalidated: int = 0       # ... dropped (federated/warm/cold entries)
    refreshes: int = 0         # in-place revalidations completed
    refresh_cost: float = 0.0  # origin spend on revalidation fetches
    refresh_skipped: int = 0   # refreshes foregone (headroom / in flight)


class ChangeFeed:
    """Origin-side update feed over a mutable world's schedule.

    One pending clock event per *watched* intent at a time: when it
    fires, notices fan out (per-subscriber WAN delay) and the next
    update event for that intent is scheduled — unless no subscriber
    still holds the intent (its ``interest`` predicate), in which case
    the watch lapses and the next admission re-arms it, so feed work is
    bounded by *live cached* knowledge, not by everything ever cached.
    ``watch`` is idempotent and lazy — a static intent (``next_update``
    = inf) never schedules. Versions are counted per fire (one fire per
    scheduled update, since ``next_update`` strictly advances), not
    re-derived from the float schedule: at an exact update instant the
    floor in ``intent_version`` can land one step short, and a
    short-by-one notice would no-op the whole fan-out.
    """

    def __init__(self, world, clock):
        self.world = world
        self.clock = clock
        # (callback(intent, version, t_update), one-way delay,
        #  interest(intent) -> bool or None = always interested)
        self._subs: list[tuple[Callable, float, Optional[Callable]]] = []
        self._watched: set[int] = set()
        self._version: dict[int, int] = {}  # last version announced
        self.events = 0

    def subscribe(self, callback: Callable, delay: float,
                  interest: Optional[Callable] = None) -> None:
        self._subs.append((callback, float(delay), interest))

    def watch(self, intent: Optional[int]) -> None:
        if intent is None or intent in self._watched:
            return
        intent = int(intent)
        t_next = self.world.next_update(intent, self.clock.now)
        if t_next == float("inf"):
            return
        self._watched.add(intent)
        # (re)sync the counter: updates that elapsed while unwatched
        # notified nobody, but nobody held the intent then either
        self._version[intent] = max(
            self._version.get(intent, 0),
            self.world.intent_version(intent, self.clock.now),
        )
        self.clock.push(t_next, self._fire, intent, t_next)

    def _fire(self, intent: int, t_update: float) -> None:
        self.events += 1
        version = self._version[intent] = self._version[intent] + 1
        for cb, delay, _ in self._subs:
            self.clock.push(t_update + delay, cb, intent, version, t_update)
        if any(i is None or i(intent) for _, _, i in self._subs):
            t_next = self.world.next_update(intent, t_update)
            self.clock.push(t_next, self._fire, intent, t_next)
        else:
            self._watched.discard(intent)  # next admission re-watches


class FreshnessManager:
    """Per-region freshness policy over one cache + origin service."""

    def __init__(self, *, cache: CortexCache, remote, world, clock,
                 cfg: Optional[FreshnessConfig] = None,
                 feed: Optional[ChangeFeed] = None):
        self.cache = cache
        self.remote = remote
        self.world = world
        self.clock = clock
        self.cfg = cfg or FreshnessConfig()
        self.feed = feed
        self.stats = FreshnessStats()
        self._inflight: set[int] = set()
        self._tracer = NULL_TRACER
        self._region = 0
        # §17 overload seam: an armed OverloadController may pause
        # refresh-ahead under limiter-headroom / SLO pressure. None =
        # legacy behavior, bit-identical.
        self.overload = None
        if feed is not None and self.cfg.invalidation:
            # interest predicate lets the feed stop firing for intents
            # this cache no longer holds (O(1) via the intent index)
            feed.subscribe(self._on_notice, self.cfg.feed_delay,
                           interest=cache.has_intent)
        if self.cfg.refresh_ahead:
            # promotions re-enter HOT without passing the engine's
            # insert hook — re-arm their refresh-ahead timers here
            cache.on_promote = self._on_promote

    def bind_tracer(self, tracer, region: int = 0) -> None:
        """Arm §15 tracing: refresh fetches emit background spans,
        invalidation drops emit background markers. Observational only —
        no virtual-time effect."""
        self._tracer = tracer
        self._region = region

    # ------------------------------------------------------------ hooks

    def on_insert(self, se) -> None:
        """Admission hook (every insert path: miss fill, prefetch,
        federated transfer): start watching the intent's change feed and
        arm the refresh-ahead timer."""
        if self.feed is not None and self.cfg.invalidation:
            self.feed.watch(se.intent)
        # no timer for federated copies: provenance says the source
        # region revalidates, so the tick would be a guaranteed no-op
        if self.cfg.refresh_ahead and se.origin is None:
            self._schedule_refresh(se.se_id, se.expires_at)

    def _on_promote(self, se) -> None:
        """A warm entry re-entered HOT (cache.on_promote): its previous
        timer died during the warm sojourn — arm a fresh one."""
        if se.origin is None:
            self._schedule_refresh(se.se_id, se.expires_at)

    # ----------------------------------------------------- invalidation

    def _on_notice(self, intent: int, version: int, t_update: float) -> None:
        """Change-feed notice arrived (``feed_delay`` after the origin
        update): fan out over every cached entry of that intent."""
        self.stats.notices += 1
        now = self.clock.now
        for se in self.cache.ses_for_intent(intent):
            if se.version >= version:
                continue  # already refreshed past this update
            self.stats.stale_found += 1
            refreshable = (
                self.cfg.refresh_ahead
                and getattr(se, "tier", "hot") == "hot"
                # provenance: only the region that fetched from the
                # origin revalidates; federated copies drop and re-lease
                and se.origin is None
                and se.freq - se.freq_at_fetch >= self.cfg.refresh_min_freq
            )
            # mark_stale: this value is KNOWN outdated — keep the row
            # (freq/embedding/LCFU standing survive) but stop serving it
            # until the refetch lands, unlike the TTL-triggered refresh
            # where the value is still presumed fresh
            if refreshable and self._start_refresh(se.se_id,
                                                   mark_stale=True):
                continue
            self.cache.invalidate_se(se.se_id, now)
            self.stats.invalidated += 1
            self._tracer.marker(BACKGROUND, "invalidation_drop", now,
                                self._region)

    # ---------------------------------------------------- refresh-ahead

    def _schedule_refresh(self, se_id: int, expires_at: float) -> None:
        """Arm one revalidation event shortly before this expiry. The
        armed expiry is passed along so a timer armed for a PREVIOUS
        lifetime (entry since renewed, or row re-used by a different
        lifecycle) fires as a no-op."""
        now = self.clock.now
        t = expires_at - self.cfg.refresh_margin * max(expires_at - now, 0.0)
        if t <= now:
            return
        self.clock.push(t, self._refresh_tick, se_id, expires_at)

    def _refresh_tick(self, se_id: int, armed_expiry: float) -> None:
        row = self.cache.soa.id2row.get(se_id)
        if row is None:
            return  # evicted / demoted / invalidated meanwhile
        if float(self.cache.soa.expires_at[row]) != armed_expiry:
            return  # renewed since this timer was armed
        se = self.cache.store[se_id]
        # "earning its keep" = hits since the LAST renewal, not lifetime
        # freq — otherwise one early hit buys perpetual renewals
        if se.origin is not None or \
                se.freq - se.freq_at_fetch < self.cfg.refresh_min_freq:
            return  # not ours to revalidate / not earning its keep
        self._start_refresh(se_id)

    def _start_refresh(self, se_id: int, *, mark_stale: bool = False) -> bool:
        """Kick one origin revalidation fetch. A TTL-triggered refresh
        (``mark_stale=False``) keeps serving the current value — it is
        still presumed fresh, the fetch merely renews it. A
        notice-triggered refresh marks the row ``revalidating``: the
        value is known stale, so stage 1 stops offering it until the
        fetch lands."""
        if se_id in self._inflight:
            self.stats.refresh_skipped += 1
            if mark_stale:
                self.cache.store[se_id].revalidating = True
            return True  # a refresh is already on its way
        now = self.clock.now
        if self.remote.headroom(now) < self.cfg.refresh_min_headroom:
            self.stats.refresh_skipped += 1
            return False
        if self.overload is not None and not self.overload.allow_refresh(
                self.remote.headroom(now), now):
            # §17: refresh-ahead paused under overload pressure
            self.stats.refresh_skipped += 1
            return False
        key = self.cache.store[se_id].key
        if mark_stale:
            self.cache.store[se_id].revalidating = True
        self._inflight.add(se_id)
        out = self.remote.fetch(
            now,
            latency_mult=self.world.latency_mult(key),
            cost_mult=self.world.cost_mult(key),
        )
        if out.failed:
            # origin brownout (§17): the revalidation fetch died — the
            # entry simply stays as-is (possibly marked revalidating);
            # a later notice/TTL timer will try again
            self._inflight.discard(se_id)
            self.stats.refresh_skipped += 1
            return False
        self.stats.refresh_cost += out.cost
        self._tracer.span(BACKGROUND, "refresh", now, out.finish,
                          self._region)
        self.clock.push(out.finish, self._refresh_done, se_id, key)
        return True

    def _refresh_done(self, se_id: int, key: str) -> None:
        self._inflight.discard(se_id)
        now = self.clock.now
        se = self.cache.refresh_entry(
            se_id,
            value=self.world.fetch(key, now),
            version=self.world.version_at(key, now),
            now=now,
        )
        if se is None:
            return  # left the hot tier while the fetch was in flight
        self.stats.refreshes += 1
        if self.cfg.refresh_ahead:
            self._schedule_refresh(se_id, se.expires_at)
